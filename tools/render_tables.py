"""Render EXPERIMENTS.md tables from dryrun_results.json / perf_jag.json /
bench_output.txt. Usage: python tools/render_tables.py"""
import json
import os
import sys

HW = dict(peak=197e12, hbm=819e9, link=50e9)


EXTRA = ("dryrun_results_widedeep.json", "dryrun_results_minicpm.json",
         "dryrun_results_qwen3.json", "dryrun_results_extra.json")


def _load(path):
    d = json.load(open(path))
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in d["results"]}
    for p in EXTRA:
        if os.path.exists(p):
            for r in json.load(open(p))["results"]:
                key = (r["arch"], r["shape"], r["mesh"])
                if key not in seen:
                    d["results"].append(r)
                    seen.add(key)
    d["results"].sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return d


def roofline_table(path="dryrun_results.json", mesh="single"):
    d = _load(path)
    rows = [r for r in d["results"] if r["mesh"] == mesh]
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | useful | mem/dev (GiB) |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for r in rows:
        mem = (f"{r['mem_per_device'] / 2**30:.2f}"
               if r.get("mem_per_device") else "-")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_comp'] * 1e3:.2f} | "
            f"{r['t_mem'] * 1e3:.2f} | {r['t_coll'] * 1e3:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.3f} | {mem} |")
    return "\n".join(out)


def dryrun_summary(path="dryrun_results.json"):
    d = _load(path)
    ok = d["results"]
    meshes = {}
    for r in ok:
        meshes.setdefault(r["mesh"], []).append(r)
    lines = [f"- compiled cells: {len(ok)} ok / "
             f"{len(d['failures'])} failed"]
    for m, rs in sorted(meshes.items()):
        fits = sum(1 for r in rs
                   if (r.get("mem_per_device") or 0) <= 16 * 2**30)
        lines.append(f"- mesh {m}: {len(rs)} cells, {fits} within "
                     f"16 GiB/chip")
    for f in d["failures"]:
        lines.append(f"- FAILED: {f['arch']} x {f['shape']} x {f['mesh']}: "
                     f"{f['error'][:140]}")
    return "\n".join(lines)


def perf_table(path="perf_jag.json"):
    if not os.path.exists(path):
        return "(pending)"
    rows = json.load(open(path))
    out = ["| variant | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "mem/dev (GiB) | mem-term speedup vs baseline |",
           "|---|---:|---:|---:|---:|---:|"]
    base = rows[0]["t_mem"]
    for r in rows:
        out.append(
            f"| {r['arch'].split('/')[-1]} | {r['t_comp'] * 1e3:.2f} | "
            f"{r['t_mem'] * 1e3:.0f} | {r['t_coll'] * 1e3:.2f} | "
            f"{(r['mem_per_device'] or 0) / 2**30:.2f} | "
            f"{base / r['t_mem']:.2f}x |")
    return "\n".join(out)


def bench_section(path="bench_output.txt", prefix=""):
    if not os.path.exists(path):
        return "(pending)"
    out = []
    for line in open(path):
        if line.startswith(prefix):
            out.append("    " + line.rstrip())
    return "\n".join(out) if out else "(pending)"


if __name__ == "__main__":
    what = sys.argv[1] if len(sys.argv) > 1 else "all"
    if what in ("all", "roofline"):
        print("### Roofline (single-pod)\n")
        print(roofline_table())
    if what in ("all", "summary"):
        print("\n### Dry-run summary\n")
        print(dryrun_summary())
    if what in ("all", "perf"):
        print("\n### Perf (jag serve)\n")
        print(perf_table())
