"""Inject generated tables into EXPERIMENTS.md placeholder markers.

Usage: python tools/finalize_experiments.py
Idempotent: markers are kept as HTML comments and content between
<!-- X --> and <!-- /X --> is replaced (or inserted after a bare marker).
"""
import re
import sys

sys.path.insert(0, "tools")
from render_tables import dryrun_summary, roofline_table


def inject(text: str, marker: str, content: str) -> str:
    block = f"<!-- {marker} -->\n{content}\n<!-- /{marker} -->"
    pat = re.compile(f"<!-- {marker} -->.*?<!-- /{marker} -->", re.S)
    if pat.search(text):
        return pat.sub(block, text)
    return text.replace(f"<!-- {marker} -->", block)


def main():
    path = "EXPERIMENTS.md"
    text = open(path).read()
    text = inject(text, "DRYRUN_SUMMARY", dryrun_summary())
    text = inject(text, "ROOFLINE_TABLE", roofline_table())
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
