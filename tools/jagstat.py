#!/usr/bin/env python
"""jagstat: per-route serving summary from a telemetry trace dump.

Usage:
    python tools/jagstat.py TRACES.jsonl [--drift-threshold X] [--json]

One row per realized route: traffic share, latency percentiles
(p50/p95/p99 us over per-query wall time), mean n_dist (the work/recall
proxy), median predicted-vs-observed relative cost error, and drift
status. The input is a ``TraceBuffer.dump_jsonl`` file (see
``repro.obs``; produce one with ``Telemetry().traces.dump_jsonl(path)``
or ``benchmarks/obs_bench.py --traces PATH``).
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

import numpy as np  # noqa: E402

from repro.obs.drift import relative_error  # noqa: E402
from repro.obs.trace import load_jsonl  # noqa: E402


def summarize(records, threshold=0.5):
    """Per-realized-route summary rows, route-name sorted."""
    groups = {}
    for t in records:
        groups.setdefault(t.route, []).append(t)
    total = sum(len(v) for v in groups.values()) or 1
    rows = []
    for route in sorted(groups):
        rs = groups[route]
        lat = np.asarray([t.observed_us for t in rs], np.float64)
        errs = [e for e in (relative_error(t) for t in rs) if e is not None]
        med = float(np.median(errs)) if errs else None
        rows.append({
            "route": route,
            "queries": len(rs),
            "share_pct": round(100.0 * len(rs) / total, 1),
            "p50_us": round(float(np.percentile(lat, 50)), 1),
            "p95_us": round(float(np.percentile(lat, 95)), 1),
            "p99_us": round(float(np.percentile(lat, 99)), 1),
            "mean_n_dist": round(float(np.mean([t.n_dist for t in rs])), 1),
            "rel_err": None if med is None else round(med, 3),
            "drift": None if med is None else bool(med > threshold),
        })
    return rows


def render(rows):
    cols = ("route", "queries", "share%", "p50us", "p95us", "p99us",
            "n_dist~", "relerr~", "drift")
    table = [cols]
    for r in rows:
        table.append((
            r["route"], str(r["queries"]), str(r["share_pct"]),
            str(r["p50_us"]), str(r["p95_us"]), str(r["p99_us"]),
            str(r["mean_n_dist"]),
            "-" if r["rel_err"] is None else str(r["rel_err"]),
            "-" if r["drift"] is None else ("DRIFT" if r["drift"] else "ok")))
    widths = [max(len(row[i]) for row in table) for i in range(len(cols))]
    return "\n".join("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
                     for row in table)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="per-route serving summary from a telemetry trace dump")
    ap.add_argument("traces", help="JSONL file from TraceBuffer.dump_jsonl")
    ap.add_argument("--drift-threshold", type=float, default=0.5,
                    help="median rel-err above this flags DRIFT (default .5)")
    ap.add_argument("--json", action="store_true",
                    help="emit summary rows as JSON instead of a table")
    args = ap.parse_args(argv)

    records = load_jsonl(args.traces)
    if not records:
        print(f"no trace records in {args.traces}", file=sys.stderr)
        return 1
    rows = summarize(records, args.drift_threshold)
    if args.json:
        json.dump(rows, sys.stdout, indent=1)
        print()
    else:
        print(f"# {len(records)} traces, {len(rows)} routes "
              f"(drift threshold {args.drift_threshold})")
        print(render(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
