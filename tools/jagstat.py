#!/usr/bin/env python
"""jagstat: per-route serving summary from a telemetry trace dump.

Usage:
    python tools/jagstat.py TRACES.jsonl [--drift-threshold X] [--json]
    python tools/jagstat.py TRACES.jsonl --health [--shadow SHADOW.jsonl]

Default mode prints one row per realized route: traffic share, latency
percentiles (p50/p95/p99 us over per-query wall time), mean n_dist (the
work/recall proxy), median predicted-vs-observed relative cost error,
and drift status. The input is a ``TraceBuffer.dump_jsonl`` file (see
``repro.obs``; produce one with ``Telemetry().traces.dump_jsonl(path)``
or ``benchmarks/obs_bench.py --traces PATH``).

``--health`` instead renders the fused pass/warn/fail SLO document
(``repro.obs.health``) over the trace window, optionally joined with a
shadow-audit dump (``ShadowAuditor.dump_jsonl``) for the recall section.
The exit code is 1 only when the overall status is ``fail``.

Empty or truncated dumps are not errors: jagstat prints an explicit
"no traces" line and exits 0, so log rotation racing a dump never turns
into a paging incident.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

import numpy as np  # noqa: E402

from repro.obs.drift import relative_error  # noqa: E402
from repro.obs.trace import load_jsonl  # noqa: E402


def summarize(records, threshold=0.5):
    """Per-realized-route summary rows, route-name sorted."""
    groups = {}
    for t in records:
        groups.setdefault(t.route, []).append(t)
    total = sum(len(v) for v in groups.values()) or 1
    rows = []
    for route in sorted(groups):
        rs = groups[route]
        lat = np.asarray([t.observed_us for t in rs], np.float64)
        errs = [e for e in (relative_error(t) for t in rs) if e is not None]
        med = float(np.median(errs)) if errs else None
        rows.append({
            "route": route,
            "queries": len(rs),
            "share_pct": round(100.0 * len(rs) / total, 1),
            "p50_us": round(float(np.percentile(lat, 50)), 1),
            "p95_us": round(float(np.percentile(lat, 95)), 1),
            "p99_us": round(float(np.percentile(lat, 99)), 1),
            "mean_n_dist": round(float(np.mean([t.n_dist for t in rs])), 1),
            "rel_err": None if med is None else round(med, 3),
            "drift": None if med is None else bool(med > threshold),
        })
    return rows


def render(rows):
    cols = ("route", "queries", "share%", "p50us", "p95us", "p99us",
            "n_dist~", "relerr~", "drift")
    table = [cols]
    for r in rows:
        table.append((
            r["route"], str(r["queries"]), str(r["share_pct"]),
            str(r["p50_us"]), str(r["p95_us"]), str(r["p99_us"]),
            str(r["mean_n_dist"]),
            "-" if r["rel_err"] is None else str(r["rel_err"]),
            "-" if r["drift"] is None else ("DRIFT" if r["drift"] else "ok")))
    widths = [max(len(row[i]) for row in table) for i in range(len(cols))]
    return "\n".join("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
                     for row in table)


def run_health(records, args) -> int:
    """``--health``: render the fused SLO document; exit 1 only on fail."""
    from repro.obs import (HealthSLO, health_report, load_shadow_jsonl,
                           render_health)
    shadow = load_shadow_jsonl(args.shadow) if args.shadow else ()
    slo = HealthSLO(recall=args.slo_recall,
                    p99_us=args.slo_p99_us,
                    drift_threshold=args.drift_threshold)
    report = health_report(records, shadow, slo)
    if args.json:
        json.dump(report, sys.stdout, indent=1)
        print()
    else:
        print(render_health(report))
    return 1 if report["status"] == "fail" else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="per-route serving summary from a telemetry trace dump")
    ap.add_argument("traces", help="JSONL file from TraceBuffer.dump_jsonl")
    ap.add_argument("--drift-threshold", type=float, default=0.5,
                    help="median rel-err above this flags DRIFT (default .5)")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary (or health report) as JSON")
    ap.add_argument("--health", action="store_true",
                    help="render the pass/warn/fail serving health report")
    ap.add_argument("--shadow", default=None, metavar="PATH",
                    help="shadow-audit JSONL (ShadowAuditor.dump_jsonl) "
                         "for the --health recall section")
    ap.add_argument("--slo-recall", type=float, default=0.9,
                    help="--health recall@k floor per cell (default .9)")
    ap.add_argument("--slo-p99-us", type=float, default=None,
                    help="--health per-route p99 latency bound in us "
                         "(default: latency not judged)")
    args = ap.parse_args(argv)

    records = load_jsonl(args.traces) if os.path.exists(args.traces) else []
    if args.health:
        return run_health(records, args)
    if not records:
        print(f"no traces: 0 records in {args.traces}")
        return 0
    rows = summarize(records, args.drift_threshold)
    if args.json:
        json.dump(rows, sys.stdout, indent=1)
        print()
    else:
        print(f"# {len(records)} traces, {len(rows)} routes "
              f"(drift threshold {args.drift_threshold})")
        print(render(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
