"""jagcheck: the repo's two-layer static-analysis gate.

Usage: python tools/jagcheck.py [--lint-only | --audit-only]
                                [--no-sharded] [--json AUDIT.json]

Layer 1 (repro.analysis.lint) AST-lints ``src/repro`` against the
repo-specific rules JAG001–JAG005, with the config/allowlist in
``pyproject.toml`` ``[tool.jagcheck]``. Layer 2 (repro.analysis.audit)
builds a small index and re-lowers every executor route to assert the
compiled-program contracts (gather/collective/callback/f64 budgets),
writing the diffable ``AUDIT.json``.

Exit status is non-zero on any unjustified lint finding, configuration
error (reason-less or stale allowlist entry), or audit violation — the
CI ``static-analysis`` stage gates on it.
"""
import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.analysis.lint import run_lint  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=".")
    ap.add_argument("--lint-only", action="store_true",
                    help="skip the compiled-route auditor")
    ap.add_argument("--audit-only", action="store_true",
                    help="skip the AST lint")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the faked-device sharded audit section")
    ap.add_argument("--json", default="AUDIT.json", metavar="PATH",
                    help="where to write the audit report")
    args = ap.parse_args(argv)
    failed = False

    if not args.audit_only:
        report = run_lint(args.root)
        for f in report.findings + report.config_errors:
            print(f)
        for f, ent in report.suppressed:
            print(f"# allowed {f.rule} {f.path}:{f.line} — {ent.reason}")
        n = len(report.findings) + len(report.config_errors)
        print(f"# jagcheck lint: {n} finding(s), "
              f"{len(report.suppressed)} allowlisted")
        failed |= not report.ok

    if not args.lint_only:
        from repro.analysis.audit import run_audit
        audit = run_audit(args.root, sharded=not args.no_sharded)
        with open(args.json, "w") as fh:
            json.dump(audit, fh, indent=1)
        for name, r in audit["routes"].items():
            print(f"# audit {name}: gathers={r['gathers_total']} "
                  f"gpe={r['gathers_per_expansion']} "
                  f"collectives={r['collectives']}")
        for name, r in audit.get("sharded", {}).get("routes", {}).items():
            print(f"# audit sharded/{name}: "
                  f"gathers={r['gathers_total']} "
                  f"collectives={r['collectives']}")
        for v in audit["violations"]:
            print(f"VIOLATION: {v}")
        print(f"# jagcheck audit: {len(audit['violations'])} violation(s) "
              f"-> {args.json}")
        failed |= bool(audit["violations"])

    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
