"""Streaming-insert subsystem: live delta segment over the frozen graph.

``StreamingJAGIndex`` wraps a built ``JAGIndex`` with a growable
``DeltaSegment`` and an epoch counter; inserts are O(1) amortized appends,
searches merge the planner-routed graph result with an exact delta scan,
and compaction folds the delta into the graph with the build's batch-insert
primitive. See stream/index.py for the full architecture notes.
"""
from .delta import DeltaSegment
from .index import StreamingJAGIndex

__all__ = ["DeltaSegment", "StreamingJAGIndex"]
