"""StreamingJAGIndex: a mutable index layer over the frozen JAG graph.

Architecture (base + delta + epoch, redisvl-style index lifecycle):

  * **base** — a built, frozen :class:`~repro.core.jag.JAGIndex`. Its graph,
    vectors, and serving layouts never mutate in place.
  * **delta** — a :class:`~repro.stream.delta.DeltaSegment`: vectors + attr
    rows appended in O(1) amortized batches, searched exactly by the
    executor's brute-force ``delta`` route (ids offset past the base).
  * **epoch** — a monotonic counter bumped by every insert batch and every
    compaction. The executor's caches (compiled routes, planner sample
    buffers, fused engines) are keyed by it, so serving state can never
    outlive the data it was built against, and the planner's selectivity
    probe always samples the LIVE base+delta attribute table.

Every search merges the base result (any planner route over the graph
segment) with the delta scan into one exact top-k per query
(``serve.dispatch.merge_topk``) — with an exact base route the result is
bit-identical to brute-force filtered k-NN over the concatenated database.
Compaction triggering is cost-driven when a calibrated ``repro.cost``
model is attached (:meth:`attach_cost_model`, or loaded with the
archive): the delta scan is a tax EVERY search pays, so the index
compacts at the break-even point where the predicted tax over the next
``query_horizon`` searches exceeds the predicted total compaction cost.
With no model the static ``compact_frac * base_n`` row-count cutoff is
the exact fallback. Either way :meth:`compact` re-runs the build's
batch-insert primitive (core/build.py, Algorithm 3) to fold the delta
rows into the graph, extends the fused f32 serving layout row-wise,
resets the delta, and bumps the epoch. ``save``/``load`` persist the
delta segment, epoch, and cost model alongside the base archive, so a
restarted server resumes mid-stream bit-for-bit.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.beam_search import SearchResult
from ..core.build import finalize_graph, make_insert_step
from ..core.distances import sq_norms
from ..core.filters import AttrTable, as_filter
from ..core.jag import JAGConfig, JAGIndex
from .delta import DeltaSegment


class StreamingJAGIndex:
    """A live (insertable) view over a frozen JAGIndex + delta segment.

    Mirrors the executor-facing surface of :class:`JAGIndex` (``graph``,
    ``xb``, ``attr``, ``entry``, ``fused_layout``, ...), so
    ``serve.Executor`` runs its routes over the graph segment unchanged —
    except that ``attr`` is the MERGED base+delta table (identical rows for
    base ids; the planner's probe sees inserted rows immediately).
    """

    def __init__(self, base: JAGIndex, delta: Optional[DeltaSegment] = None,
                 *, epoch: int = 0, compact_frac: float = 0.25,
                 n_compactions: int = 0, query_horizon: int = 100_000):
        self.base = base
        self.delta = delta if delta is not None else DeltaSegment.for_table(
            base.attr, int(base.xb.shape[1]))
        self.epoch = int(epoch)
        self.compact_frac = float(compact_frac)
        self.n_compactions = int(n_compactions)
        # cost-driven compaction: the model lives on the WRAPPER (compaction
        # replaces .base with a fresh index, which would drop it), seeded
        # from whatever the base archive carried
        self.cost_model = base.cost_model
        self.cost_metric = base.cost_metric
        # telemetry lives on the WRAPPER too (same compaction-survival
        # argument) and hooks into the wrapper's epoch-aware executor
        self.telemetry = None
        self.query_horizon = int(query_horizon)
        self.delta_tax_us = 0.0      # predicted delta-scan us served so far
        self._last_k = 10            # most recent served k (merge-tax term)
        self._executor = None
        self._merged: Optional[Tuple[int, AttrTable]] = None

    @classmethod
    def build(cls, xb, attr: AttrTable, cfg: JAGConfig = JAGConfig(), *,
              compact_frac: float = 0.25, query_horizon: int = 100_000,
              verbose: bool = False) -> "StreamingJAGIndex":
        """Build the base graph, then serve it live."""
        return cls(JAGIndex.build(xb, attr, cfg, verbose=verbose),
                   compact_frac=compact_frac, query_horizon=query_horizon)

    # -- executor-facing surface (graph segment + live attr table) ---------
    @property
    def xb(self):
        return self.base.xb

    @property
    def xb_norm(self):
        return self.base.xb_norm

    @property
    def graph(self):
        return self.base.graph

    @property
    def degree(self):
        return self.base.degree

    @property
    def entry(self):
        return self.base.entry

    @property
    def cfg(self):
        return self.base.cfg

    @property
    def build_cfg(self):
        return self.base.build_cfg

    @property
    def attr(self) -> AttrTable:
        """The LIVE attribute table: base rows then delta rows.

        Cached per epoch. Base ids index identical rows, so graph-segment
        routes gather the same attributes they would from the frozen table;
        the planner's selectivity probe samples over all ``n`` live rows.
        """
        if self.delta.n == 0:
            return self.base.attr
        if self._merged is None or self._merged[0] != self.epoch:
            _, dattr = self.delta.device()
            self._merged = (self.epoch, self.base.attr.append(dattr))
        return self._merged[1]

    @property
    def n(self) -> int:
        return int(self.base.xb.shape[0]) + self.delta.n

    def fused_layout(self, vec_dtype: str = "f32"):
        return self.base.fused_layout(vec_dtype)

    def quantized(self):
        return self.base.quantized()

    @property
    def executor(self):
        """This index's epoch-aware ``serve.Executor`` (NOT the base's: it
        must see the live attr table and the streaming epoch)."""
        if self._executor is None:
            from ..serve.executor import Executor
            self._executor = Executor(self)
        return self._executor

    def delta_arrays(self) -> Tuple[jnp.ndarray, AttrTable, int]:
        """(delta vectors, delta attr table, id offset) for the delta route."""
        xv, dattr = self.delta.device()
        return xv, dattr, int(self.base.xb.shape[0])

    # -- cost-model plumbing (routing + compaction break-even) -------------
    def attach_cost_model(self, model, metric: str = "us") -> None:
        """Attach (or detach, with None) a calibrated ``repro.cost`` model:
        ``search_auto`` routes on predicted-cost argmin (under ``metric``,
        see ``JAGIndex.attach_cost_model``) and compaction fires on the
        delta-tax break-even instead of ``compact_frac``. Sets the
        WRAPPER's model (validation shared with the base method) — the
        base index is untouched, so compaction can't drop it."""
        JAGIndex.attach_cost_model(self, model, metric)

    def attach_telemetry(self, telemetry=...):
        """Attach (or detach) serving telemetry on the WRAPPER's executor
        (the streaming epoch and jit caches live there) — see
        ``JAGIndex.attach_telemetry``. The streaming-only signals (epoch
        rolls, compactions, delta-scan fraction) tick the same registry.
        """
        return JAGIndex.attach_telemetry(self, telemetry)

    def compaction_break_even(self, k: Optional[int] = None
                              ) -> Optional[Tuple[float, float, bool]]:
        """(delta tax us/query, compaction total us, past break-even) under
        the attached cost model, or None when uncalibrated.

        The delta scan (+ merge) is a constant tax EVERY search pays; the
        predicted tax over the next ``query_horizon`` searches against the
        predicted one-off compaction cost is the row-count-free trigger —
        a slow-compacting build tolerates a bigger delta, a hot query
        stream compacts sooner, with no hand-tuned fraction anywhere.
        ``k`` sizes the merge term of the tax; it defaults to the most
        recently served k (searches record it), so the insert-time trigger
        reasons about the traffic actually being served.
        """
        model = self.cost_model
        if model is None or not model.covers(("delta", "compact")):
            return None
        if self.delta.n == 0:
            return (0.0, 0.0, False)
        from ..cost.model import delta_scan_tax
        n, d = int(self.base.xb.shape[0]), int(self.base.xb.shape[1])
        tax = delta_scan_tax(model, n=n, d=d,
                             k=self._last_k if k is None else int(k),
                             delta_n=self.delta.n)
        cost = model.predict("compact",
                             dict(delta_n=self.delta.n, n=n, d=d))
        return (tax, cost, tax * self.query_horizon >= cost)

    def _should_compact(self) -> bool:
        """Cost break-even when calibrated; ``compact_frac`` fallback.

        ``compact_frac <= 0`` is the explicit auto-compaction OFF switch
        and wins over everything — a calibrated model must not start
        firing multi-second compactions mid-bulk-load on an index whose
        owner disabled them.
        """
        if self.compact_frac <= 0:
            return False
        be = self.compaction_break_even()
        if be is not None:
            return be[2]
        return self.delta.n > self.compact_frac * self.base.xb.shape[0]

    # -- streaming writes --------------------------------------------------
    def insert(self, vectors, attrs: AttrTable, *,
               auto_compact: bool = True) -> dict:
        """Append a batch of (vectors, attr rows); bumps the epoch.

        Amortized O(batch): rows land in the delta segment's growable host
        buffers; no graph work happens until compaction. With
        ``auto_compact`` on, the batch triggers :meth:`compact` before
        returning when the compaction policy says so — the cost-model
        break-even when calibrated, the static ``compact_frac`` row-count
        cutoff otherwise. Returns a report dict (n_added / n_total /
        epoch / compacted).
        """
        n_added = np.asarray(vectors).shape[0]
        self.delta.append(vectors, attrs)
        self.epoch += 1
        compacted = False
        if auto_compact and self._should_compact():
            compacted = self.compact()
        return dict(n_added=int(n_added), n_total=self.n, epoch=self.epoch,
                    delta_rows=self.delta.n, compacted=compacted)

    def compact(self, verbose: bool = False) -> bool:
        """Fold the delta segment into the graph; reset delta, bump epoch.

        Re-runs the build's batch-insert primitive (Algorithm 3) over ONLY
        the delta ids — ``build_cfg.n_passes`` passes, same BuildConfig the
        base was calibrated with (re-insertion passes are dedup-safe; the
        second pass is what closes the recall gap to a from-scratch
        rebuild) — then drains the overflow backlog. Ids are stable: base rows
        keep their ids and delta row j becomes id ``base_n + j``, exactly
        the ids the merged search already returned, so results are
        comparable across a compaction. The fused f32 serving layout
        extends row-wise (``serve.layout.extend_layout``) instead of
        re-packing the base; int8 state is rebuilt lazily on next use
        (its quantization scale is global).
        """
        if self.delta.n == 0:
            return False
        base = self.base
        bcfg = base.build_cfg
        if bcfg.row_width != int(base.graph.shape[1]):
            # a legacy archive (no build_cfg key) loads with DEFAULT build
            # params; folding rows with the wrong degree/row width would
            # corrupt the graph, so refuse loudly — insert/search still work
            raise ValueError(
                f"build_cfg.row_width {bcfg.row_width} != graph row width "
                f"{int(base.graph.shape[1])} (legacy archive loaded with "
                f"default BuildConfig?) — cannot compact; rebuild the base "
                f"index or save a modern archive")
        xv, dattr = self.delta.device()
        xb_new = jnp.concatenate([jnp.asarray(base.xb), xv], axis=0)
        attr_new = base.attr.append(dattr)
        xb_norm = sq_norms(xb_new)
        n0, m = int(base.xb.shape[0]), self.delta.n
        graph = jnp.concatenate(
            [base.graph,
             jnp.full((m, bcfg.row_width), -1, jnp.int32)], axis=0)
        degree = jnp.concatenate(
            [jnp.asarray(base.degree, jnp.int32),
             jnp.zeros((m,), jnp.int32)], axis=0)
        insert = make_insert_step(bcfg)
        bsz = bcfg.batch_size
        new_ids = np.arange(n0, n0 + m, dtype=np.int64)
        n_batches = (m + bsz - 1) // bsz
        for pass_i in range(bcfg.n_passes):
            for i in range(n_batches):
                ids = new_ids[i * bsz:(i + 1) * bsz]
                if len(ids) < bsz:  # pad final batch cyclically (dup-safe)
                    ids = np.resize(ids, bsz)
                graph, degree = insert(graph, degree, xb_new, xb_norm,
                                       attr_new, jnp.asarray(ids, jnp.int32),
                                       base.entry)
                if verbose:
                    print(f"  compaction pass {pass_i + 1}/{bcfg.n_passes} "
                          f"batch {i + 1}/{n_batches}")
            graph, degree = finalize_graph(graph, degree, xb_new, xb_norm,
                                           attr_new, bcfg)
        new_base = JAGIndex(xb_new, attr_new, graph, degree, base.entry,
                            base.cfg, bcfg)
        if "f32" in base._fused:
            from ..serve.layout import extend_layout
            new_base._fused["f32"] = extend_layout(base._fused["f32"],
                                                   xv, dattr)
        self.base = new_base
        self.delta.reset()
        self._merged = None
        self.epoch += 1
        self.n_compactions += 1
        if self.telemetry is not None and self.telemetry.enabled:
            self.telemetry.on_compaction()
        return True

    # -- queries (base route + delta scan, merged exactly) -----------------
    def _spans(self):
        """The attached telemetry's span recorder, if any (host-side)."""
        tel = self.telemetry
        if tel is None or not tel.enabled:
            return None
        return getattr(tel, "spans", None)

    def _with_delta(self, base_res: SearchResult, queries,
                    filt, k: int) -> SearchResult:
        from contextlib import nullcontext
        if self.telemetry is not None and self.telemetry.enabled:
            self.telemetry.on_search(delta_scanned=self.delta.n > 0)
        if self.delta.n == 0:
            return base_res
        self._last_k = int(k)
        be = self.compaction_break_even(k)
        if be is not None:          # telemetry: predicted tax actually paid
            self.delta_tax_us += be[0] * int(np.shape(queries)[0])
        spans = self._spans()
        with (spans.span("delta", rows=self.delta.n) if spans is not None
              else nullcontext()):
            extra = self.executor.delta(queries, filt, k=k)
        with (spans.span("merge") if spans is not None else nullcontext()):
            return self.executor.merge(base_res, extra, k=k)

    def search(self, queries, filt, k: int = 10, ls: int = 64,
               max_iters: int = 0, layout: str = "default") -> SearchResult:
        """JAG traversal over the graph segment + exact delta scan, merged.

        ``filt`` may be a filter expression or a raw FilterBatch; it is
        normalized ONCE here so the base traversal and the delta scan see
        the same object (one jit cache entry each)."""
        filt = as_filter(filt)
        base = JAGIndex.search(self, queries, filt, k=k, ls=ls,
                               max_iters=max_iters, layout=layout)
        return self._with_delta(base, queries, filt, k)

    def search_int8(self, queries, filt, k: int = 10,
                    ls: int = 64, max_iters: int = 0,
                    layout: str = "default") -> SearchResult:
        """int8 traversal + exact re-rank on the graph segment, merged with
        the (always full-precision) delta scan."""
        filt = as_filter(filt)
        base = JAGIndex.search_int8(self, queries, filt, k=k, ls=ls,
                                    max_iters=max_iters, layout=layout)
        return self._with_delta(base, queries, filt, k)

    def search_auto(self, queries, filt, k: int = 10,
                    ls: int = 64, max_iters: int = 0,
                    planner=None, return_plan: bool = False,
                    mode: str = "per_query", layout: str = "default",
                    dtype: str = "f32"):
        """Selectivity-adaptive search over the LIVE base+delta database.

        Delegates to ``JAGIndex.search_auto`` (this class mirrors the
        executor-facing surface it needs — crucially ``self.attr`` is the
        merged live table, so the planner's probe tracks inserted rows),
        then merges the delta scan's top-k in exactly. The delta scan runs
        once for the whole batch regardless of the per-query route split —
        it is a constant (and compaction-bounded) cost that every route
        shares, so routing decisions are unchanged by the delta.
        """
        filt = as_filter(filt)
        base, p = JAGIndex.search_auto(
            self, queries, filt, k=k, ls=ls, max_iters=max_iters,
            planner=planner, return_plan=True, mode=mode, layout=layout,
            dtype=dtype)
        res = self._with_delta(base, queries, filt, k)
        if self.delta.n > 0 and getattr(p, "realized", None) is not None:
            # the realized route includes the merged delta scan
            if isinstance(p.realized, str):
                p = p._replace(realized=p.realized + "+delta")
            else:
                p = p._replace(realized=tuple(r + "+delta"
                                              for r in p.realized))
        # shadow-oracle audit runs HERE, not in the delegated base call
        # (which skips streaming indexes): the audited result must be the
        # final served top-k over base + live delta rows
        tel = self.telemetry
        if (tel is not None and tel.enabled
                and getattr(tel, "shadow", None) is not None):
            tel.shadow_audit(self, queries, filt, res, p, k=k)
        return (res, p) if return_plan else res

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        """One archive: the base index's arrays + delta rows + epoch.

        The base half is exactly ``JAGIndex.save``'s format (a plain
        ``JAGIndex.load`` on a streaming archive recovers the graph
        segment); ``stream__*`` keys carry the live state, losslessly —
        delta vectors/attr rows round-trip bit-for-bit.
        """
        arrs = self.base._save_arrays()
        # the WRAPPER's cost-model state is authoritative either way: a
        # post-compaction base carries none (keep the wrapper's), and a
        # wrapper whose model was detached must not resurrect the base
        # archive's on the next load
        arrs.pop("cost__model", None)
        arrs.pop("cost__metric", None)
        if self.cost_model is not None:
            from ..cost.registry import to_json
            arrs["cost__model"] = np.frombuffer(
                to_json(self.cost_model).encode(), np.uint8)
            arrs["cost__metric"] = self.cost_metric
        xv, attrs = self.delta.rows()
        arrs["stream__epoch"] = np.asarray(self.epoch, np.int64)
        arrs["stream__n_compactions"] = np.asarray(self.n_compactions,
                                                   np.int64)
        arrs["stream__compact_frac"] = np.asarray(self.compact_frac,
                                                  np.float64)
        arrs["stream__query_horizon"] = np.asarray(self.query_horizon,
                                                   np.int64)
        arrs["stream__delta_xv"] = xv
        for k, v in attrs.items():
            arrs[f"stream__delta_attr__{k}"] = v
        np.savez_compressed(path, **arrs)

    @classmethod
    def load(cls, path: str) -> "StreamingJAGIndex":
        """Resume mid-stream: epoch, delta rows, and search results are
        preserved bit-for-bit. A plain (frozen) ``JAGIndex`` archive loads
        too — as epoch 0 with an empty delta."""
        z = np.load(path, allow_pickle=False)
        base = JAGIndex._from_npz(z)
        if "stream__epoch" not in z:
            return cls(base)
        idx = cls(base,
                  epoch=int(z["stream__epoch"]),
                  compact_frac=float(z["stream__compact_frac"]),
                  n_compactions=int(z["stream__n_compactions"]),
                  query_horizon=int(z["stream__query_horizon"])
                  if "stream__query_horizon" in z else 100_000)
        xv = z["stream__delta_xv"]
        if xv.shape[0]:
            pre = "stream__delta_attr__"
            rows = AttrTable(base.attr.kind,
                             {k[len(pre):]: jnp.asarray(v)
                              for k, v in z.items() if k.startswith(pre)},
                             base.attr.n_bits)
            idx.delta.append(xv, rows)
        return idx
