"""Growable delta segment: (vectors, attr rows) appended in O(1) amortized.

The mutable half of a :class:`~repro.stream.StreamingJAGIndex`. Appends land
in host-side numpy buffers that double in capacity (classic amortized O(1)
batch growth — redisvl-style index lifecycle, where ``append`` never blocks
on a rebuild); the device-side view (a jnp vector block + an
``AttrTable`` over exactly the live rows) is materialized lazily and cached
until the next append. Searching the segment is a brute-force masked scan
(the executor's ``delta`` route), which is exact and — because compaction
folds the delta into the graph before it exceeds a configurable fraction of
N — never scans more than that fraction of the database.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.filters import AttrTable

_MIN_CAPACITY = 64


class DeltaSegment:
    """Append-only (vectors, attributes) buffer with doubling capacity.

    Host buffers are the source of truth (persistence serializes them
    directly); ``device()`` returns the jnp view the delta-scan route
    consumes. ``bit_weights`` never lives here — it is a global (not
    per-point) array owned by the base table.
    """

    def __init__(self, kind: str, n_bits: int, d: int,
                 attr_template: Dict[str, Tuple[np.dtype, tuple]]):
        self.kind = kind
        self.n_bits = int(n_bits)
        self.d = int(d)
        self._template = dict(attr_template)
        self.n = 0
        self._cap = 0
        self._xv = np.empty((0, self.d), np.float32)
        self._attr = {k: np.empty((0,) + shape, dt)
                      for k, (dt, shape) in self._template.items()}
        self._device: Optional[Tuple[jnp.ndarray, AttrTable]] = None

    @classmethod
    def for_table(cls, table: AttrTable, d: int) -> "DeltaSegment":
        """An empty segment shaped like ``table``'s per-point rows."""
        template = {k: (np.asarray(v).dtype, np.asarray(v).shape[1:])
                    for k, v in table.data.items() if k != "bit_weights"}
        return cls(table.kind, table.n_bits, d, template)

    def _grow(self, need: int) -> None:
        cap = max(self._cap, _MIN_CAPACITY)
        while cap < need:
            cap *= 2
        if cap == self._cap:
            return
        xv = np.empty((cap, self.d), np.float32)
        xv[:self.n] = self._xv[:self.n]
        self._xv = xv
        for k, (dt, shape) in self._template.items():
            buf = np.empty((cap,) + shape, dt)
            buf[:self.n] = self._attr[k][:self.n]
            self._attr[k] = buf
        self._cap = cap

    def append(self, vectors, attrs: AttrTable) -> int:
        """Append a batch of rows; returns the new row count.

        ``attrs`` must be an AttrTable of the segment's kind holding one
        row per appended vector (build one with the ``core.filters``
        constructors — ``range_table``, ``subset_table``, ...).
        """
        xv = np.asarray(vectors, np.float32)
        if xv.ndim != 2 or xv.shape[1] != self.d:
            raise ValueError(f"vectors must be [M, {self.d}], "
                             f"got {xv.shape}")
        if attrs.kind != self.kind or attrs.n_bits != self.n_bits:
            raise ValueError(f"attr rows are {attrs.kind}/{attrs.n_bits}, "
                             f"segment is {self.kind}/{self.n_bits}")
        if attrs.n != xv.shape[0]:
            raise ValueError(f"{xv.shape[0]} vectors vs {attrs.n} attr rows")
        m = xv.shape[0]
        self._grow(self.n + m)
        self._xv[self.n:self.n + m] = xv
        for k in self._template:
            self._attr[k][self.n:self.n + m] = np.asarray(attrs.data[k])
        self.n += m
        self._device = None
        return self.n

    def rows(self) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Host copies of exactly the live rows (persistence)."""
        return (self._xv[:self.n].copy(),
                {k: v[:self.n].copy() for k, v in self._attr.items()})

    def device(self) -> Tuple[jnp.ndarray, AttrTable]:
        """(vectors jnp [n, d], AttrTable over the n live rows), cached
        until the next append."""
        if self._device is None:
            self._device = (
                jnp.asarray(self._xv[:self.n]),
                AttrTable(self.kind,
                          {k: jnp.asarray(v[:self.n])
                           for k, v in self._attr.items()},
                          self.n_bits))
        return self._device

    def reset(self) -> None:
        """Drop every row (post-compaction); capacity is released too."""
        self.n = 0
        self._cap = 0
        self._xv = np.empty((0, self.d), np.float32)
        self._attr = {k: np.empty((0,) + shape, dt)
                      for k, (dt, shape) in self._template.items()}
        self._device = None
