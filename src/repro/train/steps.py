"""Train / serve step factories shared by the launcher, dry-run and tests.

``make_train_step(loss_fn, opt_cfg, accum)`` returns
``step(params, opt_state, batch) -> (params, opt_state, metrics)``; with
``accum > 1`` the batch's leading axis is split into microbatches scanned
sequentially (gradient accumulation — the compute/communication overlap
then comes from XLA pipelining the per-microbatch reduce-scatters).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .optimizer import AdamWState, OptConfig, apply_updates


def make_train_step(loss_fn: Callable, opt_cfg: OptConfig,
                    accum: int = 1) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics dict)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(params, opt_state: AdamWState, batch):
        if accum <= 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                loss, _, grads = grads_of(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), None

            micro_batch = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0.0)), micro_batch)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {}
        params, opt_state, opt_m = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_m)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_eval_step(loss_fn: Callable) -> Callable:
    def step(params, batch):
        loss, metrics = loss_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return metrics
    return step
