"""Hand-rolled AdamW with WSD / cosine / linear schedules (no optax offline).

Optimizer state is a pytree mirroring params (m, v in fp32) so it inherits
param shardings 1:1 (ZeRO-style full sharding comes from the param rules).
Includes global-norm clipping and a microbatch gradient-accumulation helper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"            # "cosine" | "wsd" | "linear" | "const"
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1             # WSD: final fraction spent decaying
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    total = float(cfg.total_steps)
    if cfg.schedule == "const":
        post = 1.0
    elif cfg.schedule == "linear":
        post = jnp.maximum(1.0 - s / total, cfg.min_lr_frac)
    elif cfg.schedule == "cosine":
        frac = jnp.clip(s / total, 0.0, 1.0)
        post = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "wsd":
        # Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): stable at peak lr,
        # then exponential-ish decay over the last decay_frac of training.
        decay_start = total * (1.0 - cfg.decay_frac)
        t = jnp.clip((s - decay_start) / (total - decay_start), 0.0, 1.0)
        post = jnp.where(s < decay_start, 1.0,
                         cfg.min_lr_frac ** t)
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * post


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, grads,
                  state: AdamWState) -> Tuple[Any, AdamWState, Dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "lr": lr, "grad_norm": gnorm}


def opt_specs(param_specs) -> Any:
    """Optimizer-state logical specs mirror the params (ZeRO sharding)."""
    return AdamWState((), jax.tree.map(lambda s: s, param_specs,
                                       is_leaf=_is_spec),
                      jax.tree.map(lambda s: s, param_specs,
                                   is_leaf=_is_spec))


def _is_spec(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
