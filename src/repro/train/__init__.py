"""Training substrate: optimizer, schedules, step factories."""
from .optimizer import (AdamWState, OptConfig, apply_updates, init_state,
                        opt_specs, schedule_lr, global_norm)
from .steps import make_train_step, make_eval_step
