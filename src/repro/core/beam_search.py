"""Batched GreedySearch (Algorithm 1) as a TPU-friendly ``lax.while_loop``.

B queries advance in lock-step. Per-query state:

  beam_ids/primary/secondary/visited : the l_s-slot beam, kept sorted by the
      lexicographic key (primary, secondary) at all times — "best unvisited"
      selection is then just the first unvisited slot.
  seen : packed uint32 bitmap [B, ceil(N/32)], marked at candidate-generation
      time (identical semantics to the HNSW/Vamana visited array).
  vlog : ids expanded per iteration (the paper's visited set V, consumed by
      Insert); n_dist counts distance computations for the Fig. 10-13 metric.

Termination: a lane is done when every beam slot is visited; the loop stops
when all lanes are done or after ``max_iters`` expansions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .distances import INF, KeyFn, gathered_d2
from .filters import AttrTable


class SearchResult(NamedTuple):
    ids: jnp.ndarray        # int32 [B, k]  (-1 padded)
    primary: jnp.ndarray    # f32 [B, k]
    secondary: jnp.ndarray  # f32 [B, k]   (squared L2)
    vlog: jnp.ndarray       # int32 [B, max_iters] expanded ids, -1 holes
    n_expanded: jnp.ndarray  # int32 [B]
    n_dist: jnp.ndarray     # int32 [B]


class TraversalStats(NamedTuple):
    """Per-query device-side traversal counters (``introspect=True``).

    Pure jit outputs — computed from arrays the loop already materializes,
    no host callbacks, no collectives (the auditor certifies this on the
    introspective executor route).

      hops      : beam expansions performed (== SearchResult.n_expanded)
      sat_step  : 1-based iteration at which the beam last improved (a new
                  candidate entered the kept ls slots); 0 = seeds only.
                  The frontier is saturated from this step on.
      dead_ends : iterations where the lane was active but NO filter-valid
                  candidate (primary == 0) entered the beam — the paper's
                  "navigational dead-end" events, made measurable.
    """

    hops: jnp.ndarray       # int32 [B]
    sat_step: jnp.ndarray   # int32 [B]
    dead_ends: jnp.ndarray  # int32 [B]


class _State(NamedTuple):
    it: jnp.ndarray
    beam_ids: jnp.ndarray
    beam_p: jnp.ndarray
    beam_s: jnp.ndarray
    beam_vis: jnp.ndarray
    seen: jnp.ndarray
    vlog: jnp.ndarray
    n_expanded: jnp.ndarray
    n_dist: jnp.ndarray
    # () in the standard traversal; (sat_step, dead_ends) int32 [B] pairs
    # when introspecting — keeping the standard pytree byte-identical.
    extra: tuple = ()


def _mask_dup_within_row(ids: jnp.ndarray) -> jnp.ndarray:
    """True where ids[b, j] duplicates an earlier entry of the same row."""
    eq = ids[:, :, None] == ids[:, None, :]
    lower = jnp.tril(jnp.ones(eq.shape[-2:], jnp.bool_), k=-1)
    return jnp.any(eq & lower, axis=-1)


def _sort_beam(p, s, ids, vis):
    """Lexicographic sort of beam rows by (primary, secondary)."""
    p, s, ids, vis8 = jax.lax.sort(
        (p, s, ids, vis.astype(jnp.int8)), num_keys=2)
    return p, s, ids, vis8.astype(jnp.bool_)


def greedy_search(graph: jnp.ndarray,      # int32 [N, R] (-1 sentinel)
                  xb: jnp.ndarray,         # [N, d]
                  xb_norm: jnp.ndarray,    # f32 [N]
                  attr: AttrTable,
                  queries: jnp.ndarray,    # [B, d]
                  entry: jnp.ndarray,      # int32 [S] seed vertices (or scalar)
                  key_fn: KeyFn,
                  *, ls: int, k: int, max_iters: int,
                  dist_fn=gathered_d2, expand_fn=None,
                  fetch_fn=None, dedup: str = "bitmap",
                  introspect: bool = False):
    """GreedySearch under a lexicographic comparator. See module docstring.

    ``expand_fn(p int32[B]) -> int32[B, C]`` overrides the 1-hop neighbor
    expansion (e.g. the ACORN-style 2-hop baseline); default gathers graph[p].

    ``fetch_fn(ids, q32, q_norm) -> (d2, attrs)`` fuses the distance + attr
    fetch into one row gather (int8/fused-layout serving, §Perf). Contract:
    ``ids`` int32[B, C] are candidate ids already clamped to >= 0 (but a
    conforming fetch must still tolerate/clip out-of-range ids); ``q32``
    f32[B, d] are the raw queries and ``q_norm`` f32[B] their squared norms.
    It must return ``d2`` f32[B, C] (squared L2, >= 0) and ``attrs`` — a dict
    shaped exactly like ``AttrTable.gather(ids)`` so the comparator's
    ``key_fn`` sees no difference. The fetch is invoked for the seed batch
    and once per loop iteration; it is the ONLY place candidate rows are
    read, so its gather count is the per-expansion HBM cost (2 on the
    default split path, 1 via ``serve.make_fetch_fn`` over the packed
    [vec | norm | attr] layout). When ``fetch_fn`` is given, ``xb``/
    ``xb_norm``/``attr`` are untouched (shape-only) and XLA drops them.
    ``dedup``: "bitmap" = packed seen-bits over N (exact, O(N/32) state);
    "scan" = compare against beam ∪ expansion log only (no N-sized state —
    removes the bitmap's HBM traffic; an evicted-unexpanded candidate may be
    revisited, which only costs work, never correctness).

    ``introspect=True`` returns ``(SearchResult, TraversalStats)`` instead
    of a bare SearchResult: hops / frontier-saturation step / dead-end
    events per query, as extra jit outputs. The (ids, primary, secondary)
    results are bit-identical to the standard traversal: the merge sort
    carries one extra int32 operand (a beam-vs-candidate tag) through the
    SAME stable two-key ``jax.lax.sort``, which cannot change the
    permutation the keys dictate.
    """
    N = xb.shape[0]
    B = queries.shape[0]
    Wn = (N + 31) // 32 if dedup == "bitmap" else 1
    q32 = queries.astype(jnp.float32)
    q_norm = jnp.sum(q32 * q32, axis=-1)

    def _fetch(ids):
        if fetch_fn is not None:
            return fetch_fn(ids, q32, q_norm)
        return dist_fn(xb, xb_norm, ids, q32, q_norm), attr.gather(ids)

    # --- initial beam = seed set (medoid + stratified seeds) ---------------
    entry = jnp.atleast_1d(jnp.asarray(entry, jnp.int32))
    S = entry.shape[0]
    assert S <= ls, "more seeds than beam slots"
    e_ids = jnp.broadcast_to(entry[None, :], (B, S))
    e_d2, e_attrs = _fetch(e_ids)
    e_p, e_s = key_fn(e_ids, e_attrs, e_d2)
    # dedup repeated seeds so beam rows stay duplicate-free
    sdup = _mask_dup_within_row(e_ids)
    e_p = jnp.where(sdup, INF, e_p)
    e_s = jnp.where(sdup, INF, e_s)

    beam_ids = jnp.full((B, ls), -1, jnp.int32).at[:, :S].set(e_ids)
    beam_p = jnp.full((B, ls), INF).at[:, :S].set(e_p)
    beam_s = jnp.full((B, ls), INF).at[:, :S].set(e_s)
    beam_vis = jnp.ones((B, ls), jnp.bool_).at[:, :S].set(sdup)
    beam_p, beam_s, beam_ids, beam_vis = _sort_beam(
        beam_p, beam_s, beam_ids, beam_vis)

    seen = jnp.zeros((B, Wn), jnp.uint32)
    if dedup == "bitmap":
        dup1d = _mask_dup_within_row(entry[None, :])[0]       # [S]
        bitvals = jnp.where(
            dup1d, jnp.uint32(0),
            jnp.uint32(1) << (entry % 32).astype(jnp.uint32))
        seen = seen.at[:, entry // 32].add(bitvals[None, :])

    extra0 = ((jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32))
              if introspect else ())
    st = _State(jnp.int32(0), beam_ids, beam_p, beam_s, beam_vis, seen,
                jnp.full((B, max_iters), -1, jnp.int32),
                jnp.zeros((B,), jnp.int32), jnp.ones((B,), jnp.int32),
                extra0)

    def cond(st: _State):
        return (st.it < max_iters) & jnp.any(~jnp.all(st.beam_vis, axis=1))

    def body(st: _State):
        active = ~jnp.all(st.beam_vis, axis=1)                    # [B]
        sel = jnp.argmax(~st.beam_vis, axis=1)                    # first unvis
        p = jnp.take_along_axis(st.beam_ids, sel[:, None], 1)[:, 0]
        beam_vis = st.beam_vis.at[jnp.arange(B), sel].set(
            st.beam_vis[jnp.arange(B), sel] | active)
        vlog = st.vlog.at[:, st.it].set(jnp.where(active, p, -1))

        # --- expand out-neighbors ---------------------------------------
        if expand_fn is None:
            nbrs = jnp.take(graph, jnp.maximum(p, 0), axis=0)     # [B, R]
        else:
            nbrs = expand_fn(jnp.maximum(p, 0))                   # [B, C]
        valid = (nbrs >= 0) & active[:, None]
        nbrs_c = jnp.maximum(nbrs, 0)
        if dedup == "bitmap":
            word = nbrs_c // 32
            bitv = jnp.uint32(1) << (nbrs_c % 32).astype(jnp.uint32)
            already = (jnp.take_along_axis(st.seen, word, 1) & bitv) > 0
            seen = st.seen.at[jnp.arange(B)[:, None], word].add(
                jnp.where(valid & ~already & ~_mask_dup_within_row(nbrs),
                          bitv, jnp.uint32(0)))
        else:  # "scan": membership test vs beam ∪ expansion log
            in_beam = jnp.any(
                nbrs[:, :, None] == st.beam_ids[:, None, :], axis=-1)
            in_log = jnp.any(
                nbrs[:, :, None] == st.vlog[:, None, :], axis=-1)
            already = in_beam | in_log
            seen = st.seen
        dup = _mask_dup_within_row(nbrs)
        new = valid & ~already & ~dup

        d2, c_attrs = _fetch(nbrs_c)
        cp, cs = key_fn(nbrs_c, c_attrs, d2)
        cp = jnp.where(new, cp, INF)
        cs = jnp.where(new, cs, INF)
        c_ids = jnp.where(new, nbrs, -1)
        c_vis = ~new  # masked entries visited=True so they never block/expand
        n_dist = st.n_dist + jnp.sum(new, axis=1, dtype=jnp.int32)

        # --- merge + truncate to ls --------------------------------------
        m_p = jnp.concatenate([st.beam_p, cp], axis=1)
        m_s = jnp.concatenate([st.beam_s, cs], axis=1)
        m_ids = jnp.concatenate([st.beam_ids, c_ids], axis=1)
        m_vis = jnp.concatenate([beam_vis, c_vis], axis=1)
        if introspect:
            # tag beam slots 0 / candidates 1 through the SAME stable
            # two-key sort: equal keys keep their order, so the kept
            # (ids, p, s) are bit-identical to the untagged sort — the
            # tag only reveals which kept slots a candidate entered.
            tag = jnp.concatenate(
                [jnp.zeros_like(st.beam_ids), jnp.ones_like(c_ids)], axis=1)
            m_p, m_s, m_ids, m_vis8, m_tag = jax.lax.sort(
                (m_p, m_s, m_ids, m_vis.astype(jnp.int8), tag), num_keys=2)
            m_vis = m_vis8.astype(jnp.bool_)
            entered = (m_tag[:, :ls] == 1) & (m_ids[:, :ls] >= 0)
            improved = active & jnp.any(entered, axis=1)
            valid_in = active & jnp.any(
                entered & (m_p[:, :ls] == 0.0), axis=1)
            sat_step, dead_ends = st.extra
            extra = (jnp.where(improved, st.it + 1, sat_step),
                     dead_ends + (active & ~valid_in).astype(jnp.int32))
        else:
            m_p, m_s, m_ids, m_vis = _sort_beam(m_p, m_s, m_ids, m_vis)
            extra = st.extra

        return _State(st.it + 1, m_ids[:, :ls], m_p[:, :ls], m_s[:, :ls],
                      m_vis[:, :ls], seen, vlog,
                      st.n_expanded + active.astype(jnp.int32), n_dist,
                      extra)

    st = jax.lax.while_loop(cond, body, st)

    # top-k among *visited* beam entries (Algorithm 1 line 17)
    fp = jnp.where(st.beam_vis & (st.beam_ids >= 0), st.beam_p, INF)
    fs = jnp.where(st.beam_vis & (st.beam_ids >= 0), st.beam_s, INF)
    fids = jnp.where(st.beam_vis & (st.beam_ids >= 0), st.beam_ids, -1)
    fp, fs, fids, _ = _sort_beam(fp, fs, fids,
                                 jnp.zeros_like(fids, jnp.bool_))
    result = SearchResult(fids[:, :k], fp[:, :k], fs[:, :k], st.vlog,
                          st.n_expanded, st.n_dist)
    if introspect:
        sat_step, dead_ends = st.extra
        return result, TraversalStats(st.n_expanded, sat_step, dead_ends)
    return result
