"""Beyond-paper optimizations for JAG serving (EXPERIMENTS.md §Perf).

1. **int8 database** (ScaNN/DiskANN-style): per-dimension symmetric
   quantization of the vectors used during graph traversal; candidates are
   re-ranked with the full-precision rows at the end. Halves (vs bf16) /
   quarters (vs f32) the bytes every beam expansion pulls from HBM — the
   dominant roofline term of the serve cell.

2. **fused row layout**: [int8 vec | norm | attr] packed so one gather per
   expansion fetches everything the comparator needs (vector, ||x||²,
   attribute), instead of three separate gathers over N-row operands.
   This layout is now realized for all four attribute kinds in
   ``repro.serve`` (layout.py packs the rows — f32 or int8 lanes — and
   engine.py builds the beam-search ``fetch_fn``); ``JAGIndex.search_int8``
   with ``layout="fused"`` is the int8 serving entry point. ``fuse_rows``
   below remains as the single-f32-attr-column special case used by the
   HLO measurement path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .distances import gathered_dot


def quantize_int8(xb: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-dim symmetric int8: returns (q int8 [N, d], scale f32 [d])."""
    x = jnp.asarray(xb, jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=0) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def make_int8_dist_fn(scale: jnp.ndarray):
    """gathered_d2-compatible distance over an int8 database.

    xb here is the int8 array; xb_norm holds the *dequantized* row norms.
    """
    def dist_fn(xb_q, xb_norm, ids, q32, q_norm):
        rows = jnp.take(xb_q, ids, axis=0, mode="clip").astype(jnp.float32)
        rows = rows * scale                                   # dequant
        dots = gathered_dot(rows, q32)
        d2 = jnp.take(xb_norm, ids, mode="clip") - 2.0 * dots \
            + q_norm[:, None]
        return jnp.maximum(d2, 0.0)
    return dist_fn


def rerank_exact(xb: jnp.ndarray, xb_norm: jnp.ndarray, res_ids, res_prim,
                 queries: jnp.ndarray, k: int):
    """Re-rank approximate top candidates with full-precision distances.

    Keeps the lexicographic primary (filter distance) and replaces the
    secondary with exact d2; returns re-sorted (ids, primary, d2)[:, :k].
    """
    q32 = jnp.asarray(queries, jnp.float32)
    qn = jnp.sum(q32 * q32, axis=-1)
    ids_c = jnp.maximum(res_ids, 0)
    rows = jnp.take(xb, ids_c, axis=0).astype(jnp.float32)
    d2 = (jnp.take(xb_norm, ids_c) - 2.0 * gathered_dot(rows, q32)
          + qn[:, None])
    d2 = jnp.where(res_ids >= 0, jnp.maximum(d2, 0.0), jnp.inf)
    prim = jnp.where(res_ids >= 0, res_prim, jnp.inf)
    p, s, i = jax.lax.sort((prim, d2, res_ids), num_keys=2)
    return i[:, :k], p[:, :k], s[:, :k]


def fuse_rows(xb_q: jnp.ndarray, xb_norm: jnp.ndarray,
              attr_value: jnp.ndarray) -> jnp.ndarray:
    """Pack [vec_i8_as_f32-ready | norm | attr] into one f32 row matrix.

    A production TPU layout would keep the int8 block packed; for the XLA
    measurement path we fuse as f32 columns so a single gather feeds the
    comparator (HLO then charges ONE N-row operand per expansion, matching
    the one-DMA-per-row behaviour of kernels/gather_dist.py on hardware).
    """
    return jnp.concatenate(
        [jnp.asarray(xb_q, jnp.float32),
         xb_norm[:, None].astype(jnp.float32),
         attr_value[:, None].astype(jnp.float32)], axis=1)
