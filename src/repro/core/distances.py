"""Filter distance ``dist_F``, attribute distance ``dist_A`` and the unified
lexicographic comparators of JAG §3.1–3.2.

Conventions
-----------
* Vector distances are **squared** L2 internally (monotone in true L2, so all
  orderings are unchanged); Weight-JAG takes sqrt so ``w·dist_A + dist`` mixes
  on the paper's scale.
* All comparator keys are pairs ``(primary, secondary)`` of float32, compared
  lexicographically via ``lax.sort(..., num_keys=2)``.
* ``dist_F``/``dist_A`` broadcast a per-lane filter/attribute ``[B]`` against
  gathered candidate attributes ``[B, C]``.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from .filters import (FilterBatch, Leaf, And, Or, Not,
                      BOOLEAN, LABEL, RANGE, SUBSET,
                      is_composite, kind_components, popcount)

INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------------------
# dist_F : how far attribute a is from satisfying filter f  (§3.1 examples)
# ---------------------------------------------------------------------------

def dist_f(filt, attrs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """dist_F(f_q, a) for gathered candidate attrs [B, C, ...] -> f32[B, C].

    Compound expressions compose so the invariant ``dist_F == 0 iff
    matches`` is preserved on every tree: And sums its clauses (zero iff
    all zero), Or takes the min (zero iff any zero), Not maps to the
    binary satisfied-indicator of its child (1.0 where the child matches).
    The graph route's D_F comparator therefore traverses compound filters
    natively — closer-to-satisfying regions still sort first.
    """
    if isinstance(filt, Leaf):
        return dist_f(filt.filt, attrs)
    if isinstance(filt, And):
        out = dist_f(filt.children[0], attrs)
        for c in filt.children[1:]:
            out = out + dist_f(c, attrs)
        return out
    if isinstance(filt, Or):
        out = dist_f(filt.children[0], attrs)
        for c in filt.children[1:]:
            out = jnp.minimum(out, dist_f(c, attrs))
        return out
    if isinstance(filt, Not):
        return (dist_f(filt.child, attrs) <= 0.0).astype(jnp.float32)
    k = filt.kind
    if k == LABEL:
        return (attrs["label"] != filt.data["label"][:, None]).astype(
            jnp.float32)
    if k == RANGE:
        v = attrs["value"]
        lo = filt.data["lo"][:, None]
        hi = filt.data["hi"][:, None]
        return jnp.maximum(lo - v, 0.0) + jnp.maximum(v - hi, 0.0)
    if k == SUBSET:
        f = filt.data["bits"][:, None, :]
        return popcount(f & ~attrs["bits"]).astype(jnp.float32)  # |f \ a|
    if k == BOOLEAN:
        a = attrs["assign"].astype(jnp.int32)
        return jnp.take_along_axis(filt.data["table"], a, axis=-1)
    raise ValueError(k)


# ---------------------------------------------------------------------------
# dist_A : semantic proximity between two attributes  (§3.1 examples)
# ---------------------------------------------------------------------------

def dist_a(kind: str, a_p: Dict[str, jnp.ndarray],
           a_c: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """dist_A(a_p, a_c): base attrs [B, ...] vs candidates [B, C, ...].

    Composite kinds ("label+range") sum their components' attribute
    distances, so joint tables build/calibrate with one comparator.
    """
    if is_composite(kind):
        parts = [dist_a(k2, a_p, a_c) for k2 in kind_components(kind)]
        out = parts[0]
        for p in parts[1:]:
            out = out + p
        return out
    if kind == LABEL:
        return (a_p["label"][:, None] != a_c["label"]).astype(jnp.float32)
    if kind == RANGE:
        return jnp.abs(a_p["value"][:, None] - a_c["value"])
    if kind == SUBSET:
        if "bit_weights" in a_c:
            # YFCC-style weighted distance (paper D.3):
            #   dist_A = C - sum_{i in a_u ∩ a_v} log(1/p_i)
            w = a_c["bit_weights"]                       # [L]
            inter = a_p["bits"][:, None, :] & a_c["bits"]  # [B, C, W]
            overlap = _weighted_popcount(inter, w)
            return jnp.sum(w) - overlap
        return popcount(a_p["bits"][:, None, :] ^ a_c["bits"]).astype(
            jnp.float32)
    if kind == BOOLEAN:
        x = a_p["assign"][:, None] ^ a_c["assign"]
        return jax.lax.population_count(x).astype(jnp.float32)
    raise ValueError(kind)


def _weighted_popcount(words: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Sum of per-bit weights over set bits. words [..., W], w [L<=32*W]."""
    W = words.shape[-1]
    L = w.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((words[..., :, None] >> shifts) & jnp.uint32(1)).astype(
        jnp.float32)                                     # [..., W, 32]
    bits = bits.reshape(words.shape[:-1] + (W * 32,))[..., :L]
    return bits @ w


def capped(da: jnp.ndarray, t) -> jnp.ndarray:
    """Capped attribute distance max(dist_A - t, 0) (§3.2)."""
    return jnp.maximum(da - t, 0.0)


# ---------------------------------------------------------------------------
# comparator factories: return key_fn(cand_ids, cand_attrs, d2) -> (prim, sec)
# ---------------------------------------------------------------------------

KeyFn = Callable[[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray],
                 tuple[jnp.ndarray, jnp.ndarray]]


def query_key_fn(filt) -> KeyFn:
    """D_F(q, u) = (dist_F(f_q, a_u), dist(x_q, x_u)) — Algorithm 2.

    ``filt`` may be an atomic FilterBatch or a compound FilterExpr (dist_f
    composes over the tree).
    """
    def key_fn(ids, attrs, d2):
        del ids
        return dist_f(filt, attrs), d2
    return key_fn


def unfiltered_key_fn() -> KeyFn:
    """Plain vector-distance comparator (post-filtering / 100% threshold)."""
    def key_fn(ids, attrs, d2):
        del ids, attrs
        return jnp.zeros_like(d2), d2
    return key_fn


def hard_filter_key_fn(filt: FilterBatch, penalty: float = 1.0) -> KeyFn:
    """Binary match/non-match comparator (the paper's trivial dist_F).

    Equivalent to FilteredVamana-style traversal that prefers valid nodes but
    can still pass through invalid ones.
    """
    def key_fn(ids, attrs, d2):
        del ids
        df = dist_f(filt, attrs)
        return (df > 0).astype(jnp.float32) * penalty, d2
    return key_fn


def build_threshold_key_fn(kind: str, a_p: Dict[str, jnp.ndarray],
                           t) -> KeyFn:
    """D_A^t(p, u) = (max(dist_A(a_p,a_u)-t, 0), dist(x_p,x_u)) — §3.2."""
    def key_fn(ids, attrs, d2):
        del ids
        return capped(dist_a(kind, a_p, attrs), t), d2
    return key_fn


def build_weight_key_fn(kind: str, a_p: Dict[str, jnp.ndarray],
                        w) -> KeyFn:
    """D_A^w(p, u) = w·dist_A + dist (Weight-JAG §3.4); secondary = d2."""
    def key_fn(ids, attrs, d2):
        del ids
        return w * dist_a(kind, a_p, attrs) + jnp.sqrt(d2), d2
    return key_fn


# ---------------------------------------------------------------------------
# squared-L2 helpers
# ---------------------------------------------------------------------------

def sq_norms(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)


def gathered_dot(rows: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Per-candidate dot products <rows[b, c], q[b]> -> f32[B, C].

    Deliberately an elementwise multiply + last-axis reduce, NOT
    ``einsum("bcd,bd->bc", ...)``: a batched-dot lowering picks different
    reduction vectorization per batch size, so row b's low-order float bits
    would depend on how many other queries share the batch. The per-query
    dispatcher (serve/dispatch.py) regroups arbitrary sub-batches and
    promises bit-identical per-query results to solo execution, which makes
    batch-size invariance part of this helper's contract — every gathered
    candidate dot in the codebase must go through it.
    """
    return jnp.sum(rows.astype(jnp.float32) * q.astype(jnp.float32)[:, None],
                   axis=-1)


def gathered_d2(xb: jnp.ndarray, xb_norm: jnp.ndarray, ids: jnp.ndarray,
                q: jnp.ndarray, q_norm: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 between q[b] and xb[ids[b, c]] via gather + dot.

    xb [N, d]; ids int32[B, C] (clipped); q [B, d]; -> f32[B, C].
    """
    rows = jnp.take(xb, ids, axis=0, mode="clip")        # [B, C, d]
    dots = gathered_dot(rows, q)
    d2 = jnp.take(xb_norm, ids, mode="clip") - 2.0 * dots + q_norm[:, None]
    return jnp.maximum(d2, 0.0)
