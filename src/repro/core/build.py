"""Batch-synchronous JAG construction (Insert, Algorithm 3).

Points are inserted in batches of B:
  1. For every threshold t in T (or weight w): GreedySearch from the entry
     point under D_A(t) (resp. D_A^w); union the visited logs (Alg. 3 l.4-7).
  2. Dedup/self-mask the candidate pool, keep the C-best by vector distance.
  3. JointRobustPrune -> out-neighbors of each inserted point (l.8).
  4. Reverse edges (l.9-13): proposals (v -> p) are grouped by destination via
     a sort + in-group rank, written at slot degree[v]+rank into an adjacency
     buffer with EX spare columns; destinations whose degree exceeds R are
     re-pruned in a second vectorized pass (fill factor 0.9, paper D.3).

The graph buffer is ``int32[N, R+EX]``; rows hold -1 sentinels beyond their
degree. Searches read the full buffer (spare columns are -1 except transiently
for rows awaiting a future overflow re-prune).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .beam_search import greedy_search
from .distances import (INF, build_threshold_key_fn, build_weight_key_fn,
                        dist_a, sq_norms)
from .filters import AttrTable
from .prune import joint_robust_prune, select_to_rows


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    degree: int = 32                 # R: max out-degree
    ls_build: int = 64               # l_b: build beam width
    alpha: float = 1.2
    mode: str = "threshold"          # "threshold" | "weight"
    thresholds: tuple = (jnp.inf, 0.1, 0.0)  # absolute dist_A caps
    weights: tuple = (0.0, 1.0)
    batch_size: int = 128
    cand_pool: int = 192             # C: prune candidate pool size
    max_iters: int = 0               # 0 -> 2*ls_build
    ex_slots: int = 16               # EX spare adjacency columns
    ov_max: int = 256                # max overflow vertices re-pruned / batch
    fill: float = 0.9                # overflow re-prune fill factor
    n_passes: int = 2                # DiskANN-style build passes

    @property
    def iters(self) -> int:
        return self.max_iters or 2 * self.ls_build

    @property
    def row_width(self) -> int:
        return self.degree + self.ex_slots

    @property
    def bucket_vals(self):
        return self.thresholds if self.mode == "threshold" else self.weights


# ---------------------------------------------------------------------------
# candidate pool assembly
# ---------------------------------------------------------------------------

def _dedup_pool(ids: jnp.ndarray, self_ids: jnp.ndarray) -> jnp.ndarray:
    """Mark -1 for duplicates / self / sentinel; keep first occurrence."""
    ids = jnp.where(ids == self_ids[:, None], -1, ids)
    order = jnp.argsort(ids, axis=1)
    s = jnp.take_along_axis(ids, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s[:, :1], jnp.bool_), s[:, 1:] == s[:, :-1]], axis=1)
    s = jnp.where(dup, -1, s)
    out = jnp.full_like(ids, -1)
    return out.at[jnp.arange(ids.shape[0])[:, None], order].set(s)


def _top_c(ids: jnp.ndarray, d2: jnp.ndarray, c: int):
    """Keep the c candidates with smallest vector distance."""
    key = jnp.where(ids >= 0, d2, INF)
    _, sids = jax.lax.sort((key, ids), num_keys=1)
    return sids[:, :c]


# ---------------------------------------------------------------------------
# one jitted insertion step
# ---------------------------------------------------------------------------

def make_insert_step(cfg: BuildConfig):
    """Returns insert(graph, degree, xb, xb_norm, attr, batch_ids, entry)."""

    @partial(jax.jit, donate_argnums=(0, 1))
    def insert(graph, degree, xb, xb_norm, attr: AttrTable, batch_ids, entry):
        B = batch_ids.shape[0]
        p_vec = jnp.take(xb, batch_ids, axis=0)
        p_attr = attr.gather(batch_ids)

        # --- 1. per-bucket greedy searches, union visited logs -----------
        logs = []
        for bval in cfg.bucket_vals:
            if cfg.mode == "threshold":
                kf = build_threshold_key_fn(attr.kind, p_attr,
                                            jnp.float32(bval))
            else:
                kf = build_weight_key_fn(attr.kind, p_attr, jnp.float32(bval))
            res = greedy_search(graph, xb, xb_norm, attr, p_vec, entry, kf,
                                ls=cfg.ls_build, k=1, max_iters=cfg.iters)
            logs.append(res.vlog)
        pool = jnp.concatenate(logs, axis=1)

        # --- 2. dedup + keep best C by vector distance --------------------
        pool = _dedup_pool(pool, batch_ids)
        pn = jnp.sum(p_vec.astype(jnp.float32) ** 2, axis=-1)
        pool_d2 = _pool_d2(xb, xb_norm, pool, p_vec, pn)
        cand = _top_c(pool, pool_d2, cfg.cand_pool)          # [B, C]
        cvalid = cand >= 0
        cc = jnp.maximum(cand, 0)
        d2_p = _pool_d2(xb, xb_norm, cc, p_vec, pn)
        da_p = dist_a(attr.kind, p_attr, attr.gather(cc))
        cvec = jnp.take(xb, cc, axis=0).astype(jnp.float32)  # [B, C, d]
        cnorm = jnp.take(xb_norm, cc, axis=0)
        pair_d2 = (cnorm[:, :, None] + cnorm[:, None, :]
                   - 2.0 * jnp.einsum("bcd,bed->bce", cvec, cvec))
        pair_d2 = jnp.maximum(pair_d2, 0.0)

        # --- 3. prune -> out-neighbors of p -------------------------------
        kw = (dict(thresholds=cfg.thresholds) if cfg.mode == "threshold"
              else dict(weights=cfg.weights))
        selected = joint_robust_prune(cvalid, d2_p, da_p, pair_d2,
                                      degree=cfg.degree, alpha=cfg.alpha,
                                      **kw)
        out_rows = select_to_rows(selected, cand, d2_p, cfg.degree)
        pad = jnp.full((B, cfg.ex_slots), -1, jnp.int32)
        graph = graph.at[batch_ids].set(
            jnp.concatenate([out_rows, pad], axis=1))
        degree = degree.at[batch_ids].set(
            jnp.sum(out_rows >= 0, axis=1, dtype=jnp.int32))

        # --- 4. reverse edges ---------------------------------------------
        graph, degree, overflow_v = _reverse_edges(
            graph, degree, out_rows, batch_ids, cfg)

        # --- 5. overflow re-prune -----------------------------------------
        graph, degree = _overflow_reprune(graph, degree, xb, xb_norm, attr,
                                          overflow_v, cfg)
        return graph, degree

    return insert


def _pool_d2(xb, xb_norm, ids, p_vec, p_norm):
    rows = jnp.take(xb, ids, axis=0, mode="clip").astype(jnp.float32)
    dots = jnp.einsum("bcd,bd->bc", rows, p_vec.astype(jnp.float32))
    return jnp.maximum(
        jnp.take(xb_norm, ids, mode="clip") - 2.0 * dots + p_norm[:, None],
        0.0)


def _reverse_edges(graph, degree, out_rows, batch_ids, cfg: BuildConfig):
    """Scatter (v -> p) proposals grouped by destination v.

    Duplicate-edge guards: (a) mutual selection within the batch — if v is
    also being inserted and already chose p as an out-neighbor, the (v -> p)
    proposal is dropped; (b) identical (v, p) pairs (padded tail batches).
    """
    B, R = out_rows.shape
    N = degree.shape[0]
    W = cfg.row_width
    # (a) mutual-selection mask: M[b, c] = batch_ids[c] in out_rows[b]
    is_batch = out_rows[:, :, None] == batch_ids[None, None, :]  # [B, R, B]
    M = jnp.any(is_batch, axis=1)                             # [B, B]
    # proposal (b, j) duplicates iff its target is batch point c whose own
    # out-row already contains batch_ids[b]:  is_batch[b,j,c] & M[c,b]
    mutual = jnp.any(is_batch & M.T[:, None, :], axis=-1)     # [B, R]
    v = out_rows.reshape(-1)                                  # [B*R]
    p = jnp.repeat(batch_ids, R)
    valid = (v >= 0) & ~mutual.reshape(-1)
    v_s = jnp.where(valid, v, N)                              # sentinel last
    # (b) dedup identical (v, p) pairs
    v_s, p_s = jax.lax.sort((v_s, p), num_keys=2)
    dup = jnp.concatenate([jnp.zeros((1,), jnp.bool_),
                           (v_s[1:] == v_s[:-1]) & (p_s[1:] == p_s[:-1])])
    v_s = jnp.where(dup, N, v_s)
    # (c) drop proposals already present in v's row (re-insertion passes)
    exists = jnp.any(
        jnp.take(graph, jnp.minimum(v_s, N - 1), axis=0) == p_s[:, None],
        axis=1)
    v_s = jnp.where(exists, N, v_s)
    v_s, p_s = jax.lax.sort((v_s, p_s), num_keys=1)
    ar = jnp.arange(v_s.shape[0], dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), v_s[1:] != v_s[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, ar, 0))
    rank = ar - group_start
    deg_v = jnp.take(degree, jnp.minimum(v_s, N - 1))
    slot = deg_v + rank
    ok = (v_s < N) & (slot < W)
    graph = graph.at[jnp.where(ok, v_s, N), jnp.where(ok, slot, 0)].set(
        p_s, mode="drop")
    # per-group counts at group-end positions -> new degrees
    is_end = jnp.concatenate([v_s[1:] != v_s[:-1],
                              jnp.ones((1,), jnp.bool_)])
    cnt = rank + 1
    newdeg = jnp.minimum(deg_v + cnt, W)
    degree = degree.at[jnp.where(is_end & (v_s < N), v_s, N)].set(
        newdeg, mode="drop")
    # overflow vertices: degree now beyond R -> need re-prune
    over = is_end & (v_s < N) & (newdeg > cfg.degree)
    okey = jnp.where(over, ar, jnp.int32(2 ** 30))
    _, ov_pos = jax.lax.sort((okey, ar), num_keys=1)
    ov_pos = ov_pos[:cfg.ov_max]
    overflow_v = jnp.where(
        jnp.take(over, ov_pos), jnp.take(v_s, ov_pos), -1)    # [ov_max]
    return graph, degree, overflow_v


def _overflow_reprune(graph, degree, xb, xb_norm, attr, ov: jnp.ndarray,
                      cfg: BuildConfig):
    """Re-prune rows whose degree exceeded R (Alg. 3 l.11-12)."""
    W = cfg.row_width
    OV = ov.shape[0]
    vvalid = ov >= 0
    vc = jnp.maximum(ov, 0)
    cand = jnp.take(graph, vc, axis=0)                        # [OV, W]
    cvalid = (cand >= 0) & vvalid[:, None]
    cand = jnp.where(cvalid, cand, -1)
    cand = _dedup_pool(cand, vc)
    cvalid = cand >= 0
    cc = jnp.maximum(cand, 0)

    p_vec = jnp.take(xb, vc, axis=0)
    pn = jnp.take(xb_norm, vc)
    d2_p = _pool_d2(xb, xb_norm, cc, p_vec, pn)
    da_p = dist_a(attr.kind, attr.gather(vc), attr.gather(cc))
    cvec = jnp.take(xb, cc, axis=0).astype(jnp.float32)
    cnorm = jnp.take(xb_norm, cc, axis=0)
    pair_d2 = jnp.maximum(
        cnorm[:, :, None] + cnorm[:, None, :]
        - 2.0 * jnp.einsum("bcd,bed->bce", cvec, cvec), 0.0)

    kw = (dict(thresholds=cfg.thresholds) if cfg.mode == "threshold"
          else dict(weights=cfg.weights))
    selected = joint_robust_prune(cvalid, d2_p, da_p, pair_d2,
                                  degree=cfg.degree, alpha=cfg.alpha,
                                  fill=cfg.fill, **kw)
    new_rows = select_to_rows(selected, cand, d2_p, cfg.degree)
    new_rows = jnp.concatenate(
        [new_rows, jnp.full((OV, W - cfg.degree), -1, jnp.int32)], axis=1)
    graph = graph.at[jnp.where(vvalid, vc, graph.shape[0])].set(
        new_rows, mode="drop")
    degree = degree.at[jnp.where(vvalid, vc, graph.shape[0])].set(
        jnp.sum(new_rows >= 0, axis=1, dtype=jnp.int32), mode="drop")
    return graph, degree


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def medoid(xb: jnp.ndarray) -> jnp.ndarray:
    """Point closest to the dataset mean (entry vertex s)."""
    x = xb.astype(jnp.float32)
    mu = jnp.mean(x, axis=0, keepdims=True)
    return jnp.argmin(jnp.sum((x - mu) ** 2, axis=-1)).astype(jnp.int32)


def make_seeds(xb: jnp.ndarray, n_seeds: int, seed: int = 0) -> jnp.ndarray:
    """Entry set = medoid + stratified random seeds (multi-seed beam init).

    A single-medoid entry can strand well-separated clusters behind pruned
    highways; seeding the beam with a small stratified sample restores
    reachability at negligible cost (beyond-paper robustness fix, DESIGN §2).
    """
    n = xb.shape[0]
    m = int(medoid(xb))
    if n_seeds <= 1 or n <= n_seeds:
        return jnp.asarray([m], jnp.int32)
    rng = np.random.default_rng(seed + 7919)
    strata = np.linspace(0, n, n_seeds, endpoint=False).astype(np.int64)
    extra = (strata + rng.integers(0, max(1, n // n_seeds),
                                   n_seeds)) % n
    ids = np.unique(np.concatenate([[m], extra]))[:n_seeds]
    return jnp.asarray(ids, jnp.int32)


def finalize_graph(graph, degree, xb, xb_norm, attr, cfg: BuildConfig):
    """Drain the overflow backlog: re-prune every row with degree > R."""
    reprune = jax.jit(partial(_overflow_reprune, cfg=cfg))
    for _ in range(64):  # bounded; each pass fixes up to ov_max rows
        over = np.flatnonzero(np.asarray(degree) > cfg.degree)
        if over.size == 0:
            break
        chunk = np.full(cfg.ov_max, -1, np.int32)
        chunk[:min(over.size, cfg.ov_max)] = over[:cfg.ov_max]
        graph, degree = reprune(graph, degree, xb, xb_norm, attr,
                                jnp.asarray(chunk))
    return graph, degree


def build_graph(xb: jnp.ndarray, attr: AttrTable, cfg: BuildConfig,
                seed: int = 0, entry: jnp.ndarray | None = None,
                verbose: bool = False):
    """Full index build. Returns (graph int32[N, R+EX], degree, entry)."""
    N = xb.shape[0]
    xb = jnp.asarray(xb)
    xb_norm = sq_norms(xb)
    if entry is None:
        entry = make_seeds(xb, n_seeds=8, seed=seed)
    graph = jnp.full((N, cfg.row_width), -1, jnp.int32)
    degree = jnp.zeros((N,), jnp.int32)
    insert = make_insert_step(cfg)

    rng = np.random.default_rng(seed)
    Bsz = cfg.batch_size
    n_batches = (N + Bsz - 1) // Bsz
    for pass_i in range(cfg.n_passes):
        order = rng.permutation(N)
        for i in range(n_batches):
            ids = order[i * Bsz:(i + 1) * Bsz]
            if len(ids) < Bsz:  # pad final batch cyclically (dup-tolerant)
                ids = np.resize(ids, Bsz)
            graph, degree = insert(graph, degree, xb, xb_norm, attr,
                                   jnp.asarray(ids, jnp.int32), entry)
            if verbose and (i % 20 == 0 or i == n_batches - 1):
                print(f"  pass {pass_i + 1}/{cfg.n_passes} "
                      f"batch {i + 1}/{n_batches}")
        graph, degree = finalize_graph(graph, degree, xb, xb_norm, attr, cfg)
    return graph, degree, entry
