"""Recall / QPS / distance-computation measurement harness.

recall@k follows the filtered-ANN convention used by the paper's figures:
for each query, |returned ∩ exact-top-k| / |exact-top-k|, where exact-top-k
contains only filter-satisfying points (may be < k at low selectivity) and
returned results must satisfy the filter (primary key == 0 under D_F).
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple

import jax
import numpy as np

from .ground_truth import GroundTruth


class EvalResult(NamedTuple):
    recall: float
    qps: float
    mean_dist_comps: float
    per_query_recall: np.ndarray


def recall_at_k(result_ids: np.ndarray, result_valid: np.ndarray,
                gt_ids: np.ndarray) -> np.ndarray:
    """Per-query recall. gt_ids padded with -1; result_valid masks non-matching
    returned points (e.g. primary > 0)."""
    B = gt_ids.shape[0]
    out = np.ones((B,), np.float64)
    for b in range(B):
        gt = set(int(i) for i in gt_ids[b] if i >= 0)
        if not gt:
            continue  # vacuous query: recall 1 by convention
        got = set(int(i) for i, v in zip(result_ids[b], result_valid[b]) if v)
        out[b] = len(gt & got) / len(gt)
    return out


def evaluate(search_fn: Callable[[], "SearchResult"], gt: GroundTruth,
             timed_repeats: int = 3) -> EvalResult:
    """Run a (jitted, warmed) zero-arg search closure; measure recall & QPS."""
    res = search_fn()
    jax.block_until_ready(res.ids)
    t0 = time.perf_counter()
    for _ in range(timed_repeats):
        res = search_fn()
        jax.block_until_ready(res.ids)
    dt = (time.perf_counter() - t0) / timed_repeats
    ids = np.asarray(res.ids)
    valid = np.asarray(res.primary) == 0.0
    pq = recall_at_k(ids, valid, np.asarray(gt.ids))
    qps = ids.shape[0] / dt
    nd = float(np.asarray(res.n_dist).mean()) if hasattr(res, "n_dist") else 0
    return EvalResult(float(pq.mean()), qps, nd, pq)
