"""Exact filtered nearest neighbors (= the Pre-Filtering baseline).

Brute force over validity-masked distances, blocked over the database so the
distance matrix stays bounded; the blocked path is also the production
pre-filter (paper Appendix A: isolate valid subset, scan it exactly) — the
query planner (serve/planner.py) routes low-selectivity batches here, and
the executor (serve/executor.py) adapts the result to the SearchResult
contract. ``use_kernel=True`` swaps the per-block distance matmul for the
scalar-prefetch Pallas tile scan (kernels/ops.gather_dist_tile, padded once
up front) so each database block is DMA'd HBM->VMEM once on TPU.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .distances import INF, sq_norms
from .filters import AttrTable, matches_rows


class GroundTruth(NamedTuple):
    ids: jnp.ndarray   # int32 [B, k], -1 where fewer than k valid points
    d2: jnp.ndarray    # f32 [B, k]
    n_dist: jnp.ndarray  # int32 [B]: #valid points scanned (paper Table 1 DC)
    n_feval: jnp.ndarray  # int32 [B]: short-circuit filter-clause evals


@partial(jax.jit, static_argnames=("k", "block", "use_kernel"))
def exact_filtered_knn(xb, attr: AttrTable, queries, filt,
                       k: int = 10, block: int = 4096,
                       use_kernel: bool = False) -> GroundTruth:
    """Exact top-k among filter-satisfying points, blocked scan.

    ``filt`` may be an atomic FilterBatch or a compound FilterExpr; the
    validity scan evaluates the tree per block with left-to-right
    short-circuit accounting (``n_feval`` — what the planner's clause
    reordering minimizes). ``use_kernel`` also routes the subset/boolean
    leaf validity through the Pallas popcount kernel (kernels/bitset.py).
    """
    N, d = xb.shape
    B = queries.shape[0]
    xb32 = xb.astype(jnp.float32)
    xn = sq_norms(xb32)
    q32 = queries.astype(jnp.float32)
    qn = sq_norms(q32)
    nblk = (N + block - 1) // block
    if use_kernel:
        # pad ONCE (rows to a block multiple, d to the 8-lane minimum) so
        # the fori_loop body is a bare tile DMA + reduction; padded rows
        # score against the zero vector and are masked by `inb` below
        xb_pad = jnp.pad(xb32, ((0, (-N) % block), (0, (-d) % 8)))
        q_pad = jnp.pad(q32, ((0, 0), (0, (-d) % 8)))

    top_d = jnp.full((B, k), INF)
    top_i = jnp.full((B, k), -1, jnp.int32)
    ndist = jnp.zeros((B,), jnp.int32)
    nfeval = jnp.zeros((B,), jnp.int32)

    def body(bi, carry):
        top_d, top_i, ndist, nfeval = carry
        ids = bi * block + jnp.arange(block)
        inb = ids < N
        idc = jnp.minimum(ids, N - 1)
        if use_kernel:
            from ..kernels import ops
            d2 = ops.gather_dist_tile(xb_pad, jnp.full((B,), bi, jnp.int32),
                                      q_pad, tile=block)  # [B, blk]
        else:
            xbl = jnp.take(xb32, idc, axis=0)                # [blk, d]
            d2 = (jnp.take(xn, idc)[None, :] + qn[:, None]
                  - 2.0 * q32 @ xbl.T)                       # [B, blk]
        # gather the block's [block] attr rows ONCE and broadcast against
        # the filter batch — the old [B, block] id matrix repeated the same
        # gather B times per block on the prefilter hot path
        ok, ev = matches_rows(filt, attr, idc, use_kernel=use_kernel)
        ok = ok & inb[None, :]
        d2 = jnp.where(ok, jnp.maximum(d2, 0.0), INF)
        ndist = ndist + jnp.sum(ok, axis=1, dtype=jnp.int32)
        nfeval = nfeval + jnp.sum(
            jnp.where(inb[None, :], ev, 0), axis=1, dtype=jnp.int32)
        cd = jnp.concatenate([top_d, d2], axis=1)
        ci = jnp.concatenate(
            [top_i, jnp.where(ok, ids[None, :], -1)], axis=1)
        cd, ci = jax.lax.sort((cd, ci), num_keys=1)
        return cd[:, :k], ci[:, :k], ndist, nfeval

    top_d, top_i, ndist, nfeval = jax.lax.fori_loop(
        0, nblk, body, (top_d, top_i, ndist, nfeval))
    top_i = jnp.where(jnp.isinf(top_d), -1, top_i)
    return GroundTruth(top_i, top_d, ndist, nfeval)
