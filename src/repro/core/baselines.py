"""Baseline filtered-ANN algorithms (paper §4.2 / Appendix D.4).

Implemented by mechanism, with the paper baseline each one stands in for:

  post_filter       — Post-Filtering: unfiltered Vamana-style search with an
                      oversampled beam, filter applied to the results.
  pre_filter        — Pre-Filtering: exact masked scan (ground_truth module).
  binary_jag        — FilteredVamana-flavored: strict-attribute build (T={0})
                      + binary match/non-match traversal, i.e. JAG with the
                      paper's "trivial" dist_F/dist_A (§3.1 Discussion).
  acorn             — ACORN-gamma-flavored: attribute-oblivious graph,
                      two-hop expansion at query time, predicate-passing
                      candidates prioritized.
  rwalks            — RWalks-flavored: attribute-oblivious graph + random-walk
                      attribute diffusion at build; query key =
                      h * dist_F(aggregated attrs) + dist (weighted mix, with
                      our generalized dist_F per the paper's D.4 footnote).
  stitched (labels) — StitchedVamana-flavored: one pure-vector subgraph per
                      label, queries routed to their label's subgraph.

All baselines share the batched GreedySearch / batch-build substrate, so
QPS and distance-computation comparisons against JAG are apples-to-apples —
and they all compile through the index's single ``serve.Executor`` jit
cache (previously each call re-created a fresh ``@jax.jit`` closure,
recompiling the traversal on every invocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .beam_search import SearchResult, greedy_search
from .distances import INF, dist_f, hard_filter_key_fn
from .filters import (
    AttrTable,
    FilterBatch,
    BOOLEAN,
    LABEL,
    RANGE,
    SUBSET,
    matches,
    pack_bits,
)
from .jag import JAGConfig, JAGIndex


def build_unfiltered(xb, attr: AttrTable, cfg: JAGConfig) -> JAGIndex:
    """Pure vector-distance graph (threshold quantile 100% only)."""
    c = dataclasses.replace(cfg, mode="threshold",
                            threshold_quantiles=(1.0,))
    return JAGIndex.build(xb, attr, c)


def build_binary(xb, attr: AttrTable, cfg: JAGConfig) -> JAGIndex:
    """Strict-attribute + vector graph: thresholds {0%, 100%}."""
    c = dataclasses.replace(cfg, mode="threshold",
                            threshold_quantiles=(1.0, 0.0))
    return JAGIndex.build(xb, attr, c)


# ---------------------------------------------------------------------------
# post-filtering
# ---------------------------------------------------------------------------

def post_filter_search(index: JAGIndex, queries, filt: FilterBatch,
                       k: int = 10, ls: int = 64,
                       max_iters: int = 0) -> SearchResult:
    """Unfiltered search with beam ls, keep the k best filter-passing.

    Delegates to the executor's postfilter route — the same compiled
    program ``JAGIndex.search_auto`` dispatches to at high selectivity.
    """
    return index.executor.postfilter(queries, filt, k=k, ls=ls,
                                     max_iters=max_iters or 2 * ls)


# ---------------------------------------------------------------------------
# binary (FilteredVamana-flavored)
# ---------------------------------------------------------------------------

def binary_search(index: JAGIndex, queries, filt: FilterBatch, k: int = 10,
                  ls: int = 64, max_iters: int = 0) -> SearchResult:
    max_iters = max_iters or 2 * ls
    key = ("binary", "default", "f32", k, ls, max_iters, filt.kind)

    def make():
        def run(graph, xb, xb_norm, attr, q, filt, entry):
            return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                 hard_filter_key_fn(filt), ls=ls, k=k,
                                 max_iters=max_iters)
        return run
    res = index.executor.run(key, make, index.graph, index.xb,
                             index.xb_norm, index.attr,
                             jnp.asarray(queries), filt, index.entry)
    # re-key primaries to exact dist_F==0 convention for recall accounting
    ok = res.primary == 0.0
    return SearchResult(jnp.where(ok, res.ids, -1),
                        jnp.where(ok, 0.0, INF), res.secondary,
                        res.vlog, res.n_expanded, res.n_dist)


# ---------------------------------------------------------------------------
# ACORN-gamma-flavored: two-hop expansion over an oblivious graph
# ---------------------------------------------------------------------------

def acorn_search(index: JAGIndex, queries, filt: FilterBatch, k: int = 10,
                 ls: int = 64, max_iters: int = 0,
                 hop2_per_nbr: int = 4) -> SearchResult:
    """Two-hop candidate pool; predicate-passing candidates keyed first."""
    max_iters = max_iters or 2 * ls
    W = index.graph.shape[1]
    h2 = min(hop2_per_nbr, W)
    key = ("acorn", "default", "f32", k, ls, max_iters, filt.kind, h2)

    def make():
        def run(graph, xb, xb_norm, attr, q, filt, entry):
            def expand(p):
                one = jnp.take(graph, p, axis=0)               # [B, W]
                two = jnp.take(graph, jnp.maximum(one, 0), axis=0)[..., :h2]
                two = jnp.where((one >= 0)[:, :, None], two, -1)
                return jnp.concatenate([one, two.reshape(one.shape[0], -1)],
                                       axis=1)
            return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                 hard_filter_key_fn(filt), ls=ls, k=k,
                                 max_iters=max_iters, expand_fn=expand)
        return run
    res = index.executor.run(key, make, index.graph, index.xb,
                             index.xb_norm, index.attr,
                             jnp.asarray(queries), filt, index.entry)
    ok = res.primary == 0.0
    return SearchResult(jnp.where(ok, res.ids, -1),
                        jnp.where(ok, 0.0, INF), res.secondary,
                        res.vlog, res.n_expanded, res.n_dist)


# ---------------------------------------------------------------------------
# RWalks-flavored: random-walk attribute diffusion
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RWalksIndex:
    base: JAGIndex
    agg: AttrTable          # aggregated (diffused) attributes
    h: float                # weight of the filter-distance term


def build_rwalks(xb, attr: AttrTable, cfg: JAGConfig, m: int = 5,
                 depth: int = 3, h: float = 0.1, seed: int = 0,
                 index: Optional[JAGIndex] = None) -> RWalksIndex:
    """m random walks of length `depth` aggregate attributes per node."""
    base = index if index is not None else build_unfiltered(xb, attr, cfg)
    graph = base.graph
    N, W = graph.shape
    rng = np.random.default_rng(seed)
    cur = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[:, None],
                           (N, m))

    def agg_init():
        if attr.kind == LABEL:
            L = int(np.asarray(attr.data["label"]).max()) + 1
            bits = jax.nn.one_hot(attr.data["label"], L, dtype=jnp.uint32)
            return {"bits": pack_bits(bits)}, L
        if attr.kind == RANGE:
            v = attr.data["value"]
            return {"lo": v, "hi": v}, 0
        if attr.kind == SUBSET:
            return {"bits": attr.data["bits"]}, attr.n_bits
        if attr.kind == BOOLEAN:  # diffuse assignments as a seen-set OR
            return {"assign": attr.data["assign"]}, attr.n_bits
        raise ValueError(attr.kind)

    agg, L = agg_init()
    for step in range(depth):
        r = jnp.asarray(rng.integers(0, W, (N, m)), jnp.int32)
        nxt = graph[cur, r]
        cur = jnp.where(nxt >= 0, nxt, cur)
        cc = jnp.maximum(cur, 0)
        if attr.kind == RANGE:
            v = jnp.take(attr.data["value"], cc)
            agg = {"lo": jnp.minimum(agg["lo"], jnp.min(v, axis=1)),
                   "hi": jnp.maximum(agg["hi"], jnp.max(v, axis=1))}
        elif attr.kind in (LABEL, SUBSET):
            src = (pack_bits(jax.nn.one_hot(
                jnp.take(attr.data["label"], cc), L, dtype=jnp.uint32))
                if attr.kind == LABEL else
                jnp.take(attr.data["bits"], cc, axis=0))
            acc = agg["bits"]
            for j in range(m):
                acc = acc | src[:, j]
            agg = {"bits": acc}
        # BOOLEAN: keep own assignment (diffusion undefined for predicates)
    kind = SUBSET if attr.kind in (LABEL, SUBSET) else attr.kind
    agg_table = AttrTable(kind, agg, n_bits=L or attr.n_bits)
    return RWalksIndex(base, agg_table, h)


def _rwalks_dist_f(filt: FilterBatch, agg_kind: str,
                   attrs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    if filt.kind == LABEL:   # agg is a label bitset; f passes if label seen
        lab = filt.data["label"][:, None]
        word = lab // 32
        bit = (lab % 32).astype(jnp.uint32)
        w = jnp.take_along_axis(attrs["bits"], word[..., None], axis=-1)
        return ((w[..., 0] >> bit) & 1 == 0).astype(jnp.float32)
    if filt.kind == RANGE:   # gap between query range and node interval
        lo = filt.data["lo"][:, None]
        hi = filt.data["hi"][:, None]
        return (jnp.maximum(lo - attrs["hi"], 0.0)
                + jnp.maximum(attrs["lo"] - hi, 0.0))
    return dist_f(filt, attrs)


def rwalks_search(rw: RWalksIndex, queries, filt: FilterBatch, k: int = 10,
                  ls: int = 64, max_iters: int = 0) -> SearchResult:
    max_iters = max_iters or 2 * ls
    base = rw.base
    # k only shapes the eager post-validation slice below, not the traced
    # traversal (which keeps the full ls beam) — so it stays out of the key
    key = ("rwalks", "default", "f32", 0, ls, max_iters, filt.kind,
           rw.agg.kind)

    def make():
        def run(graph, xb, xb_norm, attr, agg, h, q, filt, entry):
            def key_fn(ids, _attrs, d2):
                ag = agg.gather(ids)
                return (h * _rwalks_dist_f(filt, agg.kind, ag)
                        + jnp.sqrt(d2), d2)
            return greedy_search(graph, xb, xb_norm, attr, q, entry, key_fn,
                                 ls=ls, k=ls, max_iters=max_iters)
        return run
    res = base.executor.run(key, make, base.graph, base.xb, base.xb_norm,
                            base.attr, rw.agg, jnp.float32(rw.h),
                            jnp.asarray(queries), filt, base.entry)
    # post-validate: keep exact matches only, re-ranked by vector distance
    ids = res.ids
    ok = matches(filt, base.attr.gather(jnp.maximum(ids, 0))) & (ids >= 0)
    prim = jnp.where(ok, 0.0, INF)
    sec = jnp.where(ok, res.secondary, INF)
    idsm = jnp.where(ok, ids, -1)
    prim, sec, idsm = jax.lax.sort((prim, sec, idsm), num_keys=2)
    return SearchResult(idsm[:, :k], prim[:, :k], sec[:, :k], res.vlog,
                        res.n_expanded, res.n_dist)


# ---------------------------------------------------------------------------
# StitchedVamana-flavored (label filters)
# ---------------------------------------------------------------------------

class StitchedLabelIndex:
    """One pure-vector subgraph per label; queries routed by label."""

    def __init__(self, xb, attr: AttrTable, cfg: JAGConfig):
        assert attr.kind == LABEL
        labels = np.asarray(attr.data["label"])
        self.sub: Dict[int, tuple] = {}
        for lab in np.unique(labels):
            ids = np.flatnonzero(labels == lab)
            sub_attr = AttrTable(LABEL,
                                 {"label": jnp.asarray(labels[ids])})
            c = dataclasses.replace(
                cfg, mode="threshold", threshold_quantiles=(1.0,),
                batch_size=min(cfg.batch_size, max(8, len(ids) // 4)))
            idx = JAGIndex.build(jnp.asarray(xb)[ids], sub_attr, c)
            self.sub[int(lab)] = (idx, jnp.asarray(ids, jnp.int32))

    def search(self, queries, filt: FilterBatch, k=10, ls=64):
        """Route each query to its label subgraph (grouped by label)."""
        qlab = np.asarray(filt.data["label"])
        B = qlab.shape[0]
        ids = np.full((B, k), -1, np.int32)
        d2 = np.full((B, k), np.inf, np.float32)
        ndist = np.zeros((B,), np.int32)
        for lab, (idx, gids) in self.sub.items():
            sel = np.flatnonzero(qlab == lab)
            if sel.size == 0:
                continue
            res = idx.search_unfiltered(jnp.asarray(queries)[sel], k=k, ls=ls)
            rid = np.asarray(res.ids)
            ids[sel] = np.where(rid >= 0, np.asarray(gids)[rid], -1)
            d2[sel] = np.asarray(res.secondary)
            ndist[sel] = np.asarray(res.n_dist)
        prim = np.where(ids >= 0, 0.0, np.inf).astype(np.float32)
        return SearchResult(jnp.asarray(ids), jnp.asarray(prim),
                            jnp.asarray(d2), None, None, jnp.asarray(ndist))
