"""JAG core: the paper's contribution as a composable JAX module."""
from .filters import (AttrTable, FilterBatch, LABEL, RANGE, SUBSET, BOOLEAN,
                      label_table, range_table, subset_table, boolean_table,
                      label_filters, range_filters, subset_filters,
                      boolean_filters, matches, matches_all, selectivity,
                      pack_bits, unpack_bits,
                      And, Boolean, FilterExpr, Label, Leaf, Not, Or, Range,
                      Subset, as_filter, describe, filter_batch, joint_table,
                      matches_counted, matches_rows, n_leaves)
from .distances import dist_a, dist_f, capped, sq_norms
from .beam_search import greedy_search, SearchResult
from .build import BuildConfig, build_graph, medoid
from .prune import joint_robust_prune
from .jag import JAGConfig, JAGIndex
