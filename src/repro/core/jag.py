"""Public JAG index API: Threshold-JAG (default) and Weight-JAG (§3.3, §3.4).

Thresholds/weights are specified as *quantiles* of the empirical dist_A
distribution (paper D.3: sample |V|=500 points, take quantiles from
{100%, 10%, 1%, 0.1%, 0%}) and calibrated to absolute values at build time.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .beam_search import SearchResult, greedy_search
from .build import BuildConfig, build_graph
from .distances import dist_a, query_key_fn, sq_norms, unfiltered_key_fn
from .filters import AttrTable, FilterBatch


@dataclasses.dataclass(frozen=True)
class JAGConfig:
    degree: int = 32
    ls_build: int = 64
    alpha: float = 1.2
    mode: str = "threshold"                    # "threshold" | "weight"
    # quantiles of dist_A; 1.0 -> pure-vector edges, 0.0 -> strict-attribute
    threshold_quantiles: Tuple[float, ...] = (1.0, 0.01, 0.0)
    # weight multipliers of h = sigma_vec / sigma_attr (paper D.3)
    weight_scales: Tuple[float, ...] = (0.0, 1.0)
    batch_size: int = 128
    cand_pool: int = 192
    calib_samples: int = 512
    seed: int = 0
    ex_slots: int = 16
    ov_max: int = 256
    n_seeds: int = 8                           # multi-seed beam init


def calibrate_thresholds(attr: AttrTable, quantiles: Sequence[float],
                         n_samples: int, seed: int) -> Tuple[float, ...]:
    """Absolute dist_A caps at the requested quantiles (paper D.3)."""
    rng = np.random.default_rng(seed)
    n = attr.n
    ia = jnp.asarray(rng.integers(0, n, n_samples), jnp.int32)
    ib = jnp.asarray(rng.integers(0, n, (n_samples, 64)), jnp.int32)
    da = dist_a(attr.kind, attr.gather(ia), attr.gather(ib))
    da = np.asarray(da).reshape(-1)
    out = []
    for q in quantiles:
        if q >= 1.0:
            out.append(float(da.max()) + 1.0)  # cap above max -> pure vector
        else:
            out.append(float(np.quantile(da, q)))
    return tuple(out)


def calibrate_weight_unit(xb, attr: AttrTable, n_samples: int,
                          seed: int) -> float:
    """h = sigma(dist_vec) / sigma(dist_A) over sampled pairs (paper D.3)."""
    rng = np.random.default_rng(seed)
    n = attr.n
    ia = jnp.asarray(rng.integers(0, n, n_samples), jnp.int32)
    ib = jnp.asarray(rng.integers(0, n, (n_samples, 16)), jnp.int32)
    da = np.asarray(dist_a(attr.kind, attr.gather(ia), attr.gather(ib)))
    va = np.asarray(jnp.take(xb, ia, axis=0), dtype=np.float32)
    vb = np.asarray(jnp.take(xb, ib.reshape(-1), axis=0),
                    dtype=np.float32).reshape(n_samples, 16, -1)
    dv = np.sqrt(np.maximum(
        ((va[:, None, :] - vb) ** 2).sum(-1), 0.0))
    sa = float(np.std(da)) or 1.0
    return float(np.std(dv)) / sa


class JAGIndex:
    """A built Joint Attribute Graph over (vectors, attributes)."""

    def __init__(self, xb, attr: AttrTable, graph, degree, entry,
                 cfg: JAGConfig, build_cfg: BuildConfig):
        self.xb = jnp.asarray(xb)
        self.xb_norm = sq_norms(self.xb)
        self.attr = attr
        self.graph = graph
        self.degree = degree
        self.entry = entry
        self.cfg = cfg
        self.build_cfg = build_cfg
        self._search_jit = {}
        self._fused = {}                     # vec_dtype -> serve.FusedLayout

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, xb, attr: AttrTable, cfg: JAGConfig = JAGConfig(),
              verbose: bool = False) -> "JAGIndex":
        xb = jnp.asarray(xb)
        if cfg.mode == "threshold":
            tvals = calibrate_thresholds(attr, cfg.threshold_quantiles,
                                         cfg.calib_samples, cfg.seed)
            wvals = ()
        else:
            h = calibrate_weight_unit(xb, attr, cfg.calib_samples, cfg.seed)
            wvals = tuple(w * h for w in cfg.weight_scales)
            tvals = ()
        bcfg = BuildConfig(
            degree=cfg.degree, ls_build=cfg.ls_build, alpha=cfg.alpha,
            mode=cfg.mode, thresholds=tvals, weights=wvals,
            batch_size=cfg.batch_size, cand_pool=cfg.cand_pool,
            ex_slots=cfg.ex_slots, ov_max=cfg.ov_max)
        from .build import make_seeds
        seeds = make_seeds(xb, cfg.n_seeds, cfg.seed)
        graph, deg, entry = build_graph(xb, attr, bcfg, seed=cfg.seed,
                                        entry=seeds, verbose=verbose)
        return cls(xb, attr, graph, deg, entry, cfg, bcfg)

    # -- fused serving layout (serve/) --------------------------------------
    def fused_layout(self, vec_dtype: str = "f32"):
        """Build (once) and return the packed [vec|norm|attr] serving layout.

        The f32 layout reproduces the default path's (dist_F, dist_vec) keys
        bit-for-bit from ONE row gather per beam expansion; the int8 layout
        additionally shrinks the vector lanes to int8 codes (query-side scale
        folding). Cached per dtype; persisted by :meth:`save`.
        """
        if vec_dtype not in self._fused:
            from ..serve import build_layout
            self._fused[vec_dtype] = build_layout(self.xb, self.attr,
                                                  vec_dtype=vec_dtype)
        return self._fused[vec_dtype]

    # -- query (Algorithm 2) ------------------------------------------------
    def search(self, queries, filt: FilterBatch, k: int = 10,
               ls: int = 64, max_iters: int = 0,
               layout: str = "default") -> SearchResult:
        """Filtered top-k search under D_F = (dist_F, dist_vec).

        ``layout="fused"`` routes beam expansions through the packed serving
        layout (one gather per expansion via greedy_search's ``fetch_fn``
        hook) and returns identical ids/keys to the default two-gather path.
        """
        if layout not in ("default", "fused"):
            raise ValueError(f"layout must be 'default' or 'fused', "
                             f"got {layout!r}")
        max_iters = max_iters or 2 * ls
        key = ("f", k, ls, max_iters, filt.kind, layout)
        if layout == "fused":
            lay = self.fused_layout("f32")
            if key not in self._search_jit:
                from ..serve import make_fetch_fn

                @jax.jit
                def run(graph, xb, xb_norm, attr, lay, q, filt, entry):
                    return greedy_search(
                        graph, xb, xb_norm, attr, q, entry,
                        query_key_fn(filt), ls=ls, k=k, max_iters=max_iters,
                        fetch_fn=make_fetch_fn(lay))
                self._search_jit[key] = run
            return self._search_jit[key](self.graph, self.xb, self.xb_norm,
                                         self.attr, lay,
                                         jnp.asarray(queries), filt,
                                         self.entry)
        if key not in self._search_jit:
            @jax.jit
            def run(graph, xb, xb_norm, attr, q, filt, entry):
                return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                     query_key_fn(filt), ls=ls, k=k,
                                     max_iters=max_iters)
            self._search_jit[key] = run
        return self._search_jit[key](self.graph, self.xb, self.xb_norm,
                                     self.attr, jnp.asarray(queries), filt,
                                     self.entry)

    def search_int8(self, queries, filt: FilterBatch, k: int = 10,
                    ls: int = 64, max_iters: int = 0,
                    layout: str = "default") -> SearchResult:
        """Quantized traversal + exact re-rank (beyond-paper; §Perf).

        Graph navigation uses the int8 database (4x less HBM pull per beam
        expansion); the beam's survivors are re-ranked with full-precision
        distances so the returned top-k ordering is exact w.r.t. the
        traversed set. ``layout="fused"`` additionally packs
        [int8 vec | norm | attr] so navigation costs ONE gather per
        expansion instead of two (the quantized.py §2 layout, realized in
        serve/layout.py).
        """
        from .quantized import make_int8_dist_fn, quantize_int8, rerank_exact
        if layout not in ("default", "fused"):
            raise ValueError(f"layout must be 'default' or 'fused', "
                             f"got {layout!r}")
        max_iters = max_iters or 2 * ls
        if layout == "fused":
            lay = self.fused_layout("int8")
            key = ("q8-fused", k, ls, max_iters, filt.kind)
            if key not in self._search_jit:
                from ..serve import make_fetch_fn

                @jax.jit
                def run(graph, xb, xb_norm, attr, lay, q, filt, entry):
                    res = greedy_search(
                        graph, xb, xb_norm, attr, q, entry,
                        query_key_fn(filt), ls=ls, k=ls,
                        max_iters=max_iters, fetch_fn=make_fetch_fn(lay))
                    i, p, s = rerank_exact(xb, xb_norm, res.ids,
                                           res.primary, q, k)
                    return SearchResult(i, p, s, res.vlog, res.n_expanded,
                                        res.n_dist)
                self._search_jit[key] = run
            return self._search_jit[key](self.graph, self.xb, self.xb_norm,
                                         self.attr, lay,
                                         jnp.asarray(queries), filt,
                                         self.entry)
        if not hasattr(self, "_q8"):
            xq, scale = quantize_int8(self.xb)
            xq_norm = jnp.sum((xq.astype(jnp.float32) * scale) ** 2, -1)
            self._q8 = (xq, scale, xq_norm)
        xq, scale, xq_norm = self._q8
        key = ("q8", k, ls, max_iters, filt.kind)
        if key not in self._search_jit:
            @jax.jit
            def run(graph, xq, xq_norm, scale, xb, xb_norm, attr, q, filt,
                    entry):
                res = greedy_search(
                    graph, xq, xq_norm, attr, q, entry,
                    query_key_fn(filt), ls=ls, k=ls, max_iters=max_iters,
                    dist_fn=make_int8_dist_fn(scale))
                i, p, s = rerank_exact(xb, xb_norm, res.ids, res.primary,
                                       q, k)
                return SearchResult(i, p, s, res.vlog, res.n_expanded,
                                    res.n_dist)
            self._search_jit[key] = run
        return self._search_jit[key](self.graph, xq, xq_norm, scale,
                                     self.xb, self.xb_norm, self.attr,
                                     jnp.asarray(queries), filt,
                                     self.entry)

    def search_unfiltered(self, queries, k: int = 10, ls: int = 64,
                          max_iters: int = 0) -> SearchResult:
        """Pure vector-distance search (used by post-filtering)."""
        max_iters = max_iters or 2 * ls
        key = ("u", k, ls, max_iters)
        if key not in self._search_jit:
            @jax.jit
            def run(graph, xb, xb_norm, attr, q, entry):
                return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                     unfiltered_key_fn(), ls=ls, k=k,
                                     max_iters=max_iters)
            self._search_jit[key] = run
        return self._search_jit[key](self.graph, self.xb, self.xb_norm,
                                     self.attr, jnp.asarray(queries),
                                     self.entry)

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        """Persist the index; built fused layouts ride along losslessly.

        Packed rows are stored as raw uint32 bit patterns (``packed_bits``)
        because the attr lanes are uint32 payloads bitcast into f32 — a
        value-level f32 round-trip could canonicalize NaNs and corrupt them.
        """
        fused = {}
        for dt, lay in self._fused.items():
            fused[f"fused_{dt}__packed_bits"] = (
                np.asarray(lay.packed).view(np.uint32))
            fused[f"fused_{dt}__q_scale"] = np.asarray(lay.q_scale)
            fused[f"fused_{dt}__bit_weights"] = np.asarray(lay.bit_weights)
        np.savez_compressed(
            path,
            xb=np.asarray(self.xb), graph=np.asarray(self.graph),
            degree=np.asarray(self.degree), entry=np.asarray(self.entry),
            attr_kind=self.attr.kind, attr_nbits=self.attr.n_bits,
            cfg=np.frombuffer(repr(dataclasses.asdict(self.cfg)).encode(),
                              dtype=np.uint8),
            **{f"attr__{k}": np.asarray(v)
               for k, v in self.attr.data.items()},
            **fused)

    @classmethod
    def load(cls, path: str) -> "JAGIndex":
        z = np.load(path, allow_pickle=False)
        import ast
        cfg = JAGConfig(**{
            k: tuple(v) if isinstance(v, list) else v
            for k, v in ast.literal_eval(
                bytes(z["cfg"]).decode()).items()})
        attr = AttrTable(str(z["attr_kind"]),
                         {k[len("attr__"):]: jnp.asarray(v)
                          for k, v in z.items() if k.startswith("attr__")},
                         n_bits=int(z["attr_nbits"]))
        idx = cls(jnp.asarray(z["xb"]), attr, jnp.asarray(z["graph"]),
                  jnp.asarray(z["degree"]), jnp.asarray(z["entry"]),
                  cfg, BuildConfig())
        from ..serve import FusedLayout
        for dt in ("f32", "int8"):
            key = f"fused_{dt}__packed_bits"
            if key in z:
                idx._fused[dt] = FusedLayout(
                    jnp.asarray(z[key].view(np.float32)),
                    jnp.asarray(z[f"fused_{dt}__q_scale"]),
                    jnp.asarray(z[f"fused_{dt}__bit_weights"]),
                    attr.kind, attr.n_bits, int(z["xb"].shape[1]), dt)
        return idx

    # -- stats ---------------------------------------------------------------
    def degree_stats(self):
        d = np.asarray(jnp.sum(self.graph >= 0, axis=1))
        return dict(mean=float(d.mean()), max=int(d.max()),
                    min=int(d.min()),
                    over_budget=int((d > self.cfg.degree).sum()))
