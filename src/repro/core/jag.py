"""Public JAG index API: Threshold-JAG (default) and Weight-JAG (§3.3, §3.4).

Thresholds/weights are specified as *quantiles* of the empirical dist_A
distribution (paper D.3: sample |V|=500 points, take quantiles from
{100%, 10%, 1%, 0.1%, 0%}) and calibrated to absolute values at build time.
"""
from __future__ import annotations

import dataclasses
import io
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .beam_search import SearchResult, greedy_search
from .build import BuildConfig, build_graph, medoid
from .distances import dist_a, query_key_fn, sq_norms, unfiltered_key_fn
from .filters import AttrTable, FilterBatch


@dataclasses.dataclass(frozen=True)
class JAGConfig:
    degree: int = 32
    ls_build: int = 64
    alpha: float = 1.2
    mode: str = "threshold"                    # "threshold" | "weight"
    # quantiles of dist_A; 1.0 -> pure-vector edges, 0.0 -> strict-attribute
    threshold_quantiles: Tuple[float, ...] = (1.0, 0.01, 0.0)
    # weight multipliers of h = sigma_vec / sigma_attr (paper D.3)
    weight_scales: Tuple[float, ...] = (0.0, 1.0)
    batch_size: int = 128
    cand_pool: int = 192
    calib_samples: int = 512
    seed: int = 0
    ex_slots: int = 16
    ov_max: int = 256
    n_seeds: int = 8                           # multi-seed beam init


def calibrate_thresholds(attr: AttrTable, quantiles: Sequence[float],
                         n_samples: int, seed: int) -> Tuple[float, ...]:
    """Absolute dist_A caps at the requested quantiles (paper D.3)."""
    rng = np.random.default_rng(seed)
    n = attr.n
    ia = jnp.asarray(rng.integers(0, n, n_samples), jnp.int32)
    ib = jnp.asarray(rng.integers(0, n, (n_samples, 64)), jnp.int32)
    da = dist_a(attr.kind, attr.gather(ia), attr.gather(ib))
    da = np.asarray(da).reshape(-1)
    out = []
    for q in quantiles:
        if q >= 1.0:
            out.append(float(da.max()) + 1.0)  # cap above max -> pure vector
        else:
            out.append(float(np.quantile(da, q)))
    return tuple(out)


def calibrate_weight_unit(xb, attr: AttrTable, n_samples: int,
                          seed: int) -> float:
    """h = sigma(dist_vec) / sigma(dist_A) over sampled pairs (paper D.3)."""
    rng = np.random.default_rng(seed)
    n = attr.n
    ia = jnp.asarray(rng.integers(0, n, n_samples), jnp.int32)
    ib = jnp.asarray(rng.integers(0, n, (n_samples, 16)), jnp.int32)
    da = np.asarray(dist_a(attr.kind, attr.gather(ia), attr.gather(ib)))
    va = np.asarray(jnp.take(xb, ia, axis=0), dtype=np.float32)
    vb = np.asarray(jnp.take(xb, ib.reshape(-1), axis=0),
                    dtype=np.float32).reshape(n_samples, 16, -1)
    dv = np.sqrt(np.maximum(
        ((va[:, None, :] - vb) ** 2).sum(-1), 0.0))
    sa = float(np.std(da)) or 1.0
    return float(np.std(dv)) / sa


class JAGIndex:
    """A built Joint Attribute Graph over (vectors, attributes)."""

    def __init__(self, xb, attr: AttrTable, graph, degree, entry,
                 cfg: JAGConfig, build_cfg: BuildConfig):
        self.xb = jnp.asarray(xb)
        self.xb_norm = sq_norms(self.xb)
        self.attr = attr
        self.graph = graph
        self.degree = degree
        self.entry = entry
        self.cfg = cfg
        self.build_cfg = build_cfg
        self._search_jit = {}

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, xb, attr: AttrTable, cfg: JAGConfig = JAGConfig(),
              verbose: bool = False) -> "JAGIndex":
        xb = jnp.asarray(xb)
        if cfg.mode == "threshold":
            tvals = calibrate_thresholds(attr, cfg.threshold_quantiles,
                                         cfg.calib_samples, cfg.seed)
            wvals = ()
        else:
            h = calibrate_weight_unit(xb, attr, cfg.calib_samples, cfg.seed)
            wvals = tuple(w * h for w in cfg.weight_scales)
            tvals = ()
        bcfg = BuildConfig(
            degree=cfg.degree, ls_build=cfg.ls_build, alpha=cfg.alpha,
            mode=cfg.mode, thresholds=tvals, weights=wvals,
            batch_size=cfg.batch_size, cand_pool=cfg.cand_pool,
            ex_slots=cfg.ex_slots, ov_max=cfg.ov_max)
        from .build import make_seeds
        seeds = make_seeds(xb, cfg.n_seeds, cfg.seed)
        graph, deg, entry = build_graph(xb, attr, bcfg, seed=cfg.seed,
                                        entry=seeds, verbose=verbose)
        return cls(xb, attr, graph, deg, entry, cfg, bcfg)

    # -- query (Algorithm 2) ------------------------------------------------
    def search(self, queries, filt: FilterBatch, k: int = 10,
               ls: int = 64, max_iters: int = 0) -> SearchResult:
        """Filtered top-k search under D_F = (dist_F, dist_vec)."""
        max_iters = max_iters or 2 * ls
        key = ("f", k, ls, max_iters, filt.kind)
        if key not in self._search_jit:
            @jax.jit
            def run(graph, xb, xb_norm, attr, q, filt, entry):
                return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                     query_key_fn(filt), ls=ls, k=k,
                                     max_iters=max_iters)
            self._search_jit[key] = run
        return self._search_jit[key](self.graph, self.xb, self.xb_norm,
                                     self.attr, jnp.asarray(queries), filt,
                                     self.entry)

    def search_int8(self, queries, filt: FilterBatch, k: int = 10,
                    ls: int = 64, max_iters: int = 0) -> SearchResult:
        """Quantized traversal + exact re-rank (beyond-paper; §Perf).

        Graph navigation uses the int8 database (4x less HBM pull per beam
        expansion); the beam's survivors are re-ranked with full-precision
        distances so the returned top-k ordering is exact w.r.t. the
        traversed set.
        """
        from .quantized import make_int8_dist_fn, quantize_int8, rerank_exact
        max_iters = max_iters or 2 * ls
        if not hasattr(self, "_q8"):
            xq, scale = quantize_int8(self.xb)
            xq_norm = jnp.sum((xq.astype(jnp.float32) * scale) ** 2, -1)
            self._q8 = (xq, scale, xq_norm)
        xq, scale, xq_norm = self._q8
        key = ("q8", k, ls, max_iters, filt.kind)
        if key not in self._search_jit:
            @jax.jit
            def run(graph, xq, xq_norm, scale, xb, xb_norm, attr, q, filt,
                    entry):
                res = greedy_search(
                    graph, xq, xq_norm, attr, q, entry,
                    query_key_fn(filt), ls=ls, k=ls, max_iters=max_iters,
                    dist_fn=make_int8_dist_fn(scale))
                i, p, s = rerank_exact(xb, xb_norm, res.ids, res.primary,
                                       q, k)
                return SearchResult(i, p, s, res.vlog, res.n_expanded,
                                    res.n_dist)
            self._search_jit[key] = run
        return self._search_jit[key](self.graph, xq, xq_norm, scale,
                                     self.xb, self.xb_norm, self.attr,
                                     jnp.asarray(queries), filt,
                                     self.entry)

    def search_unfiltered(self, queries, k: int = 10, ls: int = 64,
                          max_iters: int = 0) -> SearchResult:
        """Pure vector-distance search (used by post-filtering)."""
        max_iters = max_iters or 2 * ls
        key = ("u", k, ls, max_iters)
        if key not in self._search_jit:
            @jax.jit
            def run(graph, xb, xb_norm, attr, q, entry):
                return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                     unfiltered_key_fn(), ls=ls, k=k,
                                     max_iters=max_iters)
            self._search_jit[key] = run
        return self._search_jit[key](self.graph, self.xb, self.xb_norm,
                                     self.attr, jnp.asarray(queries),
                                     self.entry)

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            xb=np.asarray(self.xb), graph=np.asarray(self.graph),
            degree=np.asarray(self.degree), entry=np.asarray(self.entry),
            attr_kind=self.attr.kind, attr_nbits=self.attr.n_bits,
            cfg=np.frombuffer(repr(dataclasses.asdict(self.cfg)).encode(),
                              dtype=np.uint8),
            **{f"attr__{k}": np.asarray(v) for k, v in self.attr.data.items()})

    @classmethod
    def load(cls, path: str) -> "JAGIndex":
        z = np.load(path, allow_pickle=False)
        import ast
        cfg = JAGConfig(**{
            k: tuple(v) if isinstance(v, list) else v
            for k, v in ast.literal_eval(
                bytes(z["cfg"]).decode()).items()})
        attr = AttrTable(str(z["attr_kind"]),
                         {k[len("attr__"):]: jnp.asarray(v)
                          for k, v in z.items() if k.startswith("attr__")},
                         n_bits=int(z["attr_nbits"]))
        return cls(jnp.asarray(z["xb"]), attr, jnp.asarray(z["graph"]),
                   jnp.asarray(z["degree"]), jnp.asarray(z["entry"]),
                   cfg, BuildConfig())

    # -- stats ---------------------------------------------------------------
    def degree_stats(self):
        d = np.asarray(jnp.sum(self.graph >= 0, axis=1))
        return dict(mean=float(d.mean()), max=int(d.max()),
                    min=int(d.min()),
                    over_budget=int((d > self.cfg.degree).sum()))
