"""Public JAG index API: Threshold-JAG (default) and Weight-JAG (§3.3, §3.4).

Thresholds/weights are specified as *quantiles* of the empirical dist_A
distribution (paper D.3: sample |V|=500 points, take quantiles from
{100%, 10%, 1%, 0.1%, 0%}) and calibrated to absolute values at build time.

Query execution is delegated to the serving pipeline: every ``search*``
entry point is a thin shim over ``serve.Executor`` (the single
jit-compilation cache — this module contains no ``jax.jit`` of its own),
and ``search_auto`` adds the selectivity-adaptive routing on top
(``serve.planner``: prefilter | graph | postfilter, banded per query and
dispatched as route-group sub-batches by ``serve.dispatch``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .beam_search import SearchResult
from .build import BuildConfig, build_graph
from .distances import dist_a, sq_norms
from .filters import AttrTable, as_filter


@dataclasses.dataclass(frozen=True)
class JAGConfig:
    degree: int = 32
    ls_build: int = 64
    alpha: float = 1.2
    mode: str = "threshold"                    # "threshold" | "weight"
    # quantiles of dist_A; 1.0 -> pure-vector edges, 0.0 -> strict-attribute
    threshold_quantiles: Tuple[float, ...] = (1.0, 0.01, 0.0)
    # weight multipliers of h = sigma_vec / sigma_attr (paper D.3)
    weight_scales: Tuple[float, ...] = (0.0, 1.0)
    batch_size: int = 128
    cand_pool: int = 192
    calib_samples: int = 512
    seed: int = 0
    ex_slots: int = 16
    ov_max: int = 256
    n_seeds: int = 8                           # multi-seed beam init


def calibrate_thresholds(attr: AttrTable, quantiles: Sequence[float],
                         n_samples: int, seed: int) -> Tuple[float, ...]:
    """Absolute dist_A caps at the requested quantiles (paper D.3)."""
    rng = np.random.default_rng(seed)
    n = attr.n
    ia = jnp.asarray(rng.integers(0, n, n_samples), jnp.int32)
    ib = jnp.asarray(rng.integers(0, n, (n_samples, 64)), jnp.int32)
    da = dist_a(attr.kind, attr.gather(ia), attr.gather(ib))
    da = np.asarray(da).reshape(-1)
    out = []
    for q in quantiles:
        if q >= 1.0:
            out.append(float(da.max()) + 1.0)  # cap above max -> pure vector
        else:
            out.append(float(np.quantile(da, q)))
    return tuple(out)


def calibrate_weight_unit(xb, attr: AttrTable, n_samples: int,
                          seed: int) -> float:
    """h = sigma(dist_vec) / sigma(dist_A) over sampled pairs (paper D.3)."""
    rng = np.random.default_rng(seed)
    n = attr.n
    ia = jnp.asarray(rng.integers(0, n, n_samples), jnp.int32)
    ib = jnp.asarray(rng.integers(0, n, (n_samples, 16)), jnp.int32)
    da = np.asarray(dist_a(attr.kind, attr.gather(ia), attr.gather(ib)))
    va = np.asarray(jnp.take(xb, ia, axis=0), dtype=np.float32)
    vb = np.asarray(jnp.take(xb, ib.reshape(-1), axis=0),
                    dtype=np.float32).reshape(n_samples, 16, -1)
    dv = np.sqrt(np.maximum(
        ((va[:, None, :] - vb) ** 2).sum(-1), 0.0))
    sa = float(np.std(da)) or 1.0
    return float(np.std(dv)) / sa


def _encode_cfg(dc) -> np.ndarray:
    """Dataclass -> uint8 repr buffer (npz-safe, allow_pickle=False)."""
    return np.frombuffer(repr(dataclasses.asdict(dc)).encode(), np.uint8)


def _decode_cfg(buf) -> dict:
    """Inverse of :func:`_encode_cfg`.

    ``repr(float('inf'))`` is ``'inf'`` which ``ast.literal_eval`` rejects;
    rewriting the bare token to the overflowing literal ``2e308`` round-trips
    it. Word-bounded, so names/values merely *containing* 'inf' are safe —
    but a string value holding 'inf' as a standalone word would still be
    rewritten: don't introduce one into JAGConfig/BuildConfig.
    """
    import ast
    import re
    txt = re.sub(r"\binf\b", "2e308", bytes(buf).decode())
    return {k: tuple(v) if isinstance(v, list) else v
            for k, v in ast.literal_eval(txt).items()}


class JAGIndex:
    """A built Joint Attribute Graph over (vectors, attributes)."""

    # Data epoch of a frozen index: never changes. The streaming layer
    # (repro.stream.StreamingJAGIndex) shadows this with a live counter so
    # the executor's epoch-aware caches invalidate as the index grows.
    epoch: int = 0

    def __init__(self, xb, attr: AttrTable, graph, degree, entry,
                 cfg: JAGConfig, build_cfg: BuildConfig):
        self.xb = jnp.asarray(xb)
        self.xb_norm = sq_norms(self.xb)
        self.attr = attr
        self.graph = graph
        self.degree = degree
        self.entry = entry
        self.cfg = cfg
        self.build_cfg = build_cfg
        self._executor = None                # serve.Executor, built lazily
        self._fused = {}                     # vec_dtype -> serve.FusedLayout
        self._q8 = None                      # (codes, scale, norms) cache
        self.cost_model = None               # repro.cost.CostModel | None
        self.cost_metric = "us"              # routing objective: us | n_dist
        self.telemetry = None                # repro.obs.Telemetry | None

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, xb, attr: AttrTable, cfg: JAGConfig = JAGConfig(),
              verbose: bool = False) -> "JAGIndex":
        xb = jnp.asarray(xb)
        if cfg.mode == "threshold":
            tvals = calibrate_thresholds(attr, cfg.threshold_quantiles,
                                         cfg.calib_samples, cfg.seed)
            wvals = ()
        else:
            h = calibrate_weight_unit(xb, attr, cfg.calib_samples, cfg.seed)
            wvals = tuple(w * h for w in cfg.weight_scales)
            tvals = ()
        bcfg = BuildConfig(
            degree=cfg.degree, ls_build=cfg.ls_build, alpha=cfg.alpha,
            mode=cfg.mode, thresholds=tvals, weights=wvals,
            batch_size=cfg.batch_size, cand_pool=cfg.cand_pool,
            ex_slots=cfg.ex_slots, ov_max=cfg.ov_max)
        from .build import make_seeds
        seeds = make_seeds(xb, cfg.n_seeds, cfg.seed)
        graph, deg, entry = build_graph(xb, attr, bcfg, seed=cfg.seed,
                                        entry=seeds, verbose=verbose)
        return cls(xb, attr, graph, deg, entry, cfg, bcfg)

    # -- serving state (serve/) ---------------------------------------------
    @property
    def executor(self):
        """The index's ``serve.Executor`` — the one jit cache every search
        entry point (and the baselines) compiles through."""
        if self._executor is None:
            from ..serve.executor import Executor
            self._executor = Executor(self)
        return self._executor

    def fused_layout(self, vec_dtype: str = "f32"):
        """Build (once) and return the packed [vec|norm|attr] serving layout.

        The f32 layout reproduces the default path's (dist_F, dist_vec) keys
        bit-for-bit from ONE row gather per beam expansion; the int8 layout
        additionally shrinks the vector lanes to int8 codes (query-side scale
        folding). Cached per dtype; persisted by :meth:`save`.
        """
        if vec_dtype not in self._fused:
            from ..serve import build_layout
            self._fused[vec_dtype] = build_layout(self.xb, self.attr,
                                                  vec_dtype=vec_dtype)
        return self._fused[vec_dtype]

    def quantized(self):
        """(codes int8 [N,d], scale f32 [d], dequantized norms f32 [N]).

        Computed once and cached; persisted by :meth:`save` so a loaded
        index never re-quantizes the database.
        """
        if self._q8 is None:
            from .quantized import quantize_int8
            xq, scale = quantize_int8(self.xb)
            xq_norm = jnp.sum((xq.astype(jnp.float32) * scale) ** 2, -1)
            self._q8 = (xq, scale, xq_norm)
        return self._q8

    def attach_cost_model(self, model, metric: str = "us") -> None:
        """Attach (or detach, with None) a calibrated ``repro.cost``
        CostModel: ``search_auto`` then routes on the argmin of predicted
        per-route cost instead of the static thresholds, and :meth:`save`
        persists the model inside the archive. Purely a routing-policy
        change — each route's results are unchanged.

        ``metric`` picks the routing objective: ``"us"`` (measured wall
        time — the serving default) or ``"n_dist"`` (the paper's
        hardware-independent distance-computation metric, deterministic
        per route and therefore what benchmarks compare on).
        """
        from ..cost.model import METRICS
        if metric not in METRICS:
            raise ValueError(f"metric must be one of {METRICS}, "
                             f"got {metric!r}")
        self.cost_model = model
        self.cost_metric = metric

    def attach_telemetry(self, telemetry=...):
        """Attach (or detach, with None) a ``repro.obs.Telemetry``.

        With telemetry attached, every :meth:`search_auto` call records
        one per-query :class:`~repro.obs.trace.TraceRecord` (band,
        realized route, selectivity, predicted costs, wall-clock us,
        n_dist/n_expanded) into the telemetry's ring buffer and ticks its
        route counters/latency histograms; the executor additionally
        reports jit-cache misses and epoch rolls. All of it happens on
        the host after routes return — compiled programs are unchanged
        (the audit runs with telemetry attached to prove it).

        Called with no argument a default ``Telemetry()`` is created.
        Returns the attached telemetry (None on detach) so
        ``tel = index.attach_telemetry()`` reads naturally.
        """
        if telemetry is ...:
            from ..obs import Telemetry
            telemetry = Telemetry()
        self.telemetry = telemetry
        ex = self.executor
        ex.miss_hook = None if telemetry is None else telemetry.on_executor_miss
        ex.roll_hook = None if telemetry is None else telemetry.on_epoch_roll
        return telemetry

    # -- query (Algorithm 2) ------------------------------------------------
    def search(self, queries, filt, k: int = 10,
               ls: int = 64, max_iters: int = 0,
               layout: str = "default") -> SearchResult:
        """Filtered top-k search under D_F = (dist_F, dist_vec).

        ``filt`` is a filter expression (``Label``/``Range``/``Subset``/
        ``Boolean`` leaves combined with ``&``/``|``/``~``) or a raw
        per-kind ``FilterBatch``; a single-leaf expression normalizes to
        the atomic path bit-identically. ``layout="fused"`` routes beam
        expansions through the packed serving layout (one gather per
        expansion via greedy_search's ``fetch_fn`` hook) and returns
        identical ids/keys to the default two-gather path.
        """
        return self.executor.graph(queries, as_filter(filt), k=k, ls=ls,
                                   max_iters=max_iters or 2 * ls,
                                   layout=layout, dtype="f32")

    def search_int8(self, queries, filt, k: int = 10,
                    ls: int = 64, max_iters: int = 0,
                    layout: str = "default") -> SearchResult:
        """Quantized traversal + exact re-rank (beyond-paper; §Perf).

        Graph navigation uses the int8 database (4x less HBM pull per beam
        expansion); the beam's survivors are re-ranked with full-precision
        distances so the returned top-k ordering is exact w.r.t. the
        traversed set. ``layout="fused"`` additionally packs
        [int8 vec | norm | attr] so navigation costs ONE gather per
        expansion instead of two.
        """
        return self.executor.graph(queries, as_filter(filt), k=k, ls=ls,
                                   max_iters=max_iters or 2 * ls,
                                   layout=layout, dtype="int8")

    def search_unfiltered(self, queries, k: int = 10, ls: int = 64,
                          max_iters: int = 0) -> SearchResult:
        """Pure vector-distance search (used by post-filtering)."""
        return self.executor.unfiltered(queries, k=k, ls=ls,
                                        max_iters=max_iters or 2 * ls)

    def search_auto(self, queries, filt, k: int = 10,
                    ls: int = 64, max_iters: int = 0,
                    planner=None, return_plan: bool = False,
                    mode: str = "per_query", layout: str = "default",
                    dtype: str = "f32"):
        """Selectivity-adaptive search: plan route(s), then execute.

        A sampled ``matches()`` probe estimates filter selectivity and
        routes to the executor's prefilter (masked exact scan), graph
        (JAG traversal), or postfilter (unfiltered + oversample) route —
        see ``serve/planner.py``.

        ``mode="per_query"`` (default) bands each query individually and
        dispatches every route group as its own contiguous sub-batch
        (``serve/dispatch.py``), scattering results back into original
        query order — a mixed-selectivity batch no longer rides the median
        query's route. ``mode="batch"`` keeps the whole-batch median
        routing. ``layout``/``dtype`` select the graph route's serving
        variant (packed fused rows and/or int8 lanes) in either mode.
        ``planner`` overrides the ``PlannerConfig`` thresholds;
        ``return_plan=True`` returns ``(result, plan)`` — a ``PerQueryPlan``
        reporting the per-group decisions, or a whole-batch ``Plan``;
        either plan's ``realized`` field carries the route variant that
        actually executed (e.g. ``graph[fused,int8]``; the streaming
        subclass appends ``+delta`` when the delta segment was merged).

        When a calibrated cost model is attached
        (:meth:`attach_cost_model`), routing decisions come from the
        argmin of predicted per-route cost (``Executor.cost_router``)
        instead of the thresholds; with no model the static behavior is
        reproduced exactly. An explicit ``planner=`` override always wins
        over the cost model — forced-route configs stay forced.
        """
        from ..serve.dispatch import (dispatch_per_query, route_descriptor,
                                      run_route)
        from ..serve.planner import (GroupPlan, PlannerConfig, plan as _plan,
                                     plan_per_query)
        filt = as_filter(filt)
        cfg = planner or PlannerConfig()
        mi = max_iters or 2 * ls
        # an explicit planner= override is an explicit routing instruction
        # (e.g. prefilter_max_sel=1.1 forcing the exact scan everywhere) —
        # an attached cost model must never shadow it
        router = (None if planner is not None
                  else self.executor.cost_router(k=k, ls=ls, filt=filt))
        tel = getattr(self, "telemetry", None)
        if tel is not None and not tel.enabled:
            tel = None
        # telemetry tap: dispatch blocks on each group and hands back
        # (group, result, traversal stats, wall seconds) — all host-side,
        # post-execution. introspect serves graph groups through the
        # executor's introspective compilation (bit-identical results,
        # extra device-side counters); spans time the host pipeline.
        timed = [] if tel is not None else None
        on_group = (None if timed is None
                    else lambda g, r, st, s: timed.append((g, r, st, s)))
        introspect = bool(getattr(tel, "introspect", False))
        spans = getattr(tel, "spans", None)

        def _span(name, **kw):
            from contextlib import nullcontext
            return nullcontext() if spans is None else spans.span(name, **kw)

        with _span("search_auto", mode=mode,
                   batch=int(np.shape(queries)[0])):
            if mode == "per_query":
                with _span("plan"):
                    p = plan_per_query(filt, self.attr, cfg,
                                       executor=self.executor, router=router)
                res = dispatch_per_query(self.executor, queries, filt, p,
                                         k=k, ls=ls, max_iters=mi,
                                         layout=layout, dtype=dtype,
                                         on_group=on_group,
                                         introspect=introspect, spans=spans)
                p = p._replace(realized=tuple(
                    route_descriptor(r, layout, dtype) for r in p.routes))
            elif mode == "batch":
                with _span("plan"):
                    p = _plan(filt, self.attr, cfg, executor=self.executor,
                              router=router)
                with _span(f"execute:{p.route}",
                           queries=int(np.shape(queries)[0])):
                    if timed is None:
                        res = run_route(self.executor, p.route, queries,
                                        filt, k=k, ls=ls, max_iters=mi,
                                        layout=layout, dtype=dtype)
                    else:
                        t0 = time.perf_counter()
                        out = run_route(self.executor, p.route, queries,
                                        filt, k=k, ls=ls, max_iters=mi,
                                        layout=layout, dtype=dtype,
                                        introspect=introspect)
                        res, stats = out if introspect else (out, None)
                        res = jax.block_until_ready(res)
                        ids = np.arange(np.asarray(p.selectivity).size,
                                        dtype=np.int32)
                        timed.append(
                            (GroupPlan(p.route, ids, p.batch_selectivity),
                             res, stats, time.perf_counter() - t0))
                p = p._replace(
                    realized=route_descriptor(p.route, layout, dtype))
            else:
                raise ValueError(f"mode must be 'per_query' or 'batch', "
                                 f"got {mode!r}")
        if timed:
            tel.record_call(
                self, p,
                [(g.route, route_descriptor(g.route, layout, dtype),
                  g.ids, r, st, s) for (g, r, st, s) in timed],
                k=k, ls=ls, router=router, filt=filt, mode=mode)
            # shadow-oracle audit of the sampled fraction — for a frozen
            # index the served result is final here; a streaming index
            # audits after its delta merge (stream.index.search_auto)
            if tel.shadow is not None and not hasattr(self, "delta_arrays"):
                tel.shadow_audit(self, queries, filt, res, p, k=k)
        return (res, p) if return_plan else res

    # -- multi-device serving (serve/sharded.py) ----------------------------
    def shard(self, n_shards: int, mesh=None):
        """Re-shard this index row-wise across ``n_shards`` devices.

        Returns a ``serve.ShardedJAGIndex`` serving the same rows behind
        the same ``search_auto`` surface; per-shard sub-graphs are rebuilt
        from this index's rows and config (a built graph's edges cross any
        row split, so an honest reshard is a rebuild). Requires N
        divisible by ``n_shards`` and that many visible devices — fake
        them with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
        """
        from ..serve.sharded import shard_index
        return shard_index(self, n_shards, mesh=mesh)

    # -- persistence ---------------------------------------------------------
    def _save_arrays(self) -> dict:
        """The index as a flat npz-ready dict (shared with repro.stream).

        Packed fused rows are stored as raw uint32 bit patterns
        (``packed_bits``) because the attr lanes are uint32 payloads bitcast
        into f32 — a value-level f32 round-trip could canonicalize NaNs and
        corrupt them. The calibrated ``BuildConfig`` and any computed int8
        quantization are included too, so :meth:`load` restores the exact
        build parameters and never re-quantizes.
        """
        extra = {}
        for dt, lay in self._fused.items():
            extra[f"fused_{dt}__packed_bits"] = (
                np.asarray(lay.packed).view(np.uint32))
            extra[f"fused_{dt}__q_scale"] = np.asarray(lay.q_scale)
            extra[f"fused_{dt}__bit_weights"] = np.asarray(lay.bit_weights)
        if self._q8 is not None:
            xq, scale, xq_norm = self._q8
            extra["q8__codes"] = np.asarray(xq)
            extra["q8__scale"] = np.asarray(scale)
            extra["q8__norms"] = np.asarray(xq_norm)
        if self.cost_model is not None:
            from ..cost.registry import to_json
            extra["cost__model"] = np.frombuffer(
                to_json(self.cost_model).encode(), np.uint8)
            extra["cost__metric"] = self.cost_metric
        return dict(
            xb=np.asarray(self.xb), graph=np.asarray(self.graph),
            degree=np.asarray(self.degree), entry=np.asarray(self.entry),
            attr_kind=self.attr.kind, attr_nbits=self.attr.n_bits,
            cfg=_encode_cfg(self.cfg),
            build_cfg=_encode_cfg(self.build_cfg),
            **{f"attr__{k}": np.asarray(v)
               for k, v in self.attr.data.items()},
            **extra)

    def save(self, path: str) -> None:
        """Persist the index; built serving state rides along losslessly."""
        np.savez_compressed(path, **self._save_arrays())

    @classmethod
    def _from_npz(cls, z) -> "JAGIndex":
        """Rebuild an index from a loaded npz mapping (shared with load and
        the streaming archive format, which adds ``stream__*`` keys)."""
        cfg = JAGConfig(**_decode_cfg(z["cfg"]))
        # archives predating the build_cfg fix fall back to defaults
        bcfg = (BuildConfig(**_decode_cfg(z["build_cfg"]))
                if "build_cfg" in z else BuildConfig())
        attr = AttrTable(str(z["attr_kind"]),
                         {k[len("attr__"):]: jnp.asarray(v)
                          for k, v in z.items() if k.startswith("attr__")},
                         n_bits=int(z["attr_nbits"]))
        idx = cls(jnp.asarray(z["xb"]), attr, jnp.asarray(z["graph"]),
                  jnp.asarray(z["degree"]), jnp.asarray(z["entry"]),
                  cfg, bcfg)
        from ..serve import FusedLayout
        for dt in ("f32", "int8"):
            key = f"fused_{dt}__packed_bits"
            if key in z:
                idx._fused[dt] = FusedLayout(
                    jnp.asarray(z[key].view(np.float32)),
                    jnp.asarray(z[f"fused_{dt}__q_scale"]),
                    jnp.asarray(z[f"fused_{dt}__bit_weights"]),
                    attr.kind, attr.n_bits, int(z["xb"].shape[1]), dt)
        if "q8__codes" in z:
            idx._q8 = (jnp.asarray(z["q8__codes"]),
                       jnp.asarray(z["q8__scale"]),
                       jnp.asarray(z["q8__norms"]))
        if "cost__model" in z:
            from ..cost.registry import from_json
            idx.cost_model = from_json(bytes(z["cost__model"]).decode())
            if "cost__metric" in z:
                idx.cost_metric = str(z["cost__metric"])
        return idx

    @classmethod
    def load(cls, path: str) -> "JAGIndex":
        return cls._from_npz(np.load(path, allow_pickle=False))

    # -- stats ---------------------------------------------------------------
    def degree_stats(self):
        d = np.asarray(jnp.sum(self.graph >= 0, axis=1))
        return dict(mean=float(d.mean()), max=int(d.max()),
                    min=int(d.min()),
                    over_budget=int((d > self.cfg.degree).sum()))
