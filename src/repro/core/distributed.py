"""Distributed JAG: shard-and-merge serving + per-shard builds (shard_map).

Architecture (DESIGN.md §4): every device owns an independent JAG shard
(vectors + sub-graph + attributes over N/n_shards points — the layout used
by production ANN services). Queries are sharded over the "pod" axis and
replicated across shards; each shard runs the batched beam search locally
and the per-shard top-k results are merged with one all-gather over the
shard axes + a local lexicographic sort. Collective bytes therefore scale
with B·k, independent of N.

Fault tolerance: a lost shard removes only its slice of candidates until
the checkpointed shard arrays are restored (graceful recall degradation);
elastic scaling = changing the number of "data"-axis shards (each shard is
self-contained).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .beam_search import greedy_search
from .distances import gathered_dot, query_key_fn
from .filters import AttrTable, FilterBatch


def _shard_map(fn, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes it at the top level with ``check_vma``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map`` whose equivalent kwarg is
    ``check_rep``.
    """
    top = getattr(jax, "shard_map", None)
    if top is not None:
        return top(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as exp
    return exp(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


@dataclasses.dataclass(frozen=True)
class ShardedServeConfig:
    k: int = 10
    ls: int = 64
    max_iters: int = 128
    query_chunk: int = 128     # bitmap-bounded query chunking per shard


def shard_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("data", "model") if a in mesh.axis_names)


def query_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod",) if a in mesh.axis_names)


def make_serve_step(mesh: Mesh, cfg: ShardedServeConfig, attr_kind: str,
                    filt_kind: str, n_bits: int = 0,
                    variant: str = "f32", dedup: str = "bitmap"):
    """Returns step(graph, xb, xb_norm, attr_data, entries, queries,
    filt_data[, scale]) -> (global ids [B, k], primary, secondary).

    ``variant``: "f32" (xb as given) | "int8" (xb int8 + trailing ``scale``
    f32[d] arg; row norms gathered) | "int8_reg" (int8, norms recomputed
    in-register from the gathered row — no norm gather). ``dedup``: see
    beam_search.greedy_search. §Perf iterations for the serve_1b cell.

    Sharded layouts (leading shard axis = flattened ("data","model")):
      graph    int32 [S, N_loc, R] (shard-local ids)
      xb             [S, N_loc, d]
      xb_norm  f32   [S, N_loc]
      attr_data      {name: [S, N_loc, ...]}
      entries  int32 [S, n_seeds]      (per-shard entry points)
      queries        [B, d]            sharded over "pod"
      filt_data      {name: [B, ...]}  sharded over "pod"
    """
    sx = shard_axes(mesh)
    qx = query_axes(mesh)
    n_shards = 1
    for a in sx:
        n_shards *= mesh.shape[a]

    def shard_fn(graph, xb, xb_norm, attr_data, entries, queries,
                 filt_data, *rest):
        graph, xb, xb_norm = graph[0], xb[0], xb_norm[0]
        attr_data = jax.tree.map(lambda x: x[0], attr_data)
        entries = entries[0]
        attr = AttrTable(attr_kind, attr_data, n_bits=n_bits)
        shard_id = jnp.int32(0)
        for a in sx:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)

        dist_fn = None
        if variant == "int8":
            from .quantized import make_int8_dist_fn
            dist_fn = make_int8_dist_fn(rest[0])
        elif variant == "int8_reg":
            scale = rest[0]

            def dist_fn(xq, _norm, ids, q32, q_norm):  # noqa: F811
                rows = jnp.take(xq, ids, axis=0,
                                mode="clip").astype(jnp.float32) * scale
                # gathered_dot, not einsum: the batched-dot lowering of
                # einsum("bcd,bd->bc") vectorizes its reduction by batch
                # size, so per-query results drift across query_chunk
                # regroupings — JAG002 (batch-invariance, PR 3 contract)
                d2 = (jnp.sum(rows * rows, -1)
                      - 2.0 * gathered_dot(rows, q32)
                      + q_norm[:, None])
                return jnp.maximum(d2, 0.0)

        def chunk_fn(args):
            q, fd = args
            filt = FilterBatch(filt_kind, fd, n_bits=n_bits)
            kw = {} if dist_fn is None else {"dist_fn": dist_fn}
            res = greedy_search(graph, xb, xb_norm, attr, q, entries,
                                query_key_fn(filt), ls=cfg.ls, k=cfg.k,
                                max_iters=cfg.max_iters, dedup=dedup, **kw)
            return res.ids, res.primary, res.secondary

        B = queries.shape[0]
        nch = max(B // cfg.query_chunk, 1)
        qc = queries.reshape(nch, B // nch, -1)
        fdc = jax.tree.map(
            lambda x: x.reshape((nch, B // nch) + x.shape[1:]), filt_data)
        ids, prim, sec = jax.lax.map(chunk_fn, (qc, fdc))
        ids = ids.reshape(B, cfg.k)
        prim = prim.reshape(B, cfg.k)
        sec = sec.reshape(B, cfg.k)
        gids = jnp.where(ids >= 0, ids + shard_id * xb.shape[0], -1)

        # merge across shards: all_gather (axis 0 = shard) + local sort
        ag_i = jax.lax.all_gather(gids, sx)      # [n_shards, B, k]
        ag_p = jax.lax.all_gather(prim, sx)
        ag_s = jax.lax.all_gather(sec, sx)
        ag_i = jnp.moveaxis(ag_i.reshape(n_shards, B, cfg.k), 0, 1
                            ).reshape(B, -1)
        ag_p = jnp.moveaxis(ag_p.reshape(n_shards, B, cfg.k), 0, 1
                            ).reshape(B, -1)
        ag_s = jnp.moveaxis(ag_s.reshape(n_shards, B, cfg.k), 0, 1
                            ).reshape(B, -1)
        p, s, i = jax.lax.sort((ag_p, ag_s, ag_i), num_keys=2)
        return i[:, :cfg.k], p[:, :cfg.k], s[:, :cfg.k]

    shard_spec = P(sx)
    q_spec = P(qx) if qx else P()
    in_specs = [shard_spec, shard_spec, shard_spec, shard_spec,
                shard_spec, q_spec, q_spec]
    if variant in ("int8", "int8_reg"):
        in_specs.append(P())        # replicated dequant scale
    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(q_spec, q_spec, q_spec),
        check_vma=False)


def make_build_step(mesh: Mesh, build_cfg, attr_kind: str, n_bits: int = 0):
    """Per-shard batched Insert over the full mesh (independent sub-graphs).

    step(graph [S,N,W], degree [S,N], xb [S,N,d], xb_norm [S,N],
         attr_data [S,N,...], batch_ids [S,B], entries [S,E])
    """
    from .build import make_insert_step
    sx = shard_axes(mesh)
    insert = make_insert_step(build_cfg)

    def shard_fn(graph, degree, xb, xb_norm, attr_data, batch_ids, entries):
        attr = AttrTable(attr_kind, jax.tree.map(lambda x: x[0], attr_data),
                         n_bits=n_bits)
        g, d = insert(graph[0], degree[0], xb[0], xb_norm[0], attr,
                      batch_ids[0], entries[0])
        return g[None], d[None]

    spec = P(sx)
    return _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(spec,) * 7, out_specs=(spec, spec), check_vma=False)
