"""Filter & attribute representations for the four filter families in JAG.

The paper (§2, §3.1) defines four filter constraints — Label equality, numeric
Range, Subset containment, and arbitrary Boolean predicates — together with a
continuous ``dist_F`` (query time) and ``dist_A`` (build time) for each.

TPU-native layout decisions (see DESIGN.md §2):
  * label      : ``int32[N]``
  * range      : ``float32[N]``
  * subset     : bit-packed ``uint32[N, W]`` with ``W = ceil(L / 32)``
  * boolean    : assignment ``uint32[N]`` (L <= MAX_BOOL_VARS bits); the filter
                 itself is a per-query *distance table* ``float32[2**L]`` built
                 by min-plus relaxation on the hypercube, so the query-time
                 ``dist_F`` (min #bit flips to satisfy f) is a single gather.

Attribute tables and filter batches are registered dataclass pytrees whose
``kind`` field is static, so they can flow through ``jax.jit`` boundaries.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

LABEL = "label"
RANGE = "range"
SUBSET = "subset"
BOOLEAN = "boolean"
KINDS = (LABEL, RANGE, SUBSET, BOOLEAN)

MAX_BOOL_VARS = 20  # distance table is 2**L floats; 20 -> 4 MiB per query.


# ---------------------------------------------------------------------------
# bit packing helpers
# ---------------------------------------------------------------------------

def n_words(n_bits: int) -> int:
    return (int(n_bits) + 31) // 32


def pack_bits(bits: np.ndarray | jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean array [..., L] into uint32 words [..., ceil(L/32)]."""
    bits = jnp.asarray(bits, dtype=jnp.uint32)
    L = bits.shape[-1]
    W = n_words(L)
    pad = W * 32 - L
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), jnp.uint32)], axis=-1)
    bits = bits.reshape(bits.shape[:-1] + (W, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, L: int) -> jnp.ndarray:
    """Unpack uint32 words [..., W] into boolean [..., L]."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    return bits[..., :L].astype(jnp.bool_)


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Population count of an unsigned integer array, summed over last axis."""
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# attr-word codec: per-point attributes <-> f32 "words" for the fused layout
#
# The serving row layout (serve/layout.py) packs [vec | norm | attr words]
# into one contiguous float32 matrix so a single gather per beam expansion
# fetches everything the comparator needs. Integer attributes are *bitcast*
# (not value-cast) into the f32 lanes, so the round-trip is exact for
# arbitrary uint32 payloads (incl. packed subset bitmaps); the only ops ever
# applied to attr lanes downstream are copies/gathers, which preserve bits.
# ---------------------------------------------------------------------------

def _u32_to_f32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.uint32),
                                        jnp.float32)


def _f32_to_u32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32),
                                        jnp.uint32)


def attr_word_width(kind: str, n_bits: int = 0) -> int:
    """Number of f32 attr words per row in the fused serving layout."""
    if kind in (LABEL, RANGE, BOOLEAN):
        return 1
    if kind == SUBSET:
        return n_words(n_bits)
    raise ValueError(kind)


def pack_attr_words(table: "AttrTable") -> jnp.ndarray:
    """Encode per-point attributes as f32 words [N, A] (A = attr_word_width).

    label/boolean/subset lanes are bitcast; range values are stored directly
    (already f32). Inverse of :func:`unpack_attr_words`.
    """
    k = table.kind
    if k == LABEL:
        return jax.lax.bitcast_convert_type(
            jnp.asarray(table.data["label"], jnp.int32),
            jnp.float32)[:, None]
    if k == RANGE:
        return table.data["value"].astype(jnp.float32)[:, None]
    if k == SUBSET:
        return _u32_to_f32(table.data["bits"])
    if k == BOOLEAN:
        return _u32_to_f32(table.data["assign"])[:, None]
    raise ValueError(k)


def unpack_attr_words(kind: str, words: jnp.ndarray, n_bits: int = 0,
                      bit_weights: Optional[jnp.ndarray] = None
                      ) -> Dict[str, jnp.ndarray]:
    """Decode gathered f32 attr words [..., A] back into an attrs dict.

    The result has the same shapes/dtypes ``AttrTable.gather`` would produce
    for the same ids, so it can feed ``dist_f``/``matches`` unchanged.
    """
    if kind == LABEL:
        return {"label": _f32_to_u32(words[..., 0]).astype(jnp.int32)}
    if kind == RANGE:
        return {"value": words[..., 0].astype(jnp.float32)}
    if kind == SUBSET:
        out = {"bits": _f32_to_u32(words)}
        if bit_weights is not None:
            out["bit_weights"] = bit_weights
        return out
    if kind == BOOLEAN:
        return {"assign": _f32_to_u32(words[..., 0])}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# attribute table (per-point metadata)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("data",), meta_fields=("kind", "n_bits"))
@dataclasses.dataclass(frozen=True)
class AttrTable:
    """Per-point attributes for one dataset.

    data layout per kind:
      label   : {"label": int32[N]}
      range   : {"value": float32[N]}
      subset  : {"bits": uint32[N, W]}  (+ optional "bit_weights": f32[L] for
                the YFCC-style log(1/p_i) weighted attribute distance, D.3)
      boolean : {"assign": uint32[N]}
    """
    kind: str
    data: Dict[str, jnp.ndarray]
    n_bits: int = 0  # L for subset/boolean kinds

    @property
    def n(self) -> int:
        return next(iter(self.data.values())).shape[0]

    def gather(self, ids: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """Gather attribute rows for (clipped) candidate ids of any shape."""
        out = {}
        for k, v in self.data.items():
            if k == "bit_weights":  # global, not per-point
                out[k] = v
            else:
                out[k] = jnp.take(v, ids, axis=0, mode="clip")
        return out

    def append(self, other: "AttrTable") -> "AttrTable":
        """Rows of ``other`` appended after this table's rows.

        The streaming layer (repro.stream) uses this to materialize the
        live base+delta attribute table the planner probes. Global
        ``bit_weights`` (not per-point) are kept from ``self``; ``other``
        must agree on kind/n_bits.
        """
        if other.kind != self.kind or other.n_bits != self.n_bits:
            raise ValueError(
                f"cannot append {other.kind}/{other.n_bits} rows to a "
                f"{self.kind}/{self.n_bits} table")
        out = {}
        for k, v in self.data.items():
            if k == "bit_weights":
                out[k] = v
            else:
                out[k] = jnp.concatenate([v, other.data[k]], axis=0)
        return AttrTable(self.kind, out, self.n_bits)


def label_table(labels) -> AttrTable:
    return AttrTable(LABEL, {"label": jnp.asarray(labels, jnp.int32)})


def range_table(values) -> AttrTable:
    return AttrTable(RANGE, {"value": jnp.asarray(values, jnp.float32)})


def subset_table(bits, n_bits: int, bit_weights=None) -> AttrTable:
    """``bits``: either packed uint32 [N, W] or boolean [N, L]."""
    bits = jnp.asarray(bits)
    if bits.dtype != jnp.uint32:
        bits = pack_bits(bits)
    data = {"bits": bits}
    if bit_weights is not None:
        data["bit_weights"] = jnp.asarray(bit_weights, jnp.float32)
    return AttrTable(SUBSET, data, n_bits=int(n_bits))


def boolean_table(assign, n_vars: int) -> AttrTable:
    assert n_vars <= MAX_BOOL_VARS
    return AttrTable(BOOLEAN, {"assign": jnp.asarray(assign, jnp.uint32)},
                     n_bits=int(n_vars))


# ---------------------------------------------------------------------------
# filter batch (per-query constraints)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("data",), meta_fields=("kind", "n_bits"))
@dataclasses.dataclass(frozen=True)
class FilterBatch:
    """A batch of B query filters.

    data layout per kind:
      label   : {"label": int32[B]}
      range   : {"lo": f32[B], "hi": f32[B]}
      subset  : {"bits": uint32[B, W]}
      boolean : {"table": f32[B, 2**L]}   # dist_F lookup table per query
                {"sat":   bool[B, 2**L]}  # exact satisfaction, for recall eval
    """
    kind: str
    data: Dict[str, jnp.ndarray]
    n_bits: int = 0

    @property
    def batch(self) -> int:
        return next(iter(self.data.values())).shape[0]

    def lane(self, i: int) -> "FilterBatch":
        return FilterBatch(self.kind,
                           {k: v[i:i + 1] for k, v in self.data.items()},
                           self.n_bits)

    def take(self, ids) -> "FilterBatch":
        """Group-gather: the sub-batch of filter lanes at positions ``ids``.

        Every lane array is per-query ([B, ...]), so a row gather on each
        yields a well-formed FilterBatch of ``len(ids)`` queries — the
        per-query dispatcher (serve/dispatch.py) uses this to hand each
        route group its own contiguous filter sub-batch.
        """
        ids = jnp.asarray(ids, jnp.int32)
        return FilterBatch(self.kind,
                           {k: jnp.take(v, ids, axis=0)
                            for k, v in self.data.items()},
                           self.n_bits)


def label_filters(labels) -> FilterBatch:
    return FilterBatch(LABEL, {"label": jnp.asarray(labels, jnp.int32)})


def range_filters(lo, hi) -> FilterBatch:
    return FilterBatch(RANGE, {"lo": jnp.asarray(lo, jnp.float32),
                               "hi": jnp.asarray(hi, jnp.float32)})


def subset_filters(bits, n_bits: int) -> FilterBatch:
    bits = jnp.asarray(bits)
    if bits.dtype != jnp.uint32:
        bits = pack_bits(bits)
    return FilterBatch(SUBSET, {"bits": bits}, n_bits=int(n_bits))


def bool_dist_table(sat: jnp.ndarray, n_vars: int) -> jnp.ndarray:
    """Hamming distance-to-satisfying-set over {0,1}^L via min-plus relaxation.

    ``sat``: bool[..., 2**L] marking satisfying assignments. L rounds of
    relaxation over all single-bit flips computes exact hypercube BFS distance
    (max distance <= L). Paper §3.1(4): dist_F(a, f) = min_{a': f(a')=1} |a-a'|.
    """
    L = int(n_vars)
    size = 1 << L
    idx = jnp.arange(size, dtype=jnp.uint32)
    dist = jnp.where(sat, 0.0, jnp.float32(2 * L + 1))

    def round_(_, d):
        for i in range(L):
            nb = jnp.take(d, (idx ^ jnp.uint32(1 << i)).astype(jnp.int32),
                          axis=-1)
            d = jnp.minimum(d, nb + 1.0)
        return d

    dist = jax.lax.fori_loop(0, L, round_, dist)
    return dist


def boolean_filters(sat: jnp.ndarray, n_vars: int) -> FilterBatch:
    """``sat``: bool[B, 2**L] truth tables of the boolean predicates."""
    sat = jnp.asarray(sat, jnp.bool_)
    table = bool_dist_table(sat, n_vars)
    return FilterBatch(BOOLEAN, {"table": table, "sat": sat},
                       n_bits=int(n_vars))


# ---------------------------------------------------------------------------
# exact pass/fail (the binary g(a, f)), used for recall + pre/post filtering
# ---------------------------------------------------------------------------

def matches(filt: FilterBatch, attrs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """g(a_p, f_q) = 1. ``attrs`` gathered to shape [B, C, ...]; filt is [B].

    Returns bool[B, C].
    """
    k = filt.kind
    if k == LABEL:
        return attrs["label"] == filt.data["label"][:, None]
    if k == RANGE:
        v = attrs["value"]
        return ((v >= filt.data["lo"][:, None]) &
                (v <= filt.data["hi"][:, None]))
    if k == SUBSET:
        f = filt.data["bits"][:, None, :]
        a = attrs["bits"]
        return jnp.all((f & ~a) == 0, axis=-1)
    if k == BOOLEAN:
        a = attrs["assign"].astype(jnp.int32)
        return jnp.take_along_axis(filt.data["sat"], a, axis=-1)
    raise ValueError(k)


def matches_sampled(filt: FilterBatch, table: AttrTable,
                    ids: jnp.ndarray) -> jnp.ndarray:
    """Validity over a fixed sample: bool[B, S] for sample ids int32[S].

    The jit-compatible probe behind the query planner's selectivity
    estimator (serve/planner.py): the S sampled attribute rows are gathered
    ONCE and broadcast [1, S, ...] against the filter batch [B] — never a
    B*S gather.
    """
    ids = jnp.asarray(ids, jnp.int32)
    attrs = table.gather(ids)  # [S, ...]
    attrs = {k: (v[None] if k != "bit_weights" else v)
             for k, v in attrs.items()}
    k = filt.kind
    if k == LABEL:
        return attrs["label"] == filt.data["label"][:, None]
    if k == RANGE:
        v = attrs["value"]
        return ((v >= filt.data["lo"][:, None]) &
                (v <= filt.data["hi"][:, None]))
    if k == SUBSET:
        f = filt.data["bits"][:, None, :]
        a = attrs["bits"]
        return jnp.all((f & ~a) == 0, axis=-1)
    if k == BOOLEAN:
        a = jnp.broadcast_to(attrs["assign"].astype(jnp.int32),
                             (filt.batch, ids.shape[0]))
        return jnp.take_along_axis(filt.data["sat"], a, axis=-1)
    raise ValueError(k)


def matches_all(filt: FilterBatch, table: AttrTable) -> jnp.ndarray:
    """Full validity matrix bool[B, N] (used by pre-filter / ground truth)."""
    return matches_sampled(filt, table, jnp.arange(table.n))


def selectivity(filt: FilterBatch, table: AttrTable) -> jnp.ndarray:
    return jnp.mean(matches_all(filt, table).astype(jnp.float32), axis=-1)
