"""Filter & attribute representations for the four filter families in JAG.

The paper (§2, §3.1) defines four filter constraints — Label equality, numeric
Range, Subset containment, and arbitrary Boolean predicates — together with a
continuous ``dist_F`` (query time) and ``dist_A`` (build time) for each.

TPU-native layout decisions (see DESIGN.md §2):
  * label      : ``int32[N]``
  * range      : ``float32[N]``
  * subset     : bit-packed ``uint32[N, W]`` with ``W = ceil(L / 32)``
  * boolean    : assignment ``uint32[N]`` (L <= MAX_BOOL_VARS bits); the filter
                 itself is a per-query *distance table* ``float32[2**L]`` built
                 by min-plus relaxation on the hypercube, so the query-time
                 ``dist_F`` (min #bit flips to satisfy f) is a single gather.

Attribute tables and filter batches are registered dataclass pytrees whose
``kind`` field is static, so they can flow through ``jax.jit`` boundaries.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LABEL = "label"
RANGE = "range"
SUBSET = "subset"
BOOLEAN = "boolean"
KINDS = (LABEL, RANGE, SUBSET, BOOLEAN)


def kind_components(kind: str) -> Tuple[str, ...]:
    """Atomic components of a (possibly composite) attr-table kind.

    Composite kinds name a table carrying several attribute families at
    once — e.g. ``"label+range"`` — so a compound filter expression can mix
    leaf families over one dataset. Atomic kinds are their own single
    component.
    """
    return tuple(kind.split("+"))


def is_composite(kind: str) -> bool:
    return "+" in kind

MAX_BOOL_VARS = 20  # distance table is 2**L floats; 20 -> 4 MiB per query.


# ---------------------------------------------------------------------------
# bit packing helpers
# ---------------------------------------------------------------------------

def n_words(n_bits: int) -> int:
    return (int(n_bits) + 31) // 32


def pack_bits(bits: np.ndarray | jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean array [..., L] into uint32 words [..., ceil(L/32)]."""
    bits = jnp.asarray(bits, dtype=jnp.uint32)
    L = bits.shape[-1]
    W = n_words(L)
    pad = W * 32 - L
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), jnp.uint32)], axis=-1)
    bits = bits.reshape(bits.shape[:-1] + (W, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, L: int) -> jnp.ndarray:
    """Unpack uint32 words [..., W] into boolean [..., L]."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    return bits[..., :L].astype(jnp.bool_)


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Population count of an unsigned integer array, summed over last axis."""
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# attr-word codec: per-point attributes <-> f32 "words" for the fused layout
#
# The serving row layout (serve/layout.py) packs [vec | norm | attr words]
# into one contiguous float32 matrix so a single gather per beam expansion
# fetches everything the comparator needs. Integer attributes are *bitcast*
# (not value-cast) into the f32 lanes, so the round-trip is exact for
# arbitrary uint32 payloads (incl. packed subset bitmaps); the only ops ever
# applied to attr lanes downstream are copies/gathers, which preserve bits.
# ---------------------------------------------------------------------------

def _u32_to_f32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.uint32),
                                        jnp.float32)


def _f32_to_u32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32),
                                        jnp.uint32)


def attr_word_width(kind: str, n_bits: int = 0) -> int:
    """Number of f32 attr words per row in the fused serving layout.

    Composite kinds (``"label+range"``) lay their components' words out
    consecutively, so the width is the sum of the component widths.
    """
    if is_composite(kind):
        return sum(attr_word_width(k, n_bits) for k in kind_components(kind))
    if kind in (LABEL, RANGE, BOOLEAN):
        return 1
    if kind == SUBSET:
        return n_words(n_bits)
    raise ValueError(kind)


def pack_attr_words(table: "AttrTable") -> jnp.ndarray:
    """Encode per-point attributes as f32 words [N, A] (A = attr_word_width).

    label/boolean/subset lanes are bitcast; range values are stored directly
    (already f32). Inverse of :func:`unpack_attr_words`.
    """
    k = table.kind
    if is_composite(k):
        # component packers only read their own data keys, so a sub-view
        # over the shared dict suffices; words concatenate in kind order
        return jnp.concatenate(
            [pack_attr_words(AttrTable(k2, table.data, table.n_bits))
             for k2 in kind_components(k)], axis=-1)
    if k == LABEL:
        return jax.lax.bitcast_convert_type(
            jnp.asarray(table.data["label"], jnp.int32),
            jnp.float32)[:, None]
    if k == RANGE:
        return table.data["value"].astype(jnp.float32)[:, None]
    if k == SUBSET:
        return _u32_to_f32(table.data["bits"])
    if k == BOOLEAN:
        return _u32_to_f32(table.data["assign"])[:, None]
    raise ValueError(k)


def unpack_attr_words(kind: str, words: jnp.ndarray, n_bits: int = 0,
                      bit_weights: Optional[jnp.ndarray] = None
                      ) -> Dict[str, jnp.ndarray]:
    """Decode gathered f32 attr words [..., A] back into an attrs dict.

    The result has the same shapes/dtypes ``AttrTable.gather`` would produce
    for the same ids, so it can feed ``dist_f``/``matches`` unchanged.
    """
    if is_composite(kind):
        out: Dict[str, jnp.ndarray] = {}
        off = 0
        for k2 in kind_components(kind):
            w = attr_word_width(k2, n_bits)
            out.update(unpack_attr_words(
                k2, words[..., off:off + w], n_bits,
                bit_weights if k2 == SUBSET else None))
            off += w
        return out
    if kind == LABEL:
        return {"label": _f32_to_u32(words[..., 0]).astype(jnp.int32)}
    if kind == RANGE:
        return {"value": words[..., 0].astype(jnp.float32)}
    if kind == SUBSET:
        out = {"bits": _f32_to_u32(words)}
        if bit_weights is not None:
            out["bit_weights"] = bit_weights
        return out
    if kind == BOOLEAN:
        return {"assign": _f32_to_u32(words[..., 0])}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# attribute table (per-point metadata)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("data",), meta_fields=("kind", "n_bits"))
@dataclasses.dataclass(frozen=True)
class AttrTable:
    """Per-point attributes for one dataset.

    data layout per kind:
      label   : {"label": int32[N]}
      range   : {"value": float32[N]}
      subset  : {"bits": uint32[N, W]}  (+ optional "bit_weights": f32[L] for
                the YFCC-style log(1/p_i) weighted attribute distance, D.3)
      boolean : {"assign": uint32[N]}
    """
    kind: str
    data: Dict[str, jnp.ndarray]
    n_bits: int = 0  # L for subset/boolean kinds

    @property
    def n(self) -> int:
        return next(iter(self.data.values())).shape[0]

    def gather(self, ids: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """Gather attribute rows for (clipped) candidate ids of any shape."""
        out = {}
        for k, v in self.data.items():
            if k == "bit_weights":  # global, not per-point
                out[k] = v
            else:
                out[k] = jnp.take(v, ids, axis=0, mode="clip")
        return out

    def append(self, other: "AttrTable") -> "AttrTable":
        """Rows of ``other`` appended after this table's rows.

        The streaming layer (repro.stream) uses this to materialize the
        live base+delta attribute table the planner probes. Global
        ``bit_weights`` (not per-point) are kept from ``self``; ``other``
        must agree on kind/n_bits.
        """
        if other.kind != self.kind or other.n_bits != self.n_bits:
            raise ValueError(
                f"cannot append {other.kind}/{other.n_bits} rows to a "
                f"{self.kind}/{self.n_bits} table")
        out = {}
        for k, v in self.data.items():
            if k == "bit_weights":
                out[k] = v
            else:
                out[k] = jnp.concatenate([v, other.data[k]], axis=0)
        return AttrTable(self.kind, out, self.n_bits)


def label_table(labels) -> AttrTable:
    return AttrTable(LABEL, {"label": jnp.asarray(labels, jnp.int32)})


def range_table(values) -> AttrTable:
    return AttrTable(RANGE, {"value": jnp.asarray(values, jnp.float32)})


def subset_table(bits, n_bits: int, bit_weights=None) -> AttrTable:
    """``bits``: either packed uint32 [N, W] or boolean [N, L]."""
    bits = jnp.asarray(bits)
    if bits.dtype != jnp.uint32:
        bits = pack_bits(bits)
    data = {"bits": bits}
    if bit_weights is not None:
        data["bit_weights"] = jnp.asarray(bit_weights, jnp.float32)
    return AttrTable(SUBSET, data, n_bits=int(n_bits))


def boolean_table(assign, n_vars: int) -> AttrTable:
    assert n_vars <= MAX_BOOL_VARS
    return AttrTable(BOOLEAN, {"assign": jnp.asarray(assign, jnp.uint32)},
                     n_bits=int(n_vars))


def joint_table(*tables: AttrTable) -> AttrTable:
    """Join per-kind attribute tables into one composite table.

    The composite kind is the ``"+"``-joined component kinds (in the given
    order); its data dict is the union of the component dicts (the per-kind
    keys never collide). Mixed-kind compound filters — e.g. a rare-label AND
    wide-range conjunction — evaluate each leaf against its own component.
    Constraints: at most one table per atomic kind; bit-carrying kinds
    (subset/boolean) must agree on ``n_bits`` (the composite carries one
    shared value); ``bit_weights`` is per-table state and unsupported here.
    """
    if len(tables) < 2:
        raise ValueError("joint_table needs >= 2 component tables")
    kinds, data, n_bits, n = [], {}, 0, None
    for t in tables:
        if is_composite(t.kind):
            raise ValueError(f"components must be atomic, got {t.kind!r}")
        if t.kind in kinds:
            raise ValueError(f"duplicate component kind {t.kind!r}")
        if "bit_weights" in t.data:
            raise ValueError("bit_weights is unsupported in joint tables")
        if t.n_bits:
            if n_bits and t.n_bits != n_bits:
                raise ValueError(
                    f"bit-kind components disagree on n_bits: "
                    f"{n_bits} vs {t.n_bits}")
            n_bits = t.n_bits
        if n is None:
            n = t.n
        elif t.n != n:
            raise ValueError(f"component row counts differ: {n} vs {t.n}")
        kinds.append(t.kind)
        data.update(t.data)
    return AttrTable("+".join(kinds), data, n_bits=n_bits)


# ---------------------------------------------------------------------------
# filter batch (per-query constraints)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("data",), meta_fields=("kind", "n_bits"))
@dataclasses.dataclass(frozen=True)
class FilterBatch:
    """A batch of B query filters.

    data layout per kind:
      label   : {"label": int32[B]}
      range   : {"lo": f32[B], "hi": f32[B]}
      subset  : {"bits": uint32[B, W]}
      boolean : {"table": f32[B, 2**L]}   # dist_F lookup table per query
                {"sat":   bool[B, 2**L]}  # exact satisfaction, for recall eval
    """
    kind: str
    data: Dict[str, jnp.ndarray]
    n_bits: int = 0

    @property
    def batch(self) -> int:
        return next(iter(self.data.values())).shape[0]

    def lane(self, i: int) -> "FilterBatch":
        return FilterBatch(self.kind,
                           {k: v[i:i + 1] for k, v in self.data.items()},
                           self.n_bits)

    def take(self, ids) -> "FilterBatch":
        """Group-gather: the sub-batch of filter lanes at positions ``ids``.

        Every lane array is per-query ([B, ...]), so a row gather on each
        yields a well-formed FilterBatch of ``len(ids)`` queries — the
        per-query dispatcher (serve/dispatch.py) uses this to hand each
        route group its own contiguous filter sub-batch.
        """
        ids = jnp.asarray(ids, jnp.int32)
        return FilterBatch(self.kind,
                           {k: jnp.take(v, ids, axis=0)
                            for k, v in self.data.items()},
                           self.n_bits)


def label_filters(labels) -> FilterBatch:
    return FilterBatch(LABEL, {"label": jnp.asarray(labels, jnp.int32)})


def range_filters(lo, hi) -> FilterBatch:
    return FilterBatch(RANGE, {"lo": jnp.asarray(lo, jnp.float32),
                               "hi": jnp.asarray(hi, jnp.float32)})


def subset_filters(bits, n_bits: int) -> FilterBatch:
    bits = jnp.asarray(bits)
    if bits.dtype != jnp.uint32:
        bits = pack_bits(bits)
    return FilterBatch(SUBSET, {"bits": bits}, n_bits=int(n_bits))


def bool_dist_table(sat: jnp.ndarray, n_vars: int) -> jnp.ndarray:
    """Hamming distance-to-satisfying-set over {0,1}^L via min-plus relaxation.

    ``sat``: bool[..., 2**L] marking satisfying assignments. L rounds of
    relaxation over all single-bit flips computes exact hypercube BFS distance
    (max distance <= L). Paper §3.1(4): dist_F(a, f) = min_{a': f(a')=1} |a-a'|.
    """
    L = int(n_vars)
    size = 1 << L
    idx = jnp.arange(size, dtype=jnp.uint32)
    dist = jnp.where(sat, 0.0, jnp.float32(2 * L + 1))

    def round_(_, d):
        for i in range(L):
            nb = jnp.take(d, (idx ^ jnp.uint32(1 << i)).astype(jnp.int32),
                          axis=-1)
            d = jnp.minimum(d, nb + 1.0)
        return d

    dist = jax.lax.fori_loop(0, L, round_, dist)
    return dist


def boolean_filters(sat: jnp.ndarray, n_vars: int) -> FilterBatch:
    """``sat``: bool[B, 2**L] truth tables of the boolean predicates."""
    sat = jnp.asarray(sat, jnp.bool_)
    table = bool_dist_table(sat, n_vars)
    return FilterBatch(BOOLEAN, {"table": table, "sat": sat},
                       n_bits=int(n_vars))


# ---------------------------------------------------------------------------
# filter expression trees: And / Or / Not over the four atomic leaves
#
# Expressions are the public filter surface. ``Label(3) & Range(0, 1)``
# builds a tree whose nodes are registered pytrees, so a whole expression
# flows through jax.jit like a FilterBatch does: the tree *structure* (and
# each leaf's static kind) lives in the treedef, only the lane arrays are
# traced. ``expr.kind`` is a structural signature string — "(label&range)" —
# so every cache key that today stores ``filt.kind`` works unchanged.
# ---------------------------------------------------------------------------

class FilterExpr:
    """Base class of compound filter expressions.

    Combine with the python operators: ``a & b`` (And), ``a | b`` (Or),
    ``~a`` (Not). Operands may be FilterExpr or raw FilterBatch (coerced to
    a Leaf). Same-op children flatten, so ``a & b & c`` is one 3-clause And.
    """

    def __and__(self, other):
        return _combine(And, self, other)

    def __rand__(self, other):
        return _combine(And, other, self)

    def __or__(self, other):
        return _combine(Or, self, other)

    def __ror__(self, other):
        return _combine(Or, other, self)

    def __invert__(self):
        if isinstance(self, Not):
            return self.child
        return Not(self)

    def __repr__(self) -> str:
        return f"FilterExpr<{describe(self)}>"

    @property
    def kind(self) -> str:
        """Structural signature, e.g. ``"(label&~range)"``: static per
        tree shape, so executor/planner cache keys distinguish expression
        structures exactly as they distinguish atomic kinds."""
        raise NotImplementedError

    @property
    def batch(self) -> int:
        return self.leaves()[0].batch

    @property
    def n_bits(self) -> int:
        return max(f.n_bits for f in self.leaves())

    def leaves(self) -> list:
        """The atomic FilterBatch leaves, depth-first left-to-right."""
        raise NotImplementedError

    def _map_leaves(self, fn) -> "FilterExpr":
        raise NotImplementedError

    def lane(self, i: int) -> "FilterExpr":
        return self._map_leaves(lambda f: f.lane(i))

    def take(self, ids) -> "FilterExpr":
        """Group-gather every leaf's lanes (see FilterBatch.take): the
        per-query dispatcher hands each route group a sub-batch of the
        whole tree."""
        ids = jnp.asarray(ids, jnp.int32)
        return self._map_leaves(lambda f: f.take(ids))


def _coerce(x) -> FilterExpr:
    if isinstance(x, FilterExpr):
        return x
    if isinstance(x, FilterBatch):
        return Leaf(x)
    raise TypeError(f"expected FilterExpr or FilterBatch, got {type(x)!r}")


def _combine(cls, a, b) -> FilterExpr:
    kids = []
    for x in (_coerce(a), _coerce(b)):
        kids.extend(x.children if isinstance(x, cls) else (x,))
    return cls(*kids)


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class Leaf(FilterExpr):
    """An atomic filter wrapped as an expression node."""
    filt: FilterBatch

    @property
    def kind(self) -> str:
        return self.filt.kind

    def leaves(self) -> list:
        return [self.filt]

    def _map_leaves(self, fn) -> "Leaf":
        return Leaf(fn(self.filt))


@dataclasses.dataclass(frozen=True, eq=False, repr=False, init=False)
class And(FilterExpr):
    """Conjunction: every clause must match."""
    children: Tuple[FilterExpr, ...]

    def __init__(self, *children):
        if len(children) == 1 and isinstance(children[0], (tuple, list)):
            children = tuple(children[0])
        if len(children) < 2:
            raise ValueError("And needs >= 2 clauses")
        object.__setattr__(self, "children",
                           tuple(_coerce(c) for c in children))

    @property
    def kind(self) -> str:
        return "(" + "&".join(c.kind for c in self.children) + ")"

    def leaves(self) -> list:
        return [f for c in self.children for f in c.leaves()]

    def _map_leaves(self, fn) -> "And":
        return And(*[c._map_leaves(fn) for c in self.children])


@dataclasses.dataclass(frozen=True, eq=False, repr=False, init=False)
class Or(FilterExpr):
    """Disjunction: at least one clause must match."""
    children: Tuple[FilterExpr, ...]

    def __init__(self, *children):
        if len(children) == 1 and isinstance(children[0], (tuple, list)):
            children = tuple(children[0])
        if len(children) < 2:
            raise ValueError("Or needs >= 2 clauses")
        object.__setattr__(self, "children",
                           tuple(_coerce(c) for c in children))

    @property
    def kind(self) -> str:
        return "(" + "|".join(c.kind for c in self.children) + ")"

    def leaves(self) -> list:
        return [f for c in self.children for f in c.leaves()]

    def _map_leaves(self, fn) -> "Or":
        return Or(*[c._map_leaves(fn) for c in self.children])


@dataclasses.dataclass(frozen=True, eq=False, repr=False, init=False)
class Not(FilterExpr):
    """Negation of a sub-expression."""
    child: FilterExpr

    def __init__(self, child):
        object.__setattr__(self, "child", _coerce(child))

    @property
    def kind(self) -> str:
        return "~" + self.child.kind

    def leaves(self) -> list:
        return self.child.leaves()

    def _map_leaves(self, fn) -> "Not":
        return Not(self.child._map_leaves(fn))


jax.tree_util.register_pytree_node(
    Leaf, lambda e: ((e.filt,), None), lambda _, c: Leaf(c[0]))
jax.tree_util.register_pytree_node(
    And, lambda e: (e.children, None), lambda _, c: And(*c))
jax.tree_util.register_pytree_node(
    Or, lambda e: (e.children, None), lambda _, c: Or(*c))
jax.tree_util.register_pytree_node(
    Not, lambda e: ((e.child,), None), lambda _, c: Not(c[0]))


def Label(labels) -> Leaf:
    """Expression leaf: label equality. Scalar or [B] per-query labels."""
    return Leaf(label_filters(jnp.atleast_1d(jnp.asarray(labels, jnp.int32))))


def Range(lo, hi) -> Leaf:
    """Expression leaf: closed numeric range [lo, hi]. Scalars or [B]."""
    lo = jnp.atleast_1d(jnp.asarray(lo, jnp.float32))
    hi = jnp.atleast_1d(jnp.asarray(hi, jnp.float32))
    lo, hi = jnp.broadcast_arrays(lo, hi)
    return Leaf(range_filters(lo, hi))


def Subset(bits, n_bits: Optional[int] = None) -> Leaf:
    """Expression leaf: required-tag containment.

    ``bits``: boolean [L] / [B, L] (n_bits inferred as L) or packed uint32
    [W] / [B, W] (``n_bits`` required).
    """
    bits = jnp.asarray(bits)
    if bits.ndim == 1:
        bits = bits[None]
    if bits.dtype != jnp.uint32:
        if n_bits is None:
            n_bits = bits.shape[-1]
    elif n_bits is None:
        raise ValueError("n_bits is required for packed uint32 bits")
    return Leaf(subset_filters(bits, n_bits))


def Boolean(sat, n_vars: Optional[int] = None) -> Leaf:
    """Expression leaf: arbitrary boolean predicate as a truth table.

    ``sat``: bool [2**L] or [B, 2**L]; ``n_vars`` (= L) inferred from the
    table size when omitted.
    """
    sat = jnp.asarray(sat, jnp.bool_)
    if sat.ndim == 1:
        sat = sat[None]
    if n_vars is None:
        n_vars = int(sat.shape[-1]).bit_length() - 1
        if (1 << n_vars) != sat.shape[-1]:
            raise ValueError(f"truth table size {sat.shape[-1]} is not 2**L")
    return Leaf(boolean_filters(sat, n_vars))


def as_filter(filt):
    """Normalize the public ``filt`` argument.

    A single-leaf expression unwraps to its FilterBatch, so it runs the
    existing atomic path bit-identically (same executor cache key, same
    compiled fn). Compound expressions and raw FilterBatch pass through.
    """
    if isinstance(filt, Leaf):
        return filt.filt
    if isinstance(filt, (FilterBatch, FilterExpr)):
        return filt
    raise TypeError(f"expected FilterExpr or FilterBatch, got {type(filt)!r}")


def n_leaves(filt) -> int:
    """Clause count: 1 for an atomic FilterBatch, #leaves for a tree."""
    return len(filt.leaves()) if isinstance(filt, FilterExpr) else 1


def filter_batch(kind: str, data, n_bits: int = 0) -> FilterBatch:
    """Deprecated raw kind-enum constructor.

    Build filters with the expression constructors (``Label``, ``Range``,
    ``Subset``, ``Boolean``) or the per-kind ``*_filters`` helpers instead.
    """
    warnings.warn(
        "filter_batch(kind, data) is deprecated; build filters with the "
        "expression constructors Label/Range/Subset/Boolean (combine with "
        "& | ~) or the *_filters helpers",
        DeprecationWarning, stacklevel=2)
    return FilterBatch(kind, dict(data), n_bits=int(n_bits))


def describe(filt) -> str:
    """Human-readable expression string (host-side; used by explain())."""
    if isinstance(filt, Leaf):
        return describe(filt.filt)
    if isinstance(filt, And):
        return "(" + " & ".join(describe(c) for c in filt.children) + ")"
    if isinstance(filt, Or):
        return "(" + " | ".join(describe(c) for c in filt.children) + ")"
    if isinstance(filt, Not):
        return "~" + describe(filt.child)
    k = filt.kind
    if k == LABEL:
        u = np.unique(np.asarray(filt.data["label"]))
        return f"label={u[0]}" if u.size == 1 else f"label[{filt.batch}]"
    if k == RANGE:
        lo = np.unique(np.asarray(filt.data["lo"]))
        hi = np.unique(np.asarray(filt.data["hi"]))
        if lo.size == 1 and hi.size == 1:
            return f"range[{lo[0]:g},{hi[0]:g}]"
        return f"range[{filt.batch} lanes]"
    if k == SUBSET:
        return f"subset[{filt.n_bits}b]"
    if k == BOOLEAN:
        return f"boolean[{filt.n_bits}v]"
    return k


# ---------------------------------------------------------------------------
# exact pass/fail (the binary g(a, f)), used for recall + pre/post filtering
# ---------------------------------------------------------------------------

def _matches_atomic(filt: FilterBatch,
                    attrs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Atomic g(a_p, f_q): attrs [B, C, ...] or broadcastable [1, C, ...]."""
    k = filt.kind
    if k == LABEL:
        return attrs["label"] == filt.data["label"][:, None]
    if k == RANGE:
        v = attrs["value"]
        return ((v >= filt.data["lo"][:, None]) &
                (v <= filt.data["hi"][:, None]))
    if k == SUBSET:
        f = filt.data["bits"][:, None, :]
        a = attrs["bits"]
        return jnp.all((f & ~a) == 0, axis=-1)
    if k == BOOLEAN:
        a = attrs["assign"].astype(jnp.int32)
        a = jnp.broadcast_to(a, (filt.batch,) + a.shape[1:])
        return jnp.take_along_axis(filt.data["sat"], a, axis=-1)
    raise ValueError(k)


def _eval_counted(filt, leaf_fn):
    """Recursive short-circuit evaluation: (ok bool[B, C], evals int32[B, C]).

    ``evals`` counts leaf evaluations under left-to-right short-circuit
    semantics — an And stops at its first failing clause, an Or at its first
    match. XLA cannot skip lanes, so the count is the *model* the clause
    reorderer optimizes and the benchmark reports (n_feval), while ``ok``
    itself is evaluated dense. Tree recursion unrolls at trace time (the
    structure is static), so the whole thing jits.
    """
    if isinstance(filt, FilterBatch):
        ok = leaf_fn(filt)
        return ok, jnp.ones(ok.shape, jnp.int32)
    if isinstance(filt, Leaf):
        return _eval_counted(filt.filt, leaf_fn)
    if isinstance(filt, Not):
        ok, ev = _eval_counted(filt.child, leaf_fn)
        return ~ok, ev
    if isinstance(filt, (And, Or)):
        is_and = isinstance(filt, And)
        ok, ev = _eval_counted(filt.children[0], leaf_fn)
        for c in filt.children[1:]:
            okc, evc = _eval_counted(c, leaf_fn)
            live = ok if is_and else ~ok
            ev = ev + jnp.where(live, evc, 0)
            ok = (ok & okc) if is_and else (ok | okc)
        return ok, ev
    raise TypeError(f"not a filter: {type(filt)!r}")


def matches(filt, attrs: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """g(a_p, f_q) = 1. ``attrs`` gathered to shape [B, C, ...]; filt is an
    atomic FilterBatch or a FilterExpr tree over batch B.

    Returns bool[B, C].
    """
    if isinstance(filt, FilterExpr):
        return _eval_counted(filt, lambda f: _matches_atomic(f, attrs))[0]
    return _matches_atomic(filt, attrs)


def matches_counted(filt, attrs: Dict[str, jnp.ndarray]):
    """(ok bool[B, C], short-circuit leaf evals int32[B, C])."""
    return _eval_counted(filt, lambda f: _matches_atomic(f, attrs))


def _broadcast_rows(table: AttrTable, ids: jnp.ndarray):
    """Sample-row attrs dict gathered ONCE and broadcast [1, S, ...]."""
    attrs = table.gather(ids)
    return {k: (v[None] if k != "bit_weights" else v)
            for k, v in attrs.items()}


def matches_sampled(filt, table: AttrTable, ids: jnp.ndarray) -> jnp.ndarray:
    """Validity over a fixed sample: bool[B, S] for sample ids int32[S].

    The jit-compatible probe behind the query planner's selectivity
    estimator (serve/planner.py): the S sampled attribute rows are gathered
    ONCE and broadcast [1, S, ...] against the filter batch [B] — never a
    B*S gather. Accepts expressions (leaves combine word-wise).
    """
    ids = jnp.asarray(ids, jnp.int32)
    return matches(filt, _broadcast_rows(table, ids))


def _onehot_words(assign: jnp.ndarray, size: int) -> jnp.ndarray:
    """Packed one-hot rows: bit assign[s] set in uint32 words [S, W]."""
    idx = jnp.arange(size, dtype=jnp.uint32)
    return pack_bits(idx[None, :] == jnp.asarray(assign, jnp.uint32)[:, None])


def matches_rows(filt, table: AttrTable, ids: jnp.ndarray,
                 use_kernel: bool = False):
    """Validity + eval counts over sample rows: (bool[B, S], int32[B, S]).

    The prefilter scan's per-block evaluator. With ``use_kernel`` the
    subset/boolean leaf validity runs through the Pallas popcount kernel
    (kernels/bitset.py): subset passes iff the deficit |f \\ a| is 0;
    boolean packs each query's satisfying set into bitset words and tests
    membership of the point's assignment via a one-hot deficit — both are
    word-wise VPU scans over the packed rows. Other leaf kinds (and the
    non-kernel path) use the dense comparators. Results are identical
    either way.
    """
    ids = jnp.asarray(ids, jnp.int32)
    raw = table.gather(ids)          # [S, ...]
    attrs = {k: (v[None] if k != "bit_weights" else v)
             for k, v in raw.items()}

    def leaf_fn(f: FilterBatch):
        if use_kernel and f.kind == SUBSET:
            from ..kernels import ops as _ops
            return _ops.subset_deficit(f.data["bits"], raw["bits"]) == 0
        if use_kernel and f.kind == BOOLEAN:
            from ..kernels import ops as _ops
            sat_w = pack_bits(f.data["sat"])                  # [B, W]
            hot = _onehot_words(raw["assign"], f.data["sat"].shape[-1])
            # deficit(sat, onehot(a)) = popcount(sat) - sat[a]
            defc = _ops.subset_deficit(sat_w, hot)            # [B, S]
            return defc == (popcount(sat_w)[:, None] - 1)
        return _matches_atomic(f, attrs)

    return _eval_counted(filt, leaf_fn)


def matches_all(filt, table: AttrTable) -> jnp.ndarray:
    """Full validity matrix bool[B, N] (used by pre-filter / ground truth)."""
    return matches_sampled(filt, table, jnp.arange(table.n))


def selectivity(filt, table: AttrTable) -> jnp.ndarray:
    return jnp.mean(matches_all(filt, table).astype(jnp.float32), axis=-1)
