"""JointRobustPrune (Algorithm 4), batched over B insertion lanes.

For each threshold ``t`` (or weight ``w``) bucket, candidates are sorted by the
bucket comparator and admitted by an α-RobustPrune scan (Vamana / DiskANN):
candidate v survives iff no previously-admitted u has
``α·dist(u, v) < dist(p, v)``  (squared form: ``α²·d2(u,v) < d2(p,v)``).

Paper implementation notes honored (D.3):
  * a candidate already admitted by an earlier bucket free-rides into the
    current bucket (counts toward its cap and dominates later candidates)
    without consuming a new edge;
  * optional early-exit fill factor (0.9·deg/|T|) used by overflow re-prunes.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .distances import INF, capped


def _bucket_order(prim: jnp.ndarray, sec: jnp.ndarray) -> jnp.ndarray:
    """Permutation sorting candidates by (prim, sec) lexicographically."""
    C = prim.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), prim.shape)
    _, _, perm = jax.lax.sort((prim, sec, idx), num_keys=2)
    return perm


def joint_robust_prune(cand_valid: jnp.ndarray,   # bool [B, C]
                       d2_p: jnp.ndarray,         # f32 [B, C] dist(p, cand)^2
                       da_p: jnp.ndarray,         # f32 [B, C] dist_A(p, cand)
                       pair_d2: jnp.ndarray,      # f32 [B, C, C]
                       *,
                       degree: int,
                       alpha: float,
                       thresholds: Sequence[float] | None = None,
                       weights: Sequence[float] | None = None,
                       fill: float = 1.0) -> jnp.ndarray:
    """Returns bool[B, C]: which candidates become out-neighbors (<= degree)."""
    assert (thresholds is None) != (weights is None)
    buckets = thresholds if thresholds is not None else weights
    n_buckets = len(buckets)
    cap = max(1, int(fill * degree / n_buckets))
    B, C = d2_p.shape
    alpha2 = jnp.float32(alpha) ** 2
    rows = jnp.arange(B)

    d2_masked = jnp.where(cand_valid, d2_p, INF)
    selected = jnp.zeros((B, C), jnp.bool_)

    for b_i, bval in enumerate(buckets):
        if thresholds is not None:
            prim = capped(da_p, jnp.float32(bval))
            sec = d2_masked
        else:
            prim = jnp.float32(bval) * da_p + jnp.sqrt(d2_masked)
            sec = d2_masked
        prim = jnp.where(cand_valid, prim, INF)
        perm = _bucket_order(prim, sec)                      # [B, C]

        def admit(j, state):
            dominated, count, selected = state
            cidx = perm[:, j]                                # [B]
            ok = (cand_valid[rows, cidx]
                  & ~dominated[rows, cidx]
                  & (count < cap))
            selected = selected.at[rows, cidx].set(
                selected[rows, cidx] | ok)
            # v_j dominates k iff alpha^2 * d2(v_j, k) < d2(p, k)
            pd = jnp.take_along_axis(
                pair_d2, cidx[:, None, None], axis=1)[:, 0, :]  # [B, C]
            dom_j = (alpha2 * pd < d2_masked)
            dominated = dominated | (ok[:, None] & dom_j)
            return dominated, count + ok.astype(jnp.int32), selected

        dominated = jnp.zeros((B, C), jnp.bool_)
        count = jnp.zeros((B,), jnp.int32)
        dominated, count, selected = jax.lax.fori_loop(
            0, C, admit, (dominated, count, selected))

    return selected


def select_to_rows(selected: jnp.ndarray, cand_ids: jnp.ndarray,
                   d2_p: jnp.ndarray, degree: int) -> jnp.ndarray:
    """Compact a selection mask into fixed-width id rows [B, degree], -1 pad.

    Survivors are ordered by vector distance (harmless; adjacency order is
    irrelevant to the algorithms).
    """
    key = jnp.where(selected, d2_p, INF)
    ids = jnp.where(selected, cand_ids, -1)
    _, out = jax.lax.sort((key, ids), num_keys=1)
    return out[:, :degree]
