"""The serving-telemetry facade attached via ``JAGIndex.attach_telemetry``.

One :class:`Telemetry` object owns the trace ring buffer, the metrics
registry, and the drift/re-calibration policy.  Everything here runs on
the host AFTER the compiled route has returned (the dispatch layer
blocks on the group result before calling back), so attaching telemetry
changes nothing about the programs the executor compiles — the audit's
per-route callback/collective budgets are identical with telemetry on.

Hook surface (all host-side, all cheap):

- ``record_call``      one ``search_auto`` call -> one trace per query
- ``on_executor_miss`` executor jit-cache miss (new ``(epoch,)+key``)
- ``on_epoch_roll``    executor dropped its caches for a new epoch
- ``on_compaction``    streaming delta folded into the frozen graph
- ``on_search``        streaming search observed (delta scanned or not)
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .drift import DEFAULT_THRESHOLD, DriftReport, detect_drift
from .metrics import MetricsRegistry
from .recal import RecalReport, recalibrate
from .shadow import ShadowAuditor
from .spans import SpanRecorder
from .trace import TraceBuffer, TraceRecord


class Telemetry:
    """Bounded trace buffer + metrics registry + recalibration policy.

    ``recal_every > 0`` turns on auto-recalibration: every that-many
    traced ``search_auto`` calls, ``maybe_recalibrate`` runs against the
    index the traces came from (drift-gated, hysteresis-gated).

    Quality observability (all off by default):

    * ``shadow`` — a sampling fraction in (0, 1] (or a pre-built
      :class:`~repro.obs.shadow.ShadowAuditor`): that fraction of served
      queries is deterministically sampled for exact-oracle replay.
      Serve time only pays a cheap host-side enqueue; the oracle runs at
      flush/report time, maintaining rolling recall estimators per
      realized route × selectivity band × epoch.
    * ``introspect`` — serve graph queries through the executor's
      introspective compilation (own cache-key component, bit-identical
      results) and stamp per-query hops / saturation step / dead-end
      counters into the trace records.
    * ``spans`` — record hierarchical pipeline spans
      (plan → gather → execute → scatter → merge) into a
      :class:`~repro.obs.spans.SpanRecorder` with Chrome-trace export.
    """

    def __init__(self, *, capacity: int = 4096,
                 drift_threshold: float = DEFAULT_THRESHOLD,
                 recal_every: int = 0,
                 recal_min_traces: int = 64,
                 enabled: bool = True,
                 shadow=0.0,
                 introspect: bool = False,
                 spans=False):
        self.traces = TraceBuffer(capacity)
        self.metrics = MetricsRegistry()
        self.drift_threshold = float(drift_threshold)
        self.recal_every = int(recal_every)
        self.recal_min_traces = int(recal_min_traces)
        self.enabled = bool(enabled)
        self.last_recal: Optional[RecalReport] = None
        if isinstance(shadow, ShadowAuditor):
            self.shadow: Optional[ShadowAuditor] = shadow
        else:
            self.shadow = (ShadowAuditor(float(shadow), capacity=capacity)
                           if shadow else None)
        self.introspect = bool(introspect)
        if isinstance(spans, SpanRecorder):
            self.spans: Optional[SpanRecorder] = spans
        else:
            self.spans = SpanRecorder() if spans else None
        self._qid = 0
        self._calls = 0

    # ---- executor / streaming hooks ------------------------------------

    def on_executor_miss(self, epoch_key: Tuple) -> None:
        """New compiled entry in the executor's jit cache."""
        route = str(epoch_key[1]) if len(epoch_key) > 1 else "?"
        self.metrics.counter("jag_jit_miss_total", route=route).inc()

    def on_epoch_roll(self, epoch: int) -> None:
        """Executor dropped caches because the index epoch advanced."""
        self.metrics.counter("jag_epoch_roll_total").inc()

    def on_compaction(self) -> None:
        self.metrics.counter("jag_compaction_total").inc()

    def on_search(self, *, delta_scanned: bool) -> None:
        """One streaming search; tracks the delta-scan fraction."""
        self.metrics.counter("jag_stream_search_total").inc()
        if delta_scanned:
            self.metrics.counter("jag_delta_scan_total").inc()

    def delta_scan_fraction(self) -> float:
        total = self.metrics.value("jag_stream_search_total")
        if total == 0:
            return 0.0
        return self.metrics.value("jag_delta_scan_total") / total

    def jit_misses(self) -> int:
        return self.metrics.counter_total("jag_jit_miss_total")

    # ---- per-call trace recording --------------------------------------

    @staticmethod
    def _index_shape(index) -> Tuple[int, int, Optional[list]]:
        """(n, d, shard) — per-shard n_loc when the index is sharded."""
        n_loc = getattr(index, "n_loc", None)
        if n_loc is not None:     # sharded: xb is [S, n_loc, d]
            return int(n_loc), int(index.d), [int(index.n_shards), int(n_loc)]
        return int(index.xb.shape[0]), int(index.xb.shape[1]), None

    def record_call(self, index, plan, groups: Sequence[Tuple], *,
                    k: int, ls: int, router=None, filt=None,
                    mode: str = "per_query") -> None:
        """Record one ``search_auto`` call: one trace per served query.

        ``groups`` is ``[(band, realized, ids, result, stats,
        wall_seconds)]`` as timed by the dispatch layer — ``result`` is
        already blocked on, so pulling ``n_dist``/``n_expanded`` (and
        the introspective ``TraversalStats``, when present) to the host
        is a copy, not a sync inside anything compiled.  ``stats`` is
        None on non-graph routes and when introspection is off.
        """
        if not self.enabled:
            return
        now = time.time()
        n, d, shard = self._index_shape(index)
        epoch = int(getattr(index, "epoch", 0))
        delta = getattr(index, "delta", None)
        delta_n = int(delta.n) if hasattr(index, "delta_arrays") else 0
        n_clauses = int(getattr(router, "n_leaves", 1) or 1)
        metric = getattr(router, "metric", None) if router is not None else None
        # a streaming index with live delta rows merges the delta scan into
        # every search — the realized route the trace reports says so (the
        # same "+delta" suffix the returned plan carries)
        suffix = "+delta" if delta_n > 0 else ""
        sel = np.asarray(plan.selectivity, np.float64).reshape(-1)
        pred_cache: Dict[float, Dict[str, float]] = {}

        self.metrics.counter("jag_search_total").inc()
        for gi, (band, realized, ids, res, stats, wall_s) in enumerate(groups):
            ids = np.asarray(ids).reshape(-1)
            size = max(int(ids.size), 1)
            per_us = float(wall_s) * 1e6 / size
            n_dist = np.asarray(res.n_dist).reshape(-1)
            n_exp = np.asarray(res.n_expanded).reshape(-1)
            dead = sat = None
            if stats is not None:
                dead = np.asarray(stats.dead_ends).reshape(-1)
                sat = np.asarray(stats.sat_step).reshape(-1)
                self.metrics.counter("jag_introspect_query_total",
                                     route=band).inc(size)
                self.metrics.counter("jag_dead_end_total",
                                     route=band).inc(int(dead.sum()))
            self.metrics.counter("jag_route_call_total", route=band).inc()
            self.metrics.counter("jag_route_query_total", route=band).inc(size)
            lat = self.metrics.histogram("jag_latency_us", route=band,
                                         lo=1.0, factor=2.0, n_buckets=32)
            nds = self.metrics.histogram("jag_n_dist", route=band,
                                         lo=1.0, factor=2.0, n_buckets=32)
            for j, qi in enumerate(ids):
                s = float(sel[qi]) if qi < sel.size else float(sel[-1])
                predicted = None
                if router is not None:
                    key = round(s, 6)
                    predicted = pred_cache.get(key)
                    if predicted is None:
                        # pure route prediction: subtract the streaming
                        # delta tax the router folds into every route —
                        # the group wall time below excludes the delta
                        # scan, which runs (and is counted) separately
                        tax = float(getattr(router, "delta_tax", 0.0))
                        predicted = {r: float(c) - tax
                                     for r, c in router.costs(s).items()}
                        pred_cache[key] = predicted
                lat.observe(per_us)
                nds.observe(float(n_dist[j]) if j < n_dist.size else 0.0)
                self.traces.append(TraceRecord(
                    qid=self._qid, ts=now, epoch=epoch, band=str(band),
                    route=str(realized) + suffix, group=gi, group_size=size,
                    batch=int(sel.size), mode=mode, sel=s, k=int(k),
                    ls=int(ls), n=n, d=d, n_clauses=n_clauses,
                    delta_n=delta_n, shard=shard, predicted=predicted,
                    cost_metric=metric, observed_us=per_us,
                    n_dist=int(n_dist[j]) if j < n_dist.size else 0,
                    n_expanded=int(n_exp[j]) if j < n_exp.size else 0,
                    dead_ends=(int(dead[j]) if dead is not None
                               and j < dead.size else None),
                    sat_step=(int(sat[j]) if sat is not None
                              and j < sat.size else None)))
                self._qid += 1

        self._calls += 1
        if self.recal_every > 0 and self._calls % self.recal_every == 0:
            self.maybe_recalibrate(index)

    # ---- shadow-oracle recall auditing ---------------------------------

    def shadow_audit(self, index, queries, filt, result, plan, *,
                     k: int) -> int:
        """Audit the sampled fraction of one served call's queries.

        Called by ``search_auto`` with the FINAL served result (after
        any streaming delta merge), after ``record_call`` — so the qids
        audited here are exactly the qids just traced.  Runs on the
        host, off the serving critical path; returns the number of
        queries audited (0 when shadow auditing is off or none sampled).
        """
        if not self.enabled or self.shadow is None:
            return 0
        sel = np.asarray(plan.selectivity, np.float64).reshape(-1)
        B = int(sel.size)
        realized = getattr(plan, "realized", None)
        if realized is None:
            realized = getattr(plan, "routes", None) or getattr(
                plan, "route", "?")
        routes = ([str(realized)] * B if isinstance(realized, str)
                  else [str(r) for r in realized])
        n = self.shadow.audit(
            index, queries, filt, result, k=int(k),
            qid0=max(self._qid - B, 0), routes=routes, sels=sel,
            epoch=int(getattr(index, "epoch", 0)))
        if n:
            self.metrics.counter("jag_shadow_audit_total").inc(n)
        return n

    # ---- health ---------------------------------------------------------

    def health_report(self, slo=None) -> dict:
        """The fused pass/warn/fail SLO document over live serving state.

        See ``repro.obs.health.health_report``; the drift threshold
        defaults to this telemetry's.
        """
        from .health import HealthSLO, health_report
        if slo is None:
            slo = HealthSLO(drift_threshold=self.drift_threshold)
        if self.shadow is not None:
            self.shadow.flush()
        shadow = list(self.shadow.records) if self.shadow is not None else ()
        return health_report(self.traces.window(), shadow, slo)

    # ---- drift / re-calibration ----------------------------------------

    def drift_status(self, *, window: int = 512,
                     min_traces: int = 16) -> DriftReport:
        return detect_drift(self.traces, threshold=self.drift_threshold,
                            min_traces=min_traces, window=window)

    def maybe_recalibrate(self, index, *, require_drift: bool = True,
                          window: Optional[int] = None) -> RecalReport:
        """Drift-gated, hysteresis-gated refit of the index's cost model.

        On a swap the candidate is attached back onto the index via
        ``attach_cost_model`` (same metric), so the very next
        ``search_auto`` routes with the re-calibrated model.
        """
        model = getattr(index, "cost_model", None)
        metric = getattr(index, "cost_metric", "us")
        if model is None:
            report = RecalReport(False, "no cost model attached", None,
                                 None, None, None, 0, 0)
        else:
            report = recalibrate(model, self.traces.window(window),
                                 metric=metric,
                                 min_traces=self.recal_min_traces,
                                 drift_threshold=self.drift_threshold,
                                 require_drift=require_drift)
            if report.swapped:
                index.attach_cost_model(report.model, metric=metric)
                self.metrics.counter("jag_recal_swap_total").inc()
        self.last_recal = report
        return report


__all__ = ["Telemetry"]
