"""Shadow-oracle recall auditing: sampled online ground-truth checks.

JAG's headline claim is *recall robustness*, but serving telemetry (PR 9)
observes only cost.  This module closes the loop without offline ground
truth: for a deterministic, configurable fraction of served queries the
auditor re-runs ``core.ground_truth.exact_filtered_knn`` over the same
filter expression — against the FULL live database (base rows plus any
streaming delta rows) — and folds the per-query hit counts into rolling
recall@k estimators keyed by realized route × selectivity band × epoch,
each with a Wilson score confidence interval.

Design constraints, all honored here:

* **Deterministic sampling** — membership is a pure hash of the
  telemetry-global query id (Knuth multiplicative hash), so a replayed
  workload audits the same queries and two processes agree without
  coordination.  Sequential qids map to an equidistributed hash
  sequence, so a fraction ``f`` samples ``~f`` of traffic evenly.
* **Off the critical path** — the serving side of an audit is a cheap
  enqueue: the sampled queries, the served top-k rows, and snapshot
  references to the live database arrays are captured on the host after
  the served result is blocked on, and the oracle replay runs later, at
  :meth:`ShadowAuditor.flush` (every reporting accessor flushes first;
  a bounded pending queue flushes synchronously at ``max_pending`` so
  memory cannot grow without bound).  Nothing here is traced into any
  compiled route (rules JAG005/JAG006; the auditor proves the budgets).
  The oracle scan itself is the existing jit'd ``exact_filtered_knn``;
  sampled sub-batches are padded to power-of-two buckets so varying
  per-call sample counts reuse a handful of compilations.
* **Exact arithmetic** — recall@k is counted the way
  ``core.recall.recall_at_k`` defines it: every ground-truth neighbor
  is one Bernoulli trial, a served id with the filter-valid key
  (``primary == 0``) that appears in the ground-truth set is a hit, and
  a vacuous query (no row passes the filter) contributes no trials.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .trace import TraceBuffer

# Knuth's multiplicative hash constant (2^32 / golden ratio)
_KNUTH = 2654435761
_Z95 = 1.959963984540054          # two-sided 95% normal quantile

# fixed geometric selectivity-band edges: the regimes the planner routes
# between (prefilter <=~1%, graph in the middle, postfilter >=~75%)
SEL_BAND_EDGES: Tuple[float, ...] = (0.001, 0.01, 0.1, 0.5)


def sel_band(sel: float) -> str:
    """The fixed selectivity band a sampled selectivity falls in."""
    for edge in SEL_BAND_EDGES:
        if sel <= edge:
            return f"sel<={edge:g}"
    return f"sel>{SEL_BAND_EDGES[-1]:g}"


def sampled_qid(qid: int, fraction: float) -> bool:
    """Deterministic hash-of-qid sampling at ``fraction`` of traffic."""
    if fraction >= 1.0:
        return True
    if fraction <= 0.0:
        return False
    return ((qid * _KNUTH) & 0xFFFFFFFF) < int(fraction * 4294967296.0)


def wilson_interval(successes: int, trials: int,
                    z: float = _Z95) -> Tuple[float, float]:
    """Wilson score interval for a binomial proportion.

    Well-behaved at small n and at p near 0/1 (unlike the normal
    approximation), which is exactly the sampled-shadow regime.
    """
    if trials <= 0:
        return (0.0, 1.0)
    n = float(trials)
    p = successes / n
    denom = 1.0 + z * z / n
    center = (p + z * z / (2.0 * n)) / denom
    half = z * math.sqrt(p * (1.0 - p) / n + z * z / (4.0 * n * n)) / denom
    return (max(0.0, center - half), min(1.0, center + half))


@dataclass(frozen=True)
class ShadowRecord:
    """One audited query: served result vs the exact oracle."""

    qid: int
    ts: float
    epoch: int
    route: str       # realized route descriptor (e.g. "graph[fused,int8]")
    band: str        # selectivity band (see :func:`sel_band`)
    sel: float
    k: int
    hits: int        # ground-truth neighbors present in the served top-k
    trials: int      # ground-truth neighbors (<= k; 0 = vacuous filter)
    recall: float    # hits / trials (1.0 on vacuous, recall_at_k convention)


class RecallCell:
    """Rolling recall estimator for one route × band × epoch cell."""

    __slots__ = ("hits", "trials", "n_queries")

    def __init__(self):
        self.hits = 0
        self.trials = 0
        self.n_queries = 0

    def update(self, hits: int, trials: int) -> None:
        self.hits += int(hits)
        self.trials += int(trials)
        self.n_queries += 1

    @property
    def estimate(self) -> float:
        return self.hits / self.trials if self.trials else 1.0

    def wilson(self, z: float = _Z95) -> Tuple[float, float]:
        return wilson_interval(self.hits, self.trials, z)


def oracle_arrays(index):
    """(vectors, attr table) covering every live row the index serves.

    Frozen ``JAGIndex``: the base arrays.  ``StreamingJAGIndex``: base
    vectors + delta vectors (``index.attr`` is already the merged live
    table, and delta ids are offset past the base — matching the oracle's
    row order exactly).  Sharded: the replicated union attr table with
    ``xb [S, n_loc, d]`` flattened shard-major, matching the globalized
    ids (``local + shard * n_loc``) the sharded routes return.
    """
    import jax.numpy as jnp
    xb = jnp.asarray(index.xb)
    if getattr(index, "n_loc", None) is not None:
        xb = xb.reshape(-1, xb.shape[-1])
    if hasattr(index, "delta_arrays") and getattr(index.delta, "n", 0) > 0:
        xv, _, _ = index.delta_arrays()
        xb = jnp.concatenate([xb, jnp.asarray(xv)], axis=0)
    return xb, index.attr


@dataclass(frozen=True)
class _PendingAudit:
    """One served call's sampled queries, snapshotted for deferred replay.

    ``xb``/``attr`` are references to the live arrays at serve time
    (append-only streaming deltas are concatenated at capture, so rows
    that exist later cannot leak into the snapshot); ``queries`` is a
    host copy of the sampled (bucket-padded) query rows; served ids and
    the filter-valid mask are host copies of the sampled result rows.
    """

    xb: object
    attr: object
    queries: np.ndarray        # [bucket, d] host copy
    filt: object               # the (immutable) served filter
    padded: np.ndarray         # int32 [bucket] indices into the batch
    n_sampled: int
    served_ids: np.ndarray     # [n_sampled, k]
    served_ok: np.ndarray      # [n_sampled, k] bool
    routes: Tuple[str, ...]
    sels: Tuple[float, ...]
    qids: Tuple[int, ...]
    epoch: int
    k: int


class ShadowAuditor:
    """Sampled shadow-oracle recall estimation over served queries.

    ``fraction`` of queries (hash-of-qid) are re-answered exactly and
    compared to what was served; per-cell estimators aggregate across
    calls.  The serve-time half (:meth:`audit`) only enqueues host
    snapshots — the oracle replay runs at :meth:`flush`, which every
    reporting accessor calls first, so sampling stays off the serving
    critical path.  ``records`` is a bounded ring of per-query
    :class:`ShadowRecord` with JSONL dump/load, so ``jagstat --health``
    can rebuild the estimators offline.
    """

    def __init__(self, fraction: float = 0.05, capacity: int = 4096,
                 max_pending: int = 256):
        if not 0.0 <= fraction <= 1.0:
            raise ValueError(f"fraction must be in [0, 1], got {fraction}")
        self.fraction = float(fraction)
        self.records = TraceBuffer(capacity)
        self.cells: Dict[Tuple[str, str, int], RecallCell] = {}
        self.n_audited = 0
        self.max_pending = int(max_pending)
        self._pending: List[_PendingAudit] = []

    @property
    def n_pending(self) -> int:
        """Sampled queries enqueued but not yet replayed."""
        return sum(e.n_sampled for e in self._pending)

    # -- the audit ---------------------------------------------------------
    def audit(self, index, queries, filt, result, *, k: int, qid0: int,
              routes: Sequence[str], sels, epoch: int = 0) -> int:
        """Enqueue the sampled subset of one served call; returns #sampled.

        ``result`` is the FINAL served ``SearchResult`` (post delta-merge
        for a streaming index), ``routes[i]``/``sels[i]`` the per-query
        realized route and sampled selectivity, ``qid0`` the telemetry
        qid of query 0.  Runs on the host after the served call returned
        and does no oracle work — it snapshots the sampled queries, the
        served rows, and the live database arrays, then defers the exact
        replay to :meth:`flush` (triggered automatically once
        ``max_pending`` calls accumulate, and by every reporting
        accessor).
        """
        sels = np.asarray(sels, np.float64).reshape(-1)
        B = int(sels.size)
        pos = [i for i in range(B) if sampled_qid(qid0 + i, self.fraction)]
        if not pos:
            return 0
        # pad the sampled sub-batch to a power-of-two bucket: the oracle
        # recompiles per batch shape, and per-call sample counts vary
        bucket = 1 << (len(pos) - 1).bit_length()
        padded = np.asarray(pos + [pos[0]] * (bucket - len(pos)), np.int32)
        served_ids = np.asarray(result.ids)[pos]
        served_ok = ((np.asarray(result.primary)[pos] == 0.0)
                     & (served_ids >= 0))
        xb, attr = oracle_arrays(index)
        self._pending.append(_PendingAudit(
            xb=xb, attr=attr,
            queries=np.asarray(queries)[padded], filt=filt, padded=padded,
            n_sampled=len(pos), served_ids=served_ids, served_ok=served_ok,
            routes=tuple(str(routes[i]) if i < len(routes)
                         else str(routes[-1]) for i in pos),
            sels=tuple(float(sels[i]) for i in pos),
            qids=tuple(int(qid0 + i) for i in pos),
            epoch=int(epoch), k=int(k)))
        if len(self._pending) >= self.max_pending:
            self.flush()
        return len(pos)

    def flush(self) -> int:
        """Replay every pending oracle audit; returns #queries audited."""
        if not self._pending:
            return 0
        import jax
        import jax.numpy as jnp
        from ..core.ground_truth import exact_filtered_knn

        pending, self._pending = self._pending, []
        n = 0
        for e in pending:
            q = jnp.asarray(e.queries)
            f = e.filt.take(e.padded)
            gt = jax.block_until_ready(
                exact_filtered_knn(e.xb, e.attr, q, f, k=e.k))
            gt_ids = np.asarray(gt.ids)
            now = time.time()
            for j in range(e.n_sampled):
                g = gt_ids[j]
                g = g[g >= 0]
                trials = int(g.size)
                s = e.served_ids[j][e.served_ok[j]]
                hits = int(np.intersect1d(s, g).size) if trials else 0
                band = sel_band(e.sels[j])
                cell = self.cells.setdefault(
                    (e.routes[j], band, e.epoch), RecallCell())
                cell.update(hits, trials)
                self.records.append(ShadowRecord(
                    qid=e.qids[j], ts=now, epoch=e.epoch,
                    route=e.routes[j], band=band, sel=e.sels[j], k=e.k,
                    hits=hits, trials=trials,
                    recall=(hits / trials) if trials else 1.0))
                self.n_audited += 1
                n += 1
        return n

    # -- reporting ---------------------------------------------------------
    def recall_table(self, z: float = _Z95) -> List[dict]:
        """Per-cell rows: estimate + Wilson bounds, route/band/epoch sorted."""
        self.flush()
        rows = []
        for (route, band, epoch) in sorted(self.cells):
            cell = self.cells[(route, band, epoch)]
            lo, hi = cell.wilson(z)
            rows.append({"route": route, "band": band, "epoch": epoch,
                         "n_queries": cell.n_queries,
                         "trials": cell.trials, "hits": cell.hits,
                         "recall": round(cell.estimate, 4),
                         "wilson_lo": round(lo, 4),
                         "wilson_hi": round(hi, 4)})
        return rows

    def dump_jsonl(self, path: str) -> int:
        """Write the audit records as JSON-lines; returns the count."""
        self.flush()
        return self.records.dump_jsonl(path)


def cells_from_records(records: Sequence[ShadowRecord]
                       ) -> Dict[Tuple[str, str, int], RecallCell]:
    """Rebuild per-cell estimators from dumped records (jagstat --health)."""
    cells: Dict[Tuple[str, str, int], RecallCell] = {}
    for r in records:
        cells.setdefault((r.route, r.band, int(r.epoch)),
                         RecallCell()).update(r.hits, r.trials)
    return cells


def load_shadow_jsonl(path: str) -> List[ShadowRecord]:
    """Load a :meth:`ShadowAuditor.dump_jsonl` file back into records."""
    import json
    from dataclasses import fields
    names = tuple(f.name for f in fields(ShadowRecord))
    out: List[ShadowRecord] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            raw = json.loads(line)
            if "__trace_meta__" in raw:
                continue
            out.append(ShadowRecord(**{k: v for k, v in raw.items()
                                       if k in names}))
    return out


__all__ = ["RecallCell", "SEL_BAND_EDGES", "ShadowAuditor", "ShadowRecord",
           "cells_from_records", "load_shadow_jsonl", "oracle_arrays",
           "sampled_qid", "sel_band", "wilson_interval"]
