"""Serving health: one pass/warn/fail SLO document for the whole stack.

``health_report`` fuses the quality and cost observability signals into
a single machine-checkable document:

* **shadow recall** — per route × band × epoch cells from
  ``obs.shadow`` audit records, judged against the recall SLO with the
  Wilson interval: *fail* only when the interval's upper bound is below
  the SLO (the estimator is confident recall is bad), *warn* when the
  point estimate is below it or the cell has too few trials to say.
* **dead ends** — per-route dead-end rate from introspection trace
  fields (``obs.introspect``), warn/fail thresholds.
* **latency** — per-route p50/p95/p99 over the trace window's
  ``observed_us`` (same percentile arithmetic as ``tools/jagstat.py``),
  judged against an optional p99 SLO.
* **drift** — ``obs.drift`` flags as warnings (a drifting cost model is
  a leading indicator, not a user-facing failure).

Overall status is the worst section status.  ``render_health`` formats
the document for ``tools/jagstat.py --health``; ``Telemetry.
health_report()`` builds one from live serving state.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import List, Optional, Sequence

import numpy as np

from .drift import DEFAULT_THRESHOLD, detect_drift
from .introspect import introspection_summary
from .shadow import RecallCell, cells_from_records

PASS, WARN, FAIL = "pass", "warn", "fail"
_ORDER = {PASS: 0, WARN: 1, FAIL: 2}


def _worst(statuses: Sequence[str]) -> str:
    return max(statuses, key=_ORDER.__getitem__) if statuses else PASS


@dataclass(frozen=True)
class HealthSLO:
    """The thresholds one serving deployment is judged against."""

    recall: float = 0.9            # recall@k floor per route × band cell
    min_shadow_trials: int = 20    # below this a cell can only warn, not pass
    p99_us: Optional[float] = None          # per-route p99 bound (None = off)
    dead_end_warn: float = 0.5     # dead ends per hop: warn above
    dead_end_fail: float = 0.9     # ... fail above
    drift_threshold: float = DEFAULT_THRESHOLD


def _shadow_section(shadow_records, slo: HealthSLO) -> dict:
    cells = cells_from_records(shadow_records)
    rows: List[dict] = []
    for (route, band, epoch) in sorted(cells):
        cell: RecallCell = cells[(route, band, epoch)]
        lo, hi = cell.wilson()
        if cell.trials == 0:
            status, why = WARN, "no trials (vacuous filters only)"
        elif hi < slo.recall:
            status = FAIL
            why = (f"recall confidently below SLO "
                   f"(CI upper {hi:.3f} < {slo.recall:g})")
        elif cell.estimate < slo.recall:
            status = WARN
            why = (f"point estimate {cell.estimate:.3f} below SLO "
                   f"{slo.recall:g} (CI straddles)")
        elif cell.trials < slo.min_shadow_trials:
            status = WARN
            why = (f"only {cell.trials} trials "
                   f"(< {slo.min_shadow_trials} for a confident pass)")
        else:
            status, why = PASS, ""
        rows.append({"route": route, "band": band, "epoch": epoch,
                     "n_queries": cell.n_queries, "trials": cell.trials,
                     "recall": round(cell.estimate, 4),
                     "wilson_lo": round(lo, 4), "wilson_hi": round(hi, 4),
                     "status": status, "why": why})
    status = _worst([r["status"] for r in rows]) if rows else WARN
    note = "" if rows else "no shadow audits recorded"
    return {"status": status, "note": note, "cells": rows}


def _dead_end_section(traces, slo: HealthSLO) -> dict:
    rows = []
    for r in introspection_summary(traces):
        rate = r["dead_end_rate"]
        if rate is None:
            status = WARN
        elif rate > slo.dead_end_fail:
            status = FAIL
        elif rate > slo.dead_end_warn:
            status = WARN
        else:
            status = PASS
        rows.append({**r, "status": status})
    status = _worst([r["status"] for r in rows]) if rows else PASS
    note = "" if rows else "no introspection counters in the window"
    return {"status": status, "note": note, "routes": rows}


def _latency_section(traces, slo: HealthSLO) -> dict:
    groups = {}
    for t in traces:
        groups.setdefault(t.route, []).append(float(t.observed_us))
    rows = []
    for route in sorted(groups):
        lat = np.asarray(groups[route], np.float64)
        p99 = float(np.percentile(lat, 99))
        if slo.p99_us is None:
            status = PASS
        elif p99 > 2.0 * slo.p99_us:
            status = FAIL
        elif p99 > slo.p99_us:
            status = WARN
        else:
            status = PASS
        rows.append({"route": route, "queries": int(lat.size),
                     "p50_us": round(float(np.percentile(lat, 50)), 1),
                     "p95_us": round(float(np.percentile(lat, 95)), 1),
                     "p99_us": round(p99, 1), "status": status})
    status = _worst([r["status"] for r in rows]) if rows else PASS
    note = "" if rows else "no traces in the window"
    return {"status": status, "note": note, "routes": rows}


def _drift_section(traces, slo: HealthSLO) -> dict:
    rep = detect_drift(traces, threshold=slo.drift_threshold)
    status = WARN if rep.any_drifted else PASS
    return {"status": status, "summary": rep.summary(),
            "median_rel_err": {b: round(e, 4)
                               for b, e in rep.median_rel_err.items()},
            "drifted": dict(rep.drifted)}


def health_report(traces, shadow_records=(),
                  slo: HealthSLO = HealthSLO()) -> dict:
    """Fuse recall, dead-end, latency, and drift signals into one SLO doc.

    ``traces`` is any iterable of ``TraceRecord`` (a live ``TraceBuffer``
    or a loaded dump); ``shadow_records`` any iterable of
    ``ShadowRecord``.  Pure host-side aggregation — safe to run on a
    serving process or offline on dumped windows.
    """
    traces = list(traces)
    shadow_records = list(shadow_records)
    sections = {
        "shadow_recall": _shadow_section(shadow_records, slo),
        "dead_ends": _dead_end_section(traces, slo),
        "latency": _latency_section(traces, slo),
        "drift": _drift_section(traces, slo),
    }
    return {"status": _worst([s["status"] for s in sections.values()]),
            "slo": asdict(slo),
            "n_traces": len(traces),
            "n_shadow": len(shadow_records),
            **sections}


def render_health(report: dict) -> str:
    """Human-readable rendering of a :func:`health_report` document."""
    mark = {PASS: "ok  ", WARN: "WARN", FAIL: "FAIL"}
    lines = [f"health: {report['status'].upper()}  "
             f"({report['n_traces']} traces, "
             f"{report['n_shadow']} shadow audits)"]
    sh = report["shadow_recall"]
    lines.append(f"[{mark[sh['status']]}] shadow recall"
                 + (f" — {sh['note']}" if sh["note"] else ""))
    for c in sh["cells"]:
        why = f"  ({c['why']})" if c["why"] else ""
        lines.append(
            f"         {c['route']:<24} {c['band']:<12} epoch={c['epoch']} "
            f"recall={c['recall']:.3f} "
            f"ci=[{c['wilson_lo']:.3f},{c['wilson_hi']:.3f}] "
            f"trials={c['trials']} [{c['status']}]{why}")
    de = report["dead_ends"]
    lines.append(f"[{mark[de['status']]}] dead ends"
                 + (f" — {de['note']}" if de["note"] else ""))
    for r in de["routes"]:
        rate = "-" if r["dead_end_rate"] is None else f"{r['dead_end_rate']:.3f}"
        lines.append(
            f"         {r['route']:<24} rate={rate} "
            f"hops~={r['mean_hops']} sat~={r['mean_sat_step']} "
            f"[{r['status']}]")
    la = report["latency"]
    lines.append(f"[{mark[la['status']]}] latency"
                 + (f" — {la['note']}" if la["note"] else ""))
    for r in la["routes"]:
        lines.append(
            f"         {r['route']:<24} p50={r['p50_us']} p95={r['p95_us']} "
            f"p99={r['p99_us']} us [{r['status']}]")
    dr = report["drift"]
    lines.append(f"[{mark[dr['status']]}] {dr['summary']}")
    return "\n".join(lines)


__all__ = ["FAIL", "HealthSLO", "PASS", "WARN", "health_report",
           "render_health"]
