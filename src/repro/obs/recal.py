"""Telemetry-driven cost-model re-calibration with hysteresis.

Closes the ROADMAP loop "feed served-query telemetry back into
``cost.fit``": a window of :class:`~repro.obs.trace.TraceRecord` becomes
calibration :class:`~repro.cost.model.Observation` rows (the trace
already carries every canonical feature — sel, n, d, k, ls, n_clauses —
plus the observed us / n_dist), ``cost.fit`` re-fits the routes the
window actually served, and the refit only replaces the attached model
when BOTH gates pass:

1. drift gate — :func:`~repro.obs.drift.detect_drift` flags the window
   (skippable with ``require_drift=False`` for forced refits);
2. hysteresis gate — the candidate's median relative error on a
   deterministic held-out split of the window is STRICTLY below the
   stale model's.  An unbiased window therefore never swaps (the stale
   model is already the argmin), which is what prevents oscillation.

Routes the window never served keep the stale model's coefficients
(coef-level merge), so a single-band traffic burst cannot shrink the
model's coverage below what ``Executor.cost_router`` requires.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from ..cost.model import BASE_ROUTES, CostModel, Observation, fit
from .drift import DEFAULT_THRESHOLD, DriftReport, detect_drift
from .trace import TraceRecord


def observations_from_traces(
        traces: Sequence[TraceRecord]) -> List[Observation]:
    """Convert served-query traces into ``cost.fit`` observations.

    The observation's route is the planner BAND (prefilter/graph/
    postfilter) — the cost model's vocabulary — not the realized layout
    descriptor.  Traces with non-positive wall time are dropped here the
    same way ``fit`` drops non-positive measurements.
    """
    out: List[Observation] = []
    for t in traces:
        if t.observed_us is None or t.observed_us <= 0:
            continue
        out.append(Observation(
            route=t.band,
            features=dict(sel=float(t.sel), n=float(t.n), d=float(t.d),
                          k=float(t.k), ls=float(t.ls),
                          delta_n=float(t.delta_n),
                          n_clauses=float(max(t.n_clauses, 1))),
            us=float(t.observed_us),
            n_dist=float(max(t.n_dist, 0))))
    return out


def heldout_error(model, traces: Sequence[TraceRecord],
                  metric: str = "us") -> Optional[float]:
    """Median relative error of ``model`` on a trace set, or None.

    Predictions are made directly with ``model.predict`` (no delta-tax
    folding) so stale and candidate models are compared on identical
    terms.  Works for any model exposing ``predict``/``covers`` — the
    sharded :class:`~repro.cost.model.InterpolatedCostModel` included.
    """
    errs: List[float] = []
    for t in traces:
        observed = t.n_dist if metric == "n_dist" else t.observed_us
        if observed is None or observed <= 0:
            continue
        if not model.covers((t.band,), metric):
            continue
        feats = dict(sel=float(t.sel), n=float(t.n), d=float(t.d),
                     k=float(t.k), ls=float(t.ls), delta_n=float(t.delta_n),
                     n_clauses=float(max(t.n_clauses, 1)))
        pred = float(model.predict(t.band, feats, metric))
        errs.append(abs(pred - float(observed)) / float(observed))
    if not errs:
        return None
    s = sorted(errs)
    m = len(s) // 2
    return s[m] if len(s) % 2 else 0.5 * (s[m - 1] + s[m])


def _merge(stale, refit: CostModel, metric: str) -> CostModel:
    """Candidate = refit routes layered over the stale model's coef.

    Only possible when the stale model is a plain coefficient model; an
    ``InterpolatedCostModel`` (sharded multi-grid) has no single ``coef``
    table, so the bare refit stands alone and must cover the base routes
    by itself to pass the coverage gate.
    """
    if not hasattr(stale, "coef"):
        return refit
    coef = {r: dict(ms) for r, ms in stale.coef.items()}
    for r, ms in refit.coef.items():
        coef.setdefault(r, {}).update(ms)
    stats = dict(getattr(stale, "fit_stats", {}) or {})
    stats.update(refit.fit_stats)
    meta = dict(refit.meta)
    meta["merged_over"] = sorted(set(stale.coef) - set(refit.coef))
    return CostModel(coef=coef, meta=meta, fit_stats=stats)


@dataclass(frozen=True)
class RecalReport:
    """Outcome of one re-calibration attempt."""

    swapped: bool                  # True -> `model` is the new candidate
    reason: str                    # human-readable gate outcome
    model: object                  # candidate when swapped, else the stale model
    drift: Optional[DriftReport]
    stale_err: Optional[float]     # held-out median rel err, stale model
    refit_err: Optional[float]     # held-out median rel err, candidate
    n_train: int
    n_holdout: int


def recalibrate(model, traces: Sequence[TraceRecord], *,
                metric: str = "us",
                min_traces: int = 64,
                drift_threshold: float = DEFAULT_THRESHOLD,
                require_drift: bool = True,
                holdout_every: int = 4,
                routes: Tuple[str, ...] = BASE_ROUTES) -> RecalReport:
    """Refit ``model`` from a trace window; swap only if strictly better.

    The holdout split is deterministic (every ``holdout_every``-th
    comparable trace) so repeated calls over the same window reach the
    same verdict — no sampling jitter in the hysteresis decision.
    """
    usable = [t for t in traces
              if (t.n_dist if metric == "n_dist" else t.observed_us) and
              (t.n_dist if metric == "n_dist" else t.observed_us) > 0]
    if len(usable) < min_traces:
        return RecalReport(False, f"window too small ({len(usable)} < "
                           f"{min_traces} traces)", model, None, None, None,
                           0, 0)

    drift = detect_drift(usable, threshold=drift_threshold,
                         min_traces=max(4, min_traces // 8))
    if require_drift and not drift.any_drifted:
        return RecalReport(False, "no drift: " + drift.summary(), model,
                           drift, None, None, 0, 0)

    holdout = usable[::holdout_every]
    train = [t for i, t in enumerate(usable) if i % holdout_every != 0]
    if not holdout or not train:
        return RecalReport(False, "degenerate holdout split", model, drift,
                           None, None, len(train), len(holdout))

    meta = dict(getattr(model, "meta", {}) or {})
    meta.update(source="telemetry", n_traces=len(train))
    refit = fit(observations_from_traces(train), meta)
    candidate = _merge(model, refit, metric)
    if not candidate.covers(routes, metric):
        return RecalReport(False, f"refit covers {candidate.routes()}, "
                           f"router needs {routes}", model, drift, None,
                           None, len(train), len(holdout))

    stale_err = heldout_error(model, holdout, metric)
    refit_err = heldout_error(candidate, holdout, metric)
    if stale_err is None or refit_err is None:
        return RecalReport(False, "no comparable held-out traces", model,
                           drift, stale_err, refit_err, len(train),
                           len(holdout))
    if refit_err >= stale_err:
        return RecalReport(False, f"hysteresis: refit {refit_err:.3f} >= "
                           f"stale {stale_err:.3f} on holdout", model, drift,
                           stale_err, refit_err, len(train), len(holdout))
    return RecalReport(True, f"refit {refit_err:.3f} < stale "
                       f"{stale_err:.3f} on {len(holdout)} held-out traces",
                       candidate, drift, stale_err, refit_err, len(train),
                       len(holdout))


__all__ = ["RecalReport", "recalibrate", "observations_from_traces",
           "heldout_error"]
