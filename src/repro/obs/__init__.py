"""Serving telemetry: per-query traces, route metrics, drift-driven recal.

Attach to any index with ``index.attach_telemetry()`` (off by default,
detach with ``attach_telemetry(None)``).  Everything is host-side and
post-execution — compiled routes are bit-identical with telemetry on,
which rule JAG006 and the compiled-route auditor enforce statically.
"""
from .drift import DriftReport, detect_drift, relative_error
from .metrics import Counter, Histogram, MetricsRegistry
from .recal import RecalReport, heldout_error, observations_from_traces, recalibrate
from .telemetry import Telemetry
from .trace import TraceBuffer, TraceRecord, load_jsonl

__all__ = [
    "Counter",
    "DriftReport",
    "Histogram",
    "MetricsRegistry",
    "RecalReport",
    "Telemetry",
    "TraceBuffer",
    "TraceRecord",
    "detect_drift",
    "heldout_error",
    "load_jsonl",
    "observations_from_traces",
    "recalibrate",
    "relative_error",
]
