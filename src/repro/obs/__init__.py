"""Serving telemetry: per-query traces, route metrics, drift-driven recal,
and quality observability (shadow-oracle recall, traversal introspection,
pipeline spans, the serving health report).

Attach to any index with ``index.attach_telemetry()`` (off by default,
detach with ``attach_telemetry(None)``).  Everything is host-side and
post-execution — compiled routes are bit-identical with telemetry on,
which rule JAG006 and the compiled-route auditor enforce statically.
The introspective graph route (``Telemetry(introspect=True)``) is the
one deliberate exception: it compiles a *separate* cache entry whose
extra outputs are pure device counters — still zero callbacks, zero
collectives, and bit-identical (ids, keys).
"""
from .drift import DriftReport, detect_drift, relative_error
from .health import HealthSLO, health_report, render_health
from .introspect import introspection_summary, stats_to_host
from .metrics import Counter, Histogram, MetricsRegistry
from .recal import RecalReport, heldout_error, observations_from_traces, recalibrate
from .shadow import (ShadowAuditor, ShadowRecord, cells_from_records,
                     load_shadow_jsonl, sel_band, wilson_interval)
from .spans import Span, SpanRecorder
from .telemetry import Telemetry
from .trace import TraceBuffer, TraceRecord, load_buffer, load_jsonl

__all__ = [
    "Counter",
    "DriftReport",
    "HealthSLO",
    "Histogram",
    "MetricsRegistry",
    "RecalReport",
    "ShadowAuditor",
    "ShadowRecord",
    "Span",
    "SpanRecorder",
    "Telemetry",
    "TraceBuffer",
    "TraceRecord",
    "cells_from_records",
    "detect_drift",
    "health_report",
    "heldout_error",
    "introspection_summary",
    "load_buffer",
    "load_jsonl",
    "load_shadow_jsonl",
    "observations_from_traces",
    "recalibrate",
    "relative_error",
    "render_health",
    "sel_band",
    "stats_to_host",
    "wilson_interval",
]
