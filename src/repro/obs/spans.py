"""Hierarchical span timing for the serving pipeline.

A :class:`SpanRecorder` wraps the host-side stages of one search —
plan → per-route gather → execute → scatter → merge — in nested
``with recorder.span(name):`` blocks and keeps a bounded list of
completed :class:`Span` records.  Timing is ``time.perf_counter`` on
the host around the compiled calls, never inside them (rule JAG006):
attaching spans changes nothing about the programs the executor
compiles.

``chrome_trace()`` renders the recorded spans as Chrome trace-event
JSON (``"ph": "X"`` complete events, microsecond ``ts``/``dur``) —
``export_chrome_trace(path)`` writes a file that loads directly in
Perfetto / ``chrome://tracing``.  Nesting is expressed the way those
viewers expect: same pid/tid, containment by time range; ``depth`` is
additionally recorded in ``args`` for programmatic consumers.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class Span:
    """One completed pipeline stage."""

    name: str
    t0: float                  # seconds since the recorder's origin
    t1: float
    depth: int                 # nesting depth at entry (0 = top level)
    parent: Optional[str]      # enclosing span's name, if any
    args: Dict[str, object] = field(default_factory=dict)

    @property
    def duration_us(self) -> float:
        return (self.t1 - self.t0) * 1e6


class SpanRecorder:
    """Bounded recorder of nested host-side spans.

    Appends are O(1); once ``capacity`` spans are held the oldest are
    evicted (``dropped`` counts them).  Reentrant nesting is tracked
    with an explicit stack, so recording is single-threaded like the
    rest of the serving loop.
    """

    def __init__(self, capacity: int = 8192):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.spans: List[Span] = []
        self.dropped = 0
        self._stack: List[str] = []
        self._origin = time.perf_counter()

    @contextmanager
    def span(self, name: str, **args):
        """Time a pipeline stage; nest freely."""
        depth = len(self._stack)
        parent = self._stack[-1] if self._stack else None
        self._stack.append(name)
        t0 = time.perf_counter() - self._origin
        try:
            yield self
        finally:
            t1 = time.perf_counter() - self._origin
            self._stack.pop()
            self.spans.append(Span(name, t0, t1, depth, parent, dict(args)))
            if len(self.spans) > self.capacity:
                drop = len(self.spans) - self.capacity
                del self.spans[:drop]
                self.dropped += drop

    def clear(self) -> None:
        self.spans.clear()
        self.dropped = 0

    def totals_us(self) -> Dict[str, float]:
        """Summed wall time per span name, microseconds."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.duration_us
        return out

    def chrome_trace(self) -> List[dict]:
        """The recorded spans as Chrome trace-event complete events."""
        events = []
        for s in self.spans:
            args = dict(s.args)
            args["depth"] = s.depth
            if s.parent is not None:
                args["parent"] = s.parent
            events.append({
                "name": s.name, "cat": "serve", "ph": "X",
                "ts": round(s.t0 * 1e6, 3),
                "dur": round(s.duration_us, 3),
                "pid": 0, "tid": 0, "args": args,
            })
        return events

    def export_chrome_trace(self, path: str) -> int:
        """Write ``{"traceEvents": [...]}`` JSON; returns the event count.

        The object form (rather than the bare array) keeps the file
        self-describing; both load in Perfetto and chrome://tracing.
        """
        events = self.chrome_trace()
        with open(path, "w") as fh:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, fh)
        return len(events)


__all__ = ["Span", "SpanRecorder"]
