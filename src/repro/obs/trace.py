"""Per-query trace records and the bounded host-side ring buffer.

A :class:`TraceRecord` is one served query: which planner band it fell
into, which compiled route actually ran (the realized descriptor, e.g.
``graph[fused,int8]`` or ``prefilter+delta``), the sampled selectivity,
the per-route predicted costs the router compared, and the observed
outcome (wall-clock microseconds, ``n_dist``/``n_expanded`` pulled from
the already device-resident ``SearchResult``).

Records are appended by host-side wrappers AFTER ``block_until_ready``
returns — never from inside a jit-traced function (rule JAG006) — so
tracing changes nothing about the compiled routes.  The buffer is a
fixed-capacity ring: appends are O(1), old records fall off the front,
and ``dropped`` counts what fell off.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, fields
from typing import Dict, Iterator, List, Optional


@dataclass(frozen=True)
class TraceRecord:
    """One served query, as observed by the host-side telemetry wrapper."""

    qid: int                 # monotonically increasing per-Telemetry query id
    ts: float                # host unix timestamp at record time
    epoch: int               # index epoch the query was served at
    band: str                # planner band: prefilter | graph | postfilter
    route: str               # realized descriptor, e.g. "graph[fused,int8]"
    group: int               # banded group index within the dispatch
    group_size: int          # queries sharing this group's compiled call
    batch: int               # full search_auto batch size
    mode: str                # "per_query" | "batch"
    sel: float               # sampled selectivity for this query
    k: int
    ls: int
    n: int                   # database rows (per-shard n_loc when sharded)
    d: int
    n_clauses: int           # filter expression leaf count
    delta_n: int             # streaming delta rows at serve time (0 if frozen)
    shard: Optional[List[int]]        # [n_shards, n_loc] or None
    predicted: Optional[Dict[str, float]]  # per-route predicted cost at sel
    cost_metric: Optional[str]             # metric of `predicted` ("us"|"n_dist")
    observed_us: float       # wall-clock us for this query (group wall / size)
    n_dist: int              # distance computations (from SearchResult)
    n_expanded: int          # beam expansions (from SearchResult)
    # traversal introspection (Telemetry(introspect=True), graph routes
    # only) — None when the introspective variant didn't serve this query.
    # Optional-with-default so pre-introspection JSONL dumps still load.
    dead_ends: Optional[int] = None   # iterations with no filter-valid gain
    sat_step: Optional[int] = None    # last beam-improving iteration (1-based)


_FIELDS = tuple(f.name for f in fields(TraceRecord))


class TraceBuffer:
    """Bounded ring buffer of :class:`TraceRecord`.

    Iteration yields records oldest-first.  ``dropped`` counts records
    evicted since construction; ``clear()`` resets both.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._buf: List[Optional[TraceRecord]] = [None] * self.capacity
        self._head = 0          # next write slot
        self._size = 0
        self.dropped = 0

    def __len__(self) -> int:
        return self._size

    def append(self, rec: TraceRecord) -> None:
        if self._size == self.capacity:
            self.dropped += 1
        else:
            self._size += 1
        self._buf[self._head] = rec
        self._head = (self._head + 1) % self.capacity

    def __iter__(self) -> Iterator[TraceRecord]:
        start = (self._head - self._size) % self.capacity
        for i in range(self._size):
            rec = self._buf[(start + i) % self.capacity]
            assert rec is not None
            yield rec

    def window(self, n: Optional[int] = None) -> List[TraceRecord]:
        """The most recent ``n`` records (all, when ``n`` is None)."""
        recs = list(self)
        return recs if n is None else recs[-n:]

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._head = 0
        self._size = 0
        self.dropped = 0

    def dump_jsonl(self, path: str) -> int:
        """Write all buffered records as JSON-lines; returns the count.

        The first line is a meta header (``__trace_meta__``) carrying the
        ring's ``capacity`` and ``dropped`` counter so a round-trip
        through :func:`load_buffer` preserves them; :func:`load_jsonl`
        (and any line-oriented consumer filtering on record keys) skips
        it.
        """
        n = 0
        with open(path, "w") as fh:
            fh.write(json.dumps({"__trace_meta__": 1,
                                 "capacity": self.capacity,
                                 "dropped": self.dropped}) + "\n")
            for rec in self:
                fh.write(json.dumps(asdict(rec)) + "\n")
                n += 1
        return n


def load_jsonl(path: str) -> List[TraceRecord]:
    """Load a ``dump_jsonl`` trace file back into records.

    Unknown keys are ignored and missing keys (beyond the dataclass's
    optional tail) error — the schema is the dataclass, not the file.
    Meta header lines are skipped; files dumped before the header
    existed load unchanged.
    """
    out: List[TraceRecord] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            raw = json.loads(line)
            if "__trace_meta__" in raw:
                continue
            out.append(TraceRecord(**{k: v for k, v in raw.items() if k in _FIELDS}))
    return out


def load_buffer(path: str) -> TraceBuffer:
    """Restore a :class:`TraceBuffer` from a ``dump_jsonl`` file.

    Capacity and the ``dropped`` counter come from the meta header; a
    headerless (pre-header) dump restores with capacity = record count
    (minimum 1) and ``dropped = 0``.
    """
    capacity = None
    dropped = 0
    with open(path) as fh:
        first = fh.readline().strip()
    if first:
        raw = json.loads(first)
        if "__trace_meta__" in raw:
            capacity = int(raw.get("capacity", 0)) or None
            dropped = int(raw.get("dropped", 0))
    records = load_jsonl(path)
    buf = TraceBuffer(capacity or max(len(records), 1))
    for rec in records:
        buf.append(rec)
    buf.dropped = dropped
    return buf


__all__ = ["TraceRecord", "TraceBuffer", "load_buffer", "load_jsonl"]
