"""Cost-model drift detection over a trace window.

A trace is "driftable" when it carries the router's per-route predicted
costs alongside the observed outcome.  The per-trace signal is the
relative error of the prediction for the band that actually ran:

    rel_err = |predicted[band] - observed| / observed

with ``observed`` taken in the prediction's own metric (wall-clock us
or n_dist).  Per band we report the rolling-window median — medians
resist the long latency tail — and flag drift when it crosses the
threshold.  The default threshold (0.5) is deliberately far above the
calibration fit error CI bounds (~0.25 median on-grid), so an accurate
model never flaps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

from .trace import TraceRecord

DEFAULT_THRESHOLD = 0.5


def relative_error(rec: TraceRecord) -> Optional[float]:
    """Predicted-vs-observed relative error for one trace, or None.

    None when the trace carries no prediction for its band, or the
    observation is non-positive (nothing meaningful to compare).
    """
    if not rec.predicted or rec.band not in rec.predicted:
        return None
    observed = rec.n_dist if rec.cost_metric == "n_dist" else rec.observed_us
    if observed is None or observed <= 0:
        return None
    return abs(float(rec.predicted[rec.band]) - float(observed)) / float(observed)


@dataclass(frozen=True)
class DriftReport:
    """Per-band median relative error and drift flags for one window."""

    median_rel_err: Dict[str, float]   # band -> rolling median rel err
    drifted: Dict[str, bool]           # band -> median > threshold
    n_traces: Dict[str, int]           # band -> traces contributing
    threshold: float
    window: int                        # traces considered (most recent)

    @property
    def any_drifted(self) -> bool:
        return any(self.drifted.values())

    def summary(self) -> str:
        if not self.median_rel_err:
            return "drift: no comparable traces"
        parts = []
        for band in sorted(self.median_rel_err):
            flag = "DRIFT" if self.drifted[band] else "ok"
            parts.append(f"{band}:{self.median_rel_err[band]:.3f}({flag})")
        return "drift: " + " ".join(parts)


def _median(xs: Sequence[float]) -> float:
    s = sorted(xs)
    m = len(s) // 2
    return s[m] if len(s) % 2 else 0.5 * (s[m - 1] + s[m])


def detect_drift(traces: Iterable[TraceRecord], *,
                 threshold: float = DEFAULT_THRESHOLD,
                 min_traces: int = 16,
                 window: int = 512) -> DriftReport:
    """Median relative error per band over the most recent ``window`` traces.

    Bands with fewer than ``min_traces`` comparable traces are reported
    but never flagged — a handful of outliers must not trigger a refit.
    """
    recent = list(traces)[-window:]
    errs: Dict[str, List[float]] = {}
    for rec in recent:
        e = relative_error(rec)
        if e is not None:
            errs.setdefault(rec.band, []).append(e)
    med = {band: _median(es) for band, es in errs.items()}
    return DriftReport(
        median_rel_err=med,
        drifted={band: (len(errs[band]) >= min_traces and m > threshold)
                 for band, m in med.items()},
        n_traces={band: len(es) for band, es in errs.items()},
        threshold=threshold,
        window=len(recent))


__all__ = ["DriftReport", "detect_drift", "relative_error", "DEFAULT_THRESHOLD"]
