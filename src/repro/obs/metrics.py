"""Counter / histogram registry with a Prometheus-style text exposition.

Pure host-side Python — no jax, no numpy arrays held.  Counters and
histograms are keyed by ``(name, sorted(labels))``; histograms use
geometric (log) buckets so one layout covers sub-microsecond latencies
and million-row ``n_dist`` counts alike.  Quantile accessors return the
upper bound of the bucket containing the target rank — the usual
Prometheus-histogram resolution contract.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label(v: str) -> str:
    """Escape a label value per the Prometheus text exposition format:
    backslash, double quote, and line feed must be backslash-escaped."""
    return (str(v).replace("\\", "\\\\")
                  .replace('"', '\\"')
                  .replace("\n", "\\n"))


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Geometric-bucket histogram: bounds ``lo * factor**i``.

    The last bucket is the +Inf overflow.  ``quantile(q)`` returns the
    upper bound of the bucket where the cumulative count first reaches
    ``q * count`` (``inf`` when that rank lands in the overflow bucket,
    0.0 when empty).
    """

    __slots__ = ("bounds", "counts", "count", "sum")

    def __init__(self, lo: float = 1.0, factor: float = 2.0, n_buckets: int = 40):
        if lo <= 0 or factor <= 1 or n_buckets < 1:
            raise ValueError("need lo > 0, factor > 1, n_buckets >= 1")
        self.bounds = [lo * factor ** i for i in range(n_buckets)]
        self.counts = [0] * (n_buckets + 1)   # +1 = overflow (+Inf)
        self.count = 0
        self.sum = 0.0

    def _bucket(self, v: float) -> int:
        if v <= self.bounds[0]:
            return 0
        if v > self.bounds[-1]:
            return len(self.bounds)
        lo, factor = self.bounds[0], self.bounds[1] / self.bounds[0]
        i = int(math.ceil(math.log(v / lo) / math.log(factor) - 1e-9))
        # float-precision guard: the closed-form index can land one off
        while i > 0 and v <= self.bounds[i - 1]:
            i -= 1
        while v > self.bounds[i]:
            i += 1
        return i

    def observe(self, v: float) -> None:
        self.counts[self._bucket(float(v))] += 1
        self.count += 1
        self.sum += float(v)

    def quantile(self, q: float) -> float:
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                return self.bounds[i] if i < len(self.bounds) else math.inf
        return math.inf

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Named, labelled counters and histograms with text exposition."""

    def __init__(self):
        self._counters: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Counter] = {}
        self._histograms: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Histogram] = {}

    def counter(self, name: str, **labels: str) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def histogram(self, name: str, *, lo: float = 1.0, factor: float = 2.0,
                  n_buckets: int = 40, **labels: str) -> Histogram:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(lo, factor, n_buckets)
        return h

    def value(self, name: str, **labels: str) -> int:
        """Current value of a counter (0 if it was never incremented)."""
        c = self._counters.get((name, _label_key(labels)))
        return 0 if c is None else c.value

    def counter_total(self, name: str) -> int:
        """Sum of a counter across all label sets."""
        return sum(c.value for (n, _), c in self._counters.items() if n == name)

    @staticmethod
    def _fmt_labels(labels: Tuple[Tuple[str, str], ...],
                    extra: Optional[Tuple[str, str]] = None) -> str:
        pairs = list(labels) + ([extra] if extra else [])
        if not pairs:
            return ""
        return ("{" + ",".join(f'{k}="{_escape_label(v)}"'
                               for k, v in pairs) + "}")

    def render(self) -> str:
        """Prometheus-style text exposition of every metric."""
        lines: List[str] = []
        for (name, labels), c in sorted(self._counters.items()):
            lines.append(f"{name}{self._fmt_labels(labels)} {c.value}")
        for (name, labels), h in sorted(self._histograms.items()):
            cum = 0
            for i, cnt in enumerate(h.counts):
                cum += cnt
                le = f"{h.bounds[i]:g}" if i < len(h.bounds) else "+Inf"
                lines.append(
                    f"{name}_bucket{self._fmt_labels(labels, ('le', le))} {cum}")
            lines.append(f"{name}_sum{self._fmt_labels(labels)} {h.sum:g}")
            lines.append(f"{name}_count{self._fmt_labels(labels)} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict[str, object]:
        """JSON-friendly dump: counters plus histogram percentile summaries."""
        counters = {}
        for (name, labels), c in sorted(self._counters.items()):
            counters[name + self._fmt_labels(labels)] = c.value
        hists = {}
        for (name, labels), h in sorted(self._histograms.items()):
            hists[name + self._fmt_labels(labels)] = {
                "count": h.count, "sum": h.sum, **h.percentiles()}
        return {"counters": counters, "histograms": hists}


__all__ = ["Counter", "Histogram", "MetricsRegistry"]
