"""Host-side aggregation of traversal introspection counters.

The device side lives in ``core.beam_search`` (``introspect=True``
returns a :class:`~repro.core.beam_search.TraversalStats` of per-query
``hops`` / ``sat_step`` / ``dead_ends`` as extra jit outputs — zero host
callbacks, zero collectives) and is compiled by the executor's graph
route behind its own cache-key component (``Executor.graph(...,
introspect=True)``).  ``Telemetry(introspect=True)`` turns it on for
every served graph query and stamps the counters into trace records.

This module is the pure-host half: pull stats across the device
boundary, summarize dead-end behavior per route (the FAVOR-style signal
— the paper's "prevents navigational dead-ends" claim, measured), and
feed the health report.

A *dead end* is an iteration where the lane was active but no
filter-valid candidate entered the kept beam; ``dead_end_rate`` is dead
ends per hop — 0.0 means every expansion made filter-valid progress,
1.0 means the traversal never did.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.beam_search import TraversalStats


def stats_to_host(stats: TraversalStats) -> Dict[str, np.ndarray]:
    """Device TraversalStats -> host int arrays (one sync copy per field)."""
    return {"hops": np.asarray(stats.hops, np.int64),
            "sat_step": np.asarray(stats.sat_step, np.int64),
            "dead_ends": np.asarray(stats.dead_ends, np.int64)}


def dead_end_rate(dead_ends: int, hops: int) -> Optional[float]:
    """Dead ends per hop; None when there were no hops to judge."""
    return None if hops <= 0 else dead_ends / hops


def introspection_summary(traces: Sequence) -> List[dict]:
    """Per-route introspection rows from a trace window.

    Only traces carrying the introspection fields contribute (records
    from non-graph routes, or served before ``Telemetry(introspect=
    True)``, have ``dead_ends is None`` and are skipped).  ``hops`` is
    the existing ``n_expanded`` field; ``sat_frac`` is the mean fraction
    of the traversal spent past the last beam improvement — a high value
    means iterations were spent on a saturated frontier.
    """
    groups: Dict[str, List] = {}
    for t in traces:
        if getattr(t, "dead_ends", None) is None:
            continue
        groups.setdefault(t.route, []).append(t)
    rows = []
    for route in sorted(groups):
        rs = groups[route]
        hops = np.asarray([t.n_expanded for t in rs], np.float64)
        dead = np.asarray([t.dead_ends for t in rs], np.float64)
        sat = np.asarray([t.sat_step for t in rs], np.float64)
        total_hops = float(hops.sum())
        rows.append({
            "route": route,
            "queries": len(rs),
            "mean_hops": round(float(hops.mean()), 2),
            "mean_dead_ends": round(float(dead.mean()), 2),
            "dead_end_rate": (round(float(dead.sum()) / total_hops, 4)
                              if total_hops > 0 else None),
            "mean_sat_step": round(float(sat.mean()), 2),
            "sat_frac": (round(float(np.mean(
                np.where(hops > 0, 1.0 - sat / np.maximum(hops, 1.0), 0.0)
            )), 4) if len(rs) else None),
        })
    return rows


__all__ = ["TraversalStats", "dead_end_rate", "introspection_summary",
           "stats_to_host"]
