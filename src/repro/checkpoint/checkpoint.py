"""Fault-tolerant checkpointing: per-leaf .npy + JSON manifest, atomic
commit, keep-last-k, cross-mesh elastic restore.

Layout:
  <dir>/step_000042.tmp/...   (write)
  <dir>/step_000042/          (atomic rename = commit)
      MANIFEST.json           {step, leaves: {path: {shape, dtype}}, meta}
      <flattened.key.path>.npy

Restore is mesh-agnostic: leaves are loaded host-side and re-placed with
``jax.device_put(x, sharding)`` for whatever mesh/rules the restarted job
uses — this is the elastic-scaling path (checkpoint on mesh A, resume on
mesh B; see tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}.{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)) and not hasattr(node, "shape"):
            for i, v in enumerate(node):
                rec(f"{prefix}[{i}]", v)
        else:
            flat[prefix] = node
    rec("", tree)
    return flat


def _unflatten_into(template, flat: Dict[str, Any],
                    build: Callable[[str, Any], Any]):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}.{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)) and not hasattr(node, "shape"):
            seq = [rec(f"{prefix}[{i}]", v) for i, v in enumerate(node)]
            return type(node)(seq) if not hasattr(node, "_fields") else \
                type(node)(*seq)
        return build(prefix, node)
    return rec("", template)


def save_pytree(tree, directory: str, step: int,
                meta: Optional[dict] = None, keep: int = 3) -> str:
    """Write a checkpoint atomically; prune to the newest ``keep``."""
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, v in flat.items():
        arr = np.asarray(v)
        fn = re.sub(r"[^A-Za-z0-9_.\[\]-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    steps = sorted(
        (d for d in os.listdir(directory)
         if re.fullmatch(r"step_\d+", d)))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if re.fullmatch(r"step_\d+", d)
             and os.path.exists(os.path.join(directory, d,
                                             "MANIFEST.json"))]
    return max(steps) if steps else None


def load_pytree(template, directory: str, step: int,
                shardings=None):
    """Restore into ``template``'s structure; ``shardings`` (same structure,
    optional) re-places leaves for the current mesh (elastic restore)."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    flat_sh = _flatten(shardings) if shardings is not None else {}

    def build(key, tmpl):
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(path, info["file"]))
        sh = flat_sh.get(key)
        if sh is not None:
            return jax.device_put(arr, sh)
        return jax.numpy.asarray(arr)
    return _unflatten_into(template, manifest["leaves"], build), \
        manifest["meta"]


class CheckpointManager:
    """Train-loop helper: periodic save, auto-resume, keep-k."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.dir = directory
        self.every = every
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree, meta: Optional[dict] = None,
                   force: bool = False):
        if force or (step > 0 and step % self.every == 0):
            return save_pytree(tree, self.dir, step, meta, self.keep)
        return None

    def restore_latest(self, template, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, None, None
        tree, meta = load_pytree(template, self.dir, step, shardings)
        return step, tree, meta
