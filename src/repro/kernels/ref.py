"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2dist_ref(q: jnp.ndarray, xb: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distance matrix. q [B, d], xb [N, d] -> f32 [B, N]."""
    q = q.astype(jnp.float32)
    xb = xb.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    xn = jnp.sum(xb * xb, axis=-1)
    return jnp.maximum(qn + xn[None, :] - 2.0 * q @ xb.T, 0.0)


def gather_dist_ref(xb: jnp.ndarray, ids: jnp.ndarray,
                    q: jnp.ndarray) -> jnp.ndarray:
    """Fused gather+distance. xb [N, d], ids int32 [B, C], q [B, d]
    -> f32 [B, C] squared L2 of q[b] vs xb[ids[b, c]] (ids pre-clipped)."""
    rows = jnp.take(xb, ids, axis=0).astype(jnp.float32)
    diff = rows - q.astype(jnp.float32)[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def fused_expand_ref(packed: jnp.ndarray, ids: jnp.ndarray, q: jnp.ndarray,
                     q_norm: jnp.ndarray, *, d: int
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused gather + distance + attr fetch over the packed serving layout.

    packed f32 [N, d+1+A] rows of [vec | sq-norm | attr words]; ids int32
    [B, C] (pre-clipped); q f32 [B, d] (pre-scaled for int8 layouts); q_norm
    f32 [B] -> (d2 f32 [B, C], attr words f32 [B, C, A])."""
    rows = jnp.take(packed, ids, axis=0)               # [B, C, d+1+A]
    vec = rows[..., :d].astype(jnp.float32)
    norm = rows[..., d]
    words = rows[..., d + 1:]
    dots = jnp.einsum("bcd,bd->bc", vec, q.astype(jnp.float32))
    d2 = jnp.maximum(norm - 2.0 * dots + q_norm[:, None], 0.0)
    return d2, words


def hamming_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Packed-bitset Hamming distance matrix.
    a uint32 [B, W], b uint32 [N, W] -> int32 [B, N]."""
    x = a[:, None, :] ^ b[None, :, :]
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


def subset_deficit_ref(f: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """|f \\ a| (paper's subset dist_F) matrix.
    f uint32 [B, W], a uint32 [N, W] -> int32 [B, N]."""
    x = f[:, None, :] & ~a[None, :, :]
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None) -> jnp.ndarray:
    """Reference MHA. q [B, H, Tq, D], k/v [B, Hkv, Tk, D] (GQA broadcast)."""
    B, H, Tq, D = q.shape
    Hkv = k.shape[1]
    rep = H // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Tk = k.shape[2]
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
