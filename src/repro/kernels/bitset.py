"""Packed-bitset attribute distance Pallas kernels (popcount on the VPU).

Subset/boolean attribute & filter distances over uint32-packed bitsets
(DESIGN.md §2): XOR/ANDN + ``lax.population_count`` on (bq, W)x(bn, W)
VMEM tiles, producing the [B, N] distance matrices used by the subset
dist_F (|f \\ a|), the Hamming dist_A, and the pre-filter validity scans.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popc(x):
    return jax.lax.population_count(x)


def _make_kernel(op: str):
    def kernel(a_ref, b_ref, o_ref):
        a = a_ref[...]                                    # [bq, W]
        b = b_ref[...]                                    # [bn, W]
        acc = jnp.zeros((a.shape[0], b.shape[0]), jnp.int32)
        W = a.shape[1]
        for w in range(W):  # unrolled: W is small (<= 64 words)
            if op == "xor":
                x = a[:, w][:, None] ^ b[:, w][None, :]
            else:  # "deficit": f & ~a
                x = a[:, w][:, None] & ~b[:, w][None, :]
            acc = acc + _popc(x).astype(jnp.int32)
        o_ref[...] = acc
    return kernel


@functools.partial(jax.jit, static_argnames=("op", "bq", "bn", "interpret"))
def bitset_dist(a: jnp.ndarray, b: jnp.ndarray, *, op: str = "xor",
                bq: int = 128, bn: int = 128,
                interpret: bool = False) -> jnp.ndarray:
    """Bitset distance matrix.

    a uint32 [B, W], b uint32 [N, W] -> int32 [B, N].
    op="xor": Hamming (dist_A); op="deficit": popcount(a & ~b) = |a \\ b|
    (dist_F with a=filter bits, b=attribute bits).
    """
    B, W = a.shape
    N, _ = b.shape
    bq, bn = min(bq, B), min(bn, N)
    assert B % bq == 0 and N % bn == 0, (B, N, bq, bn)
    return pl.pallas_call(
        _make_kernel(op),
        grid=(B // bq, N // bn),
        in_specs=[
            pl.BlockSpec((bq, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, W), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=interpret,
    )(a, b)
