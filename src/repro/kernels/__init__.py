"""Pallas TPU kernels for the compute hot spots (+ pure-jnp oracles)."""
from . import ops, ref
