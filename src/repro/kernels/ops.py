"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode; on TPU the
same calls compile to Mosaic. ``interpret`` auto-detects from the default
backend, overridable via argument or ``repro_force_interpret()``. Wrappers
pad inputs to tile multiples and slice results back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitset as _bitset
from . import fused_expand as _fe
from . import gather_dist as _gd
from . import l2dist as _l2

_FORCE_INTERPRET: bool | None = None


def repro_force_interpret(v: bool | None) -> None:
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = v


def _interp(explicit: bool | None) -> bool:
    if explicit is not None:
        return explicit
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def l2dist(q, xb, *, bq: int = 128, bn: int = 256, bd: int = 128,
           interpret: bool | None = None) -> jnp.ndarray:
    """Padded/sliced blocked distance matrix [B, N] (see l2dist.py)."""
    q = jnp.asarray(q)
    xb = jnp.asarray(xb)
    qp, B = _pad_to(q, 0, min(bq, max(q.shape[0], 1)))
    qp, _ = _pad_to(qp, 1, 8)
    xp, N = _pad_to(xb, 0, min(bn, max(xb.shape[0], 1)))
    xp, _ = _pad_to(xp, 1, 8)
    bq2 = min(bq, qp.shape[0])
    bn2 = min(bn, xp.shape[0])
    bd2 = min(bd, qp.shape[1])
    qp, _ = _pad_to(qp, 0, bq2)
    xp, _ = _pad_to(xp, 0, bn2)
    qp, _ = _pad_to(qp, 1, bd2)
    xp, _ = _pad_to(xp, 1, bd2)
    out = _l2.l2dist(qp, xp, bq=bq2, bn=bn2, bd=bd2,
                     interpret=_interp(interpret))
    return out[:B, :N]


def gather_dist(xb, ids, q, *, interpret: bool | None = None) -> jnp.ndarray:
    """Fused gather+distance [B, C] (ids clipped internally)."""
    ids = jnp.clip(jnp.asarray(ids, jnp.int32), 0, xb.shape[0] - 1)
    return _gd.gather_dist(jnp.asarray(xb), ids, jnp.asarray(q),
                           interpret=_interp(interpret))


def fused_expand(packed, ids, q, q_norm, *, d: int,
                 interpret: bool | None = None):
    """One-gather beam expansion over the fused serving layout.

    ``packed`` f32 [N, d+1+A] rows of [vec | sq-norm | attr words] (see
    serve/layout.py). Returns (d2 [B, C], attr words [B, C, A]) from a single
    row gather — the fetch contract of ``beam_search.greedy_search``'s
    ``fetch_fn`` hook, minus the word decode (filters.unpack_attr_words).
    ids are clipped internally; q must already be scale-folded for int8 rows.
    """
    ids = jnp.clip(jnp.asarray(ids, jnp.int32), 0, packed.shape[0] - 1)
    return _fe.fused_expand(jnp.asarray(packed, jnp.float32), ids,
                            jnp.asarray(q, jnp.float32),
                            jnp.asarray(q_norm, jnp.float32), d=d,
                            interpret=_interp(interpret))


def gather_dist_tile(xb, base, q, *, tile: int,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Contiguous-tile fused gather+distance: lane b scores database rows
    ``[base[b]*tile, (base[b]+1)*tile)`` against q[b] -> f32 [B, tile].

    Besides the sorted/bucketed build layouts, this is the prefilter
    route's masked-scan inner loop (core/ground_truth.py with
    ``use_kernel=True``): the blocked exact scan DMAs each database tile
    HBM->VMEM once per grid step. xb's row count must be a tile multiple
    and d an 8-lane multiple — callers pad once up front (padded rows score
    against the zero vector and must be masked; ``exact_filtered_knn``'s
    ``inb`` mask does).
    """
    return _gd.gather_dist_tile(jnp.asarray(xb), jnp.asarray(base, jnp.int32),
                                jnp.asarray(q), tile=tile,
                                interpret=_interp(interpret))


def hamming(a, b, *, interpret: bool | None = None) -> jnp.ndarray:
    """Packed Hamming distance matrix [B, N]."""
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    ap, B = _pad_to(a, 0, min(128, max(a.shape[0], 1)))
    bp, N = _pad_to(b, 0, min(128, max(b.shape[0], 1)))
    bq = min(128, ap.shape[0])
    bn = min(128, bp.shape[0])
    ap, _ = _pad_to(ap, 0, bq)
    bp, _ = _pad_to(bp, 0, bn)
    return _bitset.bitset_dist(ap, bp, op="xor", bq=bq, bn=bn,
                               interpret=_interp(interpret))[:B, :N]


def subset_deficit(f, a, *, interpret: bool | None = None) -> jnp.ndarray:
    """|f \\ a| matrix [B, N] (subset dist_F)."""
    f = jnp.asarray(f, jnp.uint32)
    a = jnp.asarray(a, jnp.uint32)
    fp, B = _pad_to(f, 0, min(128, max(f.shape[0], 1)))
    ap, N = _pad_to(a, 0, min(128, max(a.shape[0], 1)))
    bq = min(128, fp.shape[0])
    bn = min(128, ap.shape[0])
    fp, _ = _pad_to(fp, 0, bq)
    ap, _ = _pad_to(ap, 0, bn)
    return _bitset.bitset_dist(fp, ap, op="deficit", bq=bq, bn=bn,
                               interpret=_interp(interpret))[:B, :N]
