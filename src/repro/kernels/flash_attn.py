"""Causal GQA flash attention Pallas kernel (TPU target, interpret-tested).

For the LM cells' perf-critical layer: online-softmax attention with
(block_q x block_k) VMEM tiles, fp32 running max/sum scratch, GQA via a
grouped grid (one grid row per KV head; the G query heads of that group are
processed in the q tile's head dim). Lower-triangular blocks are skipped by
masking; the kv grid dim is arranged innermost so the accumulator lives in
VMEM scratch across kv steps.

Grid: (B * Hkv * G, Tq/block_q, Tk/block_k).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            n_kblk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, d]
    s = q @ k.T                                       # [bq, bk]
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(ki == n_kblk - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q [B, H, Tq, D]; k/v [B, Hkv, Tk, D]; H % Hkv == 0. -> [B, H, Tq, D].

    Tq/Tk must be divisible by the block sizes (ops-level callers pad).
    """
    B, H, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert H % Hkv == 0
    G = H // Hkv
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    assert Tq % bq == 0 and Tk % bk == 0
    n_kblk = Tk // bk
    scale = 1.0 / math.sqrt(D)

    # flatten (B, H) -> grid rows; kv row = qh // G
    qf = q.reshape(B * H, Tq, D)
    kf = k.reshape(B * Hkv, Tk, D)
    vf = v.reshape(B * Hkv, Tk, D)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          block_q=bq, block_k=bk, n_kblk=n_kblk),
        grid=(B * H, Tq // bq, n_kblk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Tq, D)
