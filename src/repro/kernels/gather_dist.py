"""Fused gather + squared-L2 distance Pallas kernel (scalar prefetch).

The TPU-native answer to graph pointer-chasing (DESIGN.md §2): neighbor ids
are scalar-prefetched so the ``BlockSpec.index_map`` selects which database
row block the DMA engine fetches HBM->VMEM for each grid step; the distance
reduction runs on the resident tile, so gathered rows never round-trip
through HBM. This is the beam-search expansion hot spot (the paper's
"distance computations" metric, Figs. 10-13).

Two granularities:
  gather_dist      — one grid step per (b, c) id; block = a single (1, d)
                     row selected by ``ids[g]``. Exact gather semantics.
  gather_dist_tile — one grid step per query lane; the lane's C ids must
                     point into a contiguous [C-aligned] region (used by the
                     sorted/bucketed layouts produced at build time), letting
                     the DMA fetch a (C, d) tile in one shot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _row_kernel(ids_ref, x_ref, q_ref, o_ref):
    diff = x_ref[...].astype(jnp.float32) - q_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(diff * diff, axis=-1, keepdims=True).T


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_dist(xb: jnp.ndarray, ids: jnp.ndarray, q: jnp.ndarray,
                *, interpret: bool = False) -> jnp.ndarray:
    """xb [N, d], ids int32 [B, C] (pre-clipped to [0, N)), q [B, d]
    -> f32 [B, C]: ||q[b] - xb[ids[b, c]]||^2."""
    N, d = xb.shape
    B, C = ids.shape
    flat = ids.reshape(-1)
    total = flat.shape[0]

    out = pl.pallas_call(
        _row_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(total,),
            in_specs=[
                pl.BlockSpec((1, d), lambda g, ids: (ids[g], 0)),
                pl.BlockSpec((1, d), lambda g, ids: (g // C, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda g, ids: (0, g)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, total), jnp.float32),
        interpret=interpret,
    )(flat, xb, q)
    return out.reshape(B, C)


def _tile_kernel(base_ref, x_ref, q_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # [C, d]
    q = q_ref[...].astype(jnp.float32)            # [1, d]
    o_ref[...] = (jnp.sum(x * x, axis=-1)[None, :]
                  - 2.0 * (q @ x.T)
                  + jnp.sum(q * q, axis=-1, keepdims=True))


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def gather_dist_tile(xb: jnp.ndarray, base: jnp.ndarray, q: jnp.ndarray,
                     *, tile: int, interpret: bool = False) -> jnp.ndarray:
    """Tile-granular fused gather+distance.

    ``base`` int32 [B]: tile index per query lane; lane b scores database
    rows [base[b]*tile, (base[b]+1)*tile) against q[b]. xb's row count must
    be divisible by ``tile``. Returns f32 [B, tile].
    """
    N, d = xb.shape
    B = base.shape[0]
    assert N % tile == 0

    out = pl.pallas_call(
        _tile_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B,),
            in_specs=[
                pl.BlockSpec((tile, d), lambda b, base: (base[b], 0)),
                pl.BlockSpec((1, d), lambda b, base: (b, 0)),
            ],
            out_specs=pl.BlockSpec((1, tile), lambda b, base: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, tile), jnp.float32),
        interpret=interpret,
    )(base, xb, q)
    return jnp.maximum(out, 0.0)
