"""Fused gather + distance + attribute-fetch Pallas kernel (scalar prefetch).

The serving hot path of JAG is the beam expansion: score C neighbor rows per
query lane per iteration (the paper's "distance computations", Figs. 10-13).
With the default split layout that costs TWO HBM gathers per expansion — one
over the vector matrix (``dist_fn``) and one over the attribute table
(``attr.gather``). The fused serving layout (serve/layout.py) packs each
database row as

    [ vec lanes (f32, or int8 codes widened to f32) | sq-norm | attr words ]

into one contiguous f32 matrix, and this kernel consumes it: neighbor ids are
scalar-prefetched so ``BlockSpec.index_map`` selects which packed row the DMA
engine pulls HBM->VMEM for each grid step (exactly like gather_dist.py), and
the kernel emits BOTH the squared-L2 distance and the raw attr words from the
single resident row — one gather per expansion instead of two.

int8 rows are handled with zero kernel changes: the caller pre-scales the
query (``q_eff = q * scale``) so ``codes . q_eff == dequant(codes) . q``, and
the norm lane already stores the dequantized squared norm.

Attr lanes are opaque bit payloads (filters.pack_attr_words); the kernel only
copies them, so the uint32<->f32 bitcast round-trips exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _row_kernel(d, C, ids_ref, qn_ref, x_ref, q_ref, o_dist, o_attr):
    del ids_ref  # consumed by the index_map (scalar prefetch)
    g = pl.program_id(0)
    row = x_ref[...]                                   # [1, d + 1 + A]
    vec = row[:, :d].astype(jnp.float32)               # [1, d]
    norm = row[0, d]
    q = q_ref[...].astype(jnp.float32)                 # [1, d]
    dot = jnp.sum(vec * q)
    d2 = jnp.maximum(norm - 2.0 * dot + qn_ref[g // C], 0.0)
    o_dist[...] = d2.reshape(1, 1)
    o_attr[...] = row[:, d + 1:]                       # bit-preserving copy


@functools.partial(jax.jit, static_argnames=("d", "interpret"))
def fused_expand(packed: jnp.ndarray, ids: jnp.ndarray, q: jnp.ndarray,
                 q_norm: jnp.ndarray, *, d: int,
                 interpret: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """packed f32 [N, d+1+A], ids int32 [B, C] (pre-clipped), q f32 [B, d]
    (pre-scaled for int8 layouts), q_norm f32 [B]
    -> (d2 f32 [B, C], attr words f32 [B, C, A])."""
    N, row_w = packed.shape
    A = row_w - d - 1
    assert A >= 1, "packed rows must carry at least one attr word"
    B, C = ids.shape
    flat = ids.reshape(-1)
    total = flat.shape[0]

    dist, attrs = pl.pallas_call(
        functools.partial(_row_kernel, d, C),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(total,),
            in_specs=[
                pl.BlockSpec((1, row_w), lambda g, ids, qn: (ids[g], 0)),
                pl.BlockSpec((1, d), lambda g, ids, qn: (g // C, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1), lambda g, ids, qn: (0, g)),
                pl.BlockSpec((1, A), lambda g, ids, qn: (g, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((1, total), jnp.float32),
            jax.ShapeDtypeStruct((total, A), jnp.float32),
        ],
        interpret=interpret,
    )(flat, jnp.asarray(q_norm, jnp.float32), packed, q)
    return dist.reshape(B, C), attrs.reshape(B, C, A)
