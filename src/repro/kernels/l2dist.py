"""Blocked squared-L2 distance matrix Pallas kernel (MXU formulation).

The filtered-ANN hot spot the paper measures ("distance computations",
Figs. 10-13). Used by the pre-filter brute-force scan, prune pairwise
distances, and the recsys ``retrieval_cand`` scoring path.

Grid: (B/bq, N/bn, d/bd). Each step loads a (bq, bd) query tile and a
(bn, bd) database tile into VMEM, accumulates -2*q@x^T on the MXU into the
f32 output tile, and on the last d-step adds ||q||^2 + ||x||^2 computed
from the resident tiles. Tile defaults are MXU/VPU aligned (multiples of
8x128 for f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, x_ref, o_ref, acc_ref, *, n_dblk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)            # [bq, bd]
    x = x_ref[...].astype(jnp.float32)            # [bn, bd]
    acc_ref[...] += (
        -2.0 * jax.lax.dot_general(
            q, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        + jnp.sum(q * q, axis=1, keepdims=True)
        + jnp.sum(x * x, axis=1)[None, :])

    @pl.when(pl.program_id(2) == n_dblk - 1)
    def _done():
        o_ref[...] = jnp.maximum(acc_ref[...], 0.0)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bn", "bd", "interpret"))
def l2dist(q: jnp.ndarray, xb: jnp.ndarray, *, bq: int = 128, bn: int = 256,
           bd: int = 128, interpret: bool = False) -> jnp.ndarray:
    """Squared L2 distances. q [B, d], xb [N, d] -> f32 [B, N].

    B, N, d must be divisible by the tile sizes (callers pad; see ops.py).
    """
    B, d = q.shape
    N, _ = xb.shape
    bq, bn, bd = min(bq, B), min(bn, N), min(bd, d)
    assert B % bq == 0 and N % bn == 0 and d % bd == 0, (B, N, d, bq, bn, bd)
    n_dblk = d // bd
    grid = (B // bq, N // bn, n_dblk)
    return pl.pallas_call(
        functools.partial(_kernel, n_dblk=n_dblk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bn, bd), lambda i, j, kd: (j, kd)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j, kd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, bn), jnp.float32)],
        interpret=interpret,
    )(q, xb)
