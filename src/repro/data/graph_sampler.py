"""Graph generators + a real fanout neighbor sampler (GraphSAGE-style).

The sampler is host-side numpy over a CSR adjacency (the standard
data-pipeline placement: sampling is control-flow heavy, the device step is
dense); the sampled subgraph is emitted with fixed shapes (padded) so the
jitted train step never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    feats: np.ndarray     # [N, F] float32
    edges: np.ndarray     # [E, 2] int32 (src, dst)
    labels: np.ndarray    # [N] int32
    n_classes: int

    @property
    def n(self) -> int:
        return self.feats.shape[0]


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 seed: int = 0, cluster: bool = True) -> Graph:
    """Synthetic attributed graph with homophilous clusters."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = (centers[labels] + rng.normal(size=(n_nodes, d_feat)) * 0.5
             ).astype(np.float32)
    if cluster:  # 70% intra-class edges
        intra = int(0.7 * n_edges)
        src_i = rng.integers(0, n_nodes, intra)
        # partner within same class via label-sorted permutation trick
        order = np.argsort(labels, kind="stable")
        pos = np.empty(n_nodes, np.int64)
        pos[order] = np.arange(n_nodes)
        shift = rng.integers(1, 50, intra)
        counts = np.bincount(labels, minlength=n_classes)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        lab = labels[src_i]
        dst_i = order[starts[lab]
                      + (pos[src_i] - starts[lab] + shift) % counts[lab]]
        src_r = rng.integers(0, n_nodes, n_edges - intra)
        dst_r = rng.integers(0, n_nodes, n_edges - intra)
        src = np.concatenate([src_i, src_r])
        dst = np.concatenate([dst_i, dst_r])
    else:
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
    edges = np.stack([src, dst], 1).astype(np.int32)
    return Graph(feats, edges, labels, n_classes)


def batched_molecules(n_graphs: int, nodes_per: int, edges_per: int,
                      d_feat: int, n_classes: int, seed: int = 0
                      ) -> Dict[str, np.ndarray]:
    """A batch of small graphs packed into one disjoint union."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per
    feats = rng.normal(size=(n, d_feat)).astype(np.float32)
    src = rng.integers(0, nodes_per, (n_graphs, edges_per))
    dst = rng.integers(0, nodes_per, (n_graphs, edges_per))
    off = (np.arange(n_graphs) * nodes_per)[:, None]
    edges = np.stack([(src + off).reshape(-1),
                      (dst + off).reshape(-1)], 1).astype(np.int32)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    return {"feats": feats, "edges": edges, "labels": labels,
            "graph_ids": np.repeat(np.arange(n_graphs), nodes_per)}


class NeighborSampler:
    """Fanout sampler over CSR adjacency; fixed-shape padded output."""

    def __init__(self, graph: Graph, fanouts: Tuple[int, ...],
                 seed: int = 0):
        self.g = graph
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)
        # CSR: incoming edges per node (dst -> srcs)
        order = np.argsort(graph.edges[:, 1], kind="stable")
        self.src_sorted = graph.edges[order, 0]
        dst_sorted = graph.edges[order, 1]
        self.indptr = np.searchsorted(dst_sorted, np.arange(graph.n + 1))

    def sample(self, seeds: np.ndarray) -> Dict[str, np.ndarray]:
        """Returns a reindexed subgraph: seeds first, then sampled frontier.

        Output shapes are fixed by (len(seeds), fanouts): nodes padded to
        max_nodes, edges to max_edges (padding edges are self-loops on a
        dummy node so segment ops stay valid).
        """
        layers = [seeds.astype(np.int64)]
        edge_src, edge_dst = [], []
        frontier = seeds.astype(np.int64)
        for f in self.fanouts:
            starts = self.indptr[frontier]
            degs = self.indptr[frontier + 1] - starts
            take = np.minimum(degs, f)
            # sample up to f in-neighbors per frontier node
            src_list, dst_list = [], []
            for i, v in enumerate(frontier):
                if take[i] == 0:
                    continue
                cand = self.src_sorted[starts[i]:starts[i] + degs[i]]
                pick = (cand if degs[i] <= f else
                        self.rng.choice(cand, f, replace=False))
                src_list.append(pick)
                dst_list.append(np.full(len(pick), v))
            if src_list:
                s = np.concatenate(src_list)
                d = np.concatenate(dst_list)
                edge_src.append(s)
                edge_dst.append(d)
                frontier = np.unique(s)
            else:
                frontier = np.empty((0,), np.int64)
            layers.append(frontier)

        nodes = np.unique(np.concatenate(layers))
        # seeds must map to [0, len(seeds)): put them first
        rest = np.setdiff1d(nodes, seeds, assume_unique=False)
        nodes = np.concatenate([seeds, rest])
        remap = {int(v): i for i, v in enumerate(nodes)}
        if edge_src:
            es = np.concatenate(edge_src)
            ed = np.concatenate(edge_dst)
            es = np.fromiter((remap[int(v)] for v in es), np.int32,
                             len(es))
            ed = np.fromiter((remap[int(v)] for v in ed), np.int32,
                             len(ed))
        else:
            es = ed = np.empty((0,), np.int32)

        max_nodes = int(len(seeds) * np.prod(
            [f + 1 for f in self.fanouts]))
        max_edges = int(len(seeds) * np.prod(
            [max(f, 1) for f in self.fanouts]) * len(self.fanouts))
        feats = np.zeros((max_nodes, self.g.feats.shape[1]), np.float32)
        feats[:len(nodes)] = self.g.feats[nodes]
        pad_e = max_edges - len(es)
        dummy = max_nodes - 1
        edges = np.stack([
            np.concatenate([es, np.full(pad_e, dummy, np.int32)]),
            np.concatenate([ed, np.full(pad_e, dummy, np.int32)])], 1)
        return {"feats": feats, "edges": edges,
                "labels": self.g.labels[seeds].astype(np.int32),
                "label_mask": np.ones(len(seeds), np.float32),
                "n_real_nodes": np.int32(len(nodes))}
