"""Host-side batch generators for the three model families.

Deterministic per (seed, step) so a restarted job resumes identical data
order (fault-tolerance requirement): every batch is derived from
``default_rng((seed, step))`` with no sequential RNG state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def lm_batch(step: int, batch: int, seq: int, vocab: int,
             seed: int = 0) -> Dict[str, np.ndarray]:
    """Synthetic LM tokens: Zipf-ish marginals + local repetition structure
    so the loss has learnable signal. tokens [B, seq+1]."""
    rng = np.random.default_rng((seed, step))
    z = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    tokens = (z % (vocab - 2)) + 1
    # inject copy structure: second half repeats first half shifted
    half = (seq + 1) // 2
    tokens[:, half:half * 2] = tokens[:, :half]
    return {"tokens": tokens.astype(np.int32)}


def recsys_batch(step: int, batch: int, n_sparse: int,
                 vocabs: Tuple[int, ...], n_dense: int = 13,
                 seed: int = 0, kind: str = "fm",
                 seq_len: int = 100) -> Dict[str, np.ndarray]:
    """Synthetic CTR batch with a planted logistic teacher signal."""
    rng = np.random.default_rng((seed, step))
    if kind == "din":
        total = sum(vocabs)
        target = rng.integers(0, total, batch).astype(np.int32)
        hist = rng.integers(0, total, (batch, seq_len)).astype(np.int32)
        # clicks correlate with history/target id parity overlap
        y = ((target % 7 == (hist % 7).mean(1).round()).astype(np.float32))
        return {"target_id": target, "hist_ids": hist,
                "hist_mask": np.ones((batch, seq_len), bool),
                "label": y}
    ids = np.stack([rng.integers(0, v, batch) for v in vocabs[:n_sparse]],
                   axis=1).astype(np.int32)
    dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
    logit = ((dense[:, 0] * 0.5 if n_dense else 0.0)
             + ((ids[:, 0] % 5) - 2) * 0.3)
    y = (rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
    return {"sparse_ids": ids, "dense": dense, "label": y}
