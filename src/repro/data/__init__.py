"""Data pipelines: synthetic filtered-ANN datasets (paper D.2 setups), LM
token streams, GNN graphs + samplers, recsys click logs."""
