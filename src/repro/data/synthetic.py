"""Synthetic filtered-ANN datasets reproducing the paper's setups (App. D.2).

No external downloads are available in this environment, so each dataset
family is regenerated at the paper's *structural* parameters (attribute
distributions, filter selectivity mixes) over clustered Gaussian vectors:

  sift_like      — label filter: uniform label in {0..11}; query = one label.
  msturing_range — integer attribute in [0, 1e6]; query ranges of length
                   1e6/k, k in {1,10,1e2,1e3,1e4,1e5} (mixed selectivity).
  msturing_subset— 30 Bernoulli(1/2) attributes; query requires
                   k in {0,2,..,16} of them (selectivity 1..2^-16).
  msturing_bool  — random boolean predicates over 15 vars with pass rates in
                   (2^-4,1), (2^-8,2^-4), (2^-12,2^-8), (0,2^-12).
  laion_like     — 30 keyword "clusters"; each point tagged with its 3
                   nearest keyword centers (subset filter, correlation knob:
                   positive / random / negative query keyword).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import filters as F


@dataclasses.dataclass
class FilteredDataset:
    name: str
    xb: np.ndarray                 # [N, d] float32
    attr: F.AttrTable
    queries: np.ndarray            # [B, d] float32
    filt: F.FilterBatch
    selectivity: np.ndarray        # [B] empirical selectivity per query


def _clustered(rng, n, d, n_clusters=32, spread=1.0, scale=4.0):
    centers = rng.normal(size=(n_clusters, d)) * scale
    asg = rng.integers(0, n_clusters, n)
    x = centers[asg] + rng.normal(size=(n, d)) * spread
    return x.astype(np.float32), centers, asg


def _queries(rng, centers, b, d, spread=1.0):
    asg = rng.integers(0, centers.shape[0], b)
    return (centers[asg] + rng.normal(size=(b, d)) * spread).astype(
        np.float32), asg


def sift_like(n=20000, d=64, b=256, n_labels=12, seed=0) -> FilteredDataset:
    rng = np.random.default_rng(seed)
    xb, centers, _ = _clustered(rng, n, d)
    q, _ = _queries(rng, centers, b, d)
    labels = rng.integers(0, n_labels, n)
    qlab = rng.integers(0, n_labels, b)
    sel = np.array([(labels == l).mean() for l in qlab])
    return FilteredDataset("sift_like", xb, F.label_table(labels), q,
                           F.label_filters(qlab), sel)


def msturing_range(n=20000, d=64, b=256, seed=0,
                   sel_ks=(1, 10, 100, 1000, 10_000, 100_000)
                   ) -> FilteredDataset:
    rng = np.random.default_rng(seed)
    xb, centers, _ = _clustered(rng, n, d)
    q, _ = _queries(rng, centers, b, d)
    vals = rng.integers(0, 1_000_000, n).astype(np.float32)
    k = rng.choice(sel_ks, b)
    width = 1_000_000 / k
    lo = rng.uniform(0, np.maximum(1_000_000 - width, 1))
    hi = lo + width
    sel = np.array([((vals >= l) & (vals <= h)).mean()
                    for l, h in zip(lo, hi)])
    return FilteredDataset("msturing_range", xb, F.range_table(vals), q,
                           F.range_filters(lo, hi), sel)


def msturing_subset(n=20000, d=64, b=256, n_attrs=30, seed=0,
                    req_ks=(0, 2, 4, 6, 8, 10, 12)) -> FilteredDataset:
    rng = np.random.default_rng(seed)
    xb, centers, _ = _clustered(rng, n, d)
    q, _ = _queries(rng, centers, b, d)
    bits = rng.random((n, n_attrs)) < 0.5
    k = rng.choice(req_ks, b)
    fbits = np.zeros((b, n_attrs), bool)
    for i in range(b):
        fbits[i, rng.choice(n_attrs, k[i], replace=False)] = True
    sel = np.array([(bits[:, fbits[i]].all(axis=1)).mean()
                    for i in range(b)])
    return FilteredDataset("msturing_subset", xb,
                           F.subset_table(bits, n_attrs), q,
                           F.subset_filters(fbits, n_attrs), sel)


def msturing_bool(n=20000, d=64, b=128, n_vars=15, seed=0) -> FilteredDataset:
    rng = np.random.default_rng(seed)
    xb, centers, _ = _clustered(rng, n, d)
    q, _ = _queries(rng, centers, b, d)
    assign = rng.integers(0, 1 << n_vars, n).astype(np.uint32)
    bands = [(2.0 ** -4, 1.0), (2.0 ** -8, 2.0 ** -4),
             (2.0 ** -12, 2.0 ** -8), (2.0 ** -15, 2.0 ** -12)]
    size = 1 << n_vars
    sat = np.zeros((b, size), bool)
    for i in range(b):
        lo, hi = bands[rng.integers(0, len(bands))]
        rate = np.exp(rng.uniform(np.log(max(lo, 2.0 ** -15)), np.log(hi)))
        sat[i] = rng.random(size) < rate
        if not sat[i].any():
            sat[i, rng.integers(0, size)] = True
    sel = sat[:, assign.astype(np.int64)].mean(axis=1)
    return FilteredDataset("msturing_bool", xb,
                           F.boolean_table(assign, n_vars), q,
                           F.boolean_filters(sat, n_vars), sel)


def laion_like(n=20000, d=64, b=256, n_keywords=30, tags_per_point=3,
               correlation="random", seed=0) -> FilteredDataset:
    """Keyword clusters; subset filter with controllable query correlation."""
    rng = np.random.default_rng(seed)
    keywords = rng.normal(size=(n_keywords, d)) * 4.0
    xb = (keywords[rng.integers(0, n_keywords, n)]
          + rng.normal(size=(n, d))).astype(np.float32)
    # each point tagged with its `tags_per_point` nearest keyword centers
    d2 = ((xb[:, None, :] - keywords[None]) ** 2).sum(-1)
    tags = np.argsort(d2, axis=1)[:, :tags_per_point]
    bits = np.zeros((n, n_keywords), bool)
    np.put_along_axis(bits, tags, True, axis=1)

    q = (keywords[rng.integers(0, n_keywords, b)]
         + rng.normal(size=(b, d))).astype(np.float32)
    qd2 = ((q[:, None, :] - keywords[None]) ** 2).sum(-1)
    if correlation == "positive":
        kw = np.argmin(qd2, axis=1)
    elif correlation == "negative":
        kw = np.argmax(qd2, axis=1)
    else:
        kw = rng.integers(0, n_keywords, b)
    fbits = np.zeros((b, n_keywords), bool)
    fbits[np.arange(b), kw] = True
    sel = np.array([bits[:, k].mean() for k in kw])
    return FilteredDataset(f"laion_like_{correlation}", xb,
                           F.subset_table(bits, n_keywords), q,
                           F.subset_filters(fbits, n_keywords), sel)


REGISTRY = {
    "sift_like": sift_like,
    "msturing_range": msturing_range,
    "msturing_subset": msturing_subset,
    "msturing_bool": msturing_bool,
    "laion_like": laion_like,
}


def make(name: str, **kw) -> FilteredDataset:
    return REGISTRY[name](**kw)
