"""JAG: joint attribute graphs for filtered nearest neighbor search.

The public filter surface is the expression tree: build leaves with
``Label``/``Range``/``Subset``/``Boolean``, combine them with ``&``/``|``/
``~``, and pass the result as ``filt`` to any ``JAGIndex.search*`` entry
point. A single-leaf expression normalizes to its atomic ``FilterBatch``
(``as_filter``) and runs the exact same compiled path, bit-identically.

    import repro
    f = repro.Label(3) & repro.Range(0.2, 0.8)
    idx = repro.JAGIndex.build(xb, table, repro.JAGConfig())
    res = idx.search_auto(q, f, k=10)
"""
from .core import (AttrTable, FilterBatch, JAGConfig, JAGIndex,
                   SearchResult, matches, selectivity)
from .core.filters import (And, Boolean, FilterExpr, Label, Not, Or, Range,
                           Subset, as_filter, describe, filter_batch,
                           joint_table, n_leaves)
from .core.ground_truth import GroundTruth, exact_filtered_knn

__all__ = ["And", "AttrTable", "Boolean", "FilterBatch", "FilterExpr",
           "GroundTruth", "JAGConfig", "JAGIndex", "Label", "Not", "Or",
           "Range", "SearchResult", "Subset", "as_filter", "describe",
           "exact_filtered_knn", "filter_batch", "joint_table", "matches",
           "n_leaves", "selectivity"]
