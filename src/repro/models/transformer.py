"""Decoder-only LM: dense & MoE variants covering the five assigned archs.

Features (per-arch knobs in repro.configs): GQA with separate head_dim
(gemma: 256), qk-norm (qwen3), GeGLU vs SwiGLU, tied embeddings, RoPE with
iRoPE-style NoPE-on-global layers, chunked local attention (llama4
``attn_chunk``), MoE top-1 routing with shared expert and layer interleaving
(llama4 maverick: every 2nd layer), residual/embedding scaling (minicpm).

Memory discipline for the production shapes:
  * ``forward`` (train/prefill) scans KV blocks with online softmax, so the
    score tensor never exceeds [B, T, H, kv_block] — the pure-XLA analogue
    of flash attention (the Pallas kernel is swapped in on real TPUs).
  * ``decode_step`` attends over the full cache in one einsum; the cache's
    sequence axis is sharded over "model", so XLA's sharded softmax performs
    the flash-decoding max/sum merge via collectives (DESIGN.md §4).
  * layers are scanned with remat; params are stacked [L, ...].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_constraint as lc
from .layers import rms_norm, rope, softmax_cross_entropy


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                 # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"                 # "silu" | "gelu" (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    moe_every: int = 1                # MoE on layers with (i+1) % every == 0
    capacity_factor: float = 1.25
    shared_expert: bool = True
    router_aux_weight: float = 0.01
    # attention locality (llama4 iRoPE)
    attn_chunk: int = 0               # 0 -> full attention
    global_every: int = 4             # every Nth layer global (NoPE)
    # scaling knobs (minicpm)
    emb_scale: float = 1.0
    resid_scale: float = 1.0
    norm_plus_one: bool = False       # gemma-style (1 + w) RMSNorm
    # numerics
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    vocab_pad: int = 128
    kv_block: int = 512
    # lowering strategy: scan_layers=True for compact HLO (real runs);
    # False unrolls the layer loop (dry-run: exact cost_analysis, static
    # MoE/rope branches). unroll_kv unrolls the kv-block online softmax.
    scan_layers: bool = True
    unroll_kv: bool = False
    # §Perf knobs (paper-faithful baseline keeps all off)
    attn_p_bf16: bool = False    # softmax probs in bf16 for the PV matmul
    attn_scores_bf16: bool = False  # whole score pipeline bf16 (m/l fp32)
    logits_bf16: bool = False    # bf16 logits (CE keeps fp32 logsumexp)
    remat_policy: str = "full"   # "full" | "dots" (save matmul outputs)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab + self.vocab_pad - 1)
                // self.vocab_pad) * self.vocab_pad

    def param_count(self) -> int:
        c = self.padded_vocab * self.d_model
        attn = self.d_model * self.hd * (2 * self.n_heads
                                         + 2 * self.n_kv_heads)
        ffn = 3 * self.d_model * self.d_ff
        for i in range(self.n_layers):
            c += attn + 2 * self.d_model
            if self._is_moe(i):
                c += self.n_experts * ffn + self.d_model * self.n_experts
                if self.shared_expert:
                    c += ffn
            else:
                c += ffn
        return c + self.d_model

    def active_param_count(self) -> int:
        c = self.padded_vocab * self.d_model
        attn = self.d_model * self.hd * (2 * self.n_heads
                                         + 2 * self.n_kv_heads)
        ffn = 3 * self.d_model * self.d_ff
        for i in range(self.n_layers):
            c += attn + ffn + 2 * self.d_model   # top-1: one expert active
            if self._is_moe(i) and self.shared_expert:
                c += ffn
        return c + self.d_model

    def _is_moe(self, i: int) -> bool:
        return self.n_experts > 0 and (i + 1) % self.moe_every == 0


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg: LMConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, logical-axis specs). Layer params stacked [L, ...]."""
    L, D, H, K, Dh, F, E = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                            cfg.n_kv_heads, cfg.hd, cfg.d_ff,
                            max(cfg.n_experts, 1))
    V = cfg.padded_vocab
    ks = jax.random.split(key, 12)
    pd = cfg.param_dtype

    def nrm(k, shape, fan_in):
        return (jax.random.normal(k, shape, pd) / math.sqrt(fan_in))

    p = {
        "embed": nrm(ks[0], (V, D), D),     # tied in/out embedding
        "final_norm": jnp.ones((D,), pd),
        "layers": {
            "ln1": jnp.ones((L, D), pd),
            "ln2": jnp.ones((L, D), pd),
            "wq": nrm(ks[1], (L, D, H * Dh), D),
            "wk": nrm(ks[2], (L, D, K * Dh), D),
            "wv": nrm(ks[3], (L, D, K * Dh), D),
            "wo": nrm(ks[4], (L, H * Dh, D), H * Dh),
            "gate": nrm(ks[5], (L, D, F), D),
            "up": nrm(ks[6], (L, D, F), D),
            "down": nrm(ks[7], (L, F, D), F),
        },
    }
    s = {
        "embed": ("vocab", "embed"),
        "final_norm": ("norm",),
        "layers": {
            "ln1": ("layers", "norm"), "ln2": ("layers", "norm"),
            "wq": ("layers", "embed", "heads"),
            "wk": ("layers", "embed", "kv_heads"),
            "wv": ("layers", "embed", "kv_heads"),
            "wo": ("layers", "heads", "embed"),
            "gate": ("layers", "embed", "mlp"),
            "up": ("layers", "embed", "mlp"),
            "down": ("layers", "mlp", "embed"),
        },
    }
    if cfg.qk_norm:
        p["layers"]["qnorm"] = jnp.ones((L, Dh), pd)
        p["layers"]["knorm"] = jnp.ones((L, Dh), pd)
        s["layers"]["qnorm"] = ("layers", "head_dim")
        s["layers"]["knorm"] = ("layers", "head_dim")
    if cfg.n_experts > 0:
        p["layers"]["router"] = nrm(ks[8], (L, D, cfg.n_experts), D)
        p["layers"]["e_gate"] = nrm(ks[9], (L, cfg.n_experts, D, F), D)
        p["layers"]["e_up"] = nrm(ks[10], (L, cfg.n_experts, D, F), D)
        p["layers"]["e_down"] = nrm(ks[11], (L, cfg.n_experts, F, D), F)
        s["layers"]["router"] = ("layers", "embed", "experts")
        # "expert_mlp" (not "mlp"): the model axis is already taken by the
        # experts dim (expert parallelism), so the per-expert ffn dim stays
        # FSDP/replicated — see distributed.sharding.make_rules.
        s["layers"]["e_gate"] = ("layers", "experts", "embed", "expert_mlp")
        s["layers"]["e_up"] = ("layers", "experts", "embed", "expert_mlp")
        s["layers"]["e_down"] = ("layers", "experts", "expert_mlp", "embed")
    return p, s


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attn_mask(pos_q, pos_k, is_global, chunk: int):
    m = pos_k[None, :] <= pos_q[:, None]
    if chunk:
        same = (pos_q[:, None] // chunk) == (pos_k[None, :] // chunk)
        m = m & (is_global | same)
    return m


def _attention_scan(q, k, v, pos_q, pos_k, cfg: LMConfig, is_global):
    """Online-softmax over KV blocks. q [B,T,H,Dh], k/v [B,S,K,Dh]."""
    B, T, H, Dh = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    blk = min(cfg.kv_block, S)
    pad = (-S) % blk
    if pad:  # pad KV to a block multiple; padded keys get pos = -1 (masked)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_k = jnp.concatenate(  # huge pos -> always causally masked
            [pos_k, jnp.full((pad,), jnp.iinfo(pos_k.dtype).max // 2,
                             pos_k.dtype)])
    S = S + pad
    nblk = S // blk
    sdt = jnp.bfloat16 if cfg.attn_scores_bf16 else jnp.float32
    qf = (q.reshape(B, T, K, G, Dh).astype(sdt)
          / jnp.asarray(math.sqrt(Dh), sdt))

    def step(carry, bi):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, bi * blk, blk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, bi * blk, blk, 1)
        pk = jax.lax.dynamic_slice_in_dim(pos_k, bi * blk, blk, 0)
        s = jnp.einsum("btkgd,bskd->btkgs", qf, ks.astype(sdt),
                       preferred_element_type=sdt)
        mask = _attn_mask(pos_q, pk, is_global, cfg.attn_chunk)
        s = jnp.where(mask[None, :, None, None, :], s,
                      jnp.asarray(-jnp.inf, sdt))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> nan
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None].astype(sdt))
        p = jnp.where(mask[None, :, None, None, :], p,
                      jnp.asarray(0.0, sdt))
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        if cfg.attn_p_bf16 or cfg.attn_scores_bf16:
            # halve the dominant tensor's bytes (§Perf); f32 accumulation
            pv = jnp.einsum("btkgs,bskd->btkgd",
                            p.astype(jnp.bfloat16),
                            vs.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("btkgs,bskd->btkgd", p,
                            vs.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    init = (jnp.full((B, T, K, G), -jnp.inf),
            jnp.zeros((B, T, K, G)),
            jnp.zeros((B, T, K, G, Dh)))
    if cfg.unroll_kv:  # straight-line HLO (dry-run: exact cost analysis)
        carry = init
        for bi in range(nblk):
            carry, _ = step(carry, bi)
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, init, jnp.arange(nblk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, T, H, Dh).astype(q.dtype)


def _attention_full(q, k, v, mask, length_mask=None):
    """Single-shot attention (decode): q [B,1,H,Dh], k/v [B,S,K,Dh] with the
    cache's S axis potentially sharded; softmax reductions become psums."""
    B, T, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.reshape(B, T, K, G, Dh).astype(jnp.float32) / math.sqrt(Dh)
    s = jnp.einsum("btkgd,bskd->btkgs", qf, k.astype(jnp.float32))
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, T, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MoE (top-1, sort-based dispatch with capacity)
# ---------------------------------------------------------------------------

def _moe_ffn(cfg: LMConfig, lw, x2d):
    """x2d [T, D] -> [T, D]; returns (out, aux_loss)."""
    T, D = x2d.shape
    E = cfg.n_experts
    cap = max(8, int(cfg.capacity_factor * T / E))
    logits = x2d.astype(jnp.float32) @ lw["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [T, E]
    eidx = jnp.argmax(probs, axis=-1)                        # top-1
    gate = jnp.take_along_axis(probs, eidx[:, None], 1)[:, 0]
    # switch load-balance aux
    frac = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    order = jnp.argsort(eidx)                                # group by expert
    se = jnp.take(eidx, order)
    ar = jnp.arange(T, dtype=jnp.int32)
    boundary = jnp.concatenate([jnp.ones((1,), jnp.bool_), se[1:] != se[:-1]])
    start = jax.lax.cummax(jnp.where(boundary, ar, 0))
    pos = ar - start
    keep = pos < cap                                         # capacity drop
    slot = jnp.where(keep, se * cap + pos, E * cap)          # OOB -> dropped
    xs = jnp.zeros((E * cap, D), x2d.dtype).at[slot].set(
        jnp.take(x2d, order, axis=0), mode="drop")
    xs = xs.reshape(E, cap, D)
    xs = lc(xs, ("experts", "expert_cap", "act_embed"))
    h = jnp.einsum("ecd,edf->ecf", xs, lw["e_gate"].astype(x2d.dtype))
    u = jnp.einsum("ecd,edf->ecf", xs, lw["e_up"].astype(x2d.dtype))
    h = (jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)) * u
    ys = jnp.einsum("ecf,efd->ecd", h, lw["e_down"].astype(x2d.dtype))
    ys = ys.reshape(E * cap, D)
    out = jnp.zeros_like(x2d).at[jnp.where(keep, order, T)].set(
        jnp.take(ys, jnp.minimum(slot, E * cap - 1), axis=0)
        * keep[:, None].astype(x2d.dtype), mode="drop")
    return out * gate[:, None].astype(x2d.dtype), aux


def _dense_ffn(cfg: LMConfig, lw, x):
    g = x @ lw["gate"].astype(x.dtype)
    u = x @ lw["up"].astype(x.dtype)
    g = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    return (g * u) @ lw["down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# transformer block (scanned over layers)
# ---------------------------------------------------------------------------

def _layer_flags(cfg: LMConfig, li):
    """(is_global, rope_on) — static bools when li is a Python int."""
    if not cfg.attn_chunk:
        return True, True
    if isinstance(li, int):
        ig = (li + 1) % cfg.global_every == 0
        return ig, not ig
    ig = jnp.equal((li + 1) % cfg.global_every, 0)
    return ig, ~ig


def _block(cfg: LMConfig, lw, li, x, pos_q):
    """One layer (train/prefill). x [B,T,D]. Returns (x, k, v, aux)."""
    B, T, D = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    is_global, rope_on = _layer_flags(cfg, li)

    h = rms_norm(x, lw["ln1"], plus_one=cfg.norm_plus_one)
    q = (h @ lw["wq"].astype(h.dtype)).reshape(B, T, H, Dh)
    kn = (h @ lw["wk"].astype(h.dtype)).reshape(B, T, K, Dh)
    vn = (h @ lw["wv"].astype(h.dtype)).reshape(B, T, K, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, lw["qnorm"])
        kn = rms_norm(kn, lw["knorm"])
    q = rope(q, pos_q, cfg.rope_theta, enabled=rope_on)
    kn = rope(kn, pos_q, cfg.rope_theta, enabled=rope_on)

    attn = _attention_scan(q, kn, vn, pos_q[0], pos_q[0], cfg, is_global)
    x = x + cfg.resid_scale * (attn.reshape(B, T, H * Dh)
                               @ lw["wo"].astype(x.dtype))

    h2 = rms_norm(x, lw["ln2"], plus_one=cfg.norm_plus_one)
    aux = jnp.float32(0.0)
    if cfg.n_experts > 0:
        h2d = h2.reshape(B * T, D)

        def moe_branch(h2d):
            routed, aux = _moe_ffn(cfg, lw, h2d)
            if cfg.shared_expert:
                routed = routed + _dense_ffn(cfg, lw, h2d)
            return routed, aux

        def dense_branch(h2d):
            return _dense_ffn(cfg, lw, h2d), jnp.float32(0.0)

        if isinstance(li, int):  # unrolled: static branch, exact HLO cost
            y2d, aux = (moe_branch(h2d) if cfg._is_moe(li)
                        else dense_branch(h2d))
        else:
            is_moe = jnp.equal((li + 1) % cfg.moe_every, 0)
            y2d, aux = jax.lax.cond(is_moe, moe_branch, dense_branch, h2d)
        y = y2d.reshape(B, T, D)
    else:
        y = _dense_ffn(cfg, lw, h2)
    x = x + cfg.resid_scale * y
    x = lc(x, ("batch", "seq", "act_embed"))
    return x, kn, vn, aux


def _attn_mask_decode(pos_q, pos_k, is_global, chunk: int):
    """pos_q [B, 1] current positions; pos_k [S]. -> [B, S]."""
    m = pos_k[None, :] <= pos_q
    if chunk:
        same = (pos_q // chunk) == (pos_k[None, :] // chunk)
        m = m & (is_global | same)
    return m


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _ckpt(f, cfg: LMConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


def forward(cfg: LMConfig, params, tokens: jnp.ndarray,
            remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forcing forward. tokens int32 [B, T] ->
    (logits [B, T, V], router aux loss)."""
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = x * cfg.emb_scale
    x = lc(x, ("batch", "seq", "act_embed"))
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    if cfg.scan_layers:
        def layer(carry, xs):
            x, aux = carry
            lw, li = xs
            x, _, _, a = _block(cfg, lw, li, x, pos)
            return (x, aux + a), None

        f = _ckpt(layer, cfg) if remat else layer
        (x, aux), _ = jax.lax.scan(
            f, (x, jnp.float32(0.0)),
            (params["layers"], jnp.arange(cfg.n_layers)))
    else:  # unrolled (dry-run lowering: exact per-layer HLO accounting)
        aux = jnp.float32(0.0)
        for i in range(cfg.n_layers):
            lw = jax.tree.map(lambda a: a[i], params["layers"])

            def one(lw, x, _i=i):
                xo, _, _, a = _block(cfg, lw, _i, x, pos)
                return xo, a
            f = _ckpt(one, cfg) if remat else one
            x, a = f(lw, x)
            aux = aux + a
    x = rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    out_t = jnp.bfloat16 if cfg.logits_bf16 else x.dtype
    logits = jnp.einsum("btd,vd->btv", x,
                        params["embed"].astype(x.dtype),
                        preferred_element_type=out_t)
    logits = lc(logits, ("batch", "seq", "vocab"))
    return logits, aux


def loss_fn(cfg: LMConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens[:, :-1])
    labels = tokens[:, 1:]
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:]
    # mask out padded vocab rows
    loss = softmax_cross_entropy(logits[..., :cfg.vocab], labels, mask)
    total = loss + cfg.router_aux_weight * aux
    return total, {"ce": loss, "router_aux": aux}


def init_cache(cfg: LMConfig, batch: int, max_seq: int):
    """KV cache [L, B, S, K, Dh] (+ logical specs)."""
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    cache = {"k": jnp.zeros(shape, cfg.dtype),
             "v": jnp.zeros(shape, cfg.dtype)}
    spec = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")
    return cache, {"k": spec, "v": spec}


def prefill(cfg: LMConfig, params, tokens: jnp.ndarray, cache):
    """Run the prompt, fill cache[:, :, :T], return last-position logits."""
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = x * cfg.emb_scale
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    if cfg.scan_layers:
        def layer(x, xs):
            lw, li = xs
            x, kn, vn, _ = _block(cfg, lw, li, x, pos)
            return x, (kn, vn)

        x, (ks, vs) = jax.lax.scan(
            _ckpt(layer, cfg), x,
            (params["layers"], jnp.arange(cfg.n_layers)))
    else:
        kl, vl = [], []
        for i in range(cfg.n_layers):
            lw = jax.tree.map(lambda a: a[i], params["layers"])

            def one(lw, x, _i=i):
                xo, kn, vn, _ = _block(cfg, lw, _i, x, pos)
                return xo, kn, vn
            x, kn, vn = _ckpt(one, cfg)(lw, x)
            kl.append(kn)
            vl.append(vn)
        ks, vs = jnp.stack(kl), jnp.stack(vl)
    S = cache["k"].shape[2]
    pad = [(0, 0), (0, 0), (0, S - T), (0, 0), (0, 0)]
    cache = {"k": jnp.pad(ks.astype(cfg.dtype), pad),
             "v": jnp.pad(vs.astype(cfg.dtype), pad)}
    x = rms_norm(x[:, -1:], params["final_norm"],
                 plus_one=cfg.norm_plus_one)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    return logits[:, 0], cache


def decode_step(cfg: LMConfig, params, cache, token: jnp.ndarray,
                cur_pos: jnp.ndarray):
    """One decode step. token int32 [B]; cur_pos int32 [B] (cache length).

    Returns (logits [B, V], updated cache)."""
    S = cache["k"].shape[2]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(cfg.dtype)
    x = x * cfg.emb_scale
    pos_q = cur_pos[:, None]                                  # [B, 1]
    pos_k = jnp.arange(S, dtype=jnp.int32)

    if cfg.scan_layers:
        def layer(x, xs):
            lw, li, kc, vc = xs
            # project new token's kv, then attend over cache ∪ {new}
            x, kn, vn, _ = _block_decode(cfg, lw, li, x, pos_q, pos_k,
                                         kc, vc)
            return x, (kn, vn)

        x, (kup, vup) = jax.lax.scan(
            layer, x, (params["layers"], jnp.arange(cfg.n_layers),
                       cache["k"], cache["v"]))
    else:
        kl, vl = [], []
        for i in range(cfg.n_layers):
            lw = jax.tree.map(lambda a: a[i], params["layers"])
            x, kn, vn, _ = _block_decode(cfg, lw, i, x, pos_q, pos_k,
                                         cache["k"][i], cache["v"][i])
            kl.append(kn)
            vl.append(vn)
        kup, vup = jnp.stack(kl), jnp.stack(vl)
    # scatter the new kv into the cache at cur_pos (per-batch position)
    oh = jax.nn.one_hot(cur_pos, S, dtype=cfg.dtype)[None, :, :, None, None]
    newk = cache["k"] * (1 - oh) + oh * kup[:, :, 0][:, :, None]
    newv = cache["v"] * (1 - oh) + oh * vup[:, :, 0][:, :, None]
    x = rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"].astype(x.dtype))
    return logits[:, 0], {"k": newk, "v": newv}


def _block_decode(cfg: LMConfig, lw, li, x, pos_q, pos_k, kc, vc):
    """Decode block: q from new token, kv = cache (new token's kv returned
    separately and merged by caller). kc/vc [B, S, K, Dh]."""
    B, T, D = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    is_global, rope_on = _layer_flags(cfg, li)

    h = rms_norm(x, lw["ln1"], plus_one=cfg.norm_plus_one)
    q = (h @ lw["wq"].astype(h.dtype)).reshape(B, T, H, Dh)
    kn = (h @ lw["wk"].astype(h.dtype)).reshape(B, T, K, Dh)
    vn = (h @ lw["wv"].astype(h.dtype)).reshape(B, T, K, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, lw["qnorm"])
        kn = rms_norm(kn, lw["knorm"])
    q = rope(q, pos_q, cfg.rope_theta, enabled=rope_on)
    kn = rope(kn, pos_q, cfg.rope_theta, enabled=rope_on)

    mask = _attn_mask_decode(pos_q, pos_k, is_global, cfg.attn_chunk)
    # cache attention (strictly previous positions) merged with the new
    # token's self-attention via a two-pool online-softmax combine
    mask_prev = mask & (pos_k[None, :] < pos_q)
    qf = q.reshape(B, T, K, H // K, Dh).astype(jnp.float32) / math.sqrt(Dh)
    s_self = jnp.einsum("btkgd,btkd->btkg", qf, kn.astype(jnp.float32))
    # merge: attn was softmax over prev only; redo with self via logsumexp
    # trick — recompute as weighted merge of two softmax pools:
    s_prev = jnp.einsum("btkgd,bskd->btkgs", qf, kc.astype(jnp.float32))
    s_prev = jnp.where(mask_prev[:, None, None, None, :], s_prev, -jnp.inf)
    m_prev = jnp.max(s_prev, axis=-1)
    m_all = jnp.maximum(m_prev, s_self)
    m_safe = jnp.where(jnp.isfinite(m_all), m_all, 0.0)
    p_prev = jnp.exp(s_prev - m_safe[..., None])
    p_prev = jnp.where(mask_prev[:, None, None, None, :], p_prev, 0.0)
    p_self = jnp.exp(s_self - m_safe)
    denom = jnp.sum(p_prev, -1) + p_self
    out = (jnp.einsum("btkgs,bskd->btkgd", p_prev,
                      vc.astype(jnp.float32))
           + p_self[..., None] * vn.astype(jnp.float32)[:, :, :, None, :])
    attn = (out / jnp.maximum(denom[..., None], 1e-30)).reshape(
        B, T, H * Dh).astype(x.dtype)
    x = x + cfg.resid_scale * (attn @ lw["wo"].astype(x.dtype))

    h2 = rms_norm(x, lw["ln2"], plus_one=cfg.norm_plus_one)
    if cfg.n_experts > 0:
        is_moe = jnp.equal((li + 1) % cfg.moe_every, 0)
        h2d = h2.reshape(B * T, D)

        def moe_branch(h2d):
            routed, _ = _moe_ffn(cfg, lw, h2d)
            if cfg.shared_expert:
                routed = routed + _dense_ffn(cfg, lw, h2d)
            return routed

        y = jax.lax.cond(is_moe, moe_branch,
                         lambda h: _dense_ffn(cfg, lw, h), h2d).reshape(
                             B, T, D)
    else:
        y = _dense_ffn(cfg, lw, h2)
    x = x + cfg.resid_scale * y
    return x, kn, vn, jnp.float32(0.0)
