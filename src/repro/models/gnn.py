"""GCN (Kipf & Welling, arXiv:1609.02907) via segment-sum message passing.

JAX sparse is BCOO-only, so the SpMM  Ã·X·W  is implemented as an explicit
edge gather -> ``jax.ops.segment_sum`` scatter over an edge index — the
taxonomy-mandated formulation (kernel regime: SpMM/scatter-gather). Supports
full-batch training (cora / ogb_products), sampled minibatch training with a
real fanout neighbor sampler (data/graph_sampler.py), and batched small
graphs (molecule) via a graph-id segment vector.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import softmax_cross_entropy


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    n_layers: int = 2
    d_feat: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    aggregator: str = "mean"          # mean == symmetric-normalized here
    norm: str = "sym"                 # "sym": D^-1/2 A D^-1/2, "row": D^-1 A
    dropout: float = 0.0
    dtype: any = jnp.float32

    def param_count(self) -> int:
        dims = [self.d_feat] + [self.d_hidden] * (self.n_layers - 1) + [
            self.n_classes]
        return sum(dims[i] * dims[i + 1] + dims[i + 1]
                   for i in range(len(dims) - 1))


def init_params(cfg: GCNConfig, key) -> Tuple[Dict, Dict]:
    dims = ([cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1)
            + [cfg.n_classes])
    ks = jax.random.split(key, cfg.n_layers)
    p, s = {"layers": []}, {"layers": []}
    for i in range(cfg.n_layers):
        w = (jax.random.normal(ks[i], (dims[i], dims[i + 1]), cfg.dtype)
             / math.sqrt(dims[i]))
        p["layers"].append({"w": w, "b": jnp.zeros((dims[i + 1],),
                                                   cfg.dtype)})
        s["layers"].append({"w": ("feat", "feat"), "b": ("feat",)})
    return p, s


def gcn_conv(x: jnp.ndarray, edges: jnp.ndarray, n_nodes: int,
             norm: str = "sym",
             inv_sqrt_deg: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """One propagation Ã·x. edges int32 [E, 2] (src, dst); self-loops are the
    caller's choice. Returns [N, F]."""
    src, dst = edges[:, 0], edges[:, 1]
    if inv_sqrt_deg is None:
        deg = jax.ops.segment_sum(jnp.ones_like(dst, x.dtype), dst,
                                  num_segments=n_nodes)
        inv_sqrt_deg = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
    msgs = jnp.take(x, src, axis=0)
    if norm == "sym":
        msgs = msgs * jnp.take(inv_sqrt_deg, src)[:, None]
        agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
        return agg * inv_sqrt_deg[:, None]
    # row normalization (mean aggregator)
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    return agg * (inv_sqrt_deg ** 2)[:, None]


def forward(cfg: GCNConfig, params, feats: jnp.ndarray,
            edges: jnp.ndarray) -> jnp.ndarray:
    """feats [N, d_feat], edges [E, 2] -> logits [N, n_classes]."""
    n = feats.shape[0]
    # add self loops once (standard GCN Ã = A + I)
    loops = jnp.arange(n, dtype=edges.dtype)
    edges = jnp.concatenate([edges, jnp.stack([loops, loops], 1)], axis=0)
    deg = jax.ops.segment_sum(jnp.ones((edges.shape[0],), feats.dtype),
                              edges[:, 1], num_segments=n)
    isd = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
    x = feats.astype(cfg.dtype)
    for i, lw in enumerate(params["layers"]):
        x = gcn_conv(x, edges, n, cfg.norm, isd)
        x = x @ lw["w"] + lw["b"]
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(cfg: GCNConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    """batch: feats [N,F], edges [E,2], labels [N], label_mask [N]."""
    logits = forward(cfg, params, batch["feats"], batch["edges"])
    loss = softmax_cross_entropy(logits, batch["labels"],
                                 batch.get("label_mask"))
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                   * batch.get("label_mask",
                               jnp.ones_like(batch["labels"])))
    return loss, {"ce": loss, "acc": acc}


def graph_loss_fn(cfg: GCNConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    """Graph classification over a packed batch of small graphs (molecule
    shape): mean-pool node logits per graph_id, then CE per graph."""
    logits = forward(cfg, params, batch["feats"], batch["edges"])
    ng = batch["labels"].shape[0]
    pooled = jax.ops.segment_sum(logits, batch["graph_ids"],
                                 num_segments=ng)
    cnt = jax.ops.segment_sum(
        jnp.ones((logits.shape[0],), logits.dtype), batch["graph_ids"],
        num_segments=ng)
    pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
    loss = softmax_cross_entropy(pooled, batch["labels"])
    return loss, {"ce": loss}


def sampled_loss_fn(cfg: GCNConfig, params, batch) -> Tuple[jnp.ndarray,
                                                            Dict]:
    """Minibatch variant over a sampled subgraph (graph_sampler layout):
    feats [M, F] for the union of sampled nodes, edges [E', 2] reindexed,
    labels/mask for the first `batch_nodes` seed nodes."""
    logits = forward(cfg, params, batch["feats"], batch["edges"])
    nb = batch["labels"].shape[0]
    loss = softmax_cross_entropy(logits[:nb], batch["labels"],
                                 batch.get("label_mask"))
    return loss, {"ce": loss}
