"""Shared neural-net layers as pure functions over param pytrees.

Params are nested dicts of arrays; each init also returns a parallel tree of
*logical axis* tuples consumed by ``repro.distributed.sharding`` (MaxText
convention). No framework dependency (flax/optax unavailable offline).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Specs = Dict[str, Any]


def dense(key, in_dim: int, out_dims, in_axis: str, out_axes,
          dtype=jnp.float32, scale: Optional[float] = None):
    """He/Lecun-normal dense kernel [in, *out] with logical axes."""
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    out_axes = (out_axes,) if isinstance(out_axes, str) else tuple(out_axes)
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, *out_dims), dtype) * scale
    return w, (in_axis, *out_axes)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(
        jnp.float32)
    return (x * scale).astype(dt)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float = 10000.0,
         enabled=None) -> jnp.ndarray:
    """Rotary embedding. x [..., T, H, D], pos int [..., T].

    ``enabled``: optional traced bool (iRoPE NoPE layers pass False)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs      # [..., T, half]
    ang = ang[..., None, :]                               # [..., T, 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    rx = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    rx = rx.astype(x.dtype)
    if enabled is None:
        return rx
    return jnp.where(enabled, rx, x)


def swiglu(x, w_gate, w_up, w_down, act: str = "silu"):
    """Gated MLP. x [..., d]; w_gate/w_up [d, f]; w_down [f, d]."""
    g = x @ w_gate
    u = x @ w_up
    if act == "silu":
        g = jax.nn.silu(g)
    elif act == "gelu":
        g = jax.nn.gelu(g)
    else:
        raise ValueError(act)
    return (g * u) @ w_down


def mlp_stack(key, dims, in_axis="mlp_in", hidden_axis="mlp_hidden",
              dtype=jnp.float32):
    """Plain MLP tower params: list of (w, b) with relu between."""
    ks = jax.random.split(key, len(dims) - 1)
    ws, specs = [], []
    for i, k in enumerate(ks):
        w, sp = dense(k, dims[i], dims[i + 1], in_axis, hidden_axis, dtype)
        ws.append({"w": w, "b": jnp.zeros((dims[i + 1],), dtype)})
        specs.append({"w": sp, "b": (hidden_axis,)})
    return ws, specs


def mlp_apply(ws, x, final_act: bool = False):
    for i, layer in enumerate(ws):
        x = x @ layer["w"] + layer["b"]
        if i < len(ws) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token CE; logits may be vocab-sharded (XLA inserts the psum)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if mask is not None:
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
