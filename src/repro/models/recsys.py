"""RecSys architectures: FM, DeepFM, Wide&Deep, DIN.

JAX has no native EmbeddingBag — it is built here from ``jnp.take`` +
``jax.ops.segment_sum`` (taxonomy mandate). All four models share one fused
embedding table [total_vocab, dim] (rows sharded over ("data","model") at
production scale); per-field offsets index into it.

Interactions:
  fm         — pairwise <v_i, v_j> x_i x_j via the O(nk) sum-square trick
               (Rendle ICDM'10): 0.5 * ((Σ v)² − Σ v²).
  deepfm     — FM branch ∥ deep MLP over concatenated field embeddings.
  wide-deep  — wide linear (per-feature weight) + deep MLP, concat fields.
  din        — target attention over the user behavior sequence:
               attn_mlp(concat(h, t, h−t, h*t)) -> weights -> Σ w·h.

``retrieval_scores`` implements the retrieval_cand shape: one user vector
against 10^6 candidate embeddings as a single blocked matmul (no loop) —
this is also where the JAG index plugs in (examples/recsys_retrieval_jag).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_constraint as lc
from .layers import mlp_apply, mlp_stack


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "fm"
    kind: str = "fm"                  # fm | deepfm | wide_deep | din
    n_sparse: int = 39
    embed_dim: int = 10
    # per-field vocab; default Criteo-like power-law sizes
    field_vocabs: Tuple[int, ...] = ()
    total_vocab: int = 10_000_000
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    attn_mlp_dims: Tuple[int, ...] = (80, 40)   # DIN attention tower
    seq_len: int = 100                          # DIN behavior sequence
    n_dense: int = 13                           # dense (numeric) features
    dtype: Any = jnp.float32
    table_dtype: Any = None                     # None -> dtype; §Perf: bf16

    def vocabs(self) -> Tuple[int, ...]:
        if self.field_vocabs:
            return self.field_vocabs
        # power-law split of total_vocab across fields
        n = self.n_sparse
        w = np.power(np.arange(1, n + 1, dtype=np.float64), -1.1)
        w = w / w.sum()
        v = np.maximum((w * self.total_vocab).astype(np.int64), 4)
        return tuple(int(x) for x in v)

    def param_count(self) -> int:
        c = sum(self.vocabs()) * self.embed_dim
        if self.kind in ("deepfm", "wide_deep"):
            dims = ([self.n_sparse * self.embed_dim + self.n_dense]
                    + list(self.mlp_dims) + [1])
            c += sum(dims[i] * dims[i + 1] + dims[i + 1]
                     for i in range(len(dims) - 1))
        if self.kind in ("fm", "deepfm", "wide_deep"):
            c += sum(self.vocabs())          # wide / first-order weights
        if self.kind == "din":
            dims = [4 * self.embed_dim] + list(self.attn_mlp_dims) + [1]
            c += sum(dims[i] * dims[i + 1] + dims[i + 1]
                     for i in range(len(dims) - 1))
            dims = ([3 * self.embed_dim] + list(self.mlp_dims) + [1])
            c += sum(dims[i] * dims[i + 1] + dims[i + 1]
                     for i in range(len(dims) - 1))
        return c


import numpy as np  # noqa: E402  (used by vocabs())


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  segments: jnp.ndarray, n_segments: int,
                  combine: str = "sum") -> jnp.ndarray:
    """EmbeddingBag: rows = take(table, ids); out[s] = Σ rows[segments==s].

    table [V, D]; ids int32 [K]; segments int32 [K] -> [n_segments, D].
    """
    rows = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(rows, segments, num_segments=n_segments)
    if combine == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(segments, table.dtype),
                                  segments, num_segments=n_segments)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def field_offsets(cfg: RecsysConfig) -> jnp.ndarray:
    v = np.asarray(cfg.vocabs(), np.int64)
    return jnp.asarray(np.concatenate([[0], np.cumsum(v)[:-1]]), jnp.int32)


def lookup_fields(table, sparse_ids, offsets):
    """sparse_ids int32 [B, F] (per-field local id) -> [B, F, D]."""
    flat = (sparse_ids + offsets[None, :]).reshape(-1)
    return jnp.take(table, flat, axis=0).reshape(
        sparse_ids.shape[0], sparse_ids.shape[1], -1)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg: RecsysConfig, key) -> Tuple[Dict, Dict]:
    total = sum(cfg.vocabs())
    ks = jax.random.split(key, 6)
    tdt = cfg.table_dtype or cfg.dtype
    p: Dict = {"table": (jax.random.normal(
        ks[0], (total, cfg.embed_dim), jnp.float32) * 0.01).astype(tdt)}
    s: Dict = {"table": ("table_rows", "table_dim")}
    if cfg.kind in ("fm", "deepfm", "wide_deep"):
        p["wide"] = jax.random.normal(ks[1], (total,), cfg.dtype) * 0.01
        p["bias"] = jnp.zeros((), cfg.dtype)
        s["wide"] = ("table_rows",)
        s["bias"] = ()
    if cfg.kind in ("deepfm", "wide_deep"):
        in_dim = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
        p["mlp"], s["mlp"] = mlp_stack(ks[2],
                                       [in_dim, *cfg.mlp_dims, 1],
                                       dtype=cfg.dtype)
    if cfg.kind == "din":
        p["attn_mlp"], s["attn_mlp"] = mlp_stack(
            ks[3], [4 * cfg.embed_dim, *cfg.attn_mlp_dims, 1],
            dtype=cfg.dtype)
        p["mlp"], s["mlp"] = mlp_stack(
            ks[4], [3 * cfg.embed_dim, *cfg.mlp_dims, 1], dtype=cfg.dtype)
    return p, s


# ---------------------------------------------------------------------------
# interactions
# ---------------------------------------------------------------------------

def fm_second_order(emb: jnp.ndarray) -> jnp.ndarray:
    """Σ_{i<j} <v_i, v_j> via 0.5((Σv)² − Σv²). emb [B, F, D] -> [B]."""
    s = jnp.sum(emb, axis=1)
    s2 = jnp.sum(emb * emb, axis=1)
    return 0.5 * jnp.sum(s * s - s2, axis=-1)


def din_attention(hist: jnp.ndarray, target: jnp.ndarray, attn_mlp,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Target attention. hist [B, T, D]; target [B, D] -> [B, D]."""
    B, T, D = hist.shape
    t = jnp.broadcast_to(target[:, None, :], (B, T, D))
    feats = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = mlp_apply(attn_mlp, feats.reshape(B * T, -1)).reshape(B, T)
    if mask is not None:
        w = jnp.where(mask, w, -1e30)
    w = jax.nn.softmax(w, axis=-1)
    return jnp.einsum("bt,btd->bd", w, hist)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(cfg: RecsysConfig, params, batch) -> jnp.ndarray:
    """Returns logits [B]."""
    offsets = field_offsets(cfg)
    if cfg.kind == "din":
        target = jnp.take(params["table"], batch["target_id"], axis=0)
        hist = jnp.take(params["table"], batch["hist_ids"], axis=0)
        user = din_attention(hist, target, params["attn_mlp"],
                             batch.get("hist_mask"))
        x = jnp.concatenate([user, target, user * target], axis=-1)
        return mlp_apply(params["mlp"], x)[:, 0]

    sparse = batch["sparse_ids"]                             # [B, F]
    emb = lookup_fields(params["table"], sparse, offsets)    # [B, F, D]
    emb = lc(emb, ("batch", "fields", "table_dim"))
    flat_ids = (sparse + offsets[None, :]).reshape(-1)
    first = jnp.take(params["wide"], flat_ids).reshape(
        sparse.shape).sum(axis=1) + params["bias"]
    if cfg.kind == "fm":
        return first + fm_second_order(emb)
    dense = batch.get("dense",
                      jnp.zeros((sparse.shape[0], cfg.n_dense), cfg.dtype))
    deep_in = jnp.concatenate(
        [emb.reshape(sparse.shape[0], -1), dense], axis=-1)
    deep = mlp_apply(params["mlp"], deep_in)[:, 0]
    if cfg.kind == "deepfm":
        return first + fm_second_order(emb) + deep
    if cfg.kind == "wide_deep":
        return first + deep
    raise ValueError(cfg.kind)


def loss_fn(cfg: RecsysConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    logits = forward(cfg, params, batch)
    y = batch["label"].astype(jnp.float32)
    z = logits.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(
        z))))
    return loss, {"logloss": loss}


def retrieval_scores(user_vec: jnp.ndarray,
                     cand_table: jnp.ndarray) -> jnp.ndarray:
    """Score 1 (or B) user vectors against all candidates: [B, Ncand]."""
    cand_table = lc(cand_table, ("candidates", "table_dim"))
    return user_vec @ cand_table.T


def retrieval_topk(user_vec, cand_table, k: int = 100):
    scores = retrieval_scores(user_vec, cand_table)
    return jax.lax.top_k(scores, k)
