"""Selectivity-adaptive query planner: request -> plan -> execute.

JAG's headline claim is robust performance across the entire selectivity
spectrum, but no single execution strategy wins every band (FAVOR,
arXiv:2605.07770; the CUHK experimental study, arXiv:2508.16263): at very
low selectivity an exact masked scan touches fewer points than any graph
walk, and near selectivity 1.0 an unfiltered traversal plus oversampled
filtering matches the filtered walk at lower comparator cost. This module
estimates filter selectivity with a sampled ``matches()`` probe
(jit-compatible, all four filter kinds) and routes to one of the
executor's three routes:

    sel <= prefilter_max_sel   -> "prefilter"   (masked exact scan)
    sel >= postfilter_min_sel  -> "postfilter"  (unfiltered + oversample)
    otherwise                  -> "graph"       (JAG traversal)

Two planning granularities share the probe:

  * :func:`plan` — whole-batch: one route chosen by the *median* estimate
    (``JAGIndex.search_auto(mode="batch")``).
  * :func:`plan_per_query` — the per-query router: bands the [B]
    selectivity vector query-by-query and groups queries by route, so a
    batch mixing 0.1% and 90% filters no longer drags half its queries
    down the wrong path. ``serve/dispatch.py`` gathers each group (queries
    AND filter lanes) into a contiguous sub-batch, runs it through its
    route, and scatters the results back into original query order.

``JAGIndex.search_auto`` is the end-to-end entry point (default
``mode="per_query"``); thresholds live in ``PlannerConfig`` (static today —
cost-model-driven thresholds remain a ROADMAP open item).

Streaming: both planners probe whatever attribute table they are handed —
``StreamingJAGIndex.search_auto`` passes the live base+delta table, so the
selectivity estimate tracks inserted rows immediately. The probe's device
buffers and compilation live in the executor's epoch-aware caches
(``Executor.sample_ids`` / ``Executor.run``): an insert bumps the index
epoch and evicts them, so routing can never consult a stale-n sample.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.filters import AttrTable, FilterBatch, matches_sampled

ROUTES = ("prefilter", "graph", "postfilter")


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    n_samples: int = 1024          # attr rows probed per selectivity estimate
    prefilter_max_sel: float = 0.02
    postfilter_min_sel: float = 0.75
    seed: int = 0                  # sample draw (deterministic per planner)


class Plan(NamedTuple):
    """A whole-batch routing decision."""
    route: str                 # one of ROUTES
    selectivity: np.ndarray    # f32 [B] per-query estimates
    batch_selectivity: float   # the median driving the route choice
    n_sampled: int             # probe size actually used (== n for exact)


class GroupPlan(NamedTuple):
    """One route group of a per-query plan."""
    route: str                 # one of ROUTES
    ids: np.ndarray            # int32 [G] positions in the original batch
    selectivity: float         # median estimate within the group


class PerQueryPlan(NamedTuple):
    """Per-query routing decisions for one batch.

    ``routes[b]`` is query b's route; ``groups`` lists the non-empty route
    groups in ROUTES order, each with the original-batch positions the
    dispatcher gathers/scatters by. ``route``/``batch_selectivity``
    properties mirror the whole-batch :class:`Plan` so logging and
    benchmarks can treat either plan flavor uniformly.
    """
    routes: Tuple[str, ...]    # per-query route, len B
    selectivity: np.ndarray    # f32 [B] per-query estimates
    groups: Tuple[GroupPlan, ...]
    n_sampled: int

    @property
    def route(self) -> str:
        """The single route when the batch didn't split, else "mixed"."""
        return self.groups[0].route if len(self.groups) == 1 else "mixed"

    @property
    def batch_selectivity(self) -> float:
        return float(np.median(self.selectivity))


def sample_ids(n: int, n_samples: int, seed: int = 0) -> jnp.ndarray:
    """Deterministic sample of attr-table rows; exact (arange) if it fits.

    Deliberately NOT memoized at module level: an ``lru_cache`` here would
    pin JAX device buffers process-wide across index lifetimes and test
    runs. The serving hot path goes through ``Executor.sample_ids``, which
    scopes the cached device arrays to one index's executor.
    """
    if n_samples >= n:
        return jnp.arange(n, dtype=jnp.int32)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(n, n_samples, replace=False), jnp.int32)


def estimate_selectivity(filt: FilterBatch, table: AttrTable,
                         ids: jnp.ndarray) -> jnp.ndarray:
    """Per-query selectivity estimate f32[B] from a sampled matches() probe.

    Pure jnp on registered pytrees, so it traces under ``jax.jit`` for every
    filter kind; the executor caches one compilation per (kind, |sample|).
    """
    ok = matches_sampled(filt, table, ids)
    return jnp.mean(ok.astype(jnp.float32), axis=-1)


def choose_route(sel: float, cfg: PlannerConfig) -> str:
    """Threshold router over one selectivity scalar."""
    if sel <= cfg.prefilter_max_sel:
        return "prefilter"
    if sel >= cfg.postfilter_min_sel:
        return "postfilter"
    return "graph"


def _estimate(filt: FilterBatch, table: AttrTable, cfg: PlannerConfig,
              executor) -> Tuple[np.ndarray, int]:
    """Shared probe: host f32[B] estimates + the probe size used."""
    if executor is not None:
        ids = executor.sample_ids(table.n, cfg.n_samples, cfg.seed)
    else:
        ids = sample_ids(table.n, cfg.n_samples, cfg.seed)
    n_sampled = int(ids.shape[0])
    if executor is not None:
        key = ("estimate", "default", "f32", 0, 0, 0, filt.kind, n_sampled)
        est = executor.run(key, lambda: estimate_selectivity,
                           filt, table, ids)
    else:
        est = estimate_selectivity(filt, table, ids)
    return np.asarray(est, np.float32), n_sampled


def plan(filt: FilterBatch, table: AttrTable,
         cfg: PlannerConfig = PlannerConfig(),
         executor=None) -> Plan:
    """Estimate the batch's selectivity and pick ONE route for all queries.

    When ``executor`` is given, the probe's compilation lives in the
    executor's single jit cache (keyed like every route); otherwise the
    estimate runs as a one-off traced call.
    """
    sel, n_sampled = _estimate(filt, table, cfg, executor)
    batch_sel = float(np.median(sel))
    return Plan(choose_route(batch_sel, cfg), sel, batch_sel, n_sampled)


def plan_per_query(filt: FilterBatch, table: AttrTable,
                   cfg: PlannerConfig = PlannerConfig(),
                   executor=None) -> PerQueryPlan:
    """Band the per-query selectivity vector into route groups.

    Same probe as :func:`plan`; the [B] estimates are banded query-by-query
    and grouped by route (positions kept in ascending order so the
    dispatcher's gather/scatter is a stable permutation).
    """
    sel, n_sampled = _estimate(filt, table, cfg, executor)
    routes = tuple(choose_route(float(s), cfg) for s in sel)
    routes_arr = np.asarray(routes)
    groups = []
    for route in ROUTES:
        members = np.flatnonzero(routes_arr == route)
        if members.size:
            groups.append(GroupPlan(route, members.astype(np.int32),
                                    float(np.median(sel[members]))))
    return PerQueryPlan(routes, sel, tuple(groups), n_sampled)


def explain(p, cfg: PlannerConfig = PlannerConfig()) -> str:
    """One-line human-readable routing rationale (benchmarks / logs)."""
    lo, hi = cfg.prefilter_max_sel, cfg.postfilter_min_sel
    head = f"route={p.route} sel~{p.batch_selectivity:.4f}"
    if isinstance(p, PerQueryPlan):
        split = " ".join(f"{g.route}:{g.ids.size}" for g in p.groups)
        head += f" [{split}]"
    return (f"{head} (n_sampled={p.n_sampled}, thresholds: "
            f"prefilter<={lo}, postfilter>={hi})")
