"""Selectivity-adaptive query planner: request -> plan -> execute.

JAG's headline claim is robust performance across the entire selectivity
spectrum, but no single execution strategy wins every band (FAVOR,
arXiv:2605.07770; the CUHK experimental study, arXiv:2508.16263): at very
low selectivity an exact masked scan touches fewer points than any graph
walk, and near selectivity 1.0 an unfiltered traversal plus oversampled
filtering matches the filtered walk at lower comparator cost. This module
estimates filter selectivity with a sampled ``matches()`` probe
(jit-compatible, all four filter kinds) and routes to one of the
executor's three routes:

    sel <= prefilter_max_sel   -> "prefilter"   (masked exact scan)
    sel >= postfilter_min_sel  -> "postfilter"  (unfiltered + oversample)
    otherwise                  -> "graph"       (JAG traversal)

Two planning granularities share the probe:

  * :func:`plan` — whole-batch: one route chosen by the *median* estimate
    (``JAGIndex.search_auto(mode="batch")``).
  * :func:`plan_per_query` — the per-query router: bands the [B]
    selectivity vector query-by-query and groups queries by route, so a
    batch mixing 0.1% and 90% filters no longer drags half its queries
    down the wrong path. ``serve/dispatch.py`` gathers each group (queries
    AND filter lanes) into a contiguous sub-batch, runs it through its
    route, and scatters the results back into original query order.

``JAGIndex.search_auto`` is the end-to-end entry point (default
``mode="per_query"``); the static thresholds live in ``PlannerConfig``.
When the index carries a calibrated cost model (``repro.cost``,
``JAGIndex.attach_cost_model``), both planners take a ``router``
(``cost.CostModelRouter``, built per call by ``Executor.cost_router``)
and the threshold ladder is replaced by an argmin over measured-cost
predictions per route — the static thresholds remain the exact fallback
whenever no model is attached or it doesn't cover the base routes.

Compound filters: a FilterExpr tree (core.filters And/Or/Not over the four
atomic leaves) plans exactly like an atomic filter — the probe evaluates
the WHOLE tree on the sampled rows, so the estimate is the joint
selectivity, not an independence composition. Correlated clauses (a label
that implies a range band, a subset mask nested inside the boolean
predicate it encodes) used to be composed as if independent — a
``label & range`` whose clauses coincide was estimated at sel² and
mis-routed to the exact scan; the joint probe costs the same one gather
(every leaf is evaluated on the same rows either way) and is exact on the
sample. Routing — static thresholds or cost-model argmin — stays a
per-query decision over one joint [B] selectivity vector. The prefilter
route additionally asks :func:`reorder_clauses` for the short-circuit-
optimal clause order (cheapest most-selective first, conditioned on the
clauses already placed — :func:`leaf_validity` hands it the per-leaf
boolean vectors, so the ordering also sees the correlations).

Streaming: both planners probe whatever attribute table they are handed —
``StreamingJAGIndex.search_auto`` passes the live base+delta table, so the
selectivity estimate tracks inserted rows immediately. The probe's device
buffers and compilation live in the executor's epoch-aware caches
(``Executor.sample_ids`` / ``Executor.run``): an insert bumps the index
epoch and evicts them, so routing can never consult a stale-n sample.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.filters import (AttrTable, FilterBatch, FilterExpr, Leaf, And,
                            Or, Not, _broadcast_rows, describe, matches,
                            matches_sampled)

ROUTES = ("prefilter", "graph", "postfilter")


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    n_samples: int = 1024          # attr rows probed per selectivity estimate
    prefilter_max_sel: float = 0.02
    postfilter_min_sel: float = 0.75
    seed: int = 0                  # sample draw (deterministic per planner)

    def __post_init__(self):
        # inverted thresholds would silently route the whole (0, 1] band
        # to prefilter-or-postfilter with the graph band empty or
        # ill-defined — refuse at construction, where the typo is.
        # Values past 1.0 are legal on purpose: prefilter_max_sel=1.1
        # (with postfilter_min_sel above it) forces the exact scan
        # everywhere, which tests and ground-truth tooling rely on.
        if self.n_samples < 1:
            raise ValueError(f"n_samples must be >= 1, "
                             f"got {self.n_samples}")
        if self.prefilter_max_sel < 0.0:
            raise ValueError(f"prefilter_max_sel must be >= 0, "
                             f"got {self.prefilter_max_sel}")
        if self.prefilter_max_sel >= self.postfilter_min_sel:
            raise ValueError(
                f"inverted thresholds: prefilter_max_sel "
                f"{self.prefilter_max_sel} >= postfilter_min_sel "
                f"{self.postfilter_min_sel} (the graph band would be "
                f"empty and the ladder order-dependent)")


class Plan(NamedTuple):
    """A whole-batch routing decision."""
    route: str                 # one of ROUTES
    selectivity: np.ndarray    # f32 [B] per-query estimates
    batch_selectivity: float   # the median driving the route choice
    n_sampled: int             # probe size actually used (== n for exact)
    # predicted cost/query per route at the batch median when a cost-model
    # router made the decision (in cost_metric units); None under the
    # static thresholds
    costs: Optional[Dict[str, float]] = None
    cost_metric: Optional[str] = None    # "us" | "n_dist" | None (static)
    # the route variant that actually executed (``search_auto`` stamps it
    # post-dispatch): a dispatch.route_descriptor string, e.g.
    # "graph[fused,int8]" or "prefilter+delta". None when the plan never
    # ran (planner-only construction).
    realized: Optional[str] = None


class GroupPlan(NamedTuple):
    """One route group of a per-query plan."""
    route: str                 # one of ROUTES
    ids: np.ndarray            # int32 [G] positions in the original batch
    selectivity: float         # median estimate within the group


class PerQueryPlan(NamedTuple):
    """Per-query routing decisions for one batch.

    ``routes[b]`` is query b's route; ``groups`` lists the non-empty route
    groups in ROUTES order, each with the original-batch positions the
    dispatcher gathers/scatters by. ``route``/``batch_selectivity``
    properties mirror the whole-batch :class:`Plan` so logging and
    benchmarks can treat either plan flavor uniformly.
    """
    routes: Tuple[str, ...]    # per-query route, len B
    selectivity: np.ndarray    # f32 [B] per-query estimates
    groups: Tuple[GroupPlan, ...]
    n_sampled: int
    # predicted cost/query per route at the batch median when a cost-model
    # router banded the queries (in cost_metric units); None under the
    # static thresholds
    costs: Optional[Dict[str, float]] = None
    cost_metric: Optional[str] = None    # "us" | "n_dist" | None (static)
    # per-query realized route descriptors (len B), stamped by
    # ``search_auto`` after dispatch so traces/explain agree with what
    # executed. None when the plan never ran.
    realized: Optional[Tuple[str, ...]] = None

    @property
    def route(self) -> str:
        """The single route when the batch didn't split, else "mixed"."""
        return self.groups[0].route if len(self.groups) == 1 else "mixed"

    @property
    def batch_selectivity(self) -> float:
        return float(np.median(self.selectivity))


def sample_ids(n: int, n_samples: int, seed: int = 0) -> jnp.ndarray:
    """Deterministic sample of attr-table rows; exact (arange) if it fits.

    Deliberately NOT memoized at module level: an ``lru_cache`` here would
    pin JAX device buffers process-wide across index lifetimes and test
    runs. The serving hot path goes through ``Executor.sample_ids``, which
    scopes the cached device arrays to one index's executor.
    """
    if n_samples >= n:
        return jnp.arange(n, dtype=jnp.int32)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(n, n_samples, replace=False), jnp.int32)


def estimate_selectivity(filt, table: AttrTable,
                         ids: jnp.ndarray) -> jnp.ndarray:
    """Per-query selectivity estimate f32[B] from a sampled matches() probe.

    Pure jnp on registered pytrees, so it traces under ``jax.jit`` for every
    filter kind; the executor caches one compilation per (kind, |sample|) —
    an expression's structural ``kind`` signature keys compound probes the
    same way. Compound estimates evaluate the WHOLE tree on the probe rows,
    so they are JOINT: correlated clauses (a label implying a range band)
    estimate at their true co-occurrence rate, where an independence
    composition of per-leaf means can be off by the full correlation
    factor. Atomic filters keep the identical matches_sampled probe.
    """
    if isinstance(filt, FilterBatch):
        ok = matches_sampled(filt, table, ids)
        return jnp.mean(ok.astype(jnp.float32), axis=-1)
    attrs = _broadcast_rows(table, jnp.asarray(ids, jnp.int32))
    return jnp.mean(matches(filt, attrs).astype(jnp.float32), axis=-1)


def leaf_selectivities(filt, table: AttrTable,
                       ids: jnp.ndarray) -> jnp.ndarray:
    """Per-leaf sampled selectivities f32[L, B], leaves in DFS order.

    One gather of the sample rows feeds every leaf's matches() mean.
    Marginal summaries only — the clause reorderer now probes
    :func:`leaf_validity` so it can see joint structure; this stays the
    cheap per-leaf report for benchmarks and explain-style logging.
    """
    ids = jnp.asarray(ids, jnp.int32)
    attrs = _broadcast_rows(table, ids)
    leaves = filt.leaves() if isinstance(filt, FilterExpr) else [filt]
    return jnp.stack(
        [jnp.mean(matches(f, attrs).astype(jnp.float32), axis=-1)
         for f in leaves])


def leaf_validity(filt, table: AttrTable, ids: jnp.ndarray) -> jnp.ndarray:
    """Per-leaf boolean validity bool[L, B, S] on the probe rows (DFS order).

    The raw material :func:`reorder_clauses` composes internal-node
    selectivities from WITHOUT the independence assumption: every leaf is
    evaluated on the same S sampled rows, so any And/Or node's joint
    validity is just the boolean combination of its children's vectors.
    """
    ids = jnp.asarray(ids, jnp.int32)
    attrs = _broadcast_rows(table, ids)
    leaves = filt.leaves() if isinstance(filt, FilterExpr) else [filt]
    return jnp.stack([matches(f, attrs) for f in leaves])


def _leaf_values(leaf_sels):
    """Normalize reorder inputs: scalars (independence mode) or per-leaf
    boolean arrays such as ``leaf_validity`` rows (joint mode). A mixed
    list degrades every vector to its mean so one mode runs uniformly."""
    out = [np.asarray(v) for v in leaf_sels]
    if any(a.ndim == 0 for a in out):
        return [float(a) if a.ndim == 0 else float(np.mean(a)) for a in out]
    return [a.astype(bool) for a in out]


def _frac(v) -> float:
    """Mass of a validity value: the mean of a boolean vector, or the
    scalar probability itself."""
    return float(np.mean(v)) if isinstance(v, np.ndarray) else float(v)


def _vand(a, b):
    """Conjunction of two validity values (boolean AND, or the
    independence product for scalars)."""
    if isinstance(a, np.ndarray) and isinstance(b, np.ndarray):
        return a & b
    return a * b


def _vnot(v):
    return ~v if isinstance(v, np.ndarray) else 1.0 - v


def _vtrue(like):
    return (np.ones_like(like, dtype=bool)
            if isinstance(like, np.ndarray) else 1.0)


def _order_clauses(filt, leaf_iter, reorder: bool):
    """Recursive (expr, validity, expected_evals_per_point).

    ``validity`` is either a scalar probability (legacy independence mode)
    or a boolean sample vector (joint mode): an internal node's vector is
    the boolean combination of its children's, so selectivities and
    short-circuit live-mass estimates reflect clause correlations exactly
    (on the sample). Ordering is greedy conditional: each next clause is
    the one with the best cost per unit of conditional filtering power
    GIVEN the clauses already placed — which reduces to the classic
    cost/(1-sel) (And) and cost/sel (Or) static sort when clauses are
    independent scalars.
    """
    if isinstance(filt, FilterBatch):
        return filt, next(leaf_iter), 1.0
    if isinstance(filt, Leaf):
        f, v, c = _order_clauses(filt.filt, leaf_iter, reorder)
        return Leaf(f), v, c
    if isinstance(filt, Not):
        ch, v, c = _order_clauses(filt.child, leaf_iter, reorder)
        return Not(ch), _vnot(v), c
    if isinstance(filt, (And, Or)):
        kids = [_order_clauses(c, leaf_iter, reorder)
                for c in filt.children]
        is_and = isinstance(filt, And)
        if reorder:
            ordered, live = [], _vtrue(kids[0][1])
            while kids:
                lm = _frac(live)

                def rank(t):
                    inter = _frac(_vand(live, t[1]))
                    # And: cost per conditionally-killed mass; Or: cost
                    # per conditionally-accepted mass. min() keeps the
                    # first of rank-tied clauses (written order, like the
                    # stable sort it replaces).
                    power = (lm - inter) if is_and else inter
                    return t[2] / max(power, 1e-9)

                i = min(range(len(kids)), key=lambda j: rank(kids[j]))
                t = kids.pop(i)
                ordered.append(t)
                live = _vand(live, t[1] if is_and else _vnot(t[1]))
            kids = ordered
        live, cost = _vtrue(kids[0][1]), 0.0
        for _, v, c in kids:
            cost += _frac(live) * c
            live = _vand(live, v if is_and else _vnot(v))
        val = live if is_and else _vnot(live)
        node = (And if is_and else Or)(*[k[0] for k in kids])
        return node, val, cost
    raise TypeError(f"not a filter: {type(filt)!r}")


def reorder_clauses(filt, leaf_sels):
    """Short-circuit-optimal clause order, cheapest-most-selective first.

    ``leaf_sels``: one value per leaf in DFS order — either scalar
    selectivities (e.g. the medians of :func:`leaf_selectivities`;
    composed under independence) or per-leaf boolean sample vectors (the
    rows of :func:`leaf_validity`; composed JOINTLY, so correlated
    clauses order by their true conditional filtering power). And children
    greedily take the best cost-per-killed-mass next, Or children the best
    cost-per-accepted-mass, each conditioned on the clauses already
    placed; subtree costs are expected short-circuit evals per point, so
    nesting composes. Boolean connectives commute, so the reordered tree
    is result-identical — only ``n_feval`` changes. Atomic filters pass
    through unchanged.
    """
    if not isinstance(filt, FilterExpr):
        return filt
    return _order_clauses(filt, iter(_leaf_values(leaf_sels)), True)[0]


def clause_eval_cost(filt, leaf_sels) -> float:
    """Expected short-circuit leaf evals per scanned point, given the
    tree's CURRENT clause order and per-leaf selectivities or validity
    vectors (DFS order; scalar = independence, boolean vector = joint)."""
    return _order_clauses(filt, iter(_leaf_values(leaf_sels)), False)[2]


def choose_route(sel: float, cfg: PlannerConfig) -> str:
    """Threshold router over one selectivity scalar (the static fallback;
    a calibrated ``cost.CostModelRouter`` replaces this ladder with an
    argmin over predicted per-route cost)."""
    if sel <= cfg.prefilter_max_sel:
        return "prefilter"
    if sel >= cfg.postfilter_min_sel:
        return "postfilter"
    return "graph"


def _route_of(sel: float, cfg: PlannerConfig, router) -> str:
    """One query's route: cost-model argmin when a router is attached,
    else the static threshold ladder."""
    return router.route(sel) if router is not None else choose_route(sel,
                                                                     cfg)


def _estimate(filt, table: AttrTable, cfg: PlannerConfig,
              executor) -> Tuple[np.ndarray, int]:
    """Shared probe: host f32[B] estimates + the probe size used."""
    if executor is not None:
        ids = executor.sample_ids(table.n, cfg.n_samples, cfg.seed)
    else:
        ids = sample_ids(table.n, cfg.n_samples, cfg.seed)
    n_sampled = int(ids.shape[0])
    if executor is not None:
        key = ("estimate", "default", "f32", 0, 0, 0, filt.kind, n_sampled)
        est = executor.run(key, lambda: estimate_selectivity,
                           filt, table, ids)
    else:
        est = estimate_selectivity(filt, table, ids)
    return np.asarray(est, np.float32), n_sampled


def plan(filt, table: AttrTable,
         cfg: PlannerConfig = PlannerConfig(),
         executor=None, router=None) -> Plan:
    """Estimate the batch's selectivity and pick ONE route for all queries.

    When ``executor`` is given, the probe's compilation lives in the
    executor's single jit cache (keyed like every route); otherwise the
    estimate runs as a one-off traced call. When ``router`` (a calibrated
    ``cost.CostModelRouter``) is given, the route is the argmin of
    predicted per-route cost at the batch median instead of the static
    threshold ladder, and ``Plan.costs`` reports those predictions.
    """
    sel, n_sampled = _estimate(filt, table, cfg, executor)
    batch_sel = float(np.median(sel))
    if router is None:
        return Plan(_route_of(batch_sel, cfg, None), sel, batch_sel,
                    n_sampled)
    return Plan(router.route(batch_sel), sel, batch_sel, n_sampled,
                router.costs(batch_sel), router.metric)


def plan_per_query(filt, table: AttrTable,
                   cfg: PlannerConfig = PlannerConfig(),
                   executor=None, router=None) -> PerQueryPlan:
    """Band the per-query selectivity vector into route groups.

    Same probe as :func:`plan`; the [B] estimates are banded query-by-query
    and grouped by route (positions kept in ascending order so the
    dispatcher's gather/scatter is a stable permutation). With a ``router``
    attached, each query's band is the argmin of its predicted per-route
    cost instead of the static thresholds.
    """
    sel, n_sampled = _estimate(filt, table, cfg, executor)
    routes = tuple(_route_of(float(s), cfg, router) for s in sel)
    routes_arr = np.asarray(routes)
    groups = []
    for route in ROUTES:
        members = np.flatnonzero(routes_arr == route)
        if members.size:
            groups.append(GroupPlan(route, members.astype(np.int32),
                                    float(np.median(sel[members]))))
    batch_sel = float(np.median(sel))
    if router is None:
        return PerQueryPlan(routes, sel, tuple(groups), n_sampled)
    return PerQueryPlan(routes, sel, tuple(groups), n_sampled,
                        router.costs(batch_sel), router.metric)


def _executed_note(p) -> str:
    """Realized-route summary when it differs from the planned band names.

    Empty when the plan never ran (``realized is None``) or execution was
    exactly the planned route (default layout, no delta) — ``explain``
    stays byte-stable for every pre-existing call site.
    """
    realized = getattr(p, "realized", None)
    if realized is None:
        return ""
    if isinstance(realized, str):
        return "" if realized == p.route else realized
    if tuple(realized) == tuple(getattr(p, "routes", ())):
        return ""
    counts: Dict[str, int] = {}
    for name in realized:
        counts[name] = counts.get(name, 0) + 1
    return " ".join(f"{name}:{c}" for name, c in counts.items())


def explain(p, cfg: PlannerConfig = PlannerConfig(), filt=None) -> str:
    """One-line human-readable routing rationale (benchmarks / logs).

    Pass the planned ``filt`` to prepend the filter expression, e.g.
    ``filter=(label=3 & range[0,0.5])``. Plans returned by
    ``search_auto(return_plan=True)`` carry the realized per-query route
    (serving variant / delta suffix included); when that differs from the
    planned band names, an ``executed[...]`` summary is appended.
    """
    head = f"route={p.route} sel~{p.batch_selectivity:.4f}"
    if filt is not None:
        head = f"filter={describe(filt)} {head}"
    if isinstance(p, PerQueryPlan):
        split = " ".join(f"{g.route}:{g.ids.size}" for g in p.groups)
        head += f" [{split}]"
    executed = _executed_note(p)
    if executed:
        head += f" executed[{executed}]"
    if p.costs is not None:
        unit = {"us": "us", "n_dist": "DC"}.get(p.cost_metric,
                                                p.cost_metric or "")
        pred = " ".join(f"{r}={c:.1f}{unit}" for r, c in p.costs.items())
        return f"{head} (n_sampled={p.n_sampled}, cost-model argmin: {pred})"
    lo, hi = cfg.prefilter_max_sel, cfg.postfilter_min_sel
    return (f"{head} (n_sampled={p.n_sampled}, thresholds: "
            f"prefilter<={lo}, postfilter>={hi})")
