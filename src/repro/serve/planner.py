"""Selectivity-adaptive query planner: request -> plan -> execute.

JAG's headline claim is robust performance across the entire selectivity
spectrum, but no single execution strategy wins every band (FAVOR,
arXiv:2605.07770; the CUHK experimental study, arXiv:2508.16263): at very
low selectivity an exact masked scan touches fewer points than any graph
walk, and near selectivity 1.0 an unfiltered traversal plus oversampled
filtering matches the filtered walk at lower comparator cost. This module
estimates a filter batch's selectivity with a sampled ``matches()`` probe
(jit-compatible, all four filter kinds) and routes the batch to one of the
executor's three routes:

    sel <= prefilter_max_sel   -> "prefilter"   (masked exact scan)
    sel >= postfilter_min_sel  -> "postfilter"  (unfiltered + oversample)
    otherwise                  -> "graph"       (JAG traversal)

``JAGIndex.search_auto`` is the end-to-end entry point; thresholds live in
``PlannerConfig`` (static today — cost-model-driven thresholds and
per-query route batching are ROADMAP open items).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from ..core.filters import AttrTable, FilterBatch, matches_sampled

ROUTES = ("prefilter", "graph", "postfilter")


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    n_samples: int = 1024          # attr rows probed per selectivity estimate
    prefilter_max_sel: float = 0.02
    postfilter_min_sel: float = 0.75
    seed: int = 0                  # sample draw (deterministic per planner)


class Plan(NamedTuple):
    """A routing decision for one query batch."""
    route: str                 # one of ROUTES
    selectivity: np.ndarray    # f32 [B] per-query estimates
    batch_selectivity: float   # the median driving the route choice
    n_sampled: int             # probe size actually used (== n for exact)


@functools.lru_cache(maxsize=64)
def sample_ids(n: int, n_samples: int, seed: int = 0) -> jnp.ndarray:
    """Deterministic sample of attr-table rows; exact (arange) if it fits.

    Memoized: the draw is identical for a fixed (n, n_samples, seed), and
    ``replace=False`` costs an O(n) host permutation plus a device upload —
    too much to repeat on the serving hot path of every ``plan()`` call.
    """
    if n_samples >= n:
        return jnp.arange(n, dtype=jnp.int32)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(n, n_samples, replace=False), jnp.int32)


def estimate_selectivity(filt: FilterBatch, table: AttrTable,
                         ids: jnp.ndarray) -> jnp.ndarray:
    """Per-query selectivity estimate f32[B] from a sampled matches() probe.

    Pure jnp on registered pytrees, so it traces under ``jax.jit`` for every
    filter kind; the executor caches one compilation per (kind, |sample|).
    """
    ok = matches_sampled(filt, table, ids)
    return jnp.mean(ok.astype(jnp.float32), axis=-1)


def choose_route(sel: float, cfg: PlannerConfig) -> str:
    """Threshold router over a batch-level selectivity scalar."""
    if sel <= cfg.prefilter_max_sel:
        return "prefilter"
    if sel >= cfg.postfilter_min_sel:
        return "postfilter"
    return "graph"


def plan(filt: FilterBatch, table: AttrTable,
         cfg: PlannerConfig = PlannerConfig(),
         executor=None) -> Plan:
    """Estimate the batch's selectivity and pick a route.

    When ``executor`` is given, the probe's compilation lives in the
    executor's single jit cache (keyed like every route); otherwise the
    estimate runs as a one-off traced call.
    """
    ids = sample_ids(table.n, cfg.n_samples, cfg.seed)
    n_sampled = int(ids.shape[0])
    if executor is not None:
        key = ("estimate", "default", "f32", 0, 0, 0, filt.kind, n_sampled)
        est = executor.run(key, lambda: estimate_selectivity,
                           filt, table, ids)
    else:
        est = estimate_selectivity(filt, table, ids)
    sel = np.asarray(est, np.float32)
    batch_sel = float(np.median(sel))
    return Plan(choose_route(batch_sel, cfg), sel, batch_sel, n_sampled)


def explain(p: Plan, cfg: PlannerConfig = PlannerConfig()) -> str:
    """One-line human-readable routing rationale (benchmarks / logs)."""
    lo, hi = cfg.prefilter_max_sel, cfg.postfilter_min_sel
    return (f"route={p.route} sel~{p.batch_selectivity:.4f} "
            f"(n_sampled={p.n_sampled}, thresholds: prefilter<={lo}, "
            f"postfilter>={hi})")
