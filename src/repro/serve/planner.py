"""Selectivity-adaptive query planner: request -> plan -> execute.

JAG's headline claim is robust performance across the entire selectivity
spectrum, but no single execution strategy wins every band (FAVOR,
arXiv:2605.07770; the CUHK experimental study, arXiv:2508.16263): at very
low selectivity an exact masked scan touches fewer points than any graph
walk, and near selectivity 1.0 an unfiltered traversal plus oversampled
filtering matches the filtered walk at lower comparator cost. This module
estimates filter selectivity with a sampled ``matches()`` probe
(jit-compatible, all four filter kinds) and routes to one of the
executor's three routes:

    sel <= prefilter_max_sel   -> "prefilter"   (masked exact scan)
    sel >= postfilter_min_sel  -> "postfilter"  (unfiltered + oversample)
    otherwise                  -> "graph"       (JAG traversal)

Two planning granularities share the probe:

  * :func:`plan` — whole-batch: one route chosen by the *median* estimate
    (``JAGIndex.search_auto(mode="batch")``).
  * :func:`plan_per_query` — the per-query router: bands the [B]
    selectivity vector query-by-query and groups queries by route, so a
    batch mixing 0.1% and 90% filters no longer drags half its queries
    down the wrong path. ``serve/dispatch.py`` gathers each group (queries
    AND filter lanes) into a contiguous sub-batch, runs it through its
    route, and scatters the results back into original query order.

``JAGIndex.search_auto`` is the end-to-end entry point (default
``mode="per_query"``); the static thresholds live in ``PlannerConfig``.
When the index carries a calibrated cost model (``repro.cost``,
``JAGIndex.attach_cost_model``), both planners take a ``router``
(``cost.CostModelRouter``, built per call by ``Executor.cost_router``)
and the threshold ladder is replaced by an argmin over measured-cost
predictions per route — the static thresholds remain the exact fallback
whenever no model is attached or it doesn't cover the base routes.

Compound filters: a FilterExpr tree (core.filters And/Or/Not over the four
atomic leaves) plans exactly like an atomic filter — the probe samples each
*leaf* once and composes the per-clause estimates under independence
(product for AND, inclusion-exclusion 1 - prod(1 - s_i) for OR, complement
for NOT), so routing — static thresholds or cost-model argmin — stays a
per-query decision over one composed [B] selectivity vector. The prefilter
route additionally asks :func:`reorder_clauses` for the short-circuit-
optimal clause order (cheapest most-selective first) before scanning.

Streaming: both planners probe whatever attribute table they are handed —
``StreamingJAGIndex.search_auto`` passes the live base+delta table, so the
selectivity estimate tracks inserted rows immediately. The probe's device
buffers and compilation live in the executor's epoch-aware caches
(``Executor.sample_ids`` / ``Executor.run``): an insert bumps the index
epoch and evicts them, so routing can never consult a stale-n sample.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.filters import (AttrTable, FilterBatch, FilterExpr, Leaf, And,
                            Or, Not, _broadcast_rows, describe, matches,
                            matches_sampled)

ROUTES = ("prefilter", "graph", "postfilter")


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    n_samples: int = 1024          # attr rows probed per selectivity estimate
    prefilter_max_sel: float = 0.02
    postfilter_min_sel: float = 0.75
    seed: int = 0                  # sample draw (deterministic per planner)

    def __post_init__(self):
        # inverted thresholds would silently route the whole (0, 1] band
        # to prefilter-or-postfilter with the graph band empty or
        # ill-defined — refuse at construction, where the typo is.
        # Values past 1.0 are legal on purpose: prefilter_max_sel=1.1
        # (with postfilter_min_sel above it) forces the exact scan
        # everywhere, which tests and ground-truth tooling rely on.
        if self.n_samples < 1:
            raise ValueError(f"n_samples must be >= 1, "
                             f"got {self.n_samples}")
        if self.prefilter_max_sel < 0.0:
            raise ValueError(f"prefilter_max_sel must be >= 0, "
                             f"got {self.prefilter_max_sel}")
        if self.prefilter_max_sel >= self.postfilter_min_sel:
            raise ValueError(
                f"inverted thresholds: prefilter_max_sel "
                f"{self.prefilter_max_sel} >= postfilter_min_sel "
                f"{self.postfilter_min_sel} (the graph band would be "
                f"empty and the ladder order-dependent)")


class Plan(NamedTuple):
    """A whole-batch routing decision."""
    route: str                 # one of ROUTES
    selectivity: np.ndarray    # f32 [B] per-query estimates
    batch_selectivity: float   # the median driving the route choice
    n_sampled: int             # probe size actually used (== n for exact)
    # predicted cost/query per route at the batch median when a cost-model
    # router made the decision (in cost_metric units); None under the
    # static thresholds
    costs: Optional[Dict[str, float]] = None
    cost_metric: Optional[str] = None    # "us" | "n_dist" | None (static)


class GroupPlan(NamedTuple):
    """One route group of a per-query plan."""
    route: str                 # one of ROUTES
    ids: np.ndarray            # int32 [G] positions in the original batch
    selectivity: float         # median estimate within the group


class PerQueryPlan(NamedTuple):
    """Per-query routing decisions for one batch.

    ``routes[b]`` is query b's route; ``groups`` lists the non-empty route
    groups in ROUTES order, each with the original-batch positions the
    dispatcher gathers/scatters by. ``route``/``batch_selectivity``
    properties mirror the whole-batch :class:`Plan` so logging and
    benchmarks can treat either plan flavor uniformly.
    """
    routes: Tuple[str, ...]    # per-query route, len B
    selectivity: np.ndarray    # f32 [B] per-query estimates
    groups: Tuple[GroupPlan, ...]
    n_sampled: int
    # predicted cost/query per route at the batch median when a cost-model
    # router banded the queries (in cost_metric units); None under the
    # static thresholds
    costs: Optional[Dict[str, float]] = None
    cost_metric: Optional[str] = None    # "us" | "n_dist" | None (static)

    @property
    def route(self) -> str:
        """The single route when the batch didn't split, else "mixed"."""
        return self.groups[0].route if len(self.groups) == 1 else "mixed"

    @property
    def batch_selectivity(self) -> float:
        return float(np.median(self.selectivity))


def sample_ids(n: int, n_samples: int, seed: int = 0) -> jnp.ndarray:
    """Deterministic sample of attr-table rows; exact (arange) if it fits.

    Deliberately NOT memoized at module level: an ``lru_cache`` here would
    pin JAX device buffers process-wide across index lifetimes and test
    runs. The serving hot path goes through ``Executor.sample_ids``, which
    scopes the cached device arrays to one index's executor.
    """
    if n_samples >= n:
        return jnp.arange(n, dtype=jnp.int32)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(n, n_samples, replace=False), jnp.int32)


def _compose_selectivity(filt, leaf_sel):
    """Combine per-leaf sampled selectivities over an expression tree.

    Under clause independence: And multiplies (product is <= every
    clause), Or composes by inclusion-exclusion — 1 - prod(1 - s_i) —
    which is >= every clause and capped at 1 by construction, Not
    complements. ``leaf_sel`` maps a FilterBatch to its f32[B] estimate.
    """
    if isinstance(filt, FilterBatch):
        return leaf_sel(filt)
    if isinstance(filt, Leaf):
        return _compose_selectivity(filt.filt, leaf_sel)
    if isinstance(filt, Not):
        return 1.0 - _compose_selectivity(filt.child, leaf_sel)
    if isinstance(filt, And):
        out = _compose_selectivity(filt.children[0], leaf_sel)
        for c in filt.children[1:]:
            out = out * _compose_selectivity(c, leaf_sel)
        return out
    if isinstance(filt, Or):
        miss = 1.0 - _compose_selectivity(filt.children[0], leaf_sel)
        for c in filt.children[1:]:
            miss = miss * (1.0 - _compose_selectivity(c, leaf_sel))
        return 1.0 - miss
    raise TypeError(f"not a filter: {type(filt)!r}")


def estimate_selectivity(filt, table: AttrTable,
                         ids: jnp.ndarray) -> jnp.ndarray:
    """Per-query selectivity estimate f32[B] from a sampled matches() probe.

    Pure jnp on registered pytrees, so it traces under ``jax.jit`` for every
    filter kind; the executor caches one compilation per (kind, |sample|) —
    an expression's structural ``kind`` signature keys compound probes the
    same way. Compound estimates compose the per-leaf sampled estimates
    (product / inclusion-exclusion / complement), clipped to [0, 1].
    """
    if isinstance(filt, FilterBatch):
        ok = matches_sampled(filt, table, ids)
        return jnp.mean(ok.astype(jnp.float32), axis=-1)
    attrs = _broadcast_rows(table, jnp.asarray(ids, jnp.int32))

    def leaf_sel(f):
        return jnp.mean(matches(f, attrs).astype(jnp.float32), axis=-1)

    return jnp.clip(_compose_selectivity(filt, leaf_sel), 0.0, 1.0)


def leaf_selectivities(filt, table: AttrTable,
                       ids: jnp.ndarray) -> jnp.ndarray:
    """Per-leaf sampled selectivities f32[L, B], leaves in DFS order.

    The clause reorderer's probe: one gather of the sample rows feeds
    every leaf's matches() mean.
    """
    ids = jnp.asarray(ids, jnp.int32)
    attrs = _broadcast_rows(table, ids)
    leaves = filt.leaves() if isinstance(filt, FilterExpr) else [filt]
    return jnp.stack(
        [jnp.mean(matches(f, attrs).astype(jnp.float32), axis=-1)
         for f in leaves])


def _rank_and(sel: float, cost: float) -> float:
    # classic predicate ordering: cost per unit of filtering power;
    # for unit costs this is ascending selectivity
    return cost / max(1.0 - sel, 1e-9)


def _rank_or(sel: float, cost: float) -> float:
    return cost / max(sel, 1e-9)


def _order_clauses(filt, leaf_iter, reorder: bool):
    """Recursive (expr, composed_sel, expected_evals_per_point)."""
    if isinstance(filt, FilterBatch):
        return filt, float(next(leaf_iter)), 1.0
    if isinstance(filt, Leaf):
        f, s, c = _order_clauses(filt.filt, leaf_iter, reorder)
        return Leaf(f), s, c
    if isinstance(filt, Not):
        ch, s, c = _order_clauses(filt.child, leaf_iter, reorder)
        return Not(ch), 1.0 - s, c
    if isinstance(filt, (And, Or)):
        kids = [_order_clauses(c, leaf_iter, reorder)
                for c in filt.children]
        is_and = isinstance(filt, And)
        if reorder:
            # stable sort: ties keep the written clause order
            kids.sort(key=lambda t: (_rank_and if is_and else _rank_or)(
                t[1], t[2]))
        live, cost = 1.0, 0.0
        for _, s, c in kids:
            cost += live * c
            live *= s if is_and else (1.0 - s)
        sel = live if is_and else 1.0 - live
        node = (And if is_and else Or)(*[k[0] for k in kids])
        return node, sel, cost
    raise TypeError(f"not a filter: {type(filt)!r}")


def reorder_clauses(filt, leaf_sels):
    """Short-circuit-optimal clause order, cheapest-most-selective first.

    ``leaf_sels``: one scalar selectivity per leaf in DFS order (e.g. the
    medians of :func:`leaf_selectivities`). And children sort ascending by
    cost/(1-sel) (kill cheap and early), Or children ascending by cost/sel
    (accept cheap and early); subtree costs are expected short-circuit
    evals per point, so nesting composes. Boolean connectives commute, so
    the reordered tree is result-identical — only ``n_feval`` changes.
    Atomic filters pass through unchanged.
    """
    if not isinstance(filt, FilterExpr):
        return filt
    return _order_clauses(filt, iter([float(s) for s in leaf_sels]),
                          True)[0]


def clause_eval_cost(filt, leaf_sels) -> float:
    """Expected short-circuit leaf evals per scanned point, given the
    tree's CURRENT clause order and per-leaf selectivities (DFS order)."""
    return _order_clauses(filt, iter([float(s) for s in leaf_sels]),
                          False)[2]


def choose_route(sel: float, cfg: PlannerConfig) -> str:
    """Threshold router over one selectivity scalar (the static fallback;
    a calibrated ``cost.CostModelRouter`` replaces this ladder with an
    argmin over predicted per-route cost)."""
    if sel <= cfg.prefilter_max_sel:
        return "prefilter"
    if sel >= cfg.postfilter_min_sel:
        return "postfilter"
    return "graph"


def _route_of(sel: float, cfg: PlannerConfig, router) -> str:
    """One query's route: cost-model argmin when a router is attached,
    else the static threshold ladder."""
    return router.route(sel) if router is not None else choose_route(sel,
                                                                     cfg)


def _estimate(filt, table: AttrTable, cfg: PlannerConfig,
              executor) -> Tuple[np.ndarray, int]:
    """Shared probe: host f32[B] estimates + the probe size used."""
    if executor is not None:
        ids = executor.sample_ids(table.n, cfg.n_samples, cfg.seed)
    else:
        ids = sample_ids(table.n, cfg.n_samples, cfg.seed)
    n_sampled = int(ids.shape[0])
    if executor is not None:
        key = ("estimate", "default", "f32", 0, 0, 0, filt.kind, n_sampled)
        est = executor.run(key, lambda: estimate_selectivity,
                           filt, table, ids)
    else:
        est = estimate_selectivity(filt, table, ids)
    return np.asarray(est, np.float32), n_sampled


def plan(filt, table: AttrTable,
         cfg: PlannerConfig = PlannerConfig(),
         executor=None, router=None) -> Plan:
    """Estimate the batch's selectivity and pick ONE route for all queries.

    When ``executor`` is given, the probe's compilation lives in the
    executor's single jit cache (keyed like every route); otherwise the
    estimate runs as a one-off traced call. When ``router`` (a calibrated
    ``cost.CostModelRouter``) is given, the route is the argmin of
    predicted per-route cost at the batch median instead of the static
    threshold ladder, and ``Plan.costs`` reports those predictions.
    """
    sel, n_sampled = _estimate(filt, table, cfg, executor)
    batch_sel = float(np.median(sel))
    if router is None:
        return Plan(_route_of(batch_sel, cfg, None), sel, batch_sel,
                    n_sampled)
    return Plan(router.route(batch_sel), sel, batch_sel, n_sampled,
                router.costs(batch_sel), router.metric)


def plan_per_query(filt, table: AttrTable,
                   cfg: PlannerConfig = PlannerConfig(),
                   executor=None, router=None) -> PerQueryPlan:
    """Band the per-query selectivity vector into route groups.

    Same probe as :func:`plan`; the [B] estimates are banded query-by-query
    and grouped by route (positions kept in ascending order so the
    dispatcher's gather/scatter is a stable permutation). With a ``router``
    attached, each query's band is the argmin of its predicted per-route
    cost instead of the static thresholds.
    """
    sel, n_sampled = _estimate(filt, table, cfg, executor)
    routes = tuple(_route_of(float(s), cfg, router) for s in sel)
    routes_arr = np.asarray(routes)
    groups = []
    for route in ROUTES:
        members = np.flatnonzero(routes_arr == route)
        if members.size:
            groups.append(GroupPlan(route, members.astype(np.int32),
                                    float(np.median(sel[members]))))
    batch_sel = float(np.median(sel))
    if router is None:
        return PerQueryPlan(routes, sel, tuple(groups), n_sampled)
    return PerQueryPlan(routes, sel, tuple(groups), n_sampled,
                        router.costs(batch_sel), router.metric)


def explain(p, cfg: PlannerConfig = PlannerConfig(), filt=None) -> str:
    """One-line human-readable routing rationale (benchmarks / logs).

    Pass the planned ``filt`` to prepend the filter expression, e.g.
    ``filter=(label=3 & range[0,0.5])``.
    """
    head = f"route={p.route} sel~{p.batch_selectivity:.4f}"
    if filt is not None:
        head = f"filter={describe(filt)} {head}"
    if isinstance(p, PerQueryPlan):
        split = " ".join(f"{g.route}:{g.ids.size}" for g in p.groups)
        head += f" [{split}]"
    if p.costs is not None:
        unit = {"us": "us", "n_dist": "DC"}.get(p.cost_metric,
                                                p.cost_metric or "")
        pred = " ".join(f"{r}={c:.1f}{unit}" for r, c in p.costs.items())
        return f"{head} (n_sampled={p.n_sampled}, cost-model argmin: {pred})"
    lo, hi = cfg.prefilter_max_sel, cfg.postfilter_min_sel
    return (f"{head} (n_sampled={p.n_sampled}, thresholds: "
            f"prefilter<={lo}, postfilter>={hi})")
