"""Sharded serving: shard_map executor + cross-shard exact top-k merge.

The multi-device serving subsystem (ROADMAP "millions of users" north
star): the database — vectors, row norms, attribute table, graph, entry
seeds — is sharded ROW-WISE across the mesh's "data" axis (one
self-contained JAG shard of N_loc = N / S rows per device, placed by the
``distributed.sharding`` ``db_shard`` rule), queries are replicated, and
every executor route runs INSIDE a ``jax.shard_map`` program:

  1. each shard executes the route shard-locally — the prefilter scan over
     its rows, the beam-search graph traversal from its own entry points,
     the postfilter oversampled traversal;
  2. shard-local ids are globalized onto disjoint segments
     (``+ shard_id * N_loc`` — shard s owns [s*N_loc, (s+1)*N_loc));
  3. one ``all_gather`` of the per-shard ``[B, k]`` results over the shard
     axis, then ``serve.dispatch.merge_topk`` folded across shards IN
     SHARD ORDER reduces to the exact global top-k. Collective bytes
     scale with B*k, independent of N.

Exact-merge semantics: ``merge_topk`` sorts stably on the lexicographic
(primary, secondary) key with the lower segment as the tie-winning base,
so the fold resolves equal keys to the lowest global id — exactly how one
brute-force scan over the concatenated database breaks ties. The exact
routes are therefore BIT-identical to a single-device index over the
union of shard rows (the per-shard block GEMM computes each query-row
distance independently of the blocking, measured in the test suite); the
graph route traverses per-shard sub-graphs, so its results match a
single-device index exactly at S=1 and at recall parity for S>1 (each
shard's beam covers N/S rows — the bench asserts parity per selectivity
band).

:class:`ShardedJAGIndex` wraps the stacked per-shard state behind the
same ``search_auto(queries, filt, k, ls)`` surface as ``JAGIndex`` — it
reuses the single-device planner verbatim (the selectivity probe runs on
the replicated union attribute table; per-query route banding dispatches
each route group into its own shard_map program) and the cost model
integration via :class:`ShardedExecutor.cost_router`, which predicts at
the PER-SHARD shape (n = N_loc): attach an
``repro.cost.InterpolatedCostModel`` (``CostRegistry.load_shard_grids``)
and a fresh shard count routes cost-calibrated with no dedicated
calibration pass — predictions interpolate between neighboring (N, d)
grids.

Telemetry across shards: ``n_expanded``/``n_dist`` SUM over shards (all
shards really did that work); ``vlog`` is the width-0 ``[B, 0]`` — the
per-shard traversal logs are shard-local and id-ambiguous after
globalization, so the sharded routes don't expose them (the normalized
SearchResult contract allows any vlog width). The exact-scan route's
single-device vlog is also ``[B, 0]``, so forced-prefilter results stay
bit-identical across EVERY field.

Not yet sharded (recorded in ROADMAP follow-ons): streaming deltas (the
delta route raises, as on any frozen index), int8/fused serving variants,
cross-host dispatch.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core.beam_search import SearchResult, greedy_search
from ..core.distances import INF, query_key_fn, unfiltered_key_fn
from ..core.distributed import _shard_map
from ..core.filters import AttrTable, as_filter
from ..core.ground_truth import exact_filtered_knn
from ..core.jag import JAGConfig, JAGIndex
from ..distributed.sharding import make_rules, put_db_sharded, serve_mesh
from .executor import Executor
from .dispatch import fold_topk


def _merge_across_shards(local: SearchResult, *, k: int,
                         n_shards: int) -> SearchResult:
    """Inside-shard_map reduction: ONE all_gather of the packed per-shard
    results over the "data" axis, then merge_topk folded in shard order
    (ties -> lowest segment, matching a union scan). Runs replicated on
    every shard.

    The shard-local result's five live fields (ids/primary/secondary
    [B, k] + n_expanded/n_dist [B]; vlog is dropped — see the module
    docstring) are bitcast to int32 and concatenated into one
    ``[B, 3k + 2]`` payload BEFORE the collective, so each route's whole
    cross-shard traffic is a single all-gather of B*(3k+2)*4 bytes — the
    invariant ``repro.analysis.audit`` asserts per sharded route. The
    f32<->int32 bitcast is exact for every payload (INF sentinels and NaN
    bit patterns round-trip), so the merged result is bit-identical to
    gathering each field separately.
    """
    B = local.ids.shape[0]
    bits = lambda x: jax.lax.bitcast_convert_type(x, jnp.int32)  # noqa: E731
    packed = jnp.concatenate(
        [local.ids, bits(local.primary), bits(local.secondary),
         local.n_expanded[:, None], local.n_dist[:, None]], axis=1)
    ag = jax.lax.all_gather(packed, "data")          # [S, B, 3k + 2]
    unbits = lambda x: jax.lax.bitcast_convert_type(  # noqa: E731
        x, jnp.float32)
    parts = [SearchResult(ag[s, :, :k], unbits(ag[s, :, k:2 * k]),
                          unbits(ag[s, :, 2 * k:3 * k]),
                          jnp.zeros((B, 0), jnp.int32),
                          ag[s, :, 3 * k], ag[s, :, 3 * k + 1])
             for s in range(n_shards)]
    return fold_topk(parts, k=k)


class ShardedExecutor(Executor):
    """The executor's route/cache surface over stacked per-shard arrays.

    Subclasses :class:`~repro.serve.executor.Executor`: the jit cache,
    epoch plumbing, planner sample buffers, and compound-clause
    reordering are inherited unchanged (they operate on the replicated
    union attribute table); the three base routes are overridden to
    compile shard_map programs whose results arrive pre-merged across the
    "data" axis. Cache keys reuse the inherited scheme — this executor
    belongs to one :class:`ShardedJAGIndex`, so route names can't collide
    with a single-device cache.
    """

    # -- routing shape: predict at the per-shard grid ----------------------
    def cost_router(self, *, k: int, ls: int, filt=None):
        """Per-shard cost routing: every shard executes the route over its
        own N_loc rows (the merge adds a B*k sort), so predictions use
        n = N_loc — the shard-shape grid an InterpolatedCostModel
        interpolates over — not the union row count."""
        model = getattr(self.index, "cost_model", None)
        if model is None:
            return None
        from ..cost.model import BASE_ROUTES, CostModelRouter
        from ..core.filters import n_leaves
        metric = getattr(self.index, "cost_metric", "us")
        if not model.covers(BASE_ROUTES, metric):
            return None
        idx = self.index
        clauses = 1 if filt is None else n_leaves(filt)
        return CostModelRouter(model, n=idx.n_loc, d=idx.d, k=k, ls=ls,
                               delta_n=0, metric=metric, n_leaves=clauses)

    # -- shard_map route programs ------------------------------------------
    def _sharded(self, key, make_local, db_args, queries, filt, *, k: int):
        """Compile-and-run one sharded route.

        ``make_local(*db_locals, q, filt) -> SearchResult`` is the
        shard-local body (ids still shard-local, any vlog width);
        ``db_args`` are the stacked [S, ...] trees. The wrapper drops the
        leading shard dim, globalizes ids onto the shard's segment, and
        merges across the "data" axis — one program, compiled once per
        key through the inherited cache.
        """
        idx = self.index
        mesh, S, n_loc = idx.mesh, idx.n_shards, idx.n_loc

        def make():
            def shard_fn(*args):
                db = [jax.tree.map(lambda x: x[0], a)
                      for a in args[:len(db_args)]]
                q, f = args[len(db_args)], args[len(db_args) + 1]
                res = make_local(*db, q, f)
                sid = jax.lax.axis_index("data")
                gids = jnp.where(res.ids >= 0, res.ids + sid * n_loc, -1)
                return _merge_across_shards(res._replace(ids=gids), k=k,
                                            n_shards=S)
            return _shard_map(
                shard_fn, mesh=mesh,
                in_specs=(P("data"),) * len(db_args) + (P(), P()),
                out_specs=P(), check_vma=False)
        return self.run(key, make, *db_args, jnp.asarray(queries), filt)

    def prefilter(self, queries, filt, *, k: int, block: int = 4096,
                  use_kernel: Optional[bool] = None) -> SearchResult:
        """Sharded masked exact scan: each shard scans its rows, the merge
        is exact — bit-identical to the single-device union scan."""
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        filt = self._reorder_compound(filt)
        idx = self.index
        key = ("prefilter", "default", "f32", k, 0, 0, filt.kind, block,
               use_kernel)

        def local(xb, attr_data, q, f):
            attr = AttrTable(idx.attr.kind, attr_data,
                             n_bits=idx.attr.n_bits)
            gt = exact_filtered_knn(xb, attr, q, f, k=k, block=block,
                                    use_kernel=use_kernel)
            B = q.shape[0]
            prim = jnp.where(gt.ids >= 0, jnp.float32(0.0), INF)
            return SearchResult(gt.ids, prim, gt.d2,
                                jnp.zeros((B, 0), jnp.int32),
                                jnp.zeros((B,), jnp.int32), gt.n_dist)
        return self._sharded(key, local, (idx.xb, idx.attr_data), queries,
                             filt, k=k)

    def graph(self, queries, filt, *, k: int, ls: int, max_iters: int,
              layout: str = "default", dtype: str = "f32",
              introspect: bool = False) -> SearchResult:
        """Sharded JAG traversal: each shard walks its own sub-graph from
        its own entry seeds; the exact merge keeps the k best of the S
        shard beams. Only the default f32 variant is sharded today."""
        if introspect:
            raise NotImplementedError(
                "traversal introspection is single-device only — the "
                "cross-shard merge would need per-shard stat reduction "
                "(recorded follow-on); detach Telemetry(introspect=True) "
                "before serving sharded")
        if (layout, dtype) != ("default", "f32"):
            raise NotImplementedError(
                f"sharded graph route serves layout='default', dtype='f32' "
                f"only (got {layout!r}, {dtype!r}) — int8/fused sharding "
                f"is a recorded follow-on")
        idx = self.index
        key = ("graph", layout, dtype, k, ls, max_iters, filt.kind)

        def local(graph, xb, xb_norm, attr_data, entry, q, f):
            attr = AttrTable(idx.attr.kind, attr_data,
                             n_bits=idx.attr.n_bits)
            return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                 query_key_fn(f), ls=ls, k=k,
                                 max_iters=max_iters)
        return self._sharded(key, local,
                             (idx.graph, idx.xb, idx.xb_norm,
                              idx.attr_data, idx.entry),
                             queries, filt, k=k)

    def unfiltered(self, queries, *, k: int, ls: int,
                   max_iters: int) -> SearchResult:
        """Sharded pure vector-distance traversal (no filter comparator);
        per-shard beams merge exactly like the graph route's."""
        idx = self.index
        key = ("unfiltered", "default", "f32", k, ls, max_iters, None)

        def local(graph, xb, xb_norm, attr_data, entry, q, f):
            attr = AttrTable(idx.attr.kind, attr_data,
                             n_bits=idx.attr.n_bits)
            return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                 unfiltered_key_fn(), ls=ls, k=k,
                                 max_iters=max_iters)
        return self._sharded(key, local,
                             (idx.graph, idx.xb, idx.xb_norm,
                              idx.attr_data, idx.entry),
                             queries, None, k=k)

    def postfilter(self, queries, filt, *, k: int, ls: int,
                   max_iters: int) -> SearchResult:
        """Sharded post-filtering: each shard's unfiltered ls-beam is
        filtered against its local attribute rows, then merged."""
        idx = self.index
        key = ("postfilter", "default", "f32", k, ls, max_iters, filt.kind)

        def local(graph, xb, xb_norm, attr_data, entry, q, f):
            from ..core.filters import matches
            attr = AttrTable(idx.attr.kind, attr_data,
                             n_bits=idx.attr.n_bits)
            res = greedy_search(graph, xb, xb_norm, attr, q, entry,
                                unfiltered_key_fn(), ls=ls, k=ls,
                                max_iters=max_iters)
            ids = res.ids
            ok = matches(f, attr.gather(jnp.maximum(ids, 0))) & (ids >= 0)
            prim = jnp.where(ok, 0.0, INF)
            sec = jnp.where(ok, res.secondary, INF)
            idsm = jnp.where(ok, ids, -1)
            prim, sec, idsm = jax.lax.sort((prim, sec, idsm), num_keys=2)
            n_dist = res.n_dist + jnp.sum(ids >= 0, axis=1,
                                          dtype=jnp.int32)
            return SearchResult(idsm[:, :k], prim[:, :k], sec[:, :k],
                                res.vlog, res.n_expanded, n_dist)
        return self._sharded(key, local,
                             (idx.graph, idx.xb, idx.xb_norm,
                              idx.attr_data, idx.entry),
                             queries, filt, k=k)


class ShardedJAGIndex:
    """Row-sharded JAG behind the single-device ``search_auto`` surface.

    Holds the per-shard state STACKED on a leading shard axis and placed
    on the mesh by the ``db_shard`` sharding rule:

        graph     int32 [S, N_loc, R]   shard-local neighbor ids
        xb        f32   [S, N_loc, d]
        xb_norm   f32   [S, N_loc]
        attr_data       {name: [S, N_loc, ...]}
        entry     int32 [S, E]          per-shard entry seeds

    plus the replicated union :class:`AttrTable` (``.attr``) the planner
    probes — so routing decisions see exactly the same selectivity
    estimates as a single-device index over the same rows. Build with
    :meth:`build` (splits rows contiguously, builds one sub-graph per
    shard) or :meth:`from_shards` (adopts existing per-shard indexes);
    ``JAGIndex.shard(n_shards)`` is the one-call migration path.
    """

    epoch: int = 0        # frozen, like JAGIndex — streaming is a follow-on

    def __init__(self, *, mesh: Mesh, graph, xb, xb_norm, attr_data,
                 entry, attr: AttrTable, cfg: JAGConfig):
        if "data" not in mesh.axis_names:
            raise ValueError(f"mesh needs a 'data' axis, got "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.rules = make_rules(mesh)
        self.n_shards = int(mesh.shape["data"])
        if int(graph.shape[0]) != self.n_shards:
            raise ValueError(
                f"stacked arrays carry {int(graph.shape[0])} shards but "
                f"the mesh 'data' axis is {self.n_shards}-way")
        placed = put_db_sharded(
            dict(graph=jnp.asarray(graph), xb=jnp.asarray(xb),
                 xb_norm=jnp.asarray(xb_norm),
                 attr_data={k: jnp.asarray(v)
                            for k, v in attr_data.items()},
                 entry=jnp.asarray(entry)), self.rules)
        self.graph = placed["graph"]
        self.xb = placed["xb"]
        self.xb_norm = placed["xb_norm"]
        self.attr_data = placed["attr_data"]
        self.entry = placed["entry"]
        self.attr = attr                     # replicated union table
        self.n_loc = int(self.xb.shape[1])
        self.d = int(self.xb.shape[2])
        self.cfg = cfg
        self._executor = None
        self.cost_model = None
        self.cost_metric = "us"
        self.telemetry = None
        if attr.n != self.n_shards * self.n_loc:
            raise ValueError(
                f"union attr table has {attr.n} rows, shards carry "
                f"{self.n_shards} x {self.n_loc}")

    # -- construction ------------------------------------------------------
    @classmethod
    def from_shards(cls, shards: Sequence[JAGIndex],
                    mesh: Optional[Mesh] = None) -> "ShardedJAGIndex":
        """Adopt per-shard JAGIndexes (equal row counts and attr kinds);
        shard i serves global ids [i*N_loc, (i+1)*N_loc)."""
        if not shards:
            raise ValueError("need at least one shard")
        n_loc = int(shards[0].xb.shape[0])
        kind, n_bits = shards[0].attr.kind, shards[0].attr.n_bits
        for s in shards[1:]:
            if int(s.xb.shape[0]) != n_loc:
                raise ValueError("all shards must hold the same row count "
                                 f"({n_loc} != {int(s.xb.shape[0])})")
            if s.attr.kind != kind or s.attr.n_bits != n_bits:
                raise ValueError("all shards must share one attr schema")
        mesh = mesh or serve_mesh(len(shards))
        union = AttrTable(
            kind,
            {k: jnp.concatenate([s.attr.data[k] for s in shards], axis=0)
             for k in shards[0].attr.data},
            n_bits=n_bits)
        return cls(
            mesh=mesh,
            graph=jnp.stack([s.graph for s in shards]),
            xb=jnp.stack([s.xb for s in shards]),
            xb_norm=jnp.stack([s.xb_norm for s in shards]),
            attr_data={k: jnp.stack([s.attr.data[k] for s in shards])
                       for k in shards[0].attr.data},
            entry=jnp.stack([s.entry for s in shards]),
            attr=union, cfg=shards[0].cfg)

    @classmethod
    def build(cls, xb, attr: AttrTable, cfg: JAGConfig = JAGConfig(),
              *, n_shards: Optional[int] = None, mesh: Optional[Mesh] = None,
              verbose: bool = False) -> "ShardedJAGIndex":
        """Split rows contiguously into S shards and build one sub-graph
        per shard (shard-local entry seeds included). N must divide by S —
        ragged resharding is a cross-host-dispatch follow-on."""
        if mesh is None:
            if n_shards is None:
                raise ValueError("pass n_shards or a mesh")
            mesh = serve_mesh(int(n_shards))
        S = int(mesh.shape["data"])
        xb = jnp.asarray(xb)
        n = int(xb.shape[0])
        if n % S != 0:
            raise ValueError(f"N={n} rows do not split evenly into "
                             f"{S} shards")
        n_loc = n // S
        shards: List[JAGIndex] = []
        for s in range(S):
            lo, hi = s * n_loc, (s + 1) * n_loc
            sub = AttrTable(attr.kind,
                            {k: v[lo:hi] for k, v in attr.data.items()},
                            n_bits=attr.n_bits)
            shards.append(JAGIndex.build(xb[lo:hi], sub, cfg,
                                         verbose=verbose))
        return cls.from_shards(shards, mesh=mesh)

    # -- serving (the JAGIndex surface) ------------------------------------
    @property
    def executor(self) -> ShardedExecutor:
        if self._executor is None:
            self._executor = ShardedExecutor(self)
        return self._executor

    # search_auto/attach_cost_model/attach_telemetry run the single-device
    # implementations verbatim: they only touch self.executor / self.attr /
    # self.cost_* / self.telemetry, so the sharded index IS a drop-in
    # behind the public surface. Telemetry traces record the per-shard
    # view (n = n_loc, shard = [S, n_loc]) — predictions are per-shard too.
    search_auto = JAGIndex.search_auto
    attach_cost_model = JAGIndex.attach_cost_model
    attach_telemetry = JAGIndex.attach_telemetry

    def search(self, queries, filt, k: int = 10, ls: int = 64,
               max_iters: int = 0) -> SearchResult:
        """Sharded filtered traversal (the graph route, default layout)."""
        return self.executor.graph(queries, as_filter(filt), k=k, ls=ls,
                                   max_iters=max_iters or 2 * ls)


def shard_index(index: JAGIndex, n_shards: int,
                mesh: Optional[Mesh] = None) -> ShardedJAGIndex:
    """Re-shard a built single-device index across ``n_shards`` devices.

    Sub-graphs are REBUILT per shard from the index's rows and config —
    a built graph's edges cross any row split, so slicing the adjacency
    would orphan every cross-shard edge; an honest reshard is a rebuild.
    """
    return ShardedJAGIndex.build(
        index.xb, index.attr, index.cfg,
        n_shards=None if mesh is not None else n_shards, mesh=mesh)
