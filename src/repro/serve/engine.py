"""Serving engine: turn a FusedLayout into beam-search fetch closures.

``greedy_search`` exposes a ``fetch_fn(ids, q32, q_norm) -> (d2, attrs)``
hook (core/beam_search.py) that replaces the default two-gather expansion
(vector gather for distances + attribute-table gather for dist_F). This
module builds that closure from a packed layout so every expansion is ONE
row gather.

Two execution paths share the layout:

  * XLA path (default): a single ``jnp.take`` of the packed matrix; HLO then
    charges one N-row gather operand per expansion. This is what
    ``JAGIndex.search(..., layout="fused")`` runs everywhere, including CPU.
  * kernel path: ``kernels/ops.fused_expand`` — the scalar-prefetch Pallas
    kernel that DMAs each packed row HBM->VMEM once and emits (d2, attr
    words) from the resident tile. Interpret mode on CPU, Mosaic on TPU.

Both decode attr words with ``FusedLayout.unpack_attrs`` so the returned
attrs dict is exactly what ``AttrTable.gather`` would have produced.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.distances import gathered_dot
from ..kernels import ops
from .layout import FusedLayout


def make_fetch_fn(layout: FusedLayout, *, use_kernel: bool = False,
                  interpret: bool | None = None):
    """Build a ``fetch_fn`` for ``greedy_search`` from a packed layout.

    The closure treats ``layout`` as a captured pytree, so it must be rebuilt
    if the layout object changes; ``JAGIndex`` instead passes the layout
    through the jit boundary and calls this inside (donation-friendly).
    """
    d = layout.d

    def fetch_fn(ids, q32, q_norm):
        q_eff = q32 * layout.q_scale[None, :]
        if use_kernel:
            d2, words = ops.fused_expand(layout.packed, ids, q_eff, q_norm,
                                         d=d, interpret=interpret)
        else:
            rows = jnp.take(layout.packed, ids, axis=0, mode="clip")
            dots = gathered_dot(rows[..., :d], q_eff)
            d2 = jnp.maximum(rows[..., d] - 2.0 * dots + q_norm[:, None],
                             0.0)
            words = rows[..., d + 1:]
        return d2, layout.unpack_attrs(words)

    return fetch_fn


class FusedEngine:
    """Thin serving wrapper: a layout + its fetch closure + path metadata.

    ``gathers_per_expansion`` documents the HBM-traffic contract (1 for the
    fused layout vs 2 for the split vectors+attributes path); benchmarks and
    CI assert on it so the fused path can't silently regress to two gathers.
    ``Executor.engine(vec_dtype, **kw)`` builds and caches one per
    (dtype, kwargs) over the owning index's packed layout.
    """

    gathers_per_expansion = 1

    def __init__(self, layout: FusedLayout, *, use_kernel: bool = False,
                 interpret: bool | None = None):
        self.layout = layout
        self.fetch_fn = make_fetch_fn(layout, use_kernel=use_kernel,
                                      interpret=interpret)

    @property
    def row_bytes(self) -> int:
        """HBM bytes pulled per scored candidate (one packed f32 row)."""
        return int(self.layout.packed.shape[1]) * 4
