"""Per-query route dispatch: group-gather, execute each route, scatter back.

The whole-batch planner routes every query down the route the *median*
selectivity picks — a batch mixing 0.1% and 90% filters sends half its
queries down the wrong path, exactly the regime where single-strategy
systems collapse (FAVOR, arXiv:2605.07770; the CUHK study,
arXiv:2508.16263). This module closes that gap:

  1. ``planner.plan_per_query`` bands the [B] selectivity vector into
     route groups (original-batch positions, ascending within a group);
  2. :func:`dispatch_per_query` gathers each group's queries AND filter
     lanes (``FilterBatch.take``) into a contiguous sub-batch and runs it
     through its executor route;
  3. :func:`regroup` scatters the per-group ``SearchResult``s back into
     original query order via one inverse-permutation gather per field.

:func:`merge_topk` is the streaming layer's segment merge: a base route's
top-k over the graph segment folds with the delta scan's (id-offset) top-k
into one exact top-k per query — bit-identical to scanning the
concatenated base+delta database with the base route exact on its segment.

Regrouping relies on the normalized SearchResult contract: every field is
leading-dim-[B] and ``vlog`` may be ANY width (the prefilter scan has no
traversal and emits ``[B, 0]``; graph/postfilter emit ``[B, max_iters]``)
— groups are padded with ``-1`` holes to the widest vlog before the
scatter. Per-query results are bit-identical to running each query alone
through its own route: routes apply per-row ops and batch-invariant
distance computations (every gathered candidate dot goes through
``distances.gathered_dot``), so group composition never leaks into a
query's lane. One caveat: the prefilter scan's block distances are a
``[B, d] @ [d, block]`` GEMM (a batch-invariant mul+sum there measures
~70x slower) — row-invariant on CPU (measured) and per-row by
construction in the TPU tile kernel, but an untested GPU GEMM could in
principle tile low-order float bits differently per batch size.
"""
from __future__ import annotations

import time
from contextlib import nullcontext

import jax
import jax.numpy as jnp
import numpy as np

from ..core.beam_search import SearchResult
from .planner import PerQueryPlan

__all__ = ["dispatch_per_query", "fold_topk", "merge_topk", "regroup",
           "route_descriptor", "run_route"]


def route_descriptor(route: str, layout: str = "default",
                     dtype: str = "f32") -> str:
    """The realized-route name: which compiled variant actually serves.

    Only the graph route has serving variants (layout x dtype); the other
    routes ignore those options, so their descriptor is the band name —
    ``route_descriptor("graph", "fused", "int8") == "graph[fused,int8]"``
    and everything at the defaults collapses back to the plain name.
    """
    if route == "graph" and (layout != "default" or dtype != "f32"):
        return f"graph[{layout},{dtype}]"
    return route


def run_route(executor, route: str, queries, filt, *, k: int,
              ls: int, max_iters: int, layout: str = "default",
              dtype: str = "f32", introspect: bool = False):
    """Execute one executor route by name with the serving options it takes.

    ``filt`` may be an atomic FilterBatch or a compound FilterExpr — both
    carry the same lane/take/kind surface, so every route accepts either.
    ``layout``/``dtype`` select the graph route's serving variant; the
    prefilter scan is exact f32 by construction and the postfilter
    traversal runs the default layout, so both ignore them.

    ``introspect=True`` changes the return to ``(result, stats)`` where
    ``stats`` is the graph route's per-query ``TraversalStats`` (an extra
    jit output of the introspective compilation) and None on the scan /
    postfilter routes, which have no traversal to introspect.
    """
    if route == "prefilter":
        res = executor.prefilter(queries, filt, k=k)
        return (res, None) if introspect else res
    if route == "graph":
        return executor.graph(queries, filt, k=k, ls=ls,
                              max_iters=max_iters, layout=layout,
                              dtype=dtype, introspect=introspect)
    if route == "postfilter":
        res = executor.postfilter(queries, filt, k=k, ls=ls,
                                  max_iters=max_iters)
        return (res, None) if introspect else res
    raise ValueError(f"unknown route {route!r}")


def merge_topk(base: SearchResult, extra: SearchResult, *,
               k: int) -> SearchResult:
    """Exact per-query merge of two top-k lists over disjoint id segments.

    The streaming layer's segment merge: ``base`` holds a route's top-k over
    the graph segment, ``extra`` the delta scan's top-k (ids already offset
    past the graph segment). Both order valid entries by the lexicographic
    (primary, secondary) key with -1 padding at (INF, INF), so one stable
    sort over the concatenation yields the exact top-k of the union —
    ties (primary, secondary) resolve to ``base`` entries first, matching a
    brute-force scan that visits base rows before delta rows.

    Traversal telemetry composes: ``vlog``/``n_expanded`` come from ``base``
    plus any expansions ``extra`` logged (the delta scan logs none), and
    ``n_dist`` sums — both segments' distance computations are real work.
    """
    prim = jnp.concatenate([base.primary, extra.primary], axis=1)
    sec = jnp.concatenate([base.secondary, extra.secondary], axis=1)
    ids = jnp.concatenate([base.ids, extra.ids], axis=1)
    prim, sec, ids = jax.lax.sort((prim, sec, ids), num_keys=2)
    return SearchResult(ids[:, :k], prim[:, :k], sec[:, :k], base.vlog,
                        base.n_expanded + extra.n_expanded,
                        base.n_dist + extra.n_dist)


def fold_topk(parts, *, k: int) -> SearchResult:
    """N-way :func:`merge_topk` fold over per-segment results, in order.

    The sharded executor's cross-shard reduction: ``parts[i]`` holds shard
    i's top-k with ids already globalized onto disjoint segments, and the
    fold runs in segment order, so ties on the (primary, secondary) key
    resolve to the LOWEST segment — and within a segment the lowest id —
    exactly like one brute-force scan over the concatenated database.
    ``jax.lax.sort`` is stable and the fold is left-associative, so the
    result (including telemetry sums) is identical whether segments arrive
    pre-merged or one at a time: merge_topk keeps base-side entries on
    equal keys and every later segment enters as ``extra``.
    """
    if not parts:
        raise ValueError("fold_topk needs at least one part")
    out = parts[0]
    for p in parts[1:]:
        out = merge_topk(out, p, k=k)
    return out


def regroup(parts, groups, batch: int) -> SearchResult:
    """Scatter per-group SearchResults back into original query order.

    ``parts[i]`` holds the results for the queries at original-batch
    positions ``groups[i].ids``. Fields are concatenated in group order and
    un-permuted with one gather; vlogs are -1-padded to the widest group
    first so heterogeneous route shapes concatenate cleanly.
    """
    width = max(int(r.vlog.shape[1]) for r in parts)
    parts = [r._replace(vlog=jnp.pad(r.vlog,
                                     ((0, 0), (0, width - r.vlog.shape[1])),
                                     constant_values=-1))
             if r.vlog.shape[1] != width else r for r in parts]
    order = np.concatenate([g.ids for g in groups])
    inv = np.empty(batch, np.int32)
    inv[order] = np.arange(batch, dtype=np.int32)
    inv = jnp.asarray(inv)
    return SearchResult(*(jnp.take(jnp.concatenate([getattr(r, f)
                                                    for r in parts], axis=0),
                                   inv, axis=0)
                          for f in SearchResult._fields))


def _span(spans, name: str, **args):
    """``spans.span(...)`` when a recorder is attached, else a no-op.

    Duck-typed so this module never imports ``repro.obs`` — any object
    with a ``span(name, **args)`` context manager works.
    """
    if spans is None:
        return nullcontext()
    return spans.span(name, **args)


def dispatch_per_query(executor, queries, filt,
                       pq: PerQueryPlan, *, k: int, ls: int, max_iters: int,
                       layout: str = "default", dtype: str = "f32",
                       on_group=None, introspect: bool = False,
                       spans=None) -> SearchResult:
    """Run each route group through its executor route; regroup per query.

    Each group's sub-batch shape keys its own executor compilation, so a
    workload with recurring group sizes reuses the cache like any other
    batch shape would. Compound expressions slice per group through
    ``FilterExpr.take`` (every leaf's lanes gathered in lockstep), so a
    group sees exactly its queries' filter lanes regardless of tree shape.

    ``on_group(group, result, stats, wall_seconds)`` is the telemetry
    tap: when set, each group's route is blocked on
    (``jax.block_until_ready``) and wall-timed on the host — timestamps
    never enter the compiled routes (JAG006). ``stats`` is the graph
    route's per-query ``TraversalStats`` when ``introspect=True`` (None
    otherwise). Off (None), nothing blocks and dispatch is unchanged.
    ``spans`` is an optional ``repro.obs.SpanRecorder`` timing the
    gather → execute → scatter stages (host-side, around the compiled
    calls — never inside them).
    """
    q = jnp.asarray(queries)

    def _run(group, q_g, f_g):
        with _span(spans, f"execute:{group.route}",
                   queries=int(np.shape(q_g)[0])):
            if on_group is None:
                out = run_route(executor, group.route, q_g, f_g, k=k,
                                ls=ls, max_iters=max_iters, layout=layout,
                                dtype=dtype, introspect=introspect)
                return out[0] if introspect else out
            t0 = time.perf_counter()
            out = run_route(executor, group.route, q_g, f_g, k=k, ls=ls,
                            max_iters=max_iters, layout=layout,
                            dtype=dtype, introspect=introspect)
            res, stats = out if introspect else (out, None)
            res = jax.block_until_ready(res)
            on_group(group, res, stats, time.perf_counter() - t0)
            return res

    if len(pq.groups) == 1:      # no split -> no gather/scatter round-trip
        return _run(pq.groups[0], q, filt)
    parts = []
    for g in pq.groups:
        with _span(spans, f"gather:{g.route}", queries=int(g.ids.size)):
            q_g = jnp.take(q, jnp.asarray(g.ids), axis=0)
            f_g = filt.take(g.ids)
        parts.append(_run(g, q_g, f_g))
    with _span(spans, "scatter", batch=int(q.shape[0])):
        return regroup(parts, pq.groups, q.shape[0])
