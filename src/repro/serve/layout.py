"""Fused serving row layout: [vec | sq-norm | attr words] in one matrix.

The quantized.py module docstring has long promised a packed row so "one
gather per expansion fetches everything the comparator needs (vector,
||x||^2, attribute)"; this module builds it. Each database row is laid out
contiguously as

    col 0..d-1 : vector lanes — f32 values, or int8 codes widened to f32
    col d      : squared L2 norm of the (dequantized) vector
    col d+1..  : attr words (filters.pack_attr_words — bit-exact payloads)

so a beam expansion is ONE row gather (kernels/fused_expand.py on TPU, a
single ``jnp.take`` under XLA) instead of the default path's two N-row
gathers (vectors + attribute table).

int8 rows keep the distance math kernel-identical via query scale folding:
``codes . (q * scale) == dequant(codes) . q``, with the norm lane storing the
dequantized norm. ``q_scale`` is ones for f32 layouts, so engines can always
fold unconditionally.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.filters import AttrTable, pack_attr_words, unpack_attr_words

VEC_DTYPES = ("f32", "int8")


@partial(jax.tree_util.register_dataclass,
         data_fields=("packed", "q_scale", "bit_weights"),
         meta_fields=("kind", "n_bits", "d", "vec_dtype"))
@dataclasses.dataclass(frozen=True)
class FusedLayout:
    """A packed serving matrix plus the metadata needed to interpret it.

    packed      : f32 [N, d + 1 + A] rows of [vec | sq-norm | attr words]
    q_scale     : f32 [d] per-dim query fold factor (ones for f32 rows;
                  the int8 dequant scale for int8 rows)
    bit_weights : f32 [L] weighted-subset distances (empty [0] when unused)
    kind/n_bits : the attribute family of the attr words (filters.KINDS)
    d           : vector lane count; vec_dtype: "f32" | "int8"
    """
    packed: jnp.ndarray
    q_scale: jnp.ndarray
    bit_weights: jnp.ndarray
    kind: str
    n_bits: int
    d: int
    vec_dtype: str

    @property
    def n(self) -> int:
        return self.packed.shape[0]

    @property
    def n_attr_words(self) -> int:
        return self.packed.shape[1] - self.d - 1

    def unpack_attrs(self, words: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """Decode gathered attr words [..., A] into an attrs dict."""
        bw = self.bit_weights if self.bit_weights.shape[0] else None
        return unpack_attr_words(self.kind, words, self.n_bits, bw)

    def fold_query(self, q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(q_eff, q_norm): scale-folded query + its UNfolded sq-norm."""
        q32 = jnp.asarray(q, jnp.float32)
        return q32 * self.q_scale[None, :], jnp.sum(q32 * q32, axis=-1)


def build_layout(xb, attr: AttrTable, *,
                 vec_dtype: str = "f32") -> FusedLayout:
    """Pack (vectors, attributes) into a FusedLayout.

    vec_dtype "f32" reproduces the default path's distances bit-for-bit
    (same norms, same dot); "int8" stores per-dim symmetric codes (4x less
    HBM per expansion) with query-side scale folding.
    """
    if vec_dtype not in VEC_DTYPES:
        raise ValueError(f"vec_dtype must be one of {VEC_DTYPES}")
    xb = jnp.asarray(xb)
    x32 = xb.astype(jnp.float32)
    if vec_dtype == "int8":
        from ..core.quantized import quantize_int8
        codes, scale = quantize_int8(x32)
        vec = codes.astype(jnp.float32)
        norm = jnp.sum((vec * scale[None, :]) ** 2, axis=-1)
        q_scale = jnp.asarray(scale, jnp.float32)
    else:
        vec = x32
        norm = jnp.sum(x32 * x32, axis=-1)
        q_scale = jnp.ones((x32.shape[1],), jnp.float32)
    words = pack_attr_words(attr)
    bw = attr.data.get("bit_weights")
    bw = (jnp.asarray(bw, jnp.float32) if bw is not None
          else jnp.zeros((0,), jnp.float32))
    packed = jnp.concatenate([vec, norm[:, None], words], axis=1)
    return FusedLayout(packed, q_scale, bw, attr.kind, attr.n_bits,
                       int(x32.shape[1]), vec_dtype)


def extend_layout(layout: FusedLayout, xv, attr: AttrTable) -> FusedLayout:
    """Append delta rows to a packed f32 layout without re-packing base rows.

    Streaming compaction folds the delta segment into the graph; the fused
    f32 layout extends row-wise (vec lanes are stored values, the norm is
    per-row, attr words are per-row bit payloads), so packing ONLY the new
    rows reproduces ``build_layout(concat(base, delta))`` bit-for-bit at
    O(delta) cost. int8 layouts do NOT extend: their per-dim quantization
    scale is global, so appended rows would need a re-quantization of the
    whole database — callers rebuild those lazily instead.
    """
    if layout.vec_dtype != "f32":
        raise ValueError("only f32 layouts extend losslessly; rebuild int8 "
                         "layouts after compaction (global quant scale)")
    if attr.kind != layout.kind or attr.n_bits != layout.n_bits:
        raise ValueError(f"attr rows are {attr.kind}/{attr.n_bits}, layout "
                         f"is {layout.kind}/{layout.n_bits}")
    x32 = jnp.asarray(xv).astype(jnp.float32)
    norm = jnp.sum(x32 * x32, axis=-1)
    words = pack_attr_words(attr)
    rows = jnp.concatenate([x32, norm[:, None], words], axis=1)
    return dataclasses.replace(
        layout, packed=jnp.concatenate([layout.packed, rows], axis=0))


def save_layout(path: str, layout: FusedLayout) -> None:
    """Persist a packed layout (npz; lossless — attr lanes are bit payloads).

    The vec/norm/attr lanes are stored as raw uint32 so no f32 NaN
    canonicalization can corrupt bitcast attr words on disk.
    """
    np.savez_compressed(
        path,
        packed_bits=np.asarray(layout.packed).view(np.uint32),
        q_scale=np.asarray(layout.q_scale),
        bit_weights=np.asarray(layout.bit_weights),
        kind=layout.kind, n_bits=layout.n_bits, d=layout.d,
        vec_dtype=layout.vec_dtype)


def load_layout(path: str) -> FusedLayout:
    z = np.load(path, allow_pickle=False)
    packed = jnp.asarray(z["packed_bits"].view(np.float32))
    return FusedLayout(packed, jnp.asarray(z["q_scale"]),
                       jnp.asarray(z["bit_weights"]),
                       str(z["kind"]), int(z["n_bits"]), int(z["d"]),
                       str(z["vec_dtype"]))
