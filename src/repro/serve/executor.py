"""Unified search executor: the ONE jit-compilation cache behind every path.

Before this module, each public entry point (``JAGIndex.search``,
``search_int8``, ``search_unfiltered``) carried its own copy-pasted
``@jax.jit`` cache block, and the baselines in core/baselines.py re-created
a fresh ``@jax.jit`` closure on every call (recompiling each time). The
Executor owns a single cache keyed on

    (route, layout, dtype, k, ls, max_iters, filter kind, *route extras)

so every compiled search variant in the process is enumerable
(``cache_keys()``), shared across entry points, and traced exactly once.
``JAGIndex.search/search_int8/search_unfiltered`` are thin shims over the
``graph``/``unfiltered`` routes below and return bit-identical results to
the pre-refactor per-method caches (same traced computation, same key
granularity).

Routes (serve/planner.py owns the router that picks between them):

  prefilter  — masked brute-force scan over filter-passing rows
               (core/ground_truth.py; on TPU the Pallas tile scan via
               kernels/ops.gather_dist_tile). Exact; distance computations
               scale with selectivity * N, so it wins at low selectivity.
  graph      — JAG traversal (core/beam_search.py), default or fused
               serving layout, f32 or int8 vector lanes.
  postfilter — unfiltered traversal with an oversampled beam, the filter
               applied to the survivors (near-1.0 selectivity).
  delta      — exact masked scan over a streaming index's live delta
               segment (ids offset past the graph segment). Only available
               when the executor's index exposes one
               (repro.stream.StreamingJAGIndex); ``merge`` folds its top-k
               into any base route's result, exactly.

Every cache is **epoch-aware**: keys are stored under the index's data
epoch (``JAGIndex.epoch`` is 0 forever; a ``StreamingJAGIndex`` bumps its
counter on every insert batch and compaction), and a rolled epoch evicts
all compiled routes, sample-probe buffers, and engines — a grown index can
never route on a stale-n probe or serve from a pre-compaction layout.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.beam_search import SearchResult, greedy_search
from ..core.distances import INF, query_key_fn, unfiltered_key_fn
from ..core.filters import FilterExpr, matches, n_leaves
from ..core.ground_truth import exact_filtered_knn
from ..core.quantized import make_int8_dist_fn, rerank_exact
from .engine import FusedEngine, make_fetch_fn

LAYOUTS = ("default", "fused")
VEC_DTYPES = ("f32", "int8")


class Executor:
    """Owns the single jit cache + route implementations for one index.

    Instantiated lazily by ``JAGIndex.executor``; holds only references to
    the index's device arrays (graph, vectors, attr table, layouts), never
    copies.
    """

    def __init__(self, index):
        self.index = index
        self._cache: dict = {}
        self._engines: dict = {}
        self._samples: dict = {}
        self._cache_epoch: int = self.epoch
        # analysis hook: when set to a list, run() appends every
        # (key, make, args) it executes so repro.analysis.audit can
        # re-lower the exact programs this cache serves. None in serving.
        self.trace_log: list | None = None
        # telemetry hooks (repro.obs): miss_hook(epoch_key) fires once
        # per distinct compiled cache entry, roll_hook(epoch) once per
        # epoch-driven cache eviction. Host-side only — never traced.
        self.miss_hook: Callable | None = None
        self.roll_hook: Callable | None = None

    # -- cache plumbing ----------------------------------------------------
    @property
    def epoch(self) -> int:
        """The index's data epoch (0 forever for a frozen JAGIndex)."""
        return getattr(self.index, "epoch", 0)

    def _roll_epoch(self) -> None:
        """Evict every cache built against a previous data epoch.

        Compiled routes, sample-probe device buffers, and fused engines all
        reference epoch-dependent data (live attr table shape, delta
        segment, post-compaction base arrays), so a bumped epoch invalidates
        all three wholesale. Frozen indexes never roll.
        """
        e = self.epoch
        if e != self._cache_epoch:
            self._cache.clear()
            self._samples.clear()
            self._engines.clear()
            self._cache_epoch = e
            if self.roll_hook is not None:
                self.roll_hook(e)

    def sample_ids(self, n: int, n_samples: int, seed: int = 0):
        """Planner probe rows, cached per executor (so per index).

        Replaces the former module-level ``functools.lru_cache`` on
        ``planner.sample_ids``, which pinned device buffers process-wide
        across index lifetimes and test runs; these die with the executor.
        Keys carry the data epoch: when the attr table grows (streaming
        insert), every cached probe buffer is evicted, so a grown index can
        never route on a stale-n sample.
        """
        self._roll_epoch()
        key = (self._cache_epoch, n, n_samples, seed)
        ids = self._samples.get(key)
        if ids is None:
            from .planner import sample_ids
            ids = self._samples[key] = sample_ids(n, n_samples, seed)
        return ids

    def run(self, key: Tuple, make: Callable[[], Callable], *args):
        """Execute the cached compilation for ``key``, tracing on first use.

        ``make()`` must return the pure function to ``jax.jit``; it is only
        invoked on a cache miss, so closure-captured statics (k, ls, ...)
        must be part of ``key``. Keys are stored under the current data
        epoch (``(epoch,) + key``); rolling the epoch evicts them all.
        """
        self._roll_epoch()
        if self.trace_log is not None:
            self.trace_log.append((key, make, args))
        epoch_key = (self._cache_epoch,) + key
        fn = self._cache.get(epoch_key)
        if fn is None:
            if self.miss_hook is not None:
                self.miss_hook(epoch_key)
            fn = self._cache[epoch_key] = jax.jit(make())
        return fn(*args)

    def cache_keys(self, full: bool = False) -> Tuple:
        """Route keys of every live compilation (current epoch only).

        ``full=True`` keeps the leading epoch component on each key.
        """
        return tuple(self._cache) if full else tuple(
            k[1:] for k in self._cache)

    def cost_router(self, *, k: int, ls: int, filt=None):
        """The index's calibrated ``cost.CostModelRouter`` for this search
        shape, or None (-> the planner's static thresholds).

        Threads the attached cost model into routing: the router predicts
        every base route's us/query at the live (n, d, k, ls) and folds
        the constant delta-scan tax (``delta_n``/N rows the streaming
        executor scans+merges on EVERY route) into each prediction. A
        model that doesn't cover all three base routes is treated as
        absent — partial calibrations never half-route. ``filt`` threads
        the clause count of a compound expression into the prefilter
        feature vector (log(n_clauses); 1 for atomic filters, which keeps
        legacy models' predictions unchanged).
        """
        model = getattr(self.index, "cost_model", None)
        if model is None:
            return None
        from ..cost.model import BASE_ROUTES, CostModelRouter
        metric = getattr(self.index, "cost_metric", "us")
        if not model.covers(BASE_ROUTES, metric):
            return None
        idx = self.index
        delta_n = idx.delta.n if hasattr(idx, "delta_arrays") else 0
        clauses = 1 if filt is None else n_leaves(filt)
        return CostModelRouter(model, n=int(idx.xb.shape[0]),
                               d=int(idx.xb.shape[1]), k=k, ls=ls,
                               delta_n=delta_n, metric=metric,
                               n_leaves=clauses)

    def engine(self, vec_dtype: str = "f32", **kw) -> FusedEngine:
        """FusedEngine over the index's packed layout (metadata + fetch)."""
        self._roll_epoch()
        key = (vec_dtype, tuple(sorted(kw.items())))
        if key not in self._engines:
            self._engines[key] = FusedEngine(
                self.index.fused_layout(vec_dtype), **kw)
        return self._engines[key]

    # -- graph route (JAG traversal; Algorithm 2) --------------------------
    def graph(self, queries, filt, *, k: int, ls: int,
              max_iters: int, layout: str = "default",
              dtype: str = "f32", introspect: bool = False):
        """JAG traversal. ``introspect=True`` compiles the introspective
        variant (its own cache-key component — the standard program is
        untouched) and returns ``(SearchResult, TraversalStats)`` with
        per-query hops / frontier-saturation step / dead-end counters as
        extra jit outputs: zero host callbacks, zero collectives, and
        (ids, primary, secondary) bit-identical to the standard route.
        """
        if layout not in LAYOUTS:
            raise ValueError(f"layout must be 'default' or 'fused', "
                             f"got {layout!r}")
        if dtype not in VEC_DTYPES:
            raise ValueError(f"dtype must be 'f32' or 'int8', got {dtype!r}")
        idx = self.index
        key = ("graph", layout, dtype, k, ls, max_iters, filt.kind)
        if introspect:
            key = key + ("introspect",)
        q = jnp.asarray(queries)

        if dtype == "f32" and layout == "default":
            def make():
                def run(graph, xb, xb_norm, attr, q, filt, entry):
                    return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                         query_key_fn(filt), ls=ls, k=k,
                                         max_iters=max_iters,
                                         introspect=introspect)
                return run
            return self.run(key, make, idx.graph, idx.xb, idx.xb_norm,
                            idx.attr, q, filt, idx.entry)

        if dtype == "f32":  # fused layout, full precision
            lay = idx.fused_layout("f32")

            def make():
                def run(graph, xb, xb_norm, attr, lay, q, filt, entry):
                    return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                         query_key_fn(filt), ls=ls, k=k,
                                         max_iters=max_iters,
                                         fetch_fn=make_fetch_fn(lay),
                                         introspect=introspect)
                return run
            return self.run(key, make, idx.graph, idx.xb, idx.xb_norm,
                            idx.attr, lay, q, filt, idx.entry)

        if layout == "fused":  # int8 lanes, one-gather expansion + re-rank
            lay = idx.fused_layout("int8")

            def make():
                def run(graph, xb, xb_norm, attr, lay, q, filt, entry):
                    out = greedy_search(graph, xb, xb_norm, attr, q, entry,
                                        query_key_fn(filt), ls=ls, k=ls,
                                        max_iters=max_iters,
                                        fetch_fn=make_fetch_fn(lay),
                                        introspect=introspect)
                    res, stats = out if introspect else (out, None)
                    i, p, s = rerank_exact(xb, xb_norm, res.ids,
                                           res.primary, q, k)
                    res = SearchResult(i, p, s, res.vlog, res.n_expanded,
                                       res.n_dist)
                    return (res, stats) if introspect else res
                return run
            return self.run(key, make, idx.graph, idx.xb, idx.xb_norm,
                            idx.attr, lay, q, filt, idx.entry)

        xq, scale, xq_norm = idx.quantized()  # int8, split layout

        def make():
            def run(graph, xq, xq_norm, scale, xb, xb_norm, attr, q, filt,
                    entry):
                out = greedy_search(
                    graph, xq, xq_norm, attr, q, entry,
                    query_key_fn(filt), ls=ls, k=ls, max_iters=max_iters,
                    dist_fn=make_int8_dist_fn(scale), introspect=introspect)
                res, stats = out if introspect else (out, None)
                i, p, s = rerank_exact(xb, xb_norm, res.ids, res.primary,
                                       q, k)
                res = SearchResult(i, p, s, res.vlog, res.n_expanded,
                                   res.n_dist)
                return (res, stats) if introspect else res
            return run
        return self.run(key, make, idx.graph, xq, xq_norm, scale, idx.xb,
                        idx.xb_norm, idx.attr, q, filt, idx.entry)

    # -- unfiltered traversal (feeds the postfilter route) -----------------
    def unfiltered(self, queries, *, k: int, ls: int,
                   max_iters: int) -> SearchResult:
        idx = self.index
        key = ("unfiltered", "default", "f32", k, ls, max_iters, None)

        def make():
            def run(graph, xb, xb_norm, attr, q, entry):
                return greedy_search(graph, xb, xb_norm, attr, q, entry,
                                     unfiltered_key_fn(), ls=ls, k=k,
                                     max_iters=max_iters)
            return run
        return self.run(key, make, idx.graph, idx.xb, idx.xb_norm, idx.attr,
                        jnp.asarray(queries), idx.entry)

    # -- prefilter route (masked exact scan) -------------------------------
    def _scan(self, key: Tuple, xb, attr, queries, filt, *,
              k: int, block: int, use_kernel: bool,
              offset: int = 0) -> SearchResult:
        """Exact masked scan adapted to the SearchResult contract — the one
        adapter behind both scan routes (prefilter over the base rows,
        delta over the streaming segment with an id offset).

        primary is 0 where a valid neighbor was found (the scan only ever
        returns filter-passing points), INF on -1 padding; n_dist counts
        valid points scanned, matching the paper's DC metric. vlog is the
        honest width-0 ``[B, 0]`` — there is no traversal to log — per the
        normalized contract (SearchResult.vlog may be any width; the
        per-query dispatcher pads groups to a common width when it
        regroups routes).
        """
        def make():
            def run(xb, attr, q, filt):
                gt = exact_filtered_knn(xb, attr, q, filt, k=k, block=block,
                                        use_kernel=use_kernel)
                B = q.shape[0]
                ids = (gt.ids if offset == 0
                       else jnp.where(gt.ids >= 0, gt.ids + offset, -1))
                prim = jnp.where(gt.ids >= 0, jnp.float32(0.0), INF)
                return SearchResult(ids, prim, gt.d2,
                                    jnp.zeros((B, 0), jnp.int32),
                                    jnp.zeros((B,), jnp.int32), gt.n_dist)
            return run
        return self.run(key, make, xb, attr, jnp.asarray(queries), filt)

    def _reorder_compound(self, filt):
        """Short-circuit-optimal clause order for a compound expression.

        Probes each leaf's boolean validity over the executor's cached
        sample rows (one compiled probe per tree signature) and asks the
        planner for the cheapest-most-selective-first order; the boolean
        vectors let the greedy ordering condition each pick on the clauses
        already placed, so correlated clauses rank by their true joint
        filtering power rather than an independence estimate. Host-side
        and static: the reordered tree is result-identical (connectives
        commute), it only changes which clauses the scan's short-circuit
        accounting charges (``GroundTruth.n_feval``). Atomic filters and
        single-leaf trees pass through untouched.
        """
        if not isinstance(filt, FilterExpr) or n_leaves(filt) < 2:
            return filt
        from .planner import leaf_validity, reorder_clauses
        ids = self.sample_ids(self.index.attr.n, 1024, 0)
        key = ("leafval", "default", "bool", 0, 0, 0, filt.kind,
               int(ids.shape[0]))
        valid = self.run(key, lambda: leaf_validity,
                         filt, self.index.attr, ids)
        # [L, B, S] -> per-leaf sample vectors pooled over the query batch
        # (clause order is static for the whole batch, like the old median)
        v = np.asarray(valid)
        return reorder_clauses(filt, list(v.reshape(v.shape[0], -1)))

    def prefilter(self, queries, filt, *, k: int,
                  block: int = 4096, use_kernel: bool | None = None
                  ) -> SearchResult:
        """Masked exact scan over the index's (graph-segment) rows.

        ``use_kernel`` defaults by backend (the Pallas tile scan on TPU,
        the XLA matmul scan elsewhere), matching the kernels convention.
        Compound expressions are clause-reordered (cheapest most-selective
        clause first) before the scan compiles.
        """
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        filt = self._reorder_compound(filt)
        idx = self.index
        key = ("prefilter", "default", "f32", k, 0, 0, filt.kind, block,
               use_kernel)
        return self._scan(key, idx.xb, idx.attr, queries, filt, k=k,
                          block=block, use_kernel=use_kernel)

    # -- delta route (streaming: exact scan over the live delta segment) ---
    def delta(self, queries, filt, *, k: int,
              block: int = 4096, use_kernel: bool | None = None
              ) -> SearchResult:
        """Exact masked scan over the index's delta segment, ids offset.

        The streaming layer's fourth route: the delta segment is small (it
        is compacted into the graph before it exceeds a fraction of N), so
        a brute-force scan — the same primitive as the prefilter route —
        is both exact and cheap. Returned ids live past the graph segment
        (``+ base_n``), so ``merge`` can fold them into any base route's
        top-k as if the concatenated database had been searched.

        Requires the index to expose ``delta_arrays() -> (xv, attr, offset)``
        (repro.stream.StreamingJAGIndex); frozen indexes have no delta.
        """
        if not hasattr(self.index, "delta_arrays"):
            raise TypeError("delta route needs a streaming index exposing "
                            "delta_arrays(); JAGIndex is frozen")
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        xv, dattr, offset = self.index.delta_arrays()
        # the scan pads to whole blocks — cap at the (small) delta row count
        # so a 60-row delta never pays a 4096-wide distance matrix
        block = max(1, min(block, int(xv.shape[0])))
        key = ("delta", "default", "f32", k, 0, 0, filt.kind, block,
               use_kernel, offset)
        return self._scan(key, xv, dattr, queries, filt, k=k, block=block,
                          use_kernel=use_kernel, offset=offset)

    def merge(self, base: SearchResult, extra: SearchResult, *,
              k: int) -> SearchResult:
        """Fold two per-query top-k results into one exact top-k.

        Compiled through the same cache as every route; see
        ``serve.dispatch.merge_topk`` for the ordering contract (stable on
        the (primary, secondary) key, ``base`` winning ties — matching a
        brute-force scan of base rows before delta rows).
        """
        from .dispatch import merge_topk
        key = ("merge", "default", "f32", k, 0, 0, None)
        return self.run(key, lambda: partial(merge_topk, k=k), base, extra)

    # -- postfilter route (oversampled unfiltered beam + filter) -----------
    def postfilter(self, queries, filt, *, k: int, ls: int,
                   max_iters: int) -> SearchResult:
        """Unfiltered traversal keeping the ls-beam, then keep the k best
        filter-passing survivors (the Post-Filtering baseline, fused into
        one compiled program).

        n_dist counts the traversal's distance computations PLUS the filter
        evaluations applied to the surviving beam entries — the paper's DC
        metric compares this route against prefilter/graph, both of which
        charge every point their comparator touches, so omitting the
        survivor evaluations undercounted this route.
        """
        idx = self.index
        key = ("postfilter", "default", "f32", k, ls, max_iters, filt.kind)

        def make():
            def run(graph, xb, xb_norm, attr, q, filt, entry):
                res = greedy_search(graph, xb, xb_norm, attr, q, entry,
                                    unfiltered_key_fn(), ls=ls, k=ls,
                                    max_iters=max_iters)
                ids = res.ids
                ok = matches(filt, attr.gather(jnp.maximum(ids, 0)))
                ok = ok & (ids >= 0)
                prim = jnp.where(ok, 0.0, INF)
                sec = jnp.where(ok, res.secondary, INF)
                idsm = jnp.where(ok, ids, -1)
                prim, sec, idsm = jax.lax.sort((prim, sec, idsm), num_keys=2)
                n_dist = res.n_dist + jnp.sum(ids >= 0, axis=1,
                                              dtype=jnp.int32)
                return SearchResult(idsm[:, :k], prim[:, :k], sec[:, :k],
                                    res.vlog, res.n_expanded, n_dist)
            return run
        return self.run(key, make, idx.graph, idx.xb, idx.xb_norm, idx.attr,
                        jnp.asarray(queries), filt, idx.entry)
