"""Serving subsystem: the request -> plan -> execute pipeline.

layout.py packs [vec | norm | attr] rows so one gather per beam expansion
feeds the comparator; engine.py builds the ``fetch_fn`` closures that plug
it into greedy_search; planner.py estimates filter selectivity and routes
whole batches (``plan``) or individual queries (``plan_per_query``) to a
strategy; dispatch.py gathers per-query route groups into contiguous
sub-batches and scatters the results back into original order; executor.py
owns the single jit cache behind every route (prefilter | graph |
postfilter) and every public ``JAGIndex.search*`` entry point. When a
calibrated ``repro.cost`` model is attached to the index, the planner's
static thresholds are replaced by ``Executor.cost_router``'s
argmin-of-predicted-cost routing (see ``repro.cost``).
"""
from .dispatch import (dispatch_per_query, fold_topk, merge_topk, regroup,
                       run_route)
from .engine import FusedEngine, make_fetch_fn
from .executor import Executor
from .layout import (FusedLayout, build_layout, extend_layout, load_layout,
                     save_layout)
from .planner import (GroupPlan, Plan, PerQueryPlan, PlannerConfig, ROUTES,
                      choose_route, clause_eval_cost, estimate_selectivity,
                      explain, leaf_selectivities, leaf_validity, plan,
                      plan_per_query, reorder_clauses, sample_ids)
from .sharded import ShardedJAGIndex

__all__ = ["Executor", "FusedEngine", "FusedLayout", "GroupPlan", "Plan",
           "PerQueryPlan", "PlannerConfig", "ROUTES", "ShardedJAGIndex",
           "build_layout", "choose_route", "clause_eval_cost",
           "dispatch_per_query", "estimate_selectivity", "explain",
           "extend_layout", "fold_topk", "leaf_selectivities",
           "leaf_validity", "load_layout", "make_fetch_fn", "merge_topk",
           "plan", "plan_per_query", "regroup", "reorder_clauses",
           "run_route", "sample_ids", "save_layout"]
