"""Fused serving layout subsystem: pack [vec | norm | attr] rows so one
gather per beam expansion feeds the comparator (layout.py), and build the
``fetch_fn`` closures that plug it into greedy_search (engine.py)."""
from .engine import FusedEngine, make_fetch_fn
from .layout import FusedLayout, build_layout, load_layout, save_layout

__all__ = ["FusedEngine", "FusedLayout", "build_layout", "load_layout",
           "make_fetch_fn", "save_layout"]
