"""Serving subsystem: the request -> plan -> execute pipeline.

layout.py packs [vec | norm | attr] rows so one gather per beam expansion
feeds the comparator; engine.py builds the ``fetch_fn`` closures that plug
it into greedy_search; planner.py estimates filter selectivity and routes
each query batch to a strategy; executor.py owns the single jit cache
behind every route (prefilter | graph | postfilter) and every public
``JAGIndex.search*`` entry point.
"""
from .engine import FusedEngine, make_fetch_fn
from .executor import Executor
from .layout import FusedLayout, build_layout, load_layout, save_layout
from .planner import (Plan, PlannerConfig, ROUTES, choose_route,
                      estimate_selectivity, explain, plan, sample_ids)

__all__ = ["Executor", "FusedEngine", "FusedLayout", "Plan",
           "PlannerConfig", "ROUTES", "build_layout", "choose_route",
           "estimate_selectivity", "explain", "load_layout",
           "make_fetch_fn", "plan", "sample_ids", "save_layout"]
