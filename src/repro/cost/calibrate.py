"""Micro-benchmark harness: measure per-route cost on the live hardware.

Runs every executor route (prefilter | graph | postfilter) over a
selectivity x N x d x k x ls grid of synthetic range-filtered datasets,
plus the streaming costs (delta scan, merge, total compaction) over a
delta_n grid — every measurement goes THROUGH the epoch-aware
``serve.Executor``, so timings hit exactly the compiled routes serving
uses, not a lookalike.

Timing discipline (:func:`time_route`): an explicit warmup loop absorbs
jit compilation and cache fill, then each repeat is individually
``block_until_ready``-timed and the MEDIAN per-repeat wall time is
reported — one long ``perf_counter`` over warm+cold runs (the old
``benchmarks.common.measure`` pattern) lets compile time pollute the cost
fit. ``benchmarks/common.py`` re-exports this helper so every benchmark
shares the same discipline (the implementation lives here because ``src``
must not import the repo-root ``benchmarks`` package).

The one deliberate exception: compaction is measured as ONE cold total —
every production compaction re-traces the build's insert step today, so
the cold cost IS the recurring cost.

``calibrate()`` is the one-call entry point: run the grid, fit the
log-linear model (``model.fit``), stamp backend/dtype/layout metadata for
the registry key.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax

from ..core import filters as F
from ..core.jag import JAGConfig, JAGIndex
from .model import CostModel, Observation, fit

DEFAULT_SELS = (0.001, 0.01, 0.1, 0.5, 0.9)

# grid presets: FAST is the CI smoke (seconds of build time on CPU), FULL
# is a real calibration pass at serving-representative scale
FAST_GRID = dict(ns=(1500, 3000), ds=(16,), sels=DEFAULT_SELS,
                 lss=(32, 64), k=10, b=32, delta_ns=(64, 192),
                 warmup=1, repeats=2)
FULL_GRID = dict(ns=(8000, 20000), ds=(32, 64), sels=DEFAULT_SELS,
                 lss=(32, 64, 128), k=10, b=64, delta_ns=(256, 1024),
                 warmup=1, repeats=3)


def time_route(fn, warmup: int = 1, repeats: int = 3):
    """(last result, median per-repeat wall seconds) of ``fn()``.

    ``warmup`` calls run (and block) first so jit compilation and cache
    fill never land inside a timed repeat; each repeat then times exactly
    one blocked call, and the median de-noises stragglers. This is the
    one timing primitive every benchmark and the calibration harness
    share.
    """
    res = None
    for _ in range(max(int(warmup), 0)):
        res = jax.block_until_ready(fn())
    times = []
    for _ in range(max(int(repeats), 1)):
        t0 = time.perf_counter()
        res = jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return res, float(np.median(times))


@dataclasses.dataclass
class Calibration:
    """Raw measurements + the grid/provenance metadata they carry."""
    observations: List[Observation]
    meta: Dict


def synth_dataset(n: int, d: int, b: int, seed: int):
    """(xb, uniform attr values, near-manifold queries) — range-filtered
    synthetic data whose selectivity is directly dialable via the hi cap.
    Public: ``benchmarks/cost_bench.py`` evaluates routing on the SAME
    distribution the grid was measured on."""
    rng = np.random.default_rng(seed)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    vals = rng.uniform(0, 1, n).astype(np.float32)
    q = (xb[rng.integers(0, n, b)]
         + 0.1 * rng.normal(size=(b, d))).astype(np.float32)
    return xb, vals, q


def _obs(route: str, res, dt: float, b: int,
         features: Dict[str, float]) -> Observation:
    return Observation(route=route, features=features,
                       us=dt / b * 1e6,
                       n_dist=float(np.asarray(res.n_dist).mean()))


def run_calibration(*, ns: Sequence[int] = (2000,),
                    ds: Sequence[int] = (16,),
                    sels: Sequence[float] = DEFAULT_SELS,
                    lss: Sequence[int] = (32, 64), k: int = 10, b: int = 32,
                    delta_ns: Sequence[int] = (64, 192),
                    warmup: int = 1, repeats: int = 3, seed: int = 0,
                    cfg: Optional[JAGConfig] = None,
                    include_streaming: bool = True,
                    verbose: bool = False) -> Calibration:
    """Measure every route over the grid; returns raw observations.

    One index is built per (n, d) cell; base routes are measured per
    (sel[, ls]) on it, then the streaming costs (delta scan / merge /
    compaction total) per delta_n on a fresh ``StreamingJAGIndex`` wrapper
    around the largest cell's index (wrappers never mutate the base, so
    each delta_n measures from a clean slate).
    """
    from ..stream import StreamingJAGIndex

    obs: List[Observation] = []
    builds = []
    last = None
    for n in ns:
        for d in ds:
            c = cfg or JAGConfig(degree=16, ls_build=32, batch_size=256,
                                 cand_pool=64, calib_samples=128)
            xb, vals, q = synth_dataset(n, d, b, seed)
            tab = F.range_table(vals)
            t0 = time.time()
            index = JAGIndex.build(xb, tab, c)
            builds.append(dict(n=n, d=d, build_s=round(time.time() - t0, 2)))
            last = (index, q, n, d)
            ex = index.executor
            for sel in sels:
                filt = F.range_filters(np.zeros(b, np.float32),
                                       np.full(b, sel, np.float32))
                sel_true = float(np.asarray(
                    F.selectivity(filt, tab)).mean())
                feat = dict(sel=sel_true, n=n, d=d, k=k, b=b, delta_n=0)
                res, dt = time_route(lambda: ex.prefilter(q, filt, k=k),
                                     warmup, repeats)
                obs.append(_obs("prefilter", res, dt, b, feat))
                for ls in lss:
                    featl = dict(feat, ls=ls)
                    res, dt = time_route(
                        lambda: ex.graph(q, filt, k=k, ls=ls,
                                         max_iters=2 * ls),
                        warmup, repeats)
                    obs.append(_obs("graph", res, dt, b, featl))
                    res, dt = time_route(
                        lambda: ex.postfilter(q, filt, k=k, ls=ls,
                                              max_iters=2 * ls),
                        warmup, repeats)
                    obs.append(_obs("postfilter", res, dt, b, featl))
                if verbose:
                    print(f"# calibrated n={n} d={d} sel={sel} "
                          f"({len(obs)} obs)", flush=True)

    if include_streaming and last is not None:
        index, q, n, d = last
        rng = np.random.default_rng(seed + 1)
        for dn in delta_ns:
            s = StreamingJAGIndex(index, compact_frac=0.0)
            xv = rng.normal(size=(dn, d)).astype(np.float32)
            dv = rng.uniform(0, 1, dn).astype(np.float32)
            s.insert(xv, F.range_table(dv), auto_compact=False)
            filt = F.range_filters(np.zeros(b, np.float32),
                                   np.full(b, 0.5, np.float32))
            feat = dict(sel=0.5, n=n, d=d, k=k, b=b, delta_n=dn)
            sx = s.executor
            extra, dt = time_route(lambda: sx.delta(q, filt, k=k),
                                   warmup, repeats)
            obs.append(_obs("delta", extra, dt, b, feat))
            base_res = sx.prefilter(q, filt, k=k)
            # two k points per delta_n: merge's feature vector is [1,
            # log(k)], so a single-k grid would be rank-1 and the "fit"
            # pure timing noise. merge computes ZERO distances (its
            # result's n_dist SUMS its inputs' — recording that would
            # charge the base+delta scans to the sort); n_dist=0 keeps
            # the metric honest and leaves merge uncovered under "n_dist"
            for kk in (k, 2 * k):
                # merge is tens of us — extra repeats are ~free and tame
                # the proportionally huge timer noise
                _, dt = time_route(
                    lambda: sx.merge(base_res, extra, k=kk), warmup,
                    max(repeats, 5))
                obs.append(Observation("merge", dict(feat, k=kk),
                                       us=dt / b * 1e6, n_dist=0.0))
            # compaction: ONE cold total — production compactions re-trace
            # the insert step every time, so cold IS the recurring cost
            t0 = time.perf_counter()
            s.compact()
            obs.append(Observation(
                "compact", feat, us=(time.perf_counter() - t0) * 1e6))
            if verbose:
                print(f"# calibrated streaming delta_n={dn}", flush=True)

    meta = dict(backend=jax.default_backend(), dtype="f32",
                layout="default",
                grid=dict(ns=list(ns), ds=list(ds), sels=list(sels),
                          lss=list(lss), k=k, b=b,
                          delta_ns=list(delta_ns)),
                warmup=warmup, repeats=repeats, seed=seed, builds=builds)
    return Calibration(observations=obs, meta=meta)


def calibrate(*, fast: bool = False, meta: Optional[Dict] = None,
              **overrides) -> CostModel:
    """Grid -> measurements -> fitted :class:`CostModel`, in one call.

    ``fast=True`` uses the CI smoke grid; keyword overrides replace any
    grid field. The returned model carries the registry key metadata
    (backend/dtype/layout) and per-route fit stats.
    """
    kw: Dict = dict(FAST_GRID if fast else FULL_GRID)
    kw.update(overrides)
    cal = run_calibration(**kw)
    m = dict(cal.meta)
    m.update(meta or {})
    return fit(cal.observations, m)


def calibrate_shard_grid(n: int, d: int, *, fast: bool = True,
                         meta: Optional[Dict] = None,
                         **overrides) -> CostModel:
    """One per-shard (n, d) grid entry for the sharded-serving registry.

    Measures the base routes at exactly the per-shard row count a shard
    serves (streaming costs excluded — sharded deltas are a follow-on) and
    stamps ``meta["shard_shape"] = [n, d]``, which is what
    ``registry.model_key`` suffixes the key with and what
    ``CostRegistry.load_shard_grids`` groups
    :class:`~repro.cost.model.InterpolatedCostModel` entries by. Calibrate
    two or more n points per d and any fresh shard count in between
    predicts by log-log interpolation, no new pass needed.
    """
    kw: Dict = dict(FAST_GRID if fast else FULL_GRID)
    kw.update(ns=(int(n),), ds=(int(d),), include_streaming=False)
    kw.update(overrides)
    cal = run_calibration(**kw)
    m = dict(cal.meta)
    m["shard_shape"] = [int(n), int(d)]
    m.update(meta or {})
    return fit(cal.observations, m)
