"""Calibrated cost-model subsystem: measured per-route cost curves.

The planner's static thresholds (``PlannerConfig.prefilter_max_sel`` /
``postfilter_min_sel``) and the streaming layer's static ``compact_frac``
are exactly the hand-picked cutoffs that drift as N, d, and hardware
change. This package replaces them with *measured* per-route cost curves:

  calibrate.py  micro-benchmark harness — measures us/query and distance
                computations for every executor route (prefilter | graph |
                postfilter | delta | merge) plus total compaction cost,
                over a selectivity x N x d x k x ls grid, THROUGH the
                epoch-aware ``serve.Executor`` so timings hit the real
                compiled routes.
  model.py      fitted analytic cost model — per-route log-linear terms
                (prefilter ~ N*d, graph ~ ls*iters(sel)*d, postfilter ~
                oversample*d, delta ~ delta_n*d), ``predict(route,
                features) -> cost``, and the ``CostModelRouter`` that
                argmin-routes queries when attached (static thresholds
                remain the principled fallback when uncalibrated).
  registry.py   schema-versioned JSON persistence, keyed by
                backend/dtype/layout; models also ride inside ``JAGIndex``
                archives (``cost__model`` key) so a loaded index routes
                like the one that was saved.

Integration: ``JAGIndex.attach_cost_model`` / ``Executor.cost_router``
drive ``serve.planner.plan``/``plan_per_query``; ``StreamingJAGIndex``
replaces the ``compact_frac`` trigger with a predicted delta-tax vs
compaction-cost break-even. See ``benchmarks/cost_bench.py`` for the CI
calibration smoke.
"""
from .calibrate import (Calibration, calibrate, calibrate_shard_grid,
                        run_calibration, time_route)
from .model import (BASE_ROUTES, CostModel, CostModelRouter,
                    InterpolatedCostModel, Observation, feature_names, fit,
                    phi)
from .registry import (SCHEMA_VERSION, CostRegistry, from_json, model_key,
                       to_json)

__all__ = ["BASE_ROUTES", "Calibration", "CostModel", "CostModelRouter",
           "CostRegistry", "InterpolatedCostModel", "Observation",
           "SCHEMA_VERSION", "calibrate", "calibrate_shard_grid",
           "feature_names", "fit", "from_json", "model_key", "phi",
           "run_calibration", "time_route", "to_json"]
