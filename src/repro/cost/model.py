"""Fitted analytic cost model + the argmin router it drives.

Each executor route's cost is modeled log-linearly in route-specific
feature terms (all positive, so the model is multiplicative and its
predictions can never go negative):

    log(cost) = w . phi(route, features)

    prefilter   ~ N*d            (block GEMM touches every row) x sel^c
    graph       ~ ls*d x sel^c x N^c   (iters grow as selectivity drops)
    postfilter  ~ ls*d x N^c x sel^c   (oversampled unfiltered beam)
    delta       ~ delta_n*d      (exact scan over the live segment)
    merge       ~ k              (one stable sort over 2k columns)
    compact     ~ delta_n x d    (batch-insert passes over delta ids;
                                  TOTAL us per compaction, not per query)

Fitting is plain per-route least squares on log(measured cost) over the
calibration grid (``calibrate.run_calibration``); a route with fewer
observations than coefficients stays uncalibrated and the model reports
``covers(...) == False`` for it, which makes the planner fall back to the
static thresholds — the principled degradation path.

``CostModelRouter`` is the serving-side integration: built per search call
by ``serve.Executor.cost_router`` with the live (n, d, k, ls, delta_n), it
predicts every base route's us/query — folding the constant delta-scan tax
(delta + merge) that a streaming index pays on EVERY route into each
prediction — and routes each query to the argmin.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# routes the planner chooses between; delta/merge/compact are costs every
# choice shares (streaming) or one-off maintenance, never routing targets
BASE_ROUTES = ("prefilter", "graph", "postfilter")
ALL_ROUTES = BASE_ROUTES + ("delta", "merge", "compact")
METRICS = ("us", "n_dist")
_EPS = 1e-4                       # selectivity floor inside log terms

# ONE table defines each route's feature terms: (name, value-extractor over
# the clamped canonical features). phi() and feature_names() both derive
# from it, so the coefficient labels published in artifacts can never
# drift from the values actually fitted. compact is deliberately 2 terms
# so a minimal grid (two delta_n points at one d) fully determines it —
# compaction work is insert passes over delta rows, each ~ d-proportional.
_TERMS = {
    # n_clauses (compound-filter clause count) is appended LAST so legacy
    # 3-coefficient prefilter models stay valid: predict() zero-pads short
    # coefficient vectors, and log(n_clauses)=0 at the atomic default of 1,
    # so old models' predictions are bit-identical (append-only term
    # policy — new terms must default to a canonical value whose log is 0).
    "prefilter": (("log(n*d)", lambda c: c["n"] * c["d"]),
                  ("log(sel)", lambda c: c["sel"]),
                  ("log(n_clauses)", lambda c: c["n_clauses"])),
    "graph": (("log(ls*d)", lambda c: c["ls"] * c["d"]),
              ("log(sel)", lambda c: c["sel"]),
              ("log(n)", lambda c: c["n"])),
    "postfilter": (("log(ls*d)", lambda c: c["ls"] * c["d"]),
                   ("log(n)", lambda c: c["n"]),
                   ("log(sel)", lambda c: c["sel"])),
    "delta": (("log(delta_n*d)", lambda c: c["delta_n"] * c["d"]),),
    "merge": (("log(k)", lambda c: c["k"]),),
    "compact": (("log(delta_n*d)", lambda c: c["delta_n"] * c["d"]),),
}


def _canon(features: Dict[str, float]) -> Dict[str, float]:
    """Clamped canonical features: absent keys default to benign values
    (the delta/compact terms never need a selectivity) and every value is
    floored so the log terms stay finite."""
    f = features
    return dict(sel=min(max(float(f.get("sel", 1.0)), _EPS), 1.0),
                n=max(float(f.get("n", 1.0)), 1.0),
                d=max(float(f.get("d", 1.0)), 1.0),
                ls=max(float(f.get("ls", 64.0)), 1.0),
                k=max(float(f.get("k", 10.0)), 1.0),
                delta_n=max(float(f.get("delta_n", 0.0)), 1.0),
                n_clauses=max(float(f.get("n_clauses", 1.0)), 1.0))


def feature_names(route: str) -> Tuple[str, ...]:
    """The ordered feature-term names behind ``phi(route, ...)``."""
    if route not in _TERMS:
        raise ValueError(f"unknown route {route!r}")
    return ("1",) + tuple(name for name, _ in _TERMS[route])


def phi(route: str, features: Dict[str, float]) -> np.ndarray:
    """Route-specific log-feature vector for one observation."""
    if route not in _TERMS:
        raise ValueError(f"unknown route {route!r}")
    c = _canon(features)
    return np.asarray([1.0] + [math.log(fn(c)) for _, fn in _TERMS[route]],
                      np.float64)


@dataclasses.dataclass(frozen=True)
class Observation:
    """One calibration measurement of one route.

    ``us`` is the median per-query wall time in microseconds for the query
    routes, and the TOTAL wall time for the one-off ``compact``;
    ``n_dist`` is the mean distance computations per query (0 where the
    metric has no meaning, e.g. compaction).
    """
    route: str
    features: Dict[str, float]
    us: float
    n_dist: float = 0.0


@dataclasses.dataclass
class CostModel:
    """Per-route fitted coefficients + provenance metadata.

    ``coef[route][metric]`` are the log-linear weights for
    ``phi(route, .)``; ``meta`` carries backend/dtype/layout (the registry
    key), the calibration batch size, and the grid; ``fit_stats[route]``
    records the on-grid relative prediction error so artifacts (and CI)
    can judge the fit without re-measuring.
    """
    coef: Dict[str, Dict[str, List[float]]]
    meta: Dict
    fit_stats: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def routes(self) -> Tuple[str, ...]:
        return tuple(self.coef)

    def covers(self, routes: Sequence[str], metric: str = "us") -> bool:
        """True when every requested route has fitted ``metric`` weights."""
        return all(r in self.coef and metric in self.coef[r]
                   for r in routes)

    def predict(self, route: str, features: Dict[str, float],
                metric: str = "us") -> float:
        """Predicted cost (always positive: exp of the fitted log-cost).

        Coefficient vectors shorter than the current feature table are
        zero-padded: feature terms are append-only and new terms log to 0
        at their canonical default, so a legacy model predicts exactly
        what it predicted when it was fitted.
        """
        w = np.asarray(self.coef[route][metric], np.float64)
        x = phi(route, features)
        if w.shape[0] < x.shape[0]:
            w = np.pad(w, (0, x.shape[0] - w.shape[0]))
        elif w.shape[0] > x.shape[0]:
            raise ValueError(
                f"{route}/{metric} has {w.shape[0]} coefficients but "
                f"phi() has {x.shape[0]} terms — model is from a newer "
                f"feature table")
        return float(math.exp(float(x @ w)))


@dataclasses.dataclass
class InterpolatedCostModel:
    """Cost predictions between per-shard calibrated (N, d) grids.

    Sharded serving changes the per-shard row count with the shard count
    (N_loc = N / S), and a dedicated calibration pass per shard count
    would make every resize an offline event. Instead the registry stores
    one :class:`CostModel` per calibrated per-shard grid (``meta
    ["shard_shape"] = [n, d]``) and this wrapper predicts at any fresh
    shard shape: pick the d-group with the nearest log-distance, evaluate
    the two n-bracketing grid models AT THEIR OWN grid n, and interpolate
    log-linearly in log n. Exact at the grid points (the bracketing
    weight degenerates to 0/1 and the grid model sees its own n) and
    monotone in n between them (a log-log line is monotone); outside the
    calibrated n span the nearest endpoint model extrapolates with the
    true n, i.e. its own fitted log(n) slope.

    Duck-typed to :class:`CostModel`'s ``covers``/``predict`` surface, so
    ``CostModelRouter`` and the planner take either interchangeably.
    """
    grids: List[CostModel]
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for m in self.grids:
            if "shard_shape" not in m.meta:
                raise ValueError("every grid model needs meta['shard_shape']"
                                 " = [n, d] — stamp it at calibration time")

    def routes(self) -> Tuple[str, ...]:
        common = set(self.grids[0].coef) if self.grids else set()
        for m in self.grids[1:]:
            common &= set(m.coef)
        return tuple(sorted(common))

    def covers(self, routes: Sequence[str], metric: str = "us") -> bool:
        """True when EVERY grid covers every requested route — a fresh
        shard shape may interpolate between any pair of neighbors."""
        return bool(self.grids) and all(m.covers(routes, metric)
                                        for m in self.grids)

    def _d_group(self, d: float) -> List[CostModel]:
        """Grids at the d nearest in log-distance, sorted ascending by n."""
        best = min({float(m.meta["shard_shape"][1]) for m in self.grids},
                   key=lambda gd: abs(math.log(max(gd, 1.0))
                                      - math.log(max(d, 1.0))))
        group = [m for m in self.grids
                 if float(m.meta["shard_shape"][1]) == best]
        return sorted(group, key=lambda m: float(m.meta["shard_shape"][0]))

    def predict(self, route: str, features: Dict[str, float],
                metric: str = "us") -> float:
        n = max(float(features.get("n", 1.0)), 1.0)
        group = self._d_group(float(features.get("d", 1.0)))
        lo = [m for m in group if float(m.meta["shard_shape"][0]) <= n]
        hi = [m for m in group if float(m.meta["shard_shape"][0]) >= n]
        if not lo or not hi:       # outside the span: endpoint extrapolates
            m = group[0] if not lo else group[-1]
            return m.predict(route, features, metric)
        m0, m1 = lo[-1], hi[0]
        n0 = float(m0.meta["shard_shape"][0])
        n1 = float(m1.meta["shard_shape"][0])
        p0 = m0.predict(route, {**features, "n": n0}, metric)
        if n0 == n1:
            return p0
        p1 = m1.predict(route, {**features, "n": n1}, metric)
        t = (math.log(n) - math.log(n0)) / (math.log(n1) - math.log(n0))
        return float(math.exp((1.0 - t) * math.log(max(p0, 1e-300))
                              + t * math.log(max(p1, 1e-300))))


def fit(observations: Sequence[Observation],
        meta: Optional[Dict] = None) -> CostModel:
    """Least-squares fit of log(cost) per route over a calibration run.

    Routes with fewer observations than coefficients are left out (the
    model simply does not cover them -> static-threshold fallback);
    non-positive measurements are dropped rather than poisoning the log
    fit. ``fit_stats`` reports median/max relative error of the us fit on
    its own calibration grid — the honesty metric CI bounds.
    """
    by_route: Dict[str, List[Observation]] = {}
    for ob in observations:
        by_route.setdefault(ob.route, []).append(ob)
    coef: Dict[str, Dict[str, List[float]]] = {}
    stats: Dict[str, Dict[str, float]] = {}
    for route, obs in by_route.items():
        X = np.stack([phi(route, ob.features) for ob in obs])
        fitted: Dict[str, List[float]] = {}
        for metric in METRICS:
            y = np.asarray([getattr(ob, metric) for ob in obs], np.float64)
            ok = y > 0
            # a term whose column is identically zero on this grid (e.g.
            # log(n_clauses) when every observation is an atomic filter)
            # is structurally absent: it costs no degree of freedom, and
            # min-norm lstsq pins its coefficient at exactly 0
            n_params = int(np.any(X[ok] != 0.0, axis=0).sum())
            if int(ok.sum()) < n_params:
                continue
            w, *_ = np.linalg.lstsq(X[ok], np.log(y[ok]), rcond=None)
            fitted[metric] = [float(v) for v in w]
            if metric == "us":
                pred = np.exp(X[ok] @ w)
                rel = np.abs(pred - y[ok]) / y[ok]
                stats[route] = {
                    "n_obs": int(ok.sum()),
                    "median_rel_err": float(np.median(rel)),
                    "max_rel_err": float(np.max(rel)),
                }
        if fitted:
            coef[route] = fitted
    return CostModel(coef=coef, meta=dict(meta or {}), fit_stats=stats)


class CostModelRouter:
    """Argmin-of-predicted-cost router over the executor's base routes.

    Built per search call (``serve.Executor.cost_router``) with the live
    serving shape; replaces ``planner.choose_route``'s threshold ladder.
    A streaming index's constant per-query delta tax (delta scan + merge)
    is folded into EVERY base route's prediction — it cancels in the
    argmin but makes ``costs()`` report the true per-query totals, the
    same totals the compaction break-even reasons about.
    """

    def __init__(self, model: CostModel, *, n: int, d: int, k: int,
                 ls: int, delta_n: int = 0, b: int = 1, metric: str = "us",
                 routes: Tuple[str, ...] = BASE_ROUTES, n_leaves: int = 1):
        if not model.covers(routes, metric):
            raise ValueError(f"model covers {model.routes()}, router needs "
                             f"{routes} ({metric}) — fall back to static "
                             f"thresholds")
        self.model = model
        self.routes = routes
        self.metric = metric       # "us" (wall) or "n_dist" (the DC metric)
        self.n, self.d, self.k, self.ls = int(n), int(d), int(k), int(ls)
        self.delta_n, self.b = int(delta_n), int(b)
        # compound-filter clause count -> the prefilter log(n_clauses)
        # term; 1 (atomic) contributes nothing, so legacy behavior holds
        self.n_leaves = max(int(n_leaves), 1)
        self.delta_tax = delta_scan_tax(model, n=n, d=d, k=k,
                                        delta_n=delta_n, metric=metric)

    def features(self, sel: float) -> Dict[str, float]:
        return dict(sel=float(sel), n=self.n, d=self.d, k=self.k,
                    ls=self.ls, delta_n=self.delta_n, b=self.b,
                    n_clauses=self.n_leaves)

    def costs(self, sel: float) -> Dict[str, float]:
        """Predicted cost/query per base route (delta tax folded in)."""
        f = self.features(sel)
        return {r: self.model.predict(r, f, self.metric) + self.delta_tax
                for r in self.routes}

    def route(self, sel: float) -> str:
        """The cheapest predicted route; ties break in ``routes`` order."""
        costs = self.costs(sel)
        best = self.routes[0]
        for r in self.routes[1:]:
            if costs[r] < costs[best]:
                best = r
        return best


def delta_scan_tax(model: CostModel, *, n: int, d: int, k: int,
                   delta_n: int, metric: str = "us") -> float:
    """Predicted cost/query a live delta segment adds to ANY base route.

    The streaming executor scans the delta and merges its top-k into the
    base result on every search, so the tax is delta + merge (merge only
    when calibrated — it is tiny and may be absent from a minimal model).
    Zero when the delta is empty or the model has no delta curve.
    """
    if delta_n <= 0 or not model.covers(("delta",), metric):
        return 0.0
    f = dict(delta_n=delta_n, n=n, d=d, k=k)
    tax = model.predict("delta", f, metric)
    if model.covers(("merge",), metric):
        tax += model.predict("merge", f, metric)
    return tax
