"""Schema-versioned JSON persistence for calibration artifacts.

A fitted :class:`~repro.cost.model.CostModel` is hardware truth — it is
only valid for the (backend, dtype, layout) combination it was measured
on, so artifacts are keyed by exactly that triple (``cpu-f32-default``,
``tpu-int8-fused``, ...). Two persistence paths share one JSON codec:

  * :class:`CostRegistry` — a directory of ``cost-<key>.json`` files, the
    fleet-level store benchmarks write and servers warm-start from;
  * ``JAGIndex.save``/``load`` — an attached model rides INSIDE the index
    archive (``cost__model`` uint8 key), so a restored index routes
    exactly like the one that was saved, no registry lookup needed.

``from_json`` refuses artifacts from a different schema version loudly —
a silently re-interpreted coefficient vector would mis-route every query.
"""
from __future__ import annotations

import json
import os
from typing import Optional, Sequence, Tuple

from .model import CostModel, InterpolatedCostModel

SCHEMA_VERSION = 1


def model_key(backend: str, dtype: str = "f32", layout: str = "default",
              shard_shape: Optional[Sequence[float]] = None) -> str:
    """The registry key one calibration is valid for.

    ``shard_shape = (n, d)`` suffixes the per-shard grid a sharded-serving
    calibration was measured at (``cpu-f32-default@n125000-d64``): one
    hardware triple holds many grid entries, and
    :func:`CostRegistry.load_shard_grids` folds them into an
    :class:`~repro.cost.model.InterpolatedCostModel` so a fresh shard
    count predicts without a dedicated calibration pass.
    """
    base = f"{backend}-{dtype}-{layout}"
    if shard_shape is None:
        return base
    n, d = (int(shard_shape[0]), int(shard_shape[1]))
    return f"{base}@n{n}-d{d}"


def to_json(model: CostModel) -> str:
    """Serialize a model (coefficients + meta + fit stats), stamped with
    the schema version."""
    return json.dumps({"schema": SCHEMA_VERSION, "coef": model.coef,
                       "meta": model.meta, "fit_stats": model.fit_stats},
                      indent=1, sort_keys=True)


def from_json(text: str) -> CostModel:
    """Inverse of :func:`to_json`; raises on any other schema version."""
    payload = json.loads(text)
    schema = payload.get("schema")
    if schema != SCHEMA_VERSION:
        raise ValueError(f"cost-model artifact schema {schema!r} != "
                         f"supported {SCHEMA_VERSION} — recalibrate "
                         f"instead of re-interpreting coefficients")
    return CostModel(coef=payload["coef"], meta=payload.get("meta", {}),
                     fit_stats=payload.get("fit_stats", {}))


class CostRegistry:
    """A directory of calibration artifacts, one JSON file per key."""

    def __init__(self, root: str):
        self.root = root

    def path(self, key: str) -> str:
        return os.path.join(self.root, f"cost-{key}.json")

    def key_of(self, model: CostModel) -> str:
        m = model.meta
        return model_key(m.get("backend", "unknown"),
                         m.get("dtype", "f32"),
                         m.get("layout", "default"),
                         m.get("shard_shape"))

    def save(self, model: CostModel) -> str:
        """Write the model under its own metadata key; returns the path."""
        os.makedirs(self.root, exist_ok=True)
        path = self.path(self.key_of(model))
        with open(path, "w") as fh:
            fh.write(to_json(model))
        return path

    def load(self, backend: str, dtype: str = "f32",
             layout: str = "default") -> Optional[CostModel]:
        """The stored model for this hardware key, or None (uncalibrated
        is a normal state — callers fall back to static thresholds)."""
        path = self.path(model_key(backend, dtype, layout))
        if not os.path.exists(path):
            return None
        with open(path) as fh:
            return from_json(fh.read())

    def load_shard_grids(self, backend: str, dtype: str = "f32",
                         layout: str = "default"
                         ) -> Optional[InterpolatedCostModel]:
        """Every per-shard grid calibrated for this hardware key, folded
        into one :class:`~repro.cost.model.InterpolatedCostModel`.

        Collects all ``<base>@n<N>-d<D>`` entries; returns None when no
        grid has been calibrated (the normal uncalibrated state — sharded
        serving then falls back to static thresholds like everything
        else). A loaded grid missing its ``shard_shape`` meta is a
        corrupted artifact and raises rather than silently mis-keying.
        """
        prefix = model_key(backend, dtype, layout) + "@n"
        grids = []
        for key in self.keys():
            if not key.startswith(prefix):
                continue
            with open(self.path(key)) as fh:
                grids.append(from_json(fh.read()))
        if not grids:
            return None
        return InterpolatedCostModel(
            grids, meta=dict(backend=backend, dtype=dtype, layout=layout))

    def keys(self) -> Tuple[str, ...]:
        """Every calibrated key present in the registry directory."""
        if not os.path.isdir(self.root):
            return ()
        out = []
        for name in sorted(os.listdir(self.root)):
            if name.startswith("cost-") and name.endswith(".json"):
                out.append(name[len("cost-"):-len(".json")])
        return tuple(out)
