"""Static analysis for the serving stack: `jagcheck`'s two layers.

Seven PRs of serving work accumulated invariants that used to live only in
docstrings and point tests. This package makes them machine-checked:

* :mod:`repro.analysis.lint` — Layer 1, an AST lint over ``src/repro``
  enforcing the repo-specific rules JAG001–JAG005 (jit surface, batch-
  invariant candidate dots, no module-level lru_cache over device buffers,
  epoch-keyed executor caches, no host syncs under jit) with a
  config/allowlist in ``pyproject.toml`` ``[tool.jagcheck]``.
* :mod:`repro.analysis.audit` — Layer 2, a compiled-route auditor: builds
  a small index, traces every executor route (including the sharded
  routes on faked devices) to jaxpr + lowered/compiled HLO, and statically
  asserts the performance contracts — one gather per expansion on fused
  routes, zero host callbacks / f64 ops, exactly one all-gather per
  sharded route — emitting a diffable ``AUDIT.json``.

``tools/jagcheck.py`` is the CLI; CI runs both layers on every commit.
"""
from .lint import Finding, LintConfig, lint_source, run_lint  # noqa: F401
from .audit import check_report, run_audit  # noqa: F401
