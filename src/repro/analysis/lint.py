"""Layer 1 of `jagcheck`: the repo-specific AST lint (rules JAG001–JAG005).

Each rule mechanizes an invariant a past PR established and a later change
could silently break:

  JAG001  no ``jax.jit`` outside ``serve/executor.py``, ``core/build.py``
          and the ``launch/`` paths — PR 2's "zero jit blocks in
          core/jag.py" contract, generalized: every serving compilation
          must go through the Executor's one epoch-keyed cache so compiled
          variants stay enumerable and evictable.
  JAG002  no batch-variant ``einsum("bcd,bd->bc", ...)`` candidate dots —
          PR 3's bit-identity contract: a batched-dot lowering picks
          different reduction vectorization per batch size, so per-query
          regrouping would leak group composition into a query's low-order
          float bits. Use ``distances.gathered_dot``.
  JAG003  no module-level ``functools.lru_cache``/``cache`` — the PR 3
          ``sample_ids`` bug class: a module-level memo capturing device
          buffers pins them process-wide across index lifetimes. Cache on
          the owning object instead.
  JAG004  executor-cache key hygiene: any ``*._cache[...]`` insertion must
          include an epoch component in its key expression — PR 4's
          stale-probe bug class: epoch-less keys serve pre-insert
          compilations after the index grows.
  JAG005  no ``np.asarray`` / ``.item()`` / ``float(x)`` host syncs inside
          functions traced by ``jax.jit`` (decorated, lexically wrapped,
          or returned by an executor ``make()`` factory).
  JAG006  no telemetry host work inside jit-traced functions — PR 9's
          observability contract: ``time.*`` timestamps constant-fold at
          trace time (a compiled route would report its tracing wall
          clock forever), and telemetry-object mutations (ring-buffer
          ``append``, histogram ``observe``, counter ``inc``, trace
          ``record*``) are host state that must only be touched AFTER the
          route returns, in the dispatch/search_auto wrappers.

Diagnostics are ``path:line: CODE message``. The config and allowlist live
in ``pyproject.toml`` under ``[tool.jagcheck]``; every allowlist entry
needs a non-empty ``reason`` (the one-line justification the satellite
contract requires) and entries that no longer match any finding are
themselves reported (stale suppressions hide future regressions).

Scanning is purely syntactic and per-file: a rule sees the AST of one
module at a time (no cross-module call-graph), which is exactly the level
the original bugs were visible at.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

RULES = {
    "JAG001": "jax.jit outside the executor/build/launch jit surface",
    "JAG002": "batch-variant einsum candidate dot (use distances.gathered_dot)",
    "JAG003": "module-level lru_cache can pin device buffers process-wide",
    "JAG004": "cache insertion key lacks an epoch component",
    "JAG005": "host sync inside a jit-traced function",
    "JAG006": "telemetry host work inside a jit-traced function",
    # meta-diagnostics about the allowlist itself
    "JAGCFG": "jagcheck configuration problem",
}

_EINSUM_SPEC = "bcd,bd->bc"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # posix path relative to the repo root
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    rule: str
    path: str          # fnmatch glob over the relative posix path
    reason: str


@dataclasses.dataclass
class LintConfig:
    include: Tuple[str, ...] = ("src/repro",)
    # JAG001's allowed jit surfaces (fnmatch globs) — the rule itself, not
    # suppressions: these are the three places PR 2 left jit on purpose.
    jit_allowed: Tuple[str, ...] = (
        "src/repro/serve/executor.py",
        "src/repro/core/build.py",
        "src/repro/launch/*.py",
    )
    allow: Tuple[AllowEntry, ...] = ()


# ---------------------------------------------------------------------------
# config loading (pyproject.toml [tool.jagcheck])
# ---------------------------------------------------------------------------

def _parse_toml(text: str) -> dict:
    """Parse pyproject.toml — stdlib ``tomllib`` on 3.11+, else a minimal
    subset parser (tables, array-of-tables, strings, string arrays) that
    covers everything ``[tool.jagcheck]`` uses. Python 3.10 has no tomllib
    and the container must not grow dependencies."""
    try:
        import tomllib
        return tomllib.loads(text)
    except ModuleNotFoundError:
        pass
    root: dict = {}
    cur = root
    pending: Optional[str] = None  # key of a multiline array being read
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if pending is not None:
            cur[pending] += re.findall(r'"((?:[^"\\]|\\.)*)"', line)
            if line.rstrip(",").endswith("]"):
                pending = None
            continue
        m = re.fullmatch(r"\[\[([A-Za-z0-9_.\-]+)\]\]", line)
        if m:  # array-of-tables
            node = root
            parts = m.group(1).split(".")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            cur = {}
            node.setdefault(parts[-1], []).append(cur)
            continue
        m = re.fullmatch(r"\[([A-Za-z0-9_.\-]+)\]", line)
        if m:  # table
            node = root
            for p in m.group(1).split("."):
                node = node.setdefault(p, {})
            cur = node
            continue
        m = re.match(r'([A-Za-z0-9_\-]+)\s*=\s*(.+)$', line)
        if m:
            key, val = m.group(1), m.group(2).strip()
            if val.startswith("["):
                cur[key] = re.findall(r'"((?:[^"\\]|\\.)*)"', val)
                if not val.rstrip(",").endswith("]"):
                    pending = key  # array continues on following lines
            elif val.startswith('"'):
                mm = re.match(r'"((?:[^"\\]|\\.)*)"', val)
                cur[key] = mm.group(1) if mm else val.strip('"')
            elif val in ("true", "false"):
                cur[key] = val == "true"
            else:
                try:
                    cur[key] = int(val)
                except ValueError:
                    cur[key] = val
    return root


def load_config(root: str) -> Tuple[LintConfig, List[Finding]]:
    """Read ``[tool.jagcheck]`` from ``<root>/pyproject.toml``.

    Returns (config, config-errors): an allowlist entry missing its
    ``reason`` (or ``rule``/``path``) is a JAGCFG finding, not a crash —
    jagcheck must exit non-zero on it, same as on an unjustified finding.
    """
    path = os.path.join(root, "pyproject.toml")
    errors: List[Finding] = []
    if not os.path.exists(path):
        return LintConfig(), errors
    with open(path) as fh:
        data = _parse_toml(fh.read())
    cfg = data.get("tool", {}).get("jagcheck", {})
    allow: List[AllowEntry] = []
    for i, ent in enumerate(cfg.get("allow", [])):
        rule = str(ent.get("rule", "")).strip()
        glob = str(ent.get("path", "")).strip()
        reason = str(ent.get("reason", "")).strip()
        if not (rule in RULES and glob):
            errors.append(Finding(
                "JAGCFG", "pyproject.toml", 1,
                f"allow entry #{i + 1} needs a known rule and a path "
                f"(got rule={rule!r}, path={glob!r})"))
            continue
        if not reason:
            errors.append(Finding(
                "JAGCFG", "pyproject.toml", 1,
                f"allow entry #{i + 1} ({rule} {glob}) has no reason — "
                f"every suppression needs a one-line justification"))
            continue
        allow.append(AllowEntry(rule, glob, reason))
    out = LintConfig(
        include=tuple(cfg.get("include", LintConfig.include)),
        jit_allowed=tuple(cfg.get("jit_allowed", LintConfig.jit_allowed)),
        allow=tuple(allow))
    return out, errors


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute(Name('jax'), 'jit'); '' if not a plain path."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jax_jit(node: ast.AST) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _mentions_epoch(node: ast.AST) -> bool:
    """Does any name/attribute inside the expression contain 'epoch'?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and "epoch" in sub.attr.lower():
            return True
        if isinstance(sub, ast.Name) and "epoch" in sub.id.lower():
            return True
    return False


def _decorator_is_jit(dec: ast.AST) -> bool:
    """@jax.jit, @jit, @partial(jax.jit, ...), @functools.partial(jax.jit)."""
    if _is_jax_jit(dec):
        return True
    if isinstance(dec, ast.Call):
        if _is_jax_jit(dec.func):
            return True
        if _dotted(dec.func) in ("partial", "functools.partial") and \
                dec.args and _is_jax_jit(dec.args[0]):
            return True
    return False


def _decorator_is_lru(dec: ast.AST) -> bool:
    names = ("lru_cache", "functools.lru_cache", "cache", "functools.cache")
    if _dotted(dec) in names:
        return True
    return isinstance(dec, ast.Call) and _dotted(dec.func) in names


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------

def _jag001(tree: ast.AST, path: str, cfg: LintConfig) -> List[Finding]:
    if any(fnmatch.fnmatch(path, g) for g in cfg.jit_allowed):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and _dotted(node) == "jax.jit":
            out.append(Finding(
                "JAG001", path, node.lineno,
                "jax.jit outside serve/executor.py, core/build.py and "
                "launch/ — serving compilations must go through the "
                "Executor's one epoch-keyed cache (PR 2 contract)"))
    return out


def _jag002(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _dotted(node.func).split(".")[-1] == "einsum"
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            continue
        spec = node.args[0].value.replace(" ", "")
        if spec == _EINSUM_SPEC:
            out.append(Finding(
                "JAG002", path, node.lineno,
                f'batch-variant einsum("{_EINSUM_SPEC}") candidate dot — '
                "use distances.gathered_dot: the batched-dot lowering "
                "varies its reduction with batch size, breaking per-query "
                "bit-identity (PR 3 contract)"))
    return out


def _jag003(tree: ast.Module, path: str) -> List[Finding]:
    out = []
    for node in tree.body:  # module level only: that is the bug class
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _decorator_is_lru(dec):
                    out.append(Finding(
                        "JAG003", path, dec.lineno if hasattr(dec, "lineno")
                        else node.lineno,
                        f"module-level lru_cache on {node.name}() can pin "
                        "device buffers process-wide (the PR 3 sample_ids "
                        "bug class) — cache on the owning object"))
        elif isinstance(node, ast.Assign) and isinstance(node.value,
                                                         ast.Call):
            call = node.value
            # x = lru_cache(...)(f)  /  x = lru_cache(f)
            if _decorator_is_lru(call.func) or _decorator_is_lru(call):
                out.append(Finding(
                    "JAG003", path, node.lineno,
                    "module-level lru_cache assignment can pin device "
                    "buffers process-wide (the PR 3 sample_ids bug class) "
                    "— cache on the owning object"))
    return out


def _jag004(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if not (isinstance(tgt, ast.Subscript)
                    and isinstance(tgt.value, ast.Attribute)
                    and tgt.value.attr == "_cache"):
                continue
            if not _mentions_epoch(tgt.slice):
                out.append(Finding(
                    "JAG004", path, node.lineno,
                    "_cache insertion key has no epoch component — an "
                    "epoch-less key serves stale compilations after a "
                    "streaming insert/compaction (PR 4 bug class)"))
    return out


class _JitRoots(ast.NodeVisitor):
    """Collect function nodes whose bodies jax.jit will trace.

    Three repo-idiomatic ways a function reaches the tracer:
      * decorated with ``@jax.jit`` / ``@partial(jax.jit, ...)``;
      * lexically wrapped — ``jax.jit(f)`` where ``f`` is a lambda or the
        name of a function defined in the same module scope;
      * defined inside an executor ``make()`` factory (the
        ``Executor.run(key, make, *args)`` convention jits whatever
        ``make()`` returns).
    """

    def __init__(self):
        self.roots: List[ast.AST] = []
        self._defs: Dict[str, ast.AST] = {}

    def visit_FunctionDef(self, node):
        self._defs[node.name] = node
        if any(_decorator_is_jit(d) for d in node.decorator_list):
            self.roots.append(node)
        if node.name == "make":
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.Lambda)) \
                        and sub is not node:
                    self.roots.append(sub)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        if _is_jax_jit(node.func) and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Lambda):
                self.roots.append(arg)
            elif isinstance(arg, ast.Name) and arg.id in self._defs:
                self.roots.append(self._defs[arg.id])
        self.generic_visit(node)


def _jag005(tree: ast.AST, path: str) -> List[Finding]:
    vis = _JitRoots()
    vis.visit(tree)
    out = []
    seen = set()
    for root in vis.roots:
        for node in ast.walk(root):
            if not isinstance(node, ast.Call) or node.lineno in seen:
                continue
            what = None
            fn = _dotted(node.func)
            if fn in ("np.asarray", "np.array", "numpy.asarray",
                      "numpy.array", "onp.asarray"):
                what = fn
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                what = ".item()"
            elif fn == "float" and node.args \
                    and not isinstance(node.args[0], ast.Constant):
                what = "float()"
            if what:
                seen.add(node.lineno)
                out.append(Finding(
                    "JAG005", path, node.lineno,
                    f"{what} inside a jit-traced function forces a "
                    "device->host sync (or silently constant-folds a "
                    "traced value)"))
    return out


_JAG006_TIMERS = ("time.time", "time.perf_counter", "time.monotonic",
                  "time.time_ns", "time.perf_counter_ns",
                  "time.monotonic_ns", "perf_counter", "monotonic")
_JAG006_MUTATORS = ("append", "observe", "inc", "record", "record_call")


def _jag006_chain(node: ast.AST) -> str:
    """Dotted chain like ``_dotted`` but seeing THROUGH calls.

    ``tel.metrics.counter("x").inc`` -> ``tel.metrics.counter.inc`` —
    registry accessors return the mutated object, so the owner test must
    not stop at the intervening ``Call`` node.
    """
    parts: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            break
        else:
            break
    return ".".join(reversed(parts))


def _jag006_owner_is_telemetry(chain: str) -> bool:
    """True when a dotted owner chain names a telemetry-ish object.

    Segments before the final attribute are checked: ``tel`` exactly, or
    anything containing ``telemetry``/``metric``/``trace`` — matching the
    ``repro.obs`` surface (Telemetry, TraceBuffer, MetricsRegistry) and
    the obvious local-variable spellings. ``trace_log`` is exempt: that
    is the executor's host-side audit hook, which lives in ``run()``
    (never traced) and predates the telemetry subsystem.
    """
    for seg in chain.lower().split(".")[:-1]:
        if seg == "trace_log":
            continue
        if seg == "tel" or "telemetry" in seg or "metric" in seg \
                or "trace" in seg:
            return True
    return False


def _jag006(tree: ast.AST, path: str) -> List[Finding]:
    vis = _JitRoots()
    vis.visit(tree)
    out = []
    seen = set()
    for root in vis.roots:
        for node in ast.walk(root):
            if not isinstance(node, ast.Call) or node.lineno in seen:
                continue
            fn = _dotted(node.func)
            what = None
            if fn in _JAG006_TIMERS:
                what = (f"{fn}() takes a host timestamp — under jit it "
                        "constant-folds at trace time; time in the "
                        "host-side wrapper around the route instead")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _JAG006_MUTATORS \
                    and _jag006_owner_is_telemetry(
                        fn or _jag006_chain(node.func)):
                what = (f"telemetry mutation "
                        f"{fn or _jag006_chain(node.func)}() — ring "
                        "buffers and "
                        "metric registries are host state; record after "
                        "the compiled route returns (repro.obs contract)")
            if what:
                seen.add(node.lineno)
                out.append(Finding("JAG006", path, node.lineno, what))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_source(src: str, path: str,
                cfg: Optional[LintConfig] = None) -> List[Finding]:
    """Lint one module's source text (``path`` is the repo-relative posix
    path the rules and allowlist match against). The unit the fixture
    tests drive via ``ast.parse`` on inline snippets."""
    cfg = cfg or LintConfig()
    tree = ast.parse(src)
    out = []
    out += _jag001(tree, path, cfg)
    out += _jag002(tree, path)
    out += _jag003(tree, path)
    out += _jag004(tree, path)
    out += _jag005(tree, path)
    out += _jag006(tree, path)
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))


@dataclasses.dataclass
class LintReport:
    findings: List[Finding]          # unsuppressed — these fail the build
    suppressed: List[Tuple[Finding, AllowEntry]]
    config_errors: List[Finding]     # bad/stale allowlist entries

    @property
    def ok(self) -> bool:
        return not self.findings and not self.config_errors


def run_lint(root: str, cfg: Optional[LintConfig] = None,
             config_errors: Optional[Sequence[Finding]] = None) -> LintReport:
    """Lint every ``*.py`` under the config's include dirs.

    Findings matched by a justified allowlist entry are suppressed (and
    reported separately); allowlist entries that matched nothing become
    JAGCFG findings — a stale suppression would silently swallow the next
    real regression at that path.
    """
    if cfg is None:
        cfg, errs = load_config(root)
        config_errors = list(errs) + list(config_errors or [])
    findings: List[Finding] = []
    for inc in cfg.include:
        base = os.path.join(root, inc)
        for dirpath, _dirs, files in os.walk(base):
            for name in sorted(files):
                if not name.endswith(".py"):
                    continue
                full = os.path.join(dirpath, name)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                with open(full) as fh:
                    src = fh.read()
                try:
                    findings += lint_source(src, rel, cfg)
                except SyntaxError as e:
                    findings.append(Finding(
                        "JAGCFG", rel, e.lineno or 1,
                        f"unparseable module: {e.msg}"))
    kept: List[Finding] = []
    suppressed: List[Tuple[Finding, AllowEntry]] = []
    used = set()
    for f in findings:
        ent = next((a for a in cfg.allow
                    if a.rule == f.rule and fnmatch.fnmatch(f.path, a.path)),
                   None)
        if ent is not None:
            suppressed.append((f, ent))
            used.add((ent.rule, ent.path))
        else:
            kept.append(f)
    errs = list(config_errors or [])
    for a in cfg.allow:
        if (a.rule, a.path) not in used:
            errs.append(Finding(
                "JAGCFG", "pyproject.toml", 1,
                f"stale allowlist entry: {a.rule} {a.path} matched no "
                f"finding — remove it so it cannot mask a future one"))
    return LintReport(kept, suppressed, errs)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="jagcheck layer 1: repo-specific AST lint")
    ap.add_argument("--root", default=".", help="repo root")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the suppression summary")
    args = ap.parse_args(argv)
    report = run_lint(args.root)
    for f in report.findings + report.config_errors:
        print(f)
    if not args.quiet:
        for f, ent in report.suppressed:
            print(f"# allowed {f.rule} {f.path}:{f.line} — {ent.reason}")
    n = len(report.findings) + len(report.config_errors)
    print(f"# jagcheck lint: {n} finding(s), "
          f"{len(report.suppressed)} allowlisted")
    return 1 if n else 0


if __name__ == "__main__":
    raise SystemExit(main())
