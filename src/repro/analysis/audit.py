"""Layer 2 of `jagcheck`: the compiled-route auditor.

Builds a small index, runs every executor route through the real
``serve.Executor`` (so the audited programs are exactly the ones the jit
cache serves), re-lowers each captured ``(key, make, args)`` to jaxpr,
stablehlo and compiled HLO, and statically asserts the serving stack's
performance contracts:

* **one gather per expansion** on fused-layout graph routes: inside the
  beam-search while-loop, the only N-row data gather is the packed
  ``[vec | norm | attr]`` row fetch (the adjacency fetch is the loop's one
  int32 neighbor-list gather and is counted separately). Default-layout
  routes are measured too (vector + norm + attr = 3) — both numbers land
  in ``AUDIT.json`` so a regression in either direction is diffable.
* **zero host round-trips**: no ``pure_callback``/``io_callback`` in any
  route's jaxpr and no callback custom-calls in the lowered programs.
* **zero f64 ops** anywhere — an accidental float64 promotion doubles
  every distance matrix's bytes.
* **exactly one all-gather per sharded route** (and no other collective):
  the cross-shard merge packs ids/keys/telemetry into one int32
  ``[B, 3k+2]`` array before the collective, so per-route link traffic is
  a single ``B*(3k+2)*4``-byte gather over the shard axis — measured with
  ``launch.hlo_stats`` from the compiled HLO.

The sharded section self-launches a subprocess with 8 faked host devices
(mirroring ``tests/test_sharded.py``) so the auditing process's device
count stays untouched. ``run_audit`` returns the report dict that
``tools/jagcheck.py`` writes as ``AUDIT.json``; ``check_report`` turns it
into a list of violations (empty = all contracts hold).
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

# NOTE: jax and repro.* are imported lazily inside functions so the lint
# layer (pure ast, no jax) stays importable in slim environments.

AUDIT_N, AUDIT_D, AUDIT_B = 256, 8, 4
AUDIT_K, AUDIT_LS, AUDIT_MI = 5, 16, 32
SHARD_DEVICES = 8

GRAPH_VARIANTS = [("default", "f32"), ("default", "int8"),
                  ("fused", "f32"), ("fused", "int8")]
SHARDED_ROUTES = ("prefilter", "graph", "postfilter", "unfiltered")


# ---------------------------------------------------------------------------
# stablehlo / HLO text analysis
# ---------------------------------------------------------------------------

def _match_brace(text: str, open_idx: int) -> int:
    depth = 0
    for p in range(open_idx, len(text)):
        if text[p] == "{":
            depth += 1
        elif text[p] == "}":
            depth -= 1
            if depth == 0:
                return p
    return -1


def _while_do_regions(text: str) -> List[str]:
    """The ``do { ... }`` body of every stablehlo.while, nested included."""
    out = []
    i = 0
    while True:
        j = text.find("stablehlo.while", i)
        if j < 0:
            break
        c = text.find("cond", j)
        b1 = text.find("{", c) if c >= 0 else -1
        e1 = _match_brace(text, b1) if b1 >= 0 else -1
        d = text.find("do", e1) if e1 >= 0 else -1
        b2 = text.find("{", d) if d >= 0 else -1
        e2 = _match_brace(text, b2) if b2 >= 0 else -1
        if e2 >= 0:
            out.append(text[b2:e2 + 1])
        i = j + len("stablehlo.while")
    return out


_RE_GATHER_SIG = re.compile(
    r":\s*\(tensor<([^>]*)>,\s*tensor<([^>]*)>\)\s*->")


def _gather_operands(text: str) -> List[str]:
    """Data-operand type of every stablehlo.gather, e.g. '320x10xf32'.

    Line-based: the op's attribute dict also contains the literal
    ``#stablehlo.gather<...>`` and colons (``array<i64: 1, 10>``), so the
    reliable anchor is the trailing ``: (operand types) -> result`` type
    signature on a line that *defines* a gather.
    """
    out = []
    for line in text.splitlines():
        if "stablehlo.gather\"" not in line and \
                "stablehlo.gather(" not in line:
            continue
        if "=" not in line.split("stablehlo.gather", 1)[0]:
            continue  # not a definition line
        m = _RE_GATHER_SIG.search(line)
        if m:
            out.append(m.group(1))
    return out


def _leading_dim(shape: str) -> int:
    head = shape.split("x", 1)[0]
    return int(head) if head.isdigit() else -1


_RE_FUNC = re.compile(r"func\.func (?:private |public )?@([\w$.\-]+)")
_RE_CALL = re.compile(r"\bcall @([\w$.\-]+)")


def _func_bodies(text: str) -> Dict[str, str]:
    """Body text of every module-level func (jax outlines repeated
    subcomputations — ``call @_take_3`` — so gathers the loop performs
    often live outside the while region's literal text)."""
    out: Dict[str, str] = {}
    for m in _RE_FUNC.finditer(text):
        nl = text.find("\n", m.end())
        sig = text[m.end():nl if nl > 0 else len(text)].rstrip()
        if not sig.endswith("{"):
            continue
        # the body brace is the LAST '{' on the signature line — arg
        # attribute dicts ({mhlo.layout_mode = ...}) open earlier ones
        b = text.rfind("{", m.end(), nl)
        e = _match_brace(text, b)
        if e > 0:
            out[m.group(1)] = text[b:e + 1]
    return out


def _region_gathers(region: str, bodies: Dict[str, str],
                    _stack: frozenset = frozenset()) -> List[str]:
    """Gather data-operand types executed by a region, call sites
    resolved transitively (each call site contributes its callee's
    gathers once per call)."""
    ops = _gather_operands(region)
    for m in _RE_CALL.finditer(region):
        name = m.group(1)
        if name in _stack or name not in bodies:
            continue
        ops += _region_gathers(bodies[name], bodies, _stack | {name})
    return ops


def _expansion_gathers(stable: str, n_rows: int,
                       adj_shape: str) -> Optional[int]:
    """N-row data gathers per iteration of the traversal loop.

    The traversal loop is the innermost while body whose executed gathers
    (calls resolved) include the adjacency fetch (operand ``adj_shape``,
    the int32 neighbor-list gather); its other N-row gathers are the
    per-expansion candidate data fetches — 1 on the packed fused layout,
    vector+norm+attr on the default split layout. None if no loop
    performs the adjacency gather (routes without graph traversal: exact
    scans, merges).
    """
    bodies = _func_bodies(stable)
    best: Optional[Tuple[int, List[str]]] = None
    for r in _while_do_regions(stable):
        ops = _region_gathers(r, bodies)
        if adj_shape in ops and (best is None or len(r) < best[0]):
            best = (len(r), ops)
    if best is None:
        return None
    return sum(1 for o in best[1]
               if _leading_dim(o) == n_rows and o != adj_shape)


def _count_lines(text: str, pattern: str) -> int:
    rx = re.compile(pattern)
    return sum(1 for line in text.splitlines() if rx.search(line))


def analyze_entry(fn, args, *, n_rows: int, adj_shape: str) -> Dict:
    """Lower one captured route to jaxpr/stablehlo/HLO and extract stats."""
    import jax
    from ..launch.hlo_stats import (collective_bytes, collective_counts,
                                    op_histogram)
    jaxpr = str(jax.make_jaxpr(fn)(*args))
    lowered = jax.jit(fn).lower(*args)
    stable = lowered.as_text()
    compiled = lowered.compile().as_text()
    ops = _gather_operands(stable)
    data_ops: Dict[str, int] = {}
    for o in ops:
        if _leading_dim(o) == n_rows:
            data_ops[o] = data_ops.get(o, 0) + 1
    callbacks = (_count_lines(jaxpr, r"\b(pure|io)_callback\b")
                 + _count_lines(stable, r"custom_call.*callback")
                 + _count_lines(compiled, r"custom-call.*callback"))
    f64 = (_count_lines(jaxpr, r"\bfloat64\b")
           + _count_lines(stable, r"xf64>|tensor<f64>")
           + _count_lines(compiled, r"\bf64\["))
    return {
        "gathers_total": len(ops),
        "data_gather_operands": data_ops,
        "adjacency_gathers": data_ops.get(adj_shape, 0),
        "gathers_per_expansion": _expansion_gathers(stable, n_rows,
                                                    adj_shape),
        "collectives": collective_counts(compiled),
        "collective_bytes": collective_bytes(compiled),
        "callbacks": callbacks,
        "f64_ops": f64,
        "n_ops": sum(op_histogram(compiled).values()),
    }


# ---------------------------------------------------------------------------
# route capture (through the real executor cache)
# ---------------------------------------------------------------------------

def _capture(executor, route_name: str, call) -> Tuple:
    """Run ``call()`` with the executor's trace hook armed and return the
    captured (key, make, args) whose route component matches."""
    executor.trace_log = []
    try:
        call()
        for key, make, args in executor.trace_log:
            if key[0] == route_name:
                return key, make, args
    finally:
        executor.trace_log = None
    raise AssertionError(
        f"route {route_name!r} never reached Executor.run — captured "
        f"{[k for k, _, _ in executor.trace_log]}")


def _dataset(n: int = AUDIT_N, d: int = AUDIT_D, b: int = AUDIT_B):
    import numpy as np
    from ..core import filters as F
    rng = np.random.default_rng(0)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    tab = F.range_table(rng.uniform(0, 1, n).astype(np.float32))
    filt = F.range_filters(np.zeros(b, np.float32),
                           np.full(b, 0.3, np.float32))
    q = (xb[rng.integers(0, n, b)]
         + 0.1 * rng.normal(size=(b, d))).astype(np.float32)
    return xb, tab, filt, q


def _build_cfg():
    from ..core.jag import JAGConfig
    return JAGConfig(degree=6, ls_build=8, batch_size=128, cand_pool=16,
                     calib_samples=16, n_seeds=2)


def audit_single_device() -> Dict:
    """Audit every single-device executor route/layout/dtype combination."""
    from ..core.filters import as_filter
    from ..core.jag import JAGIndex
    from ..stream import StreamingJAGIndex

    xb, tab, filt, q = _dataset()
    filt = as_filter(filt)
    index = JAGIndex.build(xb, tab, _build_cfg())
    # audit WITH telemetry attached (and exercised once): the tentpole
    # contract is that tracing is host-side only, so every program
    # captured below must meet the same zero-callback/collective budgets
    from ..obs import Telemetry
    index.attach_telemetry(Telemetry())
    index.search_auto(q, filt, k=AUDIT_K, ls=AUDIT_LS)
    ex = index.executor
    n, rw = int(index.xb.shape[0]), int(index.graph.shape[1])
    adj = f"{n}x{rw}xi32"
    k, ls, mi = AUDIT_K, AUDIT_LS, AUDIT_MI

    routes: Dict[str, Dict] = {}

    def audit(name, route_name, call, executor=ex, n_rows=n,
              adj_shape=adj):
        key, make, args = _capture(executor, route_name, call)
        routes[name] = {"key": [str(c) for c in key],
                        **analyze_entry(make(), args, n_rows=n_rows,
                                        adj_shape=adj_shape)}

    audit("prefilter", "prefilter",
          lambda: ex.prefilter(q, filt, k=k, use_kernel=False))
    for layout, dtype in GRAPH_VARIANTS:
        audit(f"graph:{layout}:{dtype}", "graph",
              lambda layout=layout, dtype=dtype: ex.graph(
                  q, filt, k=k, ls=ls, max_iters=mi,
                  layout=layout, dtype=dtype))
    # the introspective traversal (its own cache-key component) must meet
    # the exact same budgets — its extra outputs are pure device counters,
    # so zero callbacks/collectives and identical gather-per-expansion
    # counts certify that turning introspection on cannot change serving
    for layout, dtype in GRAPH_VARIANTS:
        audit(f"graph:{layout}:{dtype}:introspect", "graph",
              lambda layout=layout, dtype=dtype: ex.graph(
                  q, filt, k=k, ls=ls, max_iters=mi,
                  layout=layout, dtype=dtype, introspect=True))
    audit("postfilter", "postfilter",
          lambda: ex.postfilter(q, filt, k=k, ls=ls, max_iters=mi))
    audit("unfiltered", "unfiltered",
          lambda: ex.unfiltered(q, k=k, ls=ls, max_iters=mi))

    # streaming delta + merge over a live delta segment
    import numpy as np
    from ..core import filters as F
    rng = np.random.default_rng(1)
    stream = StreamingJAGIndex.build(xb, tab, _build_cfg())
    stream.attach_telemetry(Telemetry())
    n_new = 32
    stream.insert(rng.normal(size=(n_new, AUDIT_D)).astype(np.float32),
                  F.range_table(rng.uniform(0, 1, n_new).astype(np.float32)))
    stream.search_auto(q, filt, k=AUDIT_K, ls=AUDIT_LS)
    sex = stream.executor
    base = sex.prefilter(q, filt, k=k, use_kernel=False)
    delta = sex.delta(q, filt, k=k, use_kernel=False)
    audit("delta", "delta",
          lambda: sex.delta(q, filt, k=k, use_kernel=False),
          executor=sex, n_rows=n_new)
    audit("merge", "merge", lambda: sex.merge(base, delta, k=k),
          executor=sex)
    return {
        "meta": {"n": n, "d": AUDIT_D, "b": AUDIT_B, "k": k, "ls": ls,
                 "max_iters": mi, "graph_width": rw, "delta_n": n_new,
                 "telemetry": True,
                 "packed_row_width": int(
                     index.fused_layout("f32").packed.shape[1])},
        "routes": routes,
    }


def audit_sharded_routes() -> Dict:
    """Audit the shard_map routes — call only in a process that already
    sees ``SHARD_DEVICES`` devices (the parent uses ``run_sharded_audit``
    to fake them in a subprocess)."""
    import jax
    from ..core.filters import as_filter
    from ..serve.sharded import ShardedJAGIndex

    assert len(jax.devices()) >= SHARD_DEVICES, jax.devices()
    xb, tab, filt, q = _dataset(n=SHARD_DEVICES * 40)
    filt = as_filter(filt)
    sh = ShardedJAGIndex.build(xb, tab, _build_cfg(),
                               n_shards=SHARD_DEVICES)
    # same telemetry-attached contract as the single-device audit: the
    # shard_map routes must keep their one-all-gather budget with tracing on
    from ..obs import Telemetry
    sh.attach_telemetry(Telemetry())
    sh.search_auto(q, filt, k=AUDIT_K, ls=AUDIT_LS)
    ex = sh.executor
    n_loc, rw = sh.n_loc, int(sh.graph.shape[2])
    adj = f"{n_loc}x{rw}xi32"
    k, ls, mi = AUDIT_K, AUDIT_LS, AUDIT_MI
    routes: Dict[str, Dict] = {}

    def audit(name, call):
        key, make, args = _capture(ex, name, call)
        routes[name] = {"key": [str(c) for c in key],
                        **analyze_entry(make(), args, n_rows=n_loc,
                                        adj_shape=adj)}

    audit("prefilter", lambda: ex.prefilter(q, filt, k=k,
                                            use_kernel=False))
    audit("graph", lambda: ex.graph(q, filt, k=k, ls=ls, max_iters=mi))
    audit("postfilter", lambda: ex.postfilter(q, filt, k=k, ls=ls,
                                              max_iters=mi))
    audit("unfiltered", lambda: ex.unfiltered(q, k=k, ls=ls, max_iters=mi))
    return {
        "meta": {"devices": SHARD_DEVICES, "n_loc": n_loc, "b": AUDIT_B,
                 "k": k, "ls": ls, "telemetry": True,
                 "merge_payload_bytes": AUDIT_B * (3 * k + 2) * 4},
        "routes": routes,
    }


def audit_stamp() -> Dict:
    """Compact per-route static facts for stamping into bench artifacts
    (``benchmarks/run.py --audit`` / ``cost_bench.py --audit``) — perf
    numbers and the gather/collective counts they were measured under
    travel in one JSON."""
    return {name: {"gathers": r["gathers_total"],
                   "gathers_per_expansion": r["gathers_per_expansion"],
                   "collectives": r["collectives"]}
            for name, r in audit_single_device()["routes"].items()}


def run_sharded_audit(root: str = ".") -> Dict:
    """Run :func:`audit_sharded_routes` in a subprocess with faked host
    devices (XLA_FLAGS must be set before the first jax import, so the
    auditing process cannot fake them for itself)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{SHARD_DEVICES}",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   p for p in (os.path.join(root, "src"),
                               os.environ.get("PYTHONPATH")) if p))
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.audit", "--sharded-child"],
        cwd=root, capture_output=True, text=True, env=env, timeout=1200)
    marker = "JAGCHECK_SHARDED_JSON:"
    for line in r.stdout.splitlines():
        if line.startswith(marker):
            return json.loads(line[len(marker):])
    raise RuntimeError(
        f"sharded audit subprocess failed (rc={r.returncode}):\n"
        f"{r.stdout[-2000:]}\n{r.stderr[-2000:]}")


def run_audit(root: str = ".", sharded: bool = True) -> Dict:
    """The full audit: single-device routes in-process, sharded routes in
    a faked-device subprocess. Returns the ``AUDIT.json`` payload."""
    import jax
    report = {"backend": jax.default_backend(), **audit_single_device()}
    if sharded:
        report["sharded"] = run_sharded_audit(root)
    report["violations"] = check_report(report)
    return report


# ---------------------------------------------------------------------------
# the win conditions
# ---------------------------------------------------------------------------

def check_report(report: Dict) -> List[str]:
    """Every contract the audit enforces, as human-readable violations."""
    out: List[str] = []
    for name, r in report.get("routes", {}).items():
        if r["callbacks"]:
            out.append(f"{name}: {r['callbacks']} host callback op(s)")
        if r["f64_ops"]:
            out.append(f"{name}: {r['f64_ops']} f64 op(s)")
        if r["collectives"]:
            out.append(f"{name}: single-device route contains "
                       f"collectives {r['collectives']}")
        gpe = r.get("gathers_per_expansion")
        if name.startswith("graph:fused") and gpe != 1:
            out.append(f"{name}: {gpe} gathers per expansion "
                       f"(fused contract is exactly 1)")
        if name.startswith("graph:default") and (gpe is None or gpe < 2):
            out.append(f"{name}: expansion gather count {gpe} — the "
                       f"split layout fetches >=2 operands, so the "
                       f"loop parser is miscounting")
    sh = report.get("sharded")
    if sh:
        for name, r in sh.get("routes", {}).items():
            if r["callbacks"]:
                out.append(f"sharded/{name}: {r['callbacks']} callback(s)")
            if r["f64_ops"]:
                out.append(f"sharded/{name}: {r['f64_ops']} f64 op(s)")
            if r["collectives"] != {"all-gather": 1}:
                out.append(
                    f"sharded/{name}: collectives {r['collectives']} — "
                    f"the cross-shard merge must be exactly one "
                    f"all-gather over the shard axis")
    return out


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="jagcheck layer 2: compiled-route auditor")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the audit report (AUDIT.json)")
    ap.add_argument("--root", default=".")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the faked-device sharded section")
    ap.add_argument("--sharded-child", action="store_true",
                    help=argparse.SUPPRESS)  # internal subprocess mode
    args = ap.parse_args(argv)
    if args.sharded_child:
        print("JAGCHECK_SHARDED_JSON:"
              + json.dumps(audit_sharded_routes()), flush=True)
        return 0
    report = run_audit(args.root, sharded=not args.no_sharded)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1)
    for name, r in report["routes"].items():
        print(f"audit,{name},gathers={r['gathers_total']},"
              f"gpe={r['gathers_per_expansion']},"
              f"collectives={sum(r['collectives'].values())}")
    for name, r in report.get("sharded", {}).get("routes", {}).items():
        print(f"audit,sharded/{name},gathers={r['gathers_total']},"
              f"collectives={r['collectives']}")
    for v in report["violations"]:
        print(f"VIOLATION: {v}")
    print(f"# jagcheck audit: {len(report['violations'])} violation(s)")
    return 1 if report["violations"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
