"""Logical-axis sharding: rules map logical axis names -> mesh axes.

MaxText-style indirection: models annotate params/activations with logical
names ("embed", "mlp", "experts", "batch", ...); a rule set binds those to
physical mesh axes per run. Resolution is divisibility-aware: if a tensor
dim is not divisible by the bound mesh-axis product, the binding falls back
to replication for that dim (this is how 40-head attention stays unsharded
on a 16-way model axis while 16-head archs shard — DESIGN.md §4).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    table: Dict[str, MeshAxes]

    def axis_size(self, binding: MeshAxes) -> int:
        if binding is None:
            return 1
        names = (binding,) if isinstance(binding, str) else binding
        size = 1
        for n in names:
            size *= self.mesh.shape[n]
        return size


def make_rules(mesh: Mesh, overrides: Optional[Dict[str, MeshAxes]] = None
               ) -> Rules:
    """Default binding for the production meshes (DESIGN.md §4)."""
    axes = set(mesh.axis_names)
    dp: MeshAxes = tuple(a for a in ("pod", "data") if a in axes) or None
    tp: MeshAxes = "model" if "model" in axes else None
    fsdp = dp
    table: Dict[str, MeshAxes] = {
        # activations ("seq" -> model = sequence parallelism; decode's T=1
        # falls back to replicated via the divisibility guard)
        "batch": dp, "seq": tp, "act_embed": None,
        "cache_batch": dp if dp else None, "cache_seq": tp,
        "queries": dp, "db_shard": "data" if "data" in axes else None,
        # LM weights: fsdp on embed dim, tensor on mlp/heads/vocab/experts
        "embed": fsdp, "mlp": tp, "vocab": tp,
        "heads": tp, "kv_heads": tp, "head_dim": None,
        "experts": tp, "expert_cap": fsdp, "expert_mlp": None,
        "layers": None, "norm": None,
        # gnn / recsys
        "nodes": dp, "edges": dp, "feat": None,
        "table_rows": (tuple(a for a in ("data", "model") if a in axes)
                       or None),
        "table_dim": None, "fields": None, "mlp_in": fsdp,
        "mlp_hidden": tp, "candidates": tp,
    }
    if overrides:
        table.update(overrides)
    return Rules(mesh, table)


_local = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = current_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def resolve_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                 rules: Rules) -> P:
    """Logical axes tuple -> PartitionSpec.

    Safety valves: a binding is dropped (replicated) if the dim is not
    divisible by the bound mesh-axis product, or if any of its mesh axes
    was already consumed by an earlier dim of the same tensor.
    """
    parts = []
    used: set = set()
    for dim, name in zip(shape, axes):
        binding = rules.table.get(name) if name else None
        if binding is not None:
            names = (binding,) if isinstance(binding, str) else tuple(binding)
            free = tuple(n for n in names if n not in used)
            binding = (free[0] if len(free) == 1 else free) if free else None
        if binding is not None and dim % rules.axis_size(binding) != 0:
            binding = None  # fall back to replication for this dim
        if binding is not None:
            used.update((binding,) if isinstance(binding, str) else binding)
        parts.append(binding)
    return P(*parts)


def tree_shardings(spec_tree, shape_tree, rules: Rules):
    """Parallel trees of logical-axes tuples + shapes -> NamedShardings."""
    def one(axes, arr):
        shape = arr.shape if hasattr(arr, "shape") else arr
        return NamedSharding(rules.mesh, resolve_spec(axes, shape, rules))
    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def serve_mesh(n_shards: int) -> Mesh:
    """A ("data",)-axis mesh over the first ``n_shards`` local devices.

    The sharded serving subsystem's mesh shape: row-wise database sharding
    binds to the "data" axis (the ``db_shard`` rule below), queries stay
    replicated. Raises when the host exposes fewer devices — fake more
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    devs = jax.devices()
    if n_shards > len(devs):
        raise ValueError(
            f"n_shards={n_shards} > {len(devs)} visible devices — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards} "
            f"(or lower n_shards)")
    return Mesh(np.asarray(devs[:n_shards]), ("data",))


def put_db_sharded(tree, rules: Rules):
    """Place stacked per-shard arrays ([S, ...] leaves) on the mesh with
    the leading dim bound to the ``db_shard`` rule (-> the "data" axis).

    One ``jax.device_put`` per leaf; trailing dims stay replicated. The
    divisibility guard in :func:`resolve_spec` applies — a leading dim not
    divisible by the data-axis size falls back to replication rather than
    erroring, matching every other rule-resolved placement.
    """
    def one(x):
        spec = resolve_spec(("db_shard",) + (None,) * (x.ndim - 1),
                            x.shape, rules)
        return jax.device_put(x, NamedSharding(rules.mesh, spec))
    return jax.tree.map(one, tree)


def logical_constraint(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint via the ambient rule set (no-op if unset)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = resolve_spec(axes, x.shape, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
