"""Distribution substrate: logical-axis sharding rules, collectives helpers,
fault tolerance."""
from .sharding import (Rules, make_rules, resolve_spec, tree_shardings,
                       logical_constraint, use_rules, current_rules)
