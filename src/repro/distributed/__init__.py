"""Distribution substrate: logical-axis sharding rules, collectives helpers,
fault tolerance."""
from .sharding import (Rules, current_rules, logical_constraint, make_rules,
                       put_db_sharded, resolve_spec, serve_mesh,
                       tree_shardings, use_rules)
