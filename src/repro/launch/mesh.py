"""Production mesh builders. TPU v5e pod = 16x16 = 256 chips; multi-pod adds
a leading "pod" axis (2 pods = 512 chips for the dry-run).

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh``, feature-gated.

    ``jax.sharding.AxisType`` only exists on newer jax; older releases (e.g.
    the 0.4.x on this container) default every axis to Auto anyway, so
    omitting the kwarg there is behaviour-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def set_mesh(mesh):
    """Context manager entering ``mesh``, across jax versions.

    Newer jax has ``jax.set_mesh``; on 0.4.x the ``Mesh`` object itself is
    the context manager that scopes named-axis resolution.
    """
    fn = getattr(jax, "set_mesh", None)
    return fn(mesh) if fn is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_local_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **mesh_kwargs(2))


HW = dict(  # TPU v5e per-chip constants used by the roofline
    peak_flops=197e12,      # bf16
    hbm_bw=819e9,           # bytes/s
    link_bw=50e9,           # bytes/s per ICI link
    hbm_bytes=16 * 2 ** 30,
)
