"""Production mesh builders. TPU v5e pod = 16x16 = 256 chips; multi-pod adds
a leading "pod" axis (2 pods = 512 chips for the dry-run).

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


HW = dict(  # TPU v5e per-chip constants used by the roofline
    peak_flops=197e12,      # bf16
    hbm_bw=819e9,           # bytes/s
    link_bw=50e9,           # bytes/s per ICI link
    hbm_bytes=16 * 2 ** 30,
)
