"""Fault-tolerant training driver: ``--arch <id>`` + reduced/full configs.

Features exercised at laptop scale and lowered at production scale:
  * auto-resume from the latest committed checkpoint (crash = rerun cmd)
  * deterministic per-(seed, step) data order (restart-identical batches)
  * straggler monitoring: per-step wall time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with their step index (on real
    fleets this feeds the scheduler's hot-spare swap; here it is the hook)
  * periodic eval + metrics JSONL for the benchmark harness.

Example (trains a ~100M-param qwen3-shaped model on CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --scale tiny --steps 50 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get
from ..data.pipelines import lm_batch
from ..train import OptConfig, init_state, make_train_step


def tiny_lm(cfg):
    """~100M-param variant of an assigned LM arch (examples/train_lm)."""
    return dataclasses.replace(
        cfg, n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=0,
        d_ff=1536, vocab=8192, n_experts=min(cfg.n_experts, 4),
        attn_chunk=0, kv_block=256)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "reduced"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--schedule", default="cosine")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--straggler-factor", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="simulate a crash (fault-tolerance test)")
    args = ap.parse_args(argv)

    spec = get(args.arch)
    assert spec.family == "lm", "train driver: LM archs (GNN/recsys use " \
                                "their example scripts)"
    cfg = tiny_lm(spec.make_config()) if args.scale == "tiny" \
        else spec.make_reduced()
    # minicpm trains with WSD per its paper
    sched = "wsd" if args.arch == "minicpm-2b" else args.schedule
    opt_cfg = OptConfig(lr=args.lr, schedule=sched, warmup_steps=10,
                        total_steps=args.steps)

    from ..models import transformer as T
    params, _ = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = init_state(params)
    step_fn = jax.jit(make_train_step(
        lambda p, b: T.loss_fn(cfg, p, b), opt_cfg),
        donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
    start = 0
    got = ckpt.restore_latest({"params": params, "opt": opt})
    if got[0] is not None:
        start, tree, meta = got
        params, opt = tree["params"], tree["opt"]
        print(f"[train] resumed from step {start}")

    ewma = None
    mfile = open(args.metrics_out, "a") if args.metrics_out else None
    for step in range(start, args.steps):
        if step == args.fail_at_step:
            print(f"[train] simulating crash at step {step}")
            os._exit(42)
        batch = {k: jnp.asarray(v) for k, v in lm_batch(
            step, args.batch, args.seq, cfg.vocab, args.seed).items()}
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > args.straggler_factor * ewma:
            print(f"[straggler] step {step}: {dt:.3f}s vs EWMA {ewma:.3f}s")
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if mfile:
            mfile.write(json.dumps(
                {"step": step, "loss": float(metrics["loss"]),
                 "dt": dt}) + "\n")
        ckpt.maybe_save(step + 1, {"params": params, "opt": opt},
                        meta={"arch": args.arch})
    ckpt.maybe_save(args.steps, {"params": params, "opt": opt},
                    meta={"arch": args.arch}, force=True)
    print("[train] done; final loss",
          float(metrics["loss"]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
