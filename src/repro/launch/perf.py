import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""§Perf hillclimb harness: lower named variants of the three chosen cells
and report the roofline-term deltas vs baseline.

  PYTHONPATH=src python -m repro.launch.perf --cell jag_serve
  PYTHONPATH=src python -m repro.launch.perf --cell qwen3_train
  PYTHONPATH=src python -m repro.launch.perf --cell maverick_train

Each variant is a hypothesis -> change pair documented in EXPERIMENTS.md
§Perf; this harness produces the before/after measurements.
"""
import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import make_cell
from ..configs.shapes import JAG_SHAPES
from ..distributed.sharding import make_rules
from .dryrun import _compile
from .mesh import make_production_mesh
from . import roofline as RL


def _report(tag, mesh, cell, model_flops=None, flops_scale=None,
            analytic=None):
    compiled = _compile(cell, mesh)
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    r = RL.analyze(tag, "-", "single", n_chips, compiled,
                   model_flops if model_flops is not None
                   else cell["model_flops"],
                   flops_scale=(flops_scale if flops_scale is not None
                                else cell.get("flops_scale", 1.0)),
                   analytic_only=(analytic if analytic is not None
                                  else cell.get("analytic_only", False)))
    print(RL.format_row(r), flush=True)
    return r


# ---------------------------------------------------------------------------
# JAG serve_1b variants
# ---------------------------------------------------------------------------

def jag_serve_variants(out):
    from ..core.distributed import ShardedServeConfig, make_serve_step
    mesh = make_production_mesh()
    rules = make_rules(mesh)
    shp = JAG_SHAPES["serve_1b"]
    S = 256
    n_loc, d, W, Bq = shp["n_local"], shp["d"], shp["row_width"], shp["batch"]
    scfg = ShardedServeConfig(k=shp["k"], ls=shp["ls"],
                              max_iters=shp["max_iters"],
                              query_chunk=shp["query_chunk"])
    nch = Bq // shp["query_chunk"]
    scale_f = nch * shp["max_iters"]
    sds = jax.ShapeDtypeStruct
    shard = NamedSharding(mesh, P(("data", "model")))
    rep = NamedSharding(mesh, P())

    def args_for(vdtype, with_scale):
        a = [sds((S, n_loc, W), jnp.int32),
             sds((S, n_loc, d), vdtype),
             sds((S, n_loc), jnp.float32),
             {"value": sds((S, n_loc), jnp.float32)},
             sds((S, shp["n_seeds"]), jnp.int32),
             sds((Bq, d), jnp.bfloat16),
             {"lo": sds((Bq,), jnp.float32), "hi": sds((Bq,), jnp.float32)}]
        sh = [shard, shard, shard, {"value": shard}, shard, rep,
              {"lo": rep, "hi": rep}]
        if with_scale:
            a.append(sds((d,), jnp.float32))
            sh.append(rep)
        return tuple(a), tuple(sh)

    mf = Bq * S * shp["max_iters"] * W * d * 2
    variants = [
        ("baseline(bf16,bitmap)", "f32", "bitmap", jnp.bfloat16, False, W),
        ("v1:int8", "int8", "bitmap", jnp.int8, True, W),
        ("v2:int8+scan-dedup", "int8", "scan", jnp.int8, True, W),
        ("v3:int8+scan+reg-norm", "int8_reg", "scan", jnp.int8, True, W),
        # v4: serve-time adjacency truncated to R=64 (the EX spare build
        # columns are all -1 after finalize, so this is semantics-free)
        ("v4:int8+scan+W64", "int8", "scan", jnp.int8, True, 64),
    ]
    for tag, variant, dedup, dt, wsc, Wv in variants:
        def args_w(vdtype, with_scale, Wv=Wv):
            a = [sds((S, n_loc, Wv), jnp.int32),
                 sds((S, n_loc, d), vdtype),
                 sds((S, n_loc), jnp.float32),
                 {"value": sds((S, n_loc), jnp.float32)},
                 sds((S, shp["n_seeds"]), jnp.int32),
                 sds((Bq, d), jnp.bfloat16),
                 {"lo": sds((Bq,), jnp.float32),
                  "hi": sds((Bq,), jnp.float32)}]
            sh = [shard, shard, shard, {"value": shard}, shard, rep,
                  {"lo": rep, "hi": rep}]
            if with_scale:
                a.append(sds((d,), jnp.float32))
                sh.append(rep)
            return tuple(a), tuple(sh)

        fn = make_serve_step(mesh, scfg, "range", "range",
                             variant=variant, dedup=dedup)
        args, sh = args_w(dt, wsc)
        cell = dict(fn=fn, args=args, in_shardings=sh, out_shardings=None,
                    donate_argnums=(), rules=rules, model_flops=mf,
                    flops_scale=scale_f)
        out.append(_report(f"jag_serve/{tag}", mesh, cell))


# ---------------------------------------------------------------------------
# LM train variants
# ---------------------------------------------------------------------------

def lm_train_variants(arch, out):
    mesh = make_production_mesh()

    def with_cfg(**kw):
        from ..configs import get
        spec = get(arch)
        orig = spec.make_config
        cell = [None]

        def patched(shape=None):
            return dataclasses.replace(orig(shape), **kw)
        object.__setattr__(spec, "make_config", patched)
        try:
            cell[0] = make_cell(arch, "train_4k", mesh, lowering="unroll")
        finally:
            object.__setattr__(spec, "make_config", orig)
        return cell[0]

    out.append(_report(f"{arch}/v2:attn_scores_bf16", mesh,
                       with_cfg(attn_scores_bf16=True)))
    out.append(_report(f"{arch}/v3:+remat_dots", mesh,
                       with_cfg(attn_scores_bf16=True,
                                remat_policy="dots")))


def din_train_variants(out):
    """Cell 3: the most collective-bound baseline cell (embedding gathers
    over the row-sharded table dominate)."""
    from ..configs import get
    mesh = make_production_mesh()
    arch = "din"

    def cell_with(table_dtype=None, overrides=None):
        spec = get(arch)
        orig = spec.make_config
        if table_dtype is not None:
            def patched(shape=None):
                return dataclasses.replace(orig(shape),
                                           table_dtype=table_dtype)
            object.__setattr__(spec, "make_config", patched)
        try:
            return make_cell(arch, "train_batch", mesh,
                             rule_overrides=overrides)
        finally:
            object.__setattr__(spec, "make_config", orig)

    out.append(_report("din/baseline(f32,rows@data*model)", mesh,
                       cell_with()))
    out.append(_report("din/v1:bf16_table", mesh,
                       cell_with(table_dtype=jnp.bfloat16)))
    out.append(_report("din/v2:rows@model-only", mesh,
                       cell_with(overrides={"table_rows": "model"})))
    out.append(_report("din/v3:bf16+rows@model", mesh,
                       cell_with(table_dtype=jnp.bfloat16,
                                 overrides={"table_rows": "model"})))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="jag_serve",
                    choices=["jag_serve", "qwen3_train", "maverick_train",
                             "din_train"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = []
    if args.cell == "jag_serve":
        jag_serve_variants(rows)
    elif args.cell == "qwen3_train":
        lm_train_variants("qwen3-1.7b", rows)
    elif args.cell == "din_train":
        din_train_variants(rows)
    else:
        lm_train_variants("llama4-maverick-400b-a17b", rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump([r.to_dict() for r in rows], f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
