import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first import side effect: the XLA_FLAGS line above runs before
any jax import so make_mesh can build the 512-device production meshes on
this CPU-only host (dry-run only — tests/benches see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k --mesh multi                             # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json

Per cell: jit(step).lower(*abstract).compile() on the (16,16) single-pod
mesh AND the (2,16,16) multi-pod mesh; prints memory_analysis() (proves it
fits 16 GiB/chip) and cost_analysis(); records the roofline terms
(launch/roofline.py) into EXPERIMENTS.md's tables via --out JSON.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from ..configs import all_archs, make_cell
from ..distributed.sharding import use_rules
from .mesh import HW, make_production_mesh, set_mesh
from . import roofline as RL


def _compile(cell, mesh):
    with set_mesh(mesh), use_rules(cell["rules"]):
        jitted = jax.jit(cell["fn"],
                         in_shardings=cell["in_shardings"],
                         out_shardings=cell["out_shardings"],
                         donate_argnums=cell["donate_argnums"])
        return jitted.lower(*cell["args"]).compile()


def run_cell(arch: str, shape: str, mesh_name: str, verbose: bool = True):
    from ..configs import get as get_arch
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    t0 = time.time()
    cell = make_cell(arch, shape, mesh, lowering="unroll")
    compiled = _compile(cell, mesh)
    txt = compiled.as_text()
    r = RL.analyze(arch, shape, mesh_name, n_chips, compiled,
                   cell["model_flops"], hlo_text=txt,
                   flops_scale=cell.get("flops_scale", 1.0),
                   analytic_only=cell.get("analytic_only", False))
    # memory proof from the production (scan/remat) lowering for the cells
    # whose activation accounting depends on it (LM train/prefill)
    spec = get_arch(arch)
    mem_compiled = compiled
    if (spec.family == "lm"
            and spec.shapes[shape]["kind"] in ("train", "prefill")):
        mem_compiled = _compile(
            make_cell(arch, shape, mesh, lowering="scan"), mesh)
    dt = time.time() - t0
    ma = None
    try:
        ma = mem_compiled.memory_analysis()
        r.mem_per_device = float(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    except Exception:
        pass
    if verbose:
        print(f"[{arch} x {shape} x {mesh_name}] compiled in {dt:.1f}s  "
              f"params={cell['n_params'] / 1e9:.2f}B")
        if ma is not None:
            print(f"  memory_analysis: args="
                  f"{ma.argument_size_in_bytes / 2**30:.2f}GiB "
                  f"out={ma.output_size_in_bytes / 2**30:.2f}GiB "
                  f"alias={ma.alias_size_in_bytes / 2**30:.2f}GiB "
                  f"temp={ma.temp_size_in_bytes / 2**30:.2f}GiB "
                  f"(HBM/chip = {HW['hbm_bytes'] / 2**30:.0f}GiB)")
        ca = compiled.cost_analysis() or {}
        print(f"  cost_analysis: flops/chip={ca.get('flops', 0):.3e} "
              f"bytes/chip={ca.get('bytes accessed', 0):.3e}")
        print("  " + RL.format_row(r))
        fit = (r.mem_per_device or 0) <= HW["hbm_bytes"]
        print(f"  fits-HBM: {fit}")
    d = r.to_dict()
    d["compile_s"] = dt
    d["n_params"] = cell["n_params"]
    return d


def default_cells():
    cells = []
    for aid, spec in sorted(all_archs().items()):
        for shape in spec.shapes:
            cells.append((aid, shape))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-jag", action="store_true")
    args = ap.parse_args(argv)

    cells = default_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if args.skip_jag:
        cells = [c for c in cells if c[0] != "jag"]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    results, failures = [], []
    for aid, shape in cells:
        for mesh_name in meshes:
            try:
                results.append(run_cell(aid, shape, mesh_name))
            except Exception as e:
                traceback.print_exc()
                failures.append(
                    {"arch": aid, "shape": shape, "mesh": mesh_name,
                     "error": f"{type(e).__name__}: {e}"})
            if args.out:
                with open(args.out, "w") as f:
                    json.dump({"results": results, "failures": failures},
                              f, indent=1)
    print(f"\n=== dry-run complete: {len(results)} ok, "
          f"{len(failures)} failed ===")
    for f_ in failures:
        print("  FAILED:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
