"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

    t_comp = HLO_FLOPs / peak_FLOP/s        (per chip; cost_analysis is the
    t_mem  = HLO_bytes / HBM_bw              per-device SPMD program)
    t_coll = collective_bytes / link_bw

plus MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE, or the family analogue)
and the usefulness ratio MODEL_FLOPS / (chips · HLO_FLOPs).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from .hlo_stats import collective_bytes
from .mesh import HW


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int]
    t_comp: float
    t_mem: float
    t_coll: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    mem_per_device: Optional[float]
    n_chips: int

    def to_dict(self):
        return dataclasses.asdict(self)

    @property
    def roofline_fraction(self) -> float:
        """max(useful work term) / max(all terms): how close the dominant
        term is to being pure useful compute."""
        t_useful = (self.model_flops / self.n_chips) / HW["peak_flops"]
        return t_useful / max(self.t_comp, self.t_mem, self.t_coll, 1e-30)


def analyze(arch: str, shape: str, mesh_name: str, n_chips: int,
            compiled, model_flops: float,
            hlo_text: Optional[str] = None, flops_scale: float = 1.0,
            analytic_only: bool = False) -> Roofline:
    """``flops_scale``: multiplicative loop-trip correction for programs
    whose dominant work sits in a dynamic while loop (HloCostAnalysis
    counts bodies once). ``analytic_only``: compute term from model_flops
    (mixed-loop programs; memory/collective still measured)."""
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0)) * flops_scale
    byts = float(ca.get("bytes accessed", 0.0)) * flops_scale
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(txt)
    cb = float(coll.get("total", 0))
    if analytic_only:
        flops = max(flops, model_flops / n_chips)
    t_comp = flops / HW["peak_flops"]
    t_mem = byts / HW["hbm_bw"]
    t_coll = cb / HW["link_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bott = max(terms, key=terms.get)
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                        - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    except Exception:
        pass
    useful = model_flops / max(n_chips * flops, 1e-30)
    return Roofline(arch, shape, mesh_name, flops, byts, cb, coll,
                    t_comp, t_mem, t_coll, bott, model_flops, useful, mem,
                    n_chips)


def format_row(r: Roofline) -> str:
    frac = r.roofline_fraction
    mem = f"{r.mem_per_device / 2**30:.2f}GiB" if r.mem_per_device else "n/a"
    return (f"{r.arch:28s} {r.shape:14s} {r.mesh:9s} "
            f"comp={r.t_comp * 1e3:9.3f}ms mem={r.t_mem * 1e3:9.3f}ms "
            f"coll={r.t_coll * 1e3:9.3f}ms -> {r.bottleneck:10s} "
            f"useful={r.useful_ratio:6.3f} roofline={frac:6.3f} "
            f"mem/dev={mem}")


def save_all(rows, path: str):
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=1)


def load_all(path: str):
    with open(path) as f:
        return [Roofline(**d) for d in json.load(f)]
