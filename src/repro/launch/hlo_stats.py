"""Parse compiled/lowered HLO text for per-device collective bytes.

cost_analysis() has no collective accounting, so we regex the HLO for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops and sum their operand sizes (the per-device program implies per-chip
bytes). Shapes are parsed from the result type, e.g. ``bf16[8,128]{1,0}``;
for all-gather the *operand* (pre-gather) size is what crosses the link per
step of the ring, so we conservatively report result bytes for gather-type
ops and operand bytes otherwise — both are recorded.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")

# e.g.  %all-reduce.5 = f32[128,256]{1,0} all-reduce(...)
#       %cp = (f32[8,8]{1,0}, f32[8,8]{1,0}) collective-permute(...)
_RE_KIND = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of collective result bytes per op kind (per-device program)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _RE_KIND.search(line)
        if not m:
            continue
        kind, suffix = m.groups()
        if suffix == "-done":
            continue  # counted at -start
        head = line[:m.start()]
        if "=" not in head:
            continue  # an operand reference, not a definition
        head = head.split("=", 1)[1]  # result type(s) only
        size = sum(_bytes_of(d, s) for d, s in _RE_SHAPE.findall(head))
        out[kind] += size
        out["total"] += size
    return dict(out)


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Number of collective ops per kind (per-device program).

    Same definition-line discipline as :func:`collective_bytes` (operand
    references and ``-done`` halves are not ops), but counting instances
    instead of bytes — the analysis auditor asserts exact collective
    budgets per route (e.g. "exactly one all-gather"), which byte sums
    can't express.
    """
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _RE_KIND.search(line)
        if not m:
            continue
        kind, suffix = m.groups()
        if suffix == "-done":
            continue
        if "=" not in line[:m.start()]:
            continue
        out[kind] += 1
    return dict(out)


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Rough opcode histogram (fusion-level) for redundancy eyeballing."""
    out: Dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s*\S+\s+([a-z][a-z0-9-]*)\(", hlo_text):
        out[m.group(1)] += 1
    return dict(out)
