"""JAG production config (the paper's own system): billion-scale
shard-and-merge filtered search over the production mesh. 256 shards x
2^22 points x d=128 bf16, R=64 (+16 spare), range filters."""
from ..core.jag import JAGConfig
from .registry import ArchSpec

CONFIG = JAGConfig(degree=64, ls_build=96, alpha=1.2,
                   threshold_quantiles=(1.0, 0.01, 0.0),
                   batch_size=128, cand_pool=192)

REDUCED = JAGConfig(degree=12, ls_build=24, batch_size=128, cand_pool=64)

SPEC = ArchSpec(id="jag", family="jag",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="the paper's index at production scale")
