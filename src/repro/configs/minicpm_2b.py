"""minicpm-2b [arXiv:2404.06395; hf:openbmb/MiniCPM-2B].

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753. MiniCPM mu-P
scaling: emb_scale=12, residual scale 1.4/sqrt(40); trained with the WSD
schedule (train.optimizer schedule="wsd").
"""
import dataclasses
import math
from ..models.transformer import LMConfig
from .registry import ArchSpec

CONFIG = LMConfig(
    name="minicpm-2b", n_layers=40, d_model=2304, n_heads=36,
    n_kv_heads=36, d_ff=5760, vocab=122753, act="silu",
    emb_scale=12.0, resid_scale=1.4 / math.sqrt(40), kv_block=1024)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=72, n_heads=6, n_kv_heads=6, d_ff=128,
    vocab=512, kv_block=16, resid_scale=1.4 / math.sqrt(3))

SPEC = ArchSpec(id="minicpm-2b", family="lm",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="WSD schedule; mu-P emb/resid scaling")
