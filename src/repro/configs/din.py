"""din [arXiv:1706.06978]: target attention over a 100-item behavior
sequence; embed_dim=18, attention MLP 80-40, main MLP 200-80."""
import dataclasses
from ..models.recsys import RecsysConfig
from .registry import ArchSpec

CONFIG = RecsysConfig(
    name="din", kind="din", n_sparse=1, embed_dim=18,
    total_vocab=1 << 24, mlp_dims=(200, 80), attn_mlp_dims=(80, 40),
    seq_len=100, n_dense=0)

REDUCED = dataclasses.replace(CONFIG, total_vocab=4096, seq_len=16,
                              mlp_dims=(32, 16), attn_mlp_dims=(16, 8))

SPEC = ArchSpec(id="din", family="recsys",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="target attention (attn_mlp over h,t,h-t,h*t)")
