"""deepfm [arXiv:1703.04247]: 39 sparse fields, embed_dim=10,
MLP 400-400-400, FM interaction. Criteo-scale table: 2^25 rows."""
import dataclasses
from ..models.recsys import RecsysConfig
from .registry import ArchSpec

CONFIG = RecsysConfig(
    name="deepfm", kind="deepfm", n_sparse=39, embed_dim=10,
    total_vocab=1 << 25, mlp_dims=(400, 400, 400), n_dense=13)

REDUCED = dataclasses.replace(CONFIG, total_vocab=4096,
                              mlp_dims=(32, 32), n_dense=4)

SPEC = ArchSpec(id="deepfm", family="recsys",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="FM sum-square trick + deep MLP")
