"""fm [Rendle ICDM'10]: pure factorization machine, 39 fields,
embed_dim=10, pairwise interactions via the O(nk) sum-square trick."""
import dataclasses
from ..models.recsys import RecsysConfig
from .registry import ArchSpec

CONFIG = RecsysConfig(
    name="fm", kind="fm", n_sparse=39, embed_dim=10,
    total_vocab=1 << 25, n_dense=0)

REDUCED = dataclasses.replace(CONFIG, total_vocab=4096)

SPEC = ArchSpec(id="fm", family="recsys",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="FM 2-way, sum-square trick")
