"""Assigned input-shape sets per architecture family (40 cells total) plus
the JAG production cells."""

LM_SHAPES = {
    "train_4k":    dict(kind="train",   seq=4096,    batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,   batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,   batch=128),
    "long_500k":   dict(kind="decode",  seq=524288,  batch=1),
}

GNN_SHAPES = {
    # cora full batch
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    # reddit-scale sampled training (232965 nodes / 114.6M edges / 602 feats)
    "minibatch_lg":  dict(kind="sampled", n_nodes=232965,
                          n_edges=114_615_892, batch_nodes=1024,
                          fanout=(15, 10), d_feat=602, n_classes=41),
    # ogbn-products full batch
    "ogb_products":  dict(kind="full", n_nodes=2_449_029,
                          n_edges=61_859_140, d_feat=100, n_classes=47),
    # batched small graphs (graph classification)
    "molecule":      dict(kind="batched", n_nodes=30, n_edges=64,
                          batch=128, d_feat=32, n_classes=10),
}

RECSYS_SHAPES = {
    "train_batch":    dict(kind="train",     batch=65536),
    "serve_p99":      dict(kind="serve",     batch=512),
    "serve_bulk":     dict(kind="serve",     batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000),
}

JAG_SHAPES = {
    # billion-scale shard-and-merge serving: 256 shards x 4.19M pts = 1.07B
    "serve_1b": dict(kind="jag_serve", n_local=1 << 22, d=128, row_width=80,
                     batch=4096, k=10, ls=128, max_iters=192,
                     query_chunk=128, n_seeds=8),
    # distributed per-shard batch insert (build path)
    "build_1b": dict(kind="jag_build", n_local=1 << 22, d=128, degree=64,
                     ex_slots=16, batch=128, ls_build=96, cand_pool=192),
}
