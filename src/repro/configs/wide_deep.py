"""wide-deep [arXiv:1606.07792]: 40 sparse fields, embed_dim=32,
MLP 1024-512-256, concat interaction + wide linear branch."""
import dataclasses
from ..models.recsys import RecsysConfig
from .registry import ArchSpec

CONFIG = RecsysConfig(
    name="wide-deep", kind="wide_deep", n_sparse=40, embed_dim=32,
    total_vocab=1 << 25, mlp_dims=(1024, 512, 256), n_dense=13)

REDUCED = dataclasses.replace(CONFIG, total_vocab=4096,
                              mlp_dims=(64, 32), n_dense=4)

SPEC = ArchSpec(id="wide-deep", family="recsys",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="wide linear + deep MLP")
