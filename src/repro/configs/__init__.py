"""Architecture configs (--arch <id>). All from public literature."""
from .registry import ArchSpec, all_archs, get, make_cell
