"""gcn-cora [arXiv:1609.02907]: 2-layer GCN, d_hidden=16, mean/sym-norm
aggregation. Per-shape d_feat/n_classes follow the assigned shape set
(cora / reddit-sampled / ogbn-products / molecules)."""
from ..models.gnn import GCNConfig
from .registry import ArchSpec
from .shapes import GNN_SHAPES


def make_config(shape=None):
    shp = GNN_SHAPES[shape or "full_graph_sm"]
    return GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16,
                     aggregator="mean", norm="sym",
                     d_feat=shp["d_feat"], n_classes=shp["n_classes"])


REDUCED = GCNConfig(name="gcn-cora", n_layers=2, d_hidden=16,
                    d_feat=32, n_classes=5)

SPEC = ArchSpec(id="gcn-cora", family="gnn", make_config=make_config,
                make_reduced=lambda: REDUCED,
                notes="segment_sum message passing; fanout sampler for "
                      "minibatch_lg")
