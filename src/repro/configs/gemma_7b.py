"""gemma-7b [arXiv:2403.08295; hf:google/gemma-7b].

28L d_model=3072 16H (kv=16) head_dim=256 d_ff=24576 vocab=256000; GeGLU
activation, (1+w) RMSNorm, sqrt(d_model) embedding scaling.
"""
import dataclasses
import math
from ..models.transformer import LMConfig
from .registry import ArchSpec

CONFIG = LMConfig(
    name="gemma-7b", n_layers=28, d_model=3072, n_heads=16,
    n_kv_heads=16, head_dim=256, d_ff=24576, vocab=256_000, act="gelu",
    norm_plus_one=True, emb_scale=math.sqrt(3072), kv_block=1024)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab=512, emb_scale=8.0, kv_block=16)

SPEC = ArchSpec(id="gemma-7b", family="lm",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="GeGLU, head_dim=256, (1+w) norms")
