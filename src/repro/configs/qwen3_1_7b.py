"""qwen3-1.7b [hf:Qwen/Qwen3-1.7B].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936; qk-norm on per-head
q/k, rope_theta=1e6.
"""
import dataclasses
from ..models.transformer import LMConfig
from .registry import ArchSpec

CONFIG = LMConfig(
    name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=6144, vocab=151_936, act="silu", qk_norm=True,
    rope_theta=1_000_000.0, kv_block=1024)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, kv_block=16)

SPEC = ArchSpec(id="qwen3-1.7b", family="lm",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="qk_norm, GQA")
