"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 on every layer + shared expert; iRoPE chunked attention. ~109B total,
~17B active.
"""
import dataclasses
from ..models.transformer import LMConfig
from .registry import ArchSpec

CONFIG = LMConfig(
    name="llama4-scout-17b-a16e", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
    act="silu", n_experts=16, moe_every=1, shared_expert=True,
    attn_chunk=8192, global_every=4, rope_theta=500_000.0, kv_block=1024)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=512, n_experts=4, attn_chunk=8, global_every=2,
    kv_block=16)

SPEC = ArchSpec(id="llama4-scout-17b-a16e", family="lm",
                make_config=lambda shape=None: CONFIG,
                make_reduced=lambda: REDUCED,
                notes="MoE 16e top-1 every layer + shared expert")
