"""Architecture registry: ``--arch <id>`` resolves here.

Each arch module defines an ``ArchSpec``; the registry provides the
family-generic machinery the launcher/dry-run needs:

  make_model_cfg(arch, shape)   -> family config for that cell
  abstract_inputs(arch, shape)  -> ShapeDtypeStruct pytrees (no allocation)
  make_step(arch, shape, mesh)  -> (fn, in_shardings, donate) ready to lower

All configs come from public literature; see the per-arch module docstrings
for sources.
"""
from __future__ import annotations

import dataclasses
import importlib
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distributed.sharding import Rules, make_rules, resolve_spec
from .shapes import GNN_SHAPES, JAG_SHAPES, LM_SHAPES, RECSYS_SHAPES


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str                      # lm | gnn | recsys | jag
    make_config: Callable[..., Any]  # (shape_name=None) -> config
    make_reduced: Callable[[], Any]  # smoke-test config
    notes: str = ""

    @property
    def shapes(self):
        return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                "recsys": RECSYS_SHAPES, "jag": JAG_SHAPES}[self.family]


_ARCH_MODULES = [
    "llama4_maverick_400b_a17b", "llama4_scout_17b_a16e", "minicpm_2b",
    "gemma_7b", "qwen3_1_7b", "gcn_cora", "deepfm", "din", "fm",
    "wide_deep", "jag_billion",
]

_REGISTRY: Dict[str, ArchSpec] = {}


def get(arch_id: str) -> ArchSpec:
    if not _REGISTRY:
        for m in _ARCH_MODULES:
            mod = importlib.import_module(f"repro.configs.{m}")
            _REGISTRY[mod.SPEC.id] = mod.SPEC
    return _REGISTRY[arch_id]


def all_archs():
    get("gcn-cora")  # force registry load
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# abstract inputs + step builders per family
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shardings_for(tree_specs, tree_abstract, rules: Rules):
    def one(spec, arr):
        return NamedSharding(rules.mesh, resolve_spec(spec, arr.shape,
                                                      rules))
    return jax.tree.map(
        one, tree_specs, tree_abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def make_cell(arch_id: str, shape_name: str, mesh, *,
              opt_cfg=None, lowering: str = "unroll",
              rule_overrides: Optional[Dict] = None) -> Dict[str, Any]:
    """Everything needed to lower one (arch x shape x mesh) cell:
    {fn, args (abstract), in_shardings, out_shardings, donate_argnums,
    model_flops, params_bytes}.

    ``lowering``: "unroll" = straight-line layers (exact cost_analysis;
    XLA HloCostAnalysis counts loop bodies once) | "scan" = production
    compact HLO (remat-aware memory_analysis). The dry-run compiles LM
    train/prefill cells both ways: compute/collective stats from the
    unrolled artifact, the HBM-fit proof from the scan artifact.
    """
    spec = get(arch_id)
    shp = spec.shapes[shape_name]
    rules = make_rules(mesh, rule_overrides)
    if spec.family == "lm":
        return _lm_cell(spec, shape_name, shp, mesh, rules, opt_cfg,
                        lowering)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape_name, shp, mesh, rules, opt_cfg)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape_name, shp, mesh, rules, opt_cfg)
    if spec.family == "jag":
        return _jag_cell(spec, shape_name, shp, mesh, rules)
    raise ValueError(spec.family)


def _default_opt():
    from ..train.optimizer import OptConfig
    return OptConfig()


# --- LM ---------------------------------------------------------------------

def _lm_cell(spec, shape_name, shp, mesh, rules, opt_cfg,
             lowering: str = "unroll"):
    from ..models import transformer as T
    from ..train.optimizer import AdamWState, init_state
    from ..train.steps import make_train_step
    cfg = spec.make_config(shape_name)
    # kv_block sized so the per-layer score tensor stays bounded
    kvb = {"train": 4096, "prefill": 8192}.get(shp["kind"], cfg.kv_block)
    cfg = dataclasses.replace(cfg, scan_layers=(lowering == "scan"),
                              unroll_kv=(lowering == "unroll"),
                              kv_block=kvb)
    opt_cfg = opt_cfg or _default_opt()
    key = jax.random.PRNGKey(0)
    a_params = jax.eval_shape(lambda k: T.init_params(cfg, k)[0], key)
    _, p_specs = _lm_param_specs(cfg)
    p_shard = _shardings_for(p_specs, a_params, rules)
    B, S = shp["batch"], shp["seq"]
    dp_names = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = 1
    for a in dp_names:
        dsize *= mesh.shape[a]
    # divisibility-aware batch sharding (long_500k decodes batch=1)
    dp = P(dp_names) if B % dsize == 0 else P()
    n_params = cfg.param_count()

    if shp["kind"] == "train":
        a_opt = jax.eval_shape(init_state, a_params)
        o_shard = AdamWState(
            NamedSharding(mesh, P()),
            _shardings_for(p_specs, a_opt.m, rules),
            _shardings_for(p_specs, a_opt.v, rules))
        batch = {"tokens": _sds((B, S + 1), jnp.int32)}
        b_shard = {"tokens": NamedSharding(mesh, dp)}
        step = make_train_step(partial(T.loss_fn, cfg), opt_cfg)
        mf = 6 * cfg.active_param_count() * B * S
        return dict(fn=step, args=(a_params, a_opt, batch),
                    in_shardings=(p_shard, o_shard, b_shard),
                    out_shardings=(p_shard, o_shard, None),
                    donate_argnums=(0, 1), rules=rules,
                    model_flops=mf, n_params=n_params)

    if shp["kind"] == "prefill":
        a_cache = jax.eval_shape(
            lambda: T.init_cache(cfg, B, S)[0])
        _, c_spec = T.init_cache(cfg, 1, 1)
        c_shard = _shardings_for({"k": c_spec["k"], "v": c_spec["v"]},
                                 a_cache, rules)
        toks = _sds((B, S), jnp.int32)
        fn = partial(T.prefill, cfg)
        mf = 2 * cfg.active_param_count() * B * S
        return dict(fn=fn, args=(a_params, toks, a_cache),
                    in_shardings=(p_shard, NamedSharding(mesh, dp),
                                  c_shard),
                    out_shardings=(None, c_shard), donate_argnums=(2,),
                    rules=rules, model_flops=mf, n_params=n_params)

    # decode
    a_cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S)[0])
    _, c_spec = T.init_cache(cfg, 1, 1)
    c_shard = _shardings_for({"k": c_spec["k"], "v": c_spec["v"]},
                             a_cache, rules)
    tok = _sds((B,), jnp.int32)
    cur = _sds((B,), jnp.int32)
    fn = partial(T.decode_step, cfg)
    mf = 2 * cfg.active_param_count() * B  # one token per lane
    return dict(fn=fn, args=(a_params, a_cache, tok, cur),
                in_shardings=(p_shard, c_shard, NamedSharding(mesh, dp),
                              NamedSharding(mesh, dp)),
                out_shardings=(None, c_shard), donate_argnums=(1,),
                rules=rules, model_flops=mf, n_params=n_params)


def _lm_param_specs(cfg):
    from ..models import transformer as T
    k = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda kk: T.init_params(cfg, kk)[0], k)
    # the static spec tree doesn't depend on dims: take it from a tiny clone
    small = dataclasses.replace(cfg, n_layers=1, d_model=8, n_heads=2,
                                n_kv_heads=2, head_dim=4, d_ff=8,
                                vocab=16, n_experts=min(cfg.n_experts, 2))
    _, sp = T.init_params(small, k)
    return shapes, sp


# --- GNN ---------------------------------------------------------------------

def _gnn_cell(spec, shape_name, shp, mesh, rules, opt_cfg):
    from ..models import gnn as G
    from ..train.optimizer import AdamWState, init_state
    from ..train.steps import make_train_step
    cfg = spec.make_config(shape_name)
    opt_cfg = opt_cfg or _default_opt()
    key = jax.random.PRNGKey(0)
    a_params = jax.eval_shape(lambda k: G.init_params(cfg, k)[0], key)
    _, p_specs = G.init_params(cfg, key)
    p_shard = _shardings_for(p_specs, a_params, rules)
    a_opt = jax.eval_shape(init_state, a_params)
    o_shard = AdamWState(NamedSharding(mesh, P()),
                         _shardings_for(p_specs, a_opt.m, rules),
                         _shardings_for(p_specs, a_opt.v, rules))
    dp_names = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = 1
    for a in dp_names:
        dsize *= mesh.shape[a]

    if shp["kind"] == "sampled":
        nb = shp["batch_nodes"]
        f = shp["fanout"]
        max_nodes = _pad_to(nb * (f[0] + 1) * (f[1] + 1), 8 * dsize)
        max_edges = _pad_to(nb * (f[0] + f[0] * f[1]) * 2, 8 * dsize)
        batch = {"feats": _sds((max_nodes, shp["d_feat"]), jnp.float32),
                 "edges": _sds((max_edges, 2), jnp.int32),
                 "labels": _sds((nb,), jnp.int32),
                 "label_mask": _sds((nb,), jnp.float32)}
        loss = partial(G.sampled_loss_fn, cfg)
    elif shp["kind"] == "batched":
        n = shp["batch"] * shp["n_nodes"]
        e = shp["batch"] * shp["n_edges"]
        batch = {"feats": _sds((n, shp["d_feat"]), jnp.float32),
                 "edges": _sds((e, 2), jnp.int32),
                 "labels": _sds((shp["batch"],), jnp.int32),
                 "graph_ids": _sds((n,), jnp.int32)}
        loss = partial(G.graph_loss_fn, cfg)
    else:  # full graph
        n = _pad_to(shp["n_nodes"], 8 * dsize)
        e = _pad_to(shp["n_edges"], 8 * dsize)
        batch = {"feats": _sds((n, shp["d_feat"]), jnp.float32),
                 "edges": _sds((e, 2), jnp.int32),
                 "labels": _sds((n,), jnp.int32),
                 "label_mask": _sds((n,), jnp.float32)}
        loss = partial(G.loss_fn, cfg)

    b_shard = jax.tree.map(
        lambda a: NamedSharding(
            mesh, P(dp_names) if a.shape and a.shape[0] % dsize == 0
            else P()), batch)
    step = make_train_step(loss, opt_cfg)
    # 2 flops/edge/feat propagation + dense layers, fwd+bwd(x3)
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [
        cfg.n_classes]
    nn = batch["feats"].shape[0]
    ne = batch["edges"].shape[0]
    mf = 3 * sum(2 * ne * dims[i] + 2 * nn * dims[i] * dims[i + 1]
                 for i in range(cfg.n_layers))
    return dict(fn=step, args=(a_params, a_opt, batch),
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1), rules=rules, model_flops=mf,
                n_params=cfg.param_count())


# --- RecSys ------------------------------------------------------------------

def _recsys_cell(spec, shape_name, shp, mesh, rules, opt_cfg):
    from ..models import recsys as R
    from ..train.optimizer import AdamWState, init_state
    from ..train.steps import make_train_step
    cfg = spec.make_config(shape_name)
    opt_cfg = opt_cfg or _default_opt()
    key = jax.random.PRNGKey(0)
    a_params = jax.eval_shape(lambda k: R.init_params(cfg, k)[0], key)
    _, p_specs = R.init_params(
        dataclasses.replace(cfg, total_vocab=max(cfg.n_sparse * 8, 512),
                            field_vocabs=()), key)
    p_shard = _shardings_for(p_specs, a_params, rules)
    dp = P(tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    B = shp["batch"]

    def batch_abstract(b):
        if cfg.kind == "din":
            return {"target_id": _sds((b,), jnp.int32),
                    "hist_ids": _sds((b, cfg.seq_len), jnp.int32),
                    "hist_mask": _sds((b, cfg.seq_len), jnp.bool_),
                    "label": _sds((b,), jnp.float32)}
        return {"sparse_ids": _sds((b, cfg.n_sparse), jnp.int32),
                "dense": _sds((b, cfg.n_dense), jnp.float32),
                "label": _sds((b,), jnp.float32)}

    if shp["kind"] == "train":
        a_opt = jax.eval_shape(init_state, a_params)
        o_shard = AdamWState(NamedSharding(mesh, P()),
                             _shardings_for(p_specs, a_opt.m, rules),
                             _shardings_for(p_specs, a_opt.v, rules))
        batch = batch_abstract(B)
        b_shard = jax.tree.map(lambda a: NamedSharding(mesh, dp), batch)
        step = make_train_step(partial(R.loss_fn, cfg), opt_cfg)
        mf = 3 * _recsys_fwd_flops(cfg, B)
        return dict(fn=step, args=(a_params, a_opt, batch),
                    in_shardings=(p_shard, o_shard, b_shard),
                    out_shardings=(p_shard, o_shard, None),
                    donate_argnums=(0, 1), rules=rules, model_flops=mf,
                    n_params=cfg.param_count())

    if shp["kind"] == "serve":
        batch = batch_abstract(B)
        b_shard = jax.tree.map(lambda a: NamedSharding(mesh, dp), batch)
        fn = partial(R.forward, cfg)
        return dict(fn=fn, args=(a_params, batch),
                    in_shardings=(p_shard, b_shard),
                    out_shardings=None, donate_argnums=(),
                    rules=rules, model_flops=_recsys_fwd_flops(cfg, B),
                    n_params=cfg.param_count())

    # retrieval: 1 query x n_candidates
    nc = shp["n_candidates"]
    ncp = _pad_to(nc, 16 * 8)
    user = _sds((shp["batch"], cfg.embed_dim), jnp.float32)
    cands = _sds((ncp, cfg.embed_dim), jnp.float32)
    fn = partial(R.retrieval_topk, k=100)
    c_shard = NamedSharding(mesh, resolve_spec(
        ("candidates", "table_dim"), (ncp, cfg.embed_dim), rules))
    return dict(fn=lambda u, c: fn(u, c), args=(user, cands),
                in_shardings=(NamedSharding(mesh, P()), c_shard),
                out_shardings=None, donate_argnums=(),
                rules=rules,
                model_flops=2 * shp["batch"] * ncp * cfg.embed_dim,
                n_params=ncp * cfg.embed_dim)


def _recsys_fwd_flops(cfg, B):
    f = 2 * B * cfg.n_sparse * cfg.embed_dim          # bag sums
    if cfg.kind in ("fm", "deepfm"):
        f += 4 * B * cfg.n_sparse * cfg.embed_dim     # sum-square trick
    if cfg.kind in ("deepfm", "wide_deep"):
        dims = ([cfg.n_sparse * cfg.embed_dim + cfg.n_dense]
                + list(cfg.mlp_dims) + [1])
        f += 2 * B * sum(dims[i] * dims[i + 1]
                         for i in range(len(dims) - 1))
    if cfg.kind == "din":
        dims = [4 * cfg.embed_dim] + list(cfg.attn_mlp_dims) + [1]
        f += 2 * B * cfg.seq_len * sum(dims[i] * dims[i + 1]
                                       for i in range(len(dims) - 1))
        dims = [3 * cfg.embed_dim] + list(cfg.mlp_dims) + [1]
        f += 2 * B * sum(dims[i] * dims[i + 1]
                         for i in range(len(dims) - 1))
    return f


# --- JAG ---------------------------------------------------------------------

def _jag_cell(spec, shape_name, shp, mesh, rules):
    from ..core.build import BuildConfig
    from ..core.distributed import (ShardedServeConfig, make_build_step,
                                    make_serve_step, shard_axes)
    import numpy as np
    sx = shard_axes(mesh)
    S = 1
    for a in sx:
        S *= mesh.shape[a]
    n_loc = shp["n_local"]
    d = shp["d"]
    qx = tuple(a for a in ("pod",) if a in mesh.axis_names)
    Bq = shp["batch"] * (mesh.shape["pod"] if "pod" in mesh.axis_names
                         else 1)

    shard_spec = NamedSharding(mesh, P(sx))
    q_spec = NamedSharding(mesh, P(qx) if qx else P())

    if shp["kind"] == "jag_serve":
        W = shp["row_width"]
        cfgs = ShardedServeConfig(k=shp["k"], ls=shp["ls"],
                                  max_iters=shp["max_iters"],
                                  query_chunk=shp["query_chunk"])
        fn = make_serve_step(mesh, cfgs, "range", "range")
        args = (_sds((S, n_loc, W), jnp.int32),
                _sds((S, n_loc, d), jnp.bfloat16),
                _sds((S, n_loc), jnp.float32),
                {"value": _sds((S, n_loc), jnp.float32)},
                _sds((S, shp["n_seeds"]), jnp.int32),
                _sds((Bq, d), jnp.bfloat16),
                {"lo": _sds((Bq,), jnp.float32),
                 "hi": _sds((Bq,), jnp.float32)})
        in_sh = (shard_spec, shard_spec, shard_spec,
                 {"value": shard_spec}, shard_spec, q_spec,
                 {"lo": q_spec, "hi": q_spec})
        # model flops: expansions x R x d per query per shard (dominant)
        mf = Bq * S * shp["max_iters"] * W * d * 2
        # HloCostAnalysis counts the (chunk-map x beam-while) body once;
        # nearly all serve work lives inside that double loop, so scale
        # measured flops/bytes multiplicatively (documented in DESIGN.md).
        nch = max((shp["batch"]) // shp["query_chunk"], 1)
        return dict(fn=fn, args=args, in_shardings=in_sh,
                    out_shardings=None, donate_argnums=(), rules=rules,
                    model_flops=mf, n_params=S * n_loc * (d + W),
                    flops_scale=nch * shp["max_iters"])

    # jag_build
    bc = BuildConfig(degree=shp["degree"], ls_build=shp["ls_build"],
                     thresholds=(np.inf, 1000.0, 0.0),
                     cand_pool=shp["cand_pool"],
                     ex_slots=shp["ex_slots"], batch_size=shp["batch"])
    fn = make_build_step(mesh, bc, "range")
    W = shp["degree"] + shp["ex_slots"]
    args = (_sds((S, n_loc, W), jnp.int32),
            _sds((S, n_loc), jnp.int32),
            _sds((S, n_loc, d), jnp.bfloat16),
            _sds((S, n_loc), jnp.float32),
            {"value": _sds((S, n_loc), jnp.float32)},
            _sds((S, shp["batch"]), jnp.int32),
            _sds((S, 8), jnp.int32))
    in_sh = (shard_spec,) * 4 + ({"value": shard_spec}, shard_spec,
                                 shard_spec)
    mf = (shp["batch"] * S
          * (3 * 2 * shp["ls_build"] * W * d * 2              # searches
             + shp["cand_pool"] ** 2 * d * 2))                # pair d2
    # build mixes loop regimes (search whiles, prune fori, one-shot sorts);
    # no single multiplier is honest -> analytic-compute-only (DESIGN.md).
    return dict(fn=fn, args=args, in_shardings=in_sh,
                out_shardings=(shard_spec, shard_spec),
                donate_argnums=(0, 1), rules=rules, model_flops=mf,
                n_params=S * n_loc * (d + W), analytic_only=True)
