"""Checkpoint/restart, crash resume (subprocess), elastic cross-mesh
restore, deterministic data order."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_pytree, save_pytree


def test_save_load_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)},
            "lst": [jnp.zeros(3), jnp.full((2, 2), 7.0)]}
    save_pytree(tree, str(tmp_path), 5, meta={"x": 1})
    out, meta = load_pytree(tree, str(tmp_path), 5)
    assert meta == {"x": 1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_and_keep_k(tmp_path):
    tree = {"w": jnp.ones(4)}
    for s in (1, 2, 3, 4, 5):
        save_pytree(tree, str(tmp_path), s, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_000000004", "step_000000005"]
    assert latest_step(str(tmp_path)) == 5
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_crash_resume_subprocess(tmp_path):
    """Kill training mid-run; rerun must resume and finish identically."""
    env = dict(os.environ, PYTHONPATH="src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen3-1.7b", "--scale", "reduced", "--steps", "12",
            "--batch", "2", "--seq", "32", "--ckpt-every", "4",
            "--ckpt-dir", str(tmp_path / "ck"),
            "--metrics-out", str(tmp_path / "m1.jsonl")]
    r = subprocess.run(base + ["--fail-at-step", "6"], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 42, r.stderr[-2000:]
    assert latest_step(str(tmp_path / "ck")) == 4
    r2 = subprocess.run(base, env=env, capture_output=True, text=True,
                        cwd="/root/repo")
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 4" in r2.stdout
    assert latest_step(str(tmp_path / "ck")) == 12

    # a never-crashed control run sees the same data and converges the same
    r3 = subprocess.run(
        [*base[:-2], "--ckpt-dir", str(tmp_path / "ck3"),
         "--metrics-out", str(tmp_path / "m3.jsonl")],
        env=env, capture_output=True, text=True, cwd="/root/repo")
    assert r3.returncode == 0, r3.stderr[-2000:]
    m1 = [json.loads(l) for l in open(tmp_path / "m1.jsonl")]
    m3 = [json.loads(l) for l in open(tmp_path / "m3.jsonl")]
    last1 = [m for m in m1 if m["step"] == 11][-1]
    last3 = [m for m in m3 if m["step"] == 11][-1]
    assert abs(last1["loss"] - last3["loss"]) < 2e-2, (last1, last3)


def test_elastic_cross_mesh_restore(tmp_path):
    """Save under one sharding, restore under another mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_pytree(tree, str(tmp_path), 1)
    from repro.launch.mesh import mesh_kwargs
    mesh = jax.make_mesh((1,), ("data",), **mesh_kwargs(1))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = load_pytree(tree, str(tmp_path), 1, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_deterministic_data_order():
    from repro.data.pipelines import lm_batch
    a = lm_batch(7, 4, 16, 100, seed=3)["tokens"]
    b = lm_batch(7, 4, 16, 100, seed=3)["tokens"]
    c = lm_batch(8, 4, 16, 100, seed=3)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
