"""Index-construction invariants: degree bounds, no self loops, no duplicate
edges, connectivity/recall, prune behaviour."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import JAGConfig, JAGIndex, range_table, range_filters
from repro.core.build import medoid
from repro.core.prune import joint_robust_prune, select_to_rows


@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(0)
    n, d = 1500, 16
    centers = rng.normal(size=(8, d)) * 4
    xb = (centers[rng.integers(0, 8, n)]
          + rng.normal(size=(n, d))).astype(np.float32)
    vals = rng.uniform(0, 1000, n).astype(np.float32)
    attr = range_table(vals)
    cfg = JAGConfig(degree=16, ls_build=32, batch_size=128, cand_pool=96)
    return JAGIndex.build(xb, attr, cfg), xb, vals


def test_degree_bound(small_index):
    idx, *_ = small_index
    st = idx.degree_stats()
    assert st["over_budget"] == 0
    assert st["max"] <= idx.cfg.degree


def test_no_self_loops_or_dups(small_index):
    idx, *_ = small_index
    g = np.asarray(idx.graph)
    n = g.shape[0]
    for v in range(0, n, 37):
        row = g[v][g[v] >= 0]
        assert v not in row
        assert len(row) == len(set(row))


def test_reachability(small_index):
    """(Almost) every node is reachable from the entry point."""
    idx, *_ = small_index
    g = np.asarray(idx.graph)
    n = g.shape[0]
    seen = np.zeros(n, bool)
    frontier = [int(x) for x in np.atleast_1d(np.asarray(idx.entry))]
    seen[frontier] = True
    while frontier:
        nxt = g[frontier].reshape(-1)
        nxt = nxt[nxt >= 0]
        nxt = np.unique(nxt[~seen[nxt]])
        seen[nxt] = True
        frontier = list(nxt)
    assert seen.mean() > 0.99, f"only {seen.mean():.2%} reachable"


def test_unfiltered_recall(small_index):
    idx, xb, _ = small_index
    rng = np.random.default_rng(5)
    q = xb[rng.integers(0, len(xb), 32)] + 0.01
    res = idx.search_unfiltered(q, k=10, ls=64)
    d2 = ((q[:, None] - xb[None]) ** 2).sum(-1)
    gt = np.argsort(d2, 1)[:, :10]
    got = np.asarray(res.ids)
    rec = np.mean([len(set(gt[i]) & set(got[i])) / 10 for i in range(32)])
    assert rec > 0.9, rec


def test_filtered_recall_low_selectivity(small_index):
    idx, xb, vals = small_index
    rng = np.random.default_rng(6)
    b = 24
    q = xb[rng.integers(0, len(xb), b)] + 0.01
    lo = rng.uniform(0, 980, b).astype(np.float32)
    hi = lo + 20.0  # ~2% selectivity
    filt = range_filters(lo, hi)
    res = idx.search(q, filt, k=10, ls=96)
    mask = (vals[None] >= lo[:, None]) & (vals[None] <= hi[:, None])
    d2 = np.where(mask, ((q[:, None] - xb[None]) ** 2).sum(-1), np.inf)
    recs = []
    for i in range(b):
        gt = [j for j in np.argsort(d2[i])[:10] if d2[i, j] < np.inf]
        if not gt:
            continue
        got = [j for j, p in zip(np.asarray(res.ids)[i],
                                 np.asarray(res.primary)[i]) if p == 0]
        recs.append(len(set(gt) & set(got)) / len(gt))
    assert np.mean(recs) > 0.85, np.mean(recs)


def test_prune_respects_degree_and_alpha():
    rng = np.random.default_rng(7)
    B, C, d = 4, 48, 8
    vecs = rng.normal(size=(B, C, d)).astype(np.float32)
    p = rng.normal(size=(B, d)).astype(np.float32)
    d2p = ((vecs - p[:, None]) ** 2).sum(-1)
    pair = ((vecs[:, :, None] - vecs[:, None]) ** 2).sum(-1)
    da = rng.uniform(0, 4, (B, C)).astype(np.float32)
    valid = jnp.ones((B, C), bool)
    sel = joint_robust_prune(valid, jnp.asarray(d2p), jnp.asarray(da),
                             jnp.asarray(pair), degree=8, alpha=1.2,
                             thresholds=(np.inf, 0.0))
    sel = np.asarray(sel)
    assert (sel.sum(1) <= 8).all()
    assert (sel.sum(1) >= 1).all()
    rows = np.asarray(select_to_rows(jnp.asarray(sel),
                                     jnp.tile(np.arange(C), (B, 1)),
                                     jnp.asarray(d2p), 8))
    for b in range(B):
        got = set(rows[b][rows[b] >= 0])
        assert got == set(np.flatnonzero(sel[b]))


def test_load_legacy_archive_without_build_cfg(small_index, tmp_path):
    """Archives predating the ``build_cfg`` field load with defaults.

    ``JAGIndex.load`` falls back to ``BuildConfig()`` when the archive has
    no ``build_cfg`` key (the ``if "build_cfg" in z`` branch) — exercised
    here by stripping the key from a fresh archive. Everything else must
    round-trip: graph/vectors/attrs bit-for-bit and identical search
    results (search never consults build_cfg).
    """
    from repro.core.build import BuildConfig
    idx, xb, vals = small_index
    full = str(tmp_path / "full.npz")
    legacy = str(tmp_path / "legacy.npz")
    idx.save(full)
    with np.load(full, allow_pickle=False) as z:
        assert "build_cfg" in z
        stripped = {k: z[k] for k in z.files if k != "build_cfg"}
    np.savez_compressed(legacy, **stripped)

    from repro.core import JAGIndex
    got = JAGIndex.load(legacy)
    assert got.build_cfg == BuildConfig()            # fallback defaults
    assert got.cfg == idx.cfg                        # JAGConfig still exact
    np.testing.assert_array_equal(np.asarray(got.graph),
                                  np.asarray(idx.graph))
    np.testing.assert_array_equal(np.asarray(got.xb), np.asarray(idx.xb))
    for k, v in idx.attr.data.items():
        np.testing.assert_array_equal(np.asarray(got.attr.data[k]),
                                      np.asarray(v))
    rng = np.random.default_rng(12)
    q = xb[rng.integers(0, len(xb), 8)] + 0.01
    filt = range_filters(np.zeros(8, np.float32),
                         np.full(8, 500.0, np.float32))
    want = idx.search(q, filt, k=10, ls=64)
    res = got.search(q, filt, k=10, ls=64)
    for field in ("ids", "primary", "secondary", "n_dist"):
        np.testing.assert_array_equal(np.asarray(getattr(res, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=field)


def test_save_load_round_trips_calibrated_build_cfg(small_index, tmp_path):
    """The modern path: the CALIBRATED BuildConfig (absolute thresholds,
    not the quantile spec) survives save -> load exactly."""
    from repro.core import JAGIndex
    idx, *_ = small_index
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    got = JAGIndex.load(path)
    assert got.build_cfg == idx.build_cfg
    assert got.build_cfg.thresholds == idx.build_cfg.thresholds


def test_medoid():
    xb = np.array([[0, 0], [10, 0], [0, 10], [3, 3]], np.float32)
    assert int(medoid(jnp.asarray(xb))) == 3


def test_weight_mode_builds():
    rng = np.random.default_rng(8)
    n, d = 600, 8
    xb = rng.normal(size=(n, d)).astype(np.float32)
    attr = range_table(rng.uniform(0, 100, n))
    cfg = JAGConfig(degree=12, ls_build=24, batch_size=128, cand_pool=64,
                    mode="weight", weight_scales=(0.0, 1.0))
    idx = JAGIndex.build(xb, attr, cfg)
    assert idx.degree_stats()["over_budget"] == 0
    res = idx.search(xb[:4], range_filters([0] * 4, [100] * 4), k=5, ls=32)
    assert (np.asarray(res.ids)[:, 0] >= 0).all()


def test_int8_search_recall_parity(small_index):
    """Quantized traversal + exact rerank ~ matches fp recall (§Perf)."""
    idx, xb, vals = small_index
    rng = np.random.default_rng(9)
    b = 24
    q = xb[rng.integers(0, len(xb), b)] + 0.01
    lo = rng.uniform(0, 900, b).astype(np.float32)
    hi = lo + 100.0
    filt = range_filters(lo, hi)
    r_fp = idx.search(q, filt, k=10, ls=64)
    r_q8 = idx.search_int8(q, filt, k=10, ls=64)
    mask = (vals[None] >= lo[:, None]) & (vals[None] <= hi[:, None])
    d2 = np.where(mask, ((q[:, None] - xb[None]) ** 2).sum(-1), np.inf)

    def rec(res):
        out = []
        for i in range(b):
            gt = [j for j in np.argsort(d2[i])[:10] if d2[i, j] < np.inf]
            got = [j for j, p in zip(np.asarray(res.ids)[i],
                                     np.asarray(res.primary)[i]) if p == 0]
            if gt:
                out.append(len(set(gt) & set(got)) / len(gt))
        return np.mean(out)
    rfp, rq8 = rec(r_fp), rec(r_q8)
    assert rq8 > rfp - 0.05, (rfp, rq8)


def test_scan_dedup_recall_parity(small_index):
    """dedup='scan' (no N-sized bitmap) keeps recall (§Perf iteration)."""
    from repro.core.beam_search import greedy_search
    from repro.core.distances import query_key_fn
    idx, xb, vals = small_index
    rng = np.random.default_rng(10)
    b = 16
    q = xb[rng.integers(0, len(xb), b)] + 0.01
    lo = rng.uniform(0, 900, b).astype(np.float32)
    filt = range_filters(lo, lo + 100.0)

    def run(dedup):
        return greedy_search(idx.graph, idx.xb, idx.xb_norm, idx.attr,
                             jnp.asarray(q), idx.entry,
                             query_key_fn(filt), ls=64, k=10,
                             max_iters=128, dedup=dedup)
    r_bm = run("bitmap")
    r_sc = run("scan")
    mask = (vals[None] >= lo[:, None]) & (vals[None] <= (lo + 100)[:, None])
    d2 = np.where(mask, ((q[:, None] - xb[None]) ** 2).sum(-1), np.inf)

    def rec(res):
        out = []
        for i in range(b):
            gt = [j for j in np.argsort(d2[i])[:10] if d2[i, j] < np.inf]
            got = [j for j, p in zip(np.asarray(res.ids)[i],
                                     np.asarray(res.primary)[i]) if p == 0]
            if gt:
                out.append(len(set(gt) & set(got)) / len(gt))
        return np.mean(out)
    assert rec(r_sc) > rec(r_bm) - 0.05, (rec(r_bm), rec(r_sc))
