"""Compound filter expression trees: the public And/Or/Not filter surface.

Covers: (1) the operator algebra — ``&``/``|``/``~`` build flattened trees
with structural ``kind`` signatures, double negation cancels, raw
FilterBatch operands coerce; (2) ``as_filter`` normalization — a
single-leaf expression IS its atomic FilterBatch (same results, same
executor cache key, zero new compilations); (3) compound ``search_auto``
bit-identity with the ``exact_filtered_knn`` oracle on every forced route
and through the streaming delta merge; (4) planner JOINT selectivity
sampling (the probe evaluates the whole tree, so correlated clauses
estimate at their true co-occurrence rate) and clause reordering
(result-identical, strictly fewer short-circuit evals with the rare clause
first; validity vectors let the greedy order see correlations); (5) the
deprecation shim, ``explain(filt=)``, and ``joint_table`` validation.
"""
import warnings

import numpy as np
import pytest

from repro.core import filters as F
from repro.core.filters import (And, FilterBatch, Label, Leaf, Not, Or,
                                Range, as_filter, describe, joint_table,
                                n_leaves)
from repro.core.ground_truth import exact_filtered_knn
from repro.core.jag import JAGConfig, JAGIndex
from repro.serve.planner import (PlannerConfig, clause_eval_cost,
                                 estimate_selectivity, explain,
                                 leaf_selectivities, plan, plan_per_query,
                                 reorder_clauses)
from repro.stream import StreamingJAGIndex

N, D, B = 400, 8, 8
LS = 256          # parity beam: graph/postfilter saturate the tiny index
CFG = JAGConfig(degree=12, ls_build=24, batch_size=128, cand_pool=48,
                calib_samples=32, n_seeds=6)

# threshold configs that force ONE route everywhere (the planner refuses
# inverted ladders, so "force graph" narrows both thresholds outward)
FORCE = {"prefilter": PlannerConfig(prefilter_max_sel=1.1,
                                    postfilter_min_sel=1.2),
         "graph": PlannerConfig(prefilter_max_sel=0.0,
                                postfilter_min_sel=1.1),
         "postfilter": PlannerConfig(prefilter_max_sel=0.0,
                                     postfilter_min_sel=1e-9)}

_STATE = {}


def _setup():
    """One label+range composite index + queries, shared per session."""
    if "idx" not in _STATE:
        rng = np.random.default_rng(5)
        xb = rng.normal(size=(N, D)).astype(np.float32)
        labels = rng.integers(0, 4, N).astype(np.int32)
        labels[: N // 50] = 9                       # rare label, sel ~0.02
        vals = rng.uniform(0, 1, N).astype(np.float32)
        tab = joint_table(F.label_table(labels), F.range_table(vals))
        idx = JAGIndex.build(xb, tab, CFG)
        q = (xb[rng.integers(0, N, B)]
             + 0.1 * rng.normal(size=(B, D))).astype(np.float32)
        _STATE["idx"] = (idx, q, labels, vals)
    return _STATE["idx"]


def _np_valid(expr, labels, vals):
    """Numpy reference validity [B, N] for label/range trees."""
    if isinstance(expr, Leaf):
        return _np_valid(expr.filt, labels, vals)
    if isinstance(expr, And):
        out = _np_valid(expr.children[0], labels, vals)
        for c in expr.children[1:]:
            out = out & _np_valid(c, labels, vals)
        return out
    if isinstance(expr, Or):
        out = _np_valid(expr.children[0], labels, vals)
        for c in expr.children[1:]:
            out = out | _np_valid(c, labels, vals)
        return out
    if isinstance(expr, Not):
        return ~_np_valid(expr.child, labels, vals)
    if expr.kind == F.LABEL:
        return labels[None, :] == np.asarray(expr.data["label"])[:, None]
    lo = np.asarray(expr.data["lo"])[:, None]
    hi = np.asarray(expr.data["hi"])[:, None]
    return (vals[None, :] >= lo) & (vals[None, :] <= hi)


# ---------------------------------------------------------------------------
# operator algebra, signatures, normalization
# ---------------------------------------------------------------------------

def test_operators_build_flattened_trees_with_structural_kinds():
    a, b, c = Label(1), Range(0.0, 0.5), Label(2)
    expr = a & b & c
    assert isinstance(expr, And) and len(expr.children) == 3
    assert expr.kind == "(label&range&label)"
    assert n_leaves(expr) == 3 and expr.batch == 1
    either = a | b
    assert isinstance(either, Or) and either.kind == "(label|range)"
    neg = ~a
    assert isinstance(neg, Not) and neg.kind == "~label"
    assert ~neg is a                       # double negation cancels
    mixed = (a & b) | ~c
    assert mixed.kind == "((label&range)|~label)"
    assert repr(mixed) == f"FilterExpr<{describe(mixed)}>"
    assert describe(a & b) == "(label=1 & range[0,0.5])"
    # raw FilterBatch operands coerce on either side
    raw = F.range_filters(np.zeros(1), np.ones(1))
    assert (raw & a).kind == "(range&label)" and isinstance(raw & a, And)
    assert (a | raw).kind == "(label|range)"
    with pytest.raises(ValueError, match=">= 2"):
        And(a)
    with pytest.raises(TypeError):
        a & 3


def test_as_filter_normalizes_single_leaf_to_its_batch():
    leaf = Range(0.1, 0.9)
    got = as_filter(leaf)
    assert isinstance(got, FilterBatch) and got is leaf.filt
    raw = F.label_filters(np.zeros(3, np.int32))
    assert as_filter(raw) is raw
    tree = leaf & Label(0)
    assert as_filter(tree) is tree         # compound passes through
    with pytest.raises(TypeError):
        as_filter("label")
    assert n_leaves(raw) == 1 and n_leaves(tree) == 2


def test_lane_and_take_slice_every_leaf_in_lockstep():
    expr = Label(np.arange(6)) & Range(np.linspace(0, 1, 6), np.ones(6))
    sub = expr.take(np.asarray([4, 1], np.int32))
    assert isinstance(sub, And) and sub.batch == 2
    l0, l1 = sub.leaves()
    np.testing.assert_array_equal(np.asarray(l0.data["label"]), [4, 1])
    np.testing.assert_allclose(np.asarray(l1.data["lo"]),
                               [0.8, 0.2], atol=1e-6)
    one = expr.lane(3)
    assert one.batch == 1
    assert int(one.leaves()[0].data["label"][0]) == 3


def test_deprecated_filter_batch_constructor_warns():
    with pytest.warns(DeprecationWarning, match="Label/Range"):
        fb = F.filter_batch(F.LABEL, {"label": np.zeros(2, np.int32)})
    assert isinstance(fb, FilterBatch) and fb.kind == F.LABEL
    # the expression constructors stay warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        Label(1) & Range(0, 1)


def test_joint_table_validation():
    lab = F.label_table(np.zeros(5, np.int32))
    rng_t = F.range_table(np.zeros(5, np.float32))
    t = joint_table(lab, rng_t)
    assert t.kind == "label+range" and t.n == 5
    assert set(t.data) == {"label", "value"}
    with pytest.raises(ValueError, match=">= 2"):
        joint_table(lab)
    with pytest.raises(ValueError, match="duplicate"):
        joint_table(lab, F.label_table(np.ones(5, np.int32)))
    with pytest.raises(ValueError, match="atomic"):
        joint_table(t, F.subset_table(np.zeros((5, 8), bool), 8))
    with pytest.raises(ValueError, match="row counts"):
        joint_table(lab, F.range_table(np.zeros(4, np.float32)))
    sub8 = F.subset_table(np.zeros((5, 8), bool), 8)
    boo4 = F.boolean_table(np.zeros(5, np.uint32), 4)
    with pytest.raises(ValueError, match="n_bits"):
        joint_table(sub8, boo4)
    with pytest.raises(ValueError, match="bit_weights"):
        joint_table(lab, F.subset_table(np.zeros((5, 8), bool), 8,
                                        bit_weights=np.ones(8)))


# ---------------------------------------------------------------------------
# matches / selectivity composition
# ---------------------------------------------------------------------------

def test_compound_matches_equals_numpy_composition():
    idx, _, labels, vals = _setup()
    lo = np.linspace(0, 0.5, B).astype(np.float32)
    exprs = [
        Label(np.full(B, 9)) & Range(lo, lo + 0.4),
        Label(np.full(B, 1)) | Label(np.full(B, 2)),
        ~Range(lo, np.ones(B, np.float32)),
        (Label(np.full(B, 9)) | ~Range(np.zeros(B, np.float32), lo))
        & Range(np.zeros(B, np.float32), np.full(B, 0.9, np.float32)),
    ]
    for expr in exprs:
        got = np.asarray(F.matches_all(expr, idx.attr))
        np.testing.assert_array_equal(got, _np_valid(expr, labels, vals),
                                      err_msg=expr.kind)


def test_estimate_selectivity_is_joint_and_bounds():
    idx, _, labels, vals = _setup()
    ids = np.arange(N, dtype=np.int32)        # exact probe
    a = Label(np.full(B, 2))
    b = Range(np.zeros(B, np.float32), np.full(B, 0.3, np.float32))
    ok_a = _np_valid(a, labels, vals)          # [B, N] reference validity
    ok_b = _np_valid(b, labels, vals)
    sa = np.asarray(estimate_selectivity(as_filter(a), idx.attr, ids))
    sb = np.asarray(estimate_selectivity(as_filter(b), idx.attr, ids))
    s_and = np.asarray(estimate_selectivity(a & b, idx.attr, ids))
    s_or = np.asarray(estimate_selectivity(a | b, idx.attr, ids))
    s_not = np.asarray(estimate_selectivity(~a, idx.attr, ids))
    # JOINT semantics: the whole-tree probe equals the mean of the boolean
    # combination on the probe rows, not an independence composition
    np.testing.assert_allclose(s_and, (ok_a & ok_b).mean(axis=1), atol=1e-6)
    np.testing.assert_allclose(s_or, (ok_a | ok_b).mean(axis=1), atol=1e-6)
    np.testing.assert_allclose(s_not, 1 - sa, atol=1e-6)
    np.testing.assert_allclose(sa, ok_a.mean(axis=1), atol=1e-6)
    np.testing.assert_allclose(sb, ok_b.mean(axis=1), atol=1e-6)
    for s in (s_and, s_or, s_not):
        assert (s >= 0).all() and (s <= 1).all()
    # joint bounds are exact, not just approximate
    assert (s_and <= np.minimum(sa, sb) + 1e-6).all()
    assert (s_or >= np.maximum(sa, sb) - 1e-6).all()
    # leaf probe: DFS order, [L, B]
    ls = np.asarray(leaf_selectivities(a & b, idx.attr, ids))
    assert ls.shape == (2, B)
    np.testing.assert_allclose(ls[0], sa, atol=1e-6)
    np.testing.assert_allclose(ls[1], sb, atol=1e-6)
    # validity probe: DFS order, [L, B, S]
    from repro.serve.planner import leaf_validity
    lv = np.asarray(leaf_validity(a & b, idx.attr, ids))
    assert lv.shape == (2, B, N) and lv.dtype == bool
    np.testing.assert_array_equal(lv[0], ok_a)
    np.testing.assert_array_equal(lv[1], ok_b)


def _correlated_table():
    """1000 rows where labels IMPLY range bands (deterministic fractions).

    value[i] = (i + .5)/1000; label 7 <=> value in [0, .38) u (.5, .52),
    label 8 <=> value in (.55, .65), else i % 4. So Label(8) coincides
    exactly with Range(.55, .65): joint sel 0.1, independence product 0.01.
    """
    n2 = 1000
    vals = ((np.arange(n2) + 0.5) / n2).astype(np.float32)
    labels = (np.arange(n2) % 4).astype(np.int32)
    labels[(vals < 0.38) | ((vals > 0.5) & (vals < 0.52))] = 7
    labels[(vals > 0.55) & (vals < 0.65)] = 8
    tab = joint_table(F.label_table(labels), F.range_table(vals))
    return tab, labels, vals


def test_correlated_clauses_route_on_joint_not_independence():
    # satellite: a label that implies a range band — independence says
    # sel = 0.1 * 0.1 = 0.01 (prefilter band), the truth is 0.1 (graph
    # band): >2x wrong would mis-route every query to the exact scan
    tab, labels, vals = _correlated_table()
    ids = np.arange(tab.n, dtype=np.int32)
    expr = Label(np.full(B, 8)) & Range(np.full(B, 0.55, np.float32),
                                        np.full(B, 0.65, np.float32))
    s = np.asarray(estimate_selectivity(expr, tab, ids))
    sa, sb = np.asarray(leaf_selectivities(expr, tab, ids))
    np.testing.assert_allclose(s, 0.1, atol=1e-6)          # true joint
    np.testing.assert_allclose(sa * sb, 0.01, atol=1e-6)   # indep estimate
    assert (s / (sa * sb) > 2.0).all()                     # >2x wrong
    p = plan(expr, tab, PlannerConfig(n_samples=tab.n))
    assert p.route == "graph"              # joint 0.1: the graph band
    # the independence product would have dropped into the prefilter band
    assert float(np.median(sa * sb)) <= PlannerConfig().prefilter_max_sel
    pq = plan_per_query(expr, tab, PlannerConfig(n_samples=tab.n))
    assert all(r == "graph" for r in pq.routes)


def test_reorder_with_validity_vectors_sees_correlations():
    # A = range[0,.4] (sel .4), B = label 7 (sel .4, but A&B = .38 — B is
    # nearly redundant given A), C = range[.25,.75] (sel .5, A&C = .15).
    # Independence orders A,B,C (B's marginal ties A's); the joint greedy
    # sees B's conditional kill power is ~0 after A and orders A,C,B.
    from repro.serve.planner import leaf_validity
    tab, labels, vals = _correlated_table()
    ids = np.arange(tab.n, dtype=np.int32)
    A = Range(np.full(B, 0.0, np.float32), np.full(B, 0.4, np.float32))
    Bc = Label(np.full(B, 7))
    C = Range(np.full(B, 0.25, np.float32), np.full(B, 0.75, np.float32))
    expr = A & Bc & C
    lv = np.asarray(leaf_validity(expr, tab, ids))
    vecs = list(lv.reshape(lv.shape[0], -1))   # pooled, like the executor
    joint_order = reorder_clauses(expr, vecs)
    indep_order = reorder_clauses(expr, [0.4, 0.4, 0.5])
    assert indep_order.kind == "(range&label&range)"   # A, B, C
    assert joint_order.kind == "(range&range&label)"   # A, C, B
    # the joint order short-circuits strictly cheaper ON THE TRUE DATA
    c_joint = clause_eval_cost(joint_order, [vecs[0], vecs[2], vecs[1]])
    c_indep = clause_eval_cost(indep_order, vecs)
    assert c_joint < c_indep
    np.testing.assert_allclose(c_indep, 1 + 0.4 + 0.38, atol=1e-3)
    np.testing.assert_allclose(c_joint, 1 + 0.4 + 0.15, atol=1e-3)
    # result-identical, strictly fewer measured short-circuit evals
    rng = np.random.default_rng(11)
    xb = rng.normal(size=(tab.n, D)).astype(np.float32)
    q = xb[:B] + 0.05 * rng.normal(size=(B, D)).astype(np.float32)
    gt_i = exact_filtered_knn(xb, tab, q, indep_order, k=10)
    gt_j = exact_filtered_knn(xb, tab, q, joint_order, k=10)
    np.testing.assert_array_equal(np.asarray(gt_i.ids), np.asarray(gt_j.ids))
    np.testing.assert_array_equal(np.asarray(gt_i.d2), np.asarray(gt_j.d2))
    assert (np.asarray(gt_j.n_feval) < np.asarray(gt_i.n_feval)).all()


# ---------------------------------------------------------------------------
# end-to-end: single-leaf bit-identity, compound oracle identity per route
# ---------------------------------------------------------------------------

def test_single_leaf_expression_bit_identical_to_atomic_path():
    idx, q, _, vals = _setup()
    lo = np.zeros(B, np.float32)
    hi = np.full(B, 0.6, np.float32)
    raw = F.range_filters(lo, hi)
    before = set(idx.executor.cache_keys())
    want = idx.search(q, raw, k=10, ls=48)
    mid = set(idx.executor.cache_keys())
    got = idx.search(q, Range(lo, hi), k=10, ls=48)
    after = set(idx.executor.cache_keys())
    for f in want._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)),
                                      err_msg=f)
    # the leaf ran THROUGH the atomic compilation: no new cache entries
    assert mid - before and after == mid


def _oracle(idx, q, expr, k=10):
    return exact_filtered_knn(idx.xb, idx.attr, q, expr, k=k)


@pytest.mark.parametrize("route", ["prefilter", "graph", "postfilter"])
def test_compound_search_auto_matches_oracle_on_every_route(route):
    idx, q, _, _ = _setup()
    lo = np.zeros(B, np.float32)
    # band the composed selectivity so each forced route can saturate:
    # postfilter needs a wide filter, prefilter/graph take the rare mix
    if route == "postfilter":
        expr = (Range(lo, np.full(B, 0.95, np.float32))
                | Label(np.full(B, 9)))
    else:
        expr = (Label(np.full(B, 9)) | Label(np.full(B, 1))) \
            & Range(lo, np.full(B, 0.7, np.float32))
    res, p = idx.search_auto(q, expr, k=10, ls=LS, planner=FORCE[route],
                             return_plan=True, mode="batch")
    assert p.route == route
    gt = _oracle(idx, q, expr)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(gt.ids),
                                  err_msg=route)
    if route == "prefilter":
        np.testing.assert_array_equal(np.asarray(res.secondary),
                                      np.asarray(gt.d2))


def test_compound_per_query_dispatch_bit_identical_to_solo_routes():
    from repro.serve.dispatch import run_route
    idx, q, _, _ = _setup()
    # mixed lanes: half rare-AND (prefilter band), half wide (post band)
    hi = np.where(np.arange(B) % 2 == 0, 0.02, 0.95).astype(np.float32)
    expr = Range(np.zeros(B, np.float32), hi) & ~Label(np.full(B, 3))
    res, p = idx.search_auto(q, expr, k=10, ls=48, return_plan=True)
    assert len(p.groups) >= 2              # the batch really split
    for i in range(B):
        solo = run_route(idx.executor, p.routes[i], q[i:i + 1],
                         expr.take(np.asarray([i], np.int32)), k=10,
                         ls=48, max_iters=96)
        for f in ("ids", "primary", "secondary", "n_dist"):
            np.testing.assert_array_equal(
                np.asarray(getattr(res, f))[i],
                np.asarray(getattr(solo, f))[0],
                err_msg=(f, i, p.routes[i]))


def test_streaming_delta_merge_compound_matches_oracle():
    idx, q, labels, vals = _setup()
    rng = np.random.default_rng(13)
    s = StreamingJAGIndex(idx, compact_frac=0.9)
    m = 60
    xv = rng.normal(size=(m, D)).astype(np.float32)
    dl = rng.integers(0, 4, m).astype(np.int32)
    dv = rng.uniform(0, 1, m).astype(np.float32)
    s.insert(xv, joint_table(F.label_table(dl), F.range_table(dv)),
             auto_compact=False)
    assert s.delta.n == m
    expr = (Label(np.full(B, 9)) | Label(np.full(B, 2))) \
        & Range(np.zeros(B, np.float32), np.full(B, 0.8, np.float32))
    res = s.search_auto(q, expr, k=10, ls=LS, planner=FORCE["prefilter"])
    xb_all = np.concatenate([np.asarray(idx.xb), xv], axis=0)
    gt = exact_filtered_knn(xb_all, s.attr, q, expr, k=10)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(gt.ids))
    np.testing.assert_array_equal(np.asarray(res.secondary),
                                  np.asarray(gt.d2))


# ---------------------------------------------------------------------------
# clause reordering: result-identical, strictly fewer short-circuit evals
# ---------------------------------------------------------------------------

def test_reorder_clauses_puts_rare_clause_first_and_cuts_evals():
    idx, q, labels, vals = _setup()
    wide = Range(np.zeros(B, np.float32), np.full(B, 0.9, np.float32))
    rare = Label(np.full(B, 9))
    fixed = wide & rare                    # deliberately worst order
    ids = np.arange(N, dtype=np.int32)
    sels = np.median(np.asarray(leaf_selectivities(fixed, idx.attr, ids)),
                     axis=1)
    better = reorder_clauses(fixed, sels)
    assert better.kind == "(label&range)"  # rare clause moved first
    assert clause_eval_cost(better, [sels[1], sels[0]]) \
        < clause_eval_cost(fixed, sels)
    gt_fixed = exact_filtered_knn(idx.xb, idx.attr, q, fixed, k=10)
    gt_best = exact_filtered_knn(idx.xb, idx.attr, q, better, k=10)
    np.testing.assert_array_equal(np.asarray(gt_fixed.ids),
                                  np.asarray(gt_best.ids))
    np.testing.assert_array_equal(np.asarray(gt_fixed.d2),
                                  np.asarray(gt_best.d2))
    assert (np.asarray(gt_best.n_feval)
            < np.asarray(gt_fixed.n_feval)).all()
    # atomic filters pass through untouched
    assert reorder_clauses(as_filter(rare), sels[:1]) is as_filter(rare)


def test_executor_prefilter_reorders_compound_automatically():
    idx, q, _, _ = _setup()
    wide = Range(np.zeros(B, np.float32), np.full(B, 0.9, np.float32))
    rare = Label(np.full(B, 9))
    got = idx.executor.prefilter(q, wide & rare, k=10)
    want = idx.executor.prefilter(q, rare & wide, k=10)
    for f in ("ids", "primary", "secondary"):
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(want, f)),
                                      err_msg=f)
    # both spellings reorder to the same canonical tree -> ONE scan
    # compilation for the pair (plus the shared leaf-selectivity probe)
    keys = [k for k in idx.executor.cache_keys() if k[0] == "prefilter"
            and str(k[6]) in ("(label&range)", "(range&label)")]
    assert {str(k[6]) for k in keys} == {"(label&range)"}
    assert any(k[0] == "leafval" for k in idx.executor.cache_keys())


def test_or_reorder_puts_common_clause_first():
    # Or accepts cheap-and-likely first: cost/sel ascending
    sels = [0.02, 0.9]
    rare_first = Label(np.full(B, 9)) | Range(np.zeros(B, np.float32),
                                              np.full(B, 0.9, np.float32))
    best = reorder_clauses(rare_first, sels)
    assert best.kind == "(range|label)"
    assert clause_eval_cost(best, [0.9, 0.02]) \
        < clause_eval_cost(rare_first, sels)


# ---------------------------------------------------------------------------
# plumbing: explain, plans, cost-router clause count
# ---------------------------------------------------------------------------

def test_explain_prints_the_expression():
    idx, q, _, _ = _setup()
    expr = Label(np.full(B, 9)) & Range(np.zeros(B, np.float32),
                                        np.full(B, 0.5, np.float32))
    p = plan(expr, idx.attr, PlannerConfig())
    line = explain(p, PlannerConfig(), filt=expr)
    assert "filter=(label=9 & range[0,0.5])" in line
    assert f"route={p.route}" in line
    pq = plan_per_query(expr, idx.attr, PlannerConfig())
    assert "filter=" in explain(pq, PlannerConfig(), filt=expr)


def test_search_auto_compound_threads_clause_count_to_router():
    idx, q, _, _ = _setup()
    expr = Label(np.full(B, 2)) & Range(np.zeros(B, np.float32),
                                        np.full(B, 0.5, np.float32))
    r = idx.executor.cost_router(k=10, ls=48, filt=expr)
    assert r is None                       # no model attached here
    # but the clause count plumbs through when a model exists
    from repro.cost import fit, Observation, phi
    rng = np.random.default_rng(0)
    obs = []
    for route, w in (("prefilter", [2.0, 0.5, 0.1, 0.3]),
                     ("graph", [1.0, 0.8, -0.3, 0.2]),
                     ("postfilter", [1.5, 0.7, 0.1, 0.05])):
        for _ in range(12):
            f = dict(sel=float(rng.uniform(0.01, 1.0)),
                     n=int(rng.integers(500, 50000)),
                     d=int(rng.integers(8, 128)),
                     ls=int(rng.choice([32, 64])), k=10,
                     n_clauses=int(rng.integers(1, 5)))
            obs.append(Observation(route, f,
                                   us=float(np.exp(phi(route, f)
                                                   @ np.asarray(w)))))
    try:
        idx.attach_cost_model(fit(obs, dict(backend="cpu")))
        r2 = idx.executor.cost_router(k=10, ls=48, filt=expr)
        assert r2 is not None and r2.n_leaves == 2
        r1 = idx.executor.cost_router(k=10, ls=48)
        assert r1.n_leaves == 1
    finally:
        idx.attach_cost_model(None)
