"""Dry-run cell construction + lowering smoke (subprocess: 512 fake
devices). Full compiles live in launch/dryrun.py; here we verify the
registry produces lowerable cells for one representative of each family
quickly (trace-only)."""
import os
import subprocess
import sys


def test_trace_representative_cells():
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
import jax
from repro.configs import make_cell
from repro.distributed.sharding import use_rules
from repro.launch.mesh import make_production_mesh, set_mesh
cells = [("fm", "retrieval_cand"), ("gcn-cora", "molecule"),
         ("qwen3-1.7b", "decode_32k"), ("jag", "serve_1b")]
for mp in (False, True):
    mesh = make_production_mesh(multi_pod=mp)
    for arch, shape in cells:
        cell = make_cell(arch, shape, mesh)
        with set_mesh(mesh), use_rules(cell["rules"]):
            jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                    out_shardings=cell["out_shardings"],
                    donate_argnums=cell["donate_argnums"]).lower(
                        *cell["args"])
print("TRACE_OK")
'''
    r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                       capture_output=True, text=True, timeout=900,
                       env=dict(os.environ, PYTHONPATH="src"))
    assert "TRACE_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]


def test_registry_counts():
    from repro.configs import all_archs, get
    archs = all_archs()
    cells = sum(len(get(a).shapes) for a in archs if a != "jag")
    assert cells == 40, cells  # the assigned 40 (arch x shape) cells
    assert len(get("jag").shapes) == 2
