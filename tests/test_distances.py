"""Unit + property tests for filter/attribute distances (paper §3.1)."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import filters as F
from repro.core import distances as D


def _as2d(x):
    return jnp.asarray(x)[None, :]


class TestLabel:
    def test_dist_f_validity(self):
        filt = F.label_filters([3])
        attrs = {"label": jnp.asarray([[3, 4, 3, 0]])}
        df = D.dist_f(filt, attrs)
        g = F.matches(filt, attrs)
        np.testing.assert_array_equal(np.asarray(df) == 0, np.asarray(g))

    def test_dist_a(self):
        a1 = {"label": jnp.asarray([2])}
        a2 = {"label": jnp.asarray([[2, 5]])}
        np.testing.assert_array_equal(
            np.asarray(D.dist_a(F.LABEL, a1, a2)), [[0.0, 1.0]])


class TestRange:
    @given(st.floats(-100, 100), st.floats(0, 50), st.floats(-200, 200))
    @settings(max_examples=50, deadline=None)
    def test_validity_consistency(self, lo, width, a):
        lo, a = np.float32(lo), np.float32(a)
        hi = np.float32(lo + np.float32(width))
        filt = F.range_filters([lo], [hi])
        attrs = {"value": jnp.asarray([[a]], jnp.float32)}
        df = float(D.dist_f(filt, attrs)[0, 0])
        inside = bool(lo <= a <= hi)
        assert (df == 0.0) == inside
        if not inside:  # distance equals the gap to the nearest boundary
            gap = float(lo - a) if a < lo else float(a - hi)
            assert df == pytest.approx(gap, rel=1e-5, abs=1e-3)

    def test_dist_a_metric(self):
        a1 = {"value": jnp.asarray([1.0])}
        a2 = {"value": jnp.asarray([[1.0, 4.5, -2.0]])}
        np.testing.assert_allclose(
            np.asarray(D.dist_a(F.RANGE, a1, a2)), [[0.0, 3.5, 3.0]])


class TestSubset:
    @given(st.integers(1, 64), st.integers(0, 2 ** 30), st.integers(0, 2 ** 30))
    @settings(max_examples=50, deadline=None)
    def test_dist_f_is_deficit(self, L, fa, aa):
        L = max(L, 31)
        f = np.array([(fa >> i) & 1 for i in range(L)], bool)
        a = np.array([(aa >> i) & 1 for i in range(L)], bool)
        filt = F.subset_filters(f[None], L)
        attrs = {"bits": F.pack_bits(a[None, None])}
        df = int(D.dist_f(filt, attrs)[0, 0])
        assert df == int((f & ~a).sum())
        assert (df == 0) == bool(F.matches(filt, attrs)[0, 0])

    def test_dist_a_hamming(self):
        a = np.zeros((2, 40), bool)
        a[1, :7] = True
        t = F.subset_table(a, 40)
        a1 = {"bits": t.data["bits"][0:1]}
        da = D.dist_a(F.SUBSET, a1, {"bits": t.data["bits"][None]})
        np.testing.assert_array_equal(np.asarray(da), [[0.0, 7.0]])

    def test_weighted_dist_a(self):
        bits = np.array([[1, 1, 0], [1, 0, 1]], bool)
        w = np.array([0.5, 2.0, 1.0], np.float32)
        t = F.subset_table(bits, 3, bit_weights=w)
        a1 = {"bits": t.data["bits"][0:1], "bit_weights": t.data["bit_weights"]}
        a2 = {"bits": t.data["bits"][None], "bit_weights": t.data["bit_weights"]}
        da = np.asarray(D.dist_a(F.SUBSET, a1, a2))
        # C = sum(w) = 3.5; overlap(0,0)=2.5 -> 1.0; overlap(0,1)=0.5 -> 3.0
        np.testing.assert_allclose(da, [[1.0, 3.0]], rtol=1e-6)


class TestBoolean:
    def test_table_is_hypercube_bfs(self):
        L = 6
        size = 1 << L
        rng = np.random.default_rng(0)
        sat = rng.random(size) < 0.1
        sat[3] = True
        table = np.asarray(F.bool_dist_table(jnp.asarray(sat[None]), L))[0]
        # brute-force reference
        sat_ids = np.flatnonzero(sat)
        for a in range(size):
            ref = min(bin(a ^ s).count("1") for s in sat_ids)
            assert table[a] == ref, (a, table[a], ref)

    def test_validity(self):
        L = 5
        rng = np.random.default_rng(1)
        sat = rng.random((3, 1 << L)) < 0.3
        sat[:, 0] = True
        filt = F.boolean_filters(sat, L)
        assign = jnp.asarray(rng.integers(0, 1 << L, (3, 8)), jnp.uint32)
        attrs = {"assign": assign}
        df = np.asarray(D.dist_f(filt, attrs))
        g = np.asarray(F.matches(filt, attrs))
        np.testing.assert_array_equal(df == 0, g)


class TestCapped:
    @given(st.floats(0, 10), st.floats(0, 10))
    @settings(max_examples=30, deadline=None)
    def test_capped(self, da, t):
        c = float(D.capped(jnp.float32(da), jnp.float32(t)))
        assert c == pytest.approx(max(np.float32(da) - np.float32(t), 0.0),
                                  rel=1e-6, abs=1e-6)


def test_selectivity_matches_bruteforce():
    from repro.data.synthetic import msturing_subset
    ds = msturing_subset(n=2000, b=32, seed=3)
    sel = np.asarray(F.selectivity(ds.filt, ds.attr))
    np.testing.assert_allclose(sel, ds.selectivity, atol=1e-6)
