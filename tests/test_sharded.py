"""Sharded serving: shard_map routes + exact cross-shard top-k merge.

Covers: (1) S=1 bit-identity of the sharded ``search_auto`` surface with a
single-device index over the same rows — the exact-scan route across all
four filter kinds and a compound expression is identical on EVERY
SearchResult field, the graph route on everything but the (deliberately
width-0) vlog; (2) the 8-fake-device subprocess acceptance test: sharded
results bit-identical to a single-device index built over the union of
shard rows, all four kinds + compound; (3) construction validation
(divisibility, mesh axis, shard row-count mismatch, too few devices);
(4) cost routing at the per-shard shape through an InterpolatedCostModel.

Multi-device cases run in a subprocess with faked host devices so the rest
of the suite keeps seeing 1 device.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import filters as F
from repro.core.jag import JAGConfig, JAGIndex
from repro.core.filters import AttrTable, Label, Range, joint_table
from repro.serve.planner import PlannerConfig
from repro.serve.sharded import ShardedJAGIndex, shard_index

N, D, B = 400, 8, 6
CFG = JAGConfig(degree=10, ls_build=16, batch_size=128, cand_pool=32,
                calib_samples=32, n_seeds=4)
# the documented force-exact planner: prefilter everywhere -> both sides
# run the same masked scan, so results must be bitwise equal
FORCE_PRE = PlannerConfig(prefilter_max_sel=1.1, postfilter_min_sel=1.2)

_STATE = {}


def _mk_dataset(kind, rng):
    """(attr table, per-query filter) with mid-band selectivity."""
    if kind == F.RANGE:
        tab = F.range_table(rng.uniform(0, 1, N).astype(np.float32))
        filt = F.range_filters(np.zeros(B, np.float32),
                               np.full(B, 0.2, np.float32))
    elif kind == F.LABEL:
        tab = F.label_table(rng.integers(0, 5, N).astype(np.int32))
        filt = F.label_filters(np.full(B, 2))
    elif kind == F.SUBSET:
        tab = F.subset_table(rng.random((N, 16)) < 0.5, 16)
        fb = np.zeros((B, 16), bool)
        fb[:, :3] = True
        filt = F.subset_filters(fb, 16)
    else:  # BOOLEAN
        nv, size = 8, 1 << 8
        tab = F.boolean_table(rng.integers(0, size, N).astype(np.uint32),
                              nv)
        sat = np.zeros((B, size), bool)
        for i in range(B):
            sat[i, rng.choice(size, 64, replace=False)] = True
        filt = F.boolean_filters(sat, nv)
    return tab, filt


def _setup(kind):
    if kind not in _STATE:
        rng = np.random.default_rng(hash(kind) % 2**31)
        xb = rng.normal(size=(N, D)).astype(np.float32)
        tab, filt = _mk_dataset(kind, rng)
        q = (xb[rng.integers(0, N, B)]
             + 0.1 * rng.normal(size=(B, D))).astype(np.float32)
        union = JAGIndex.build(xb, tab, CFG)
        sharded = ShardedJAGIndex.build(xb, tab, CFG, n_shards=1)
        _STATE[kind] = (union, sharded, q, filt)
    return _STATE[kind]


def _assert_bitwise(got, want, fields=None, msg=""):
    for f in fields or want._fields:
        a, b = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert a.shape == b.shape, (msg, f, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f"{msg}:{f}")


# ---------------------------------------------------------------------------
# S=1 bit-identity on the in-process single device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
def test_s1_search_auto_exact_route_bit_identical(kind):
    union, sharded, q, filt = _setup(kind)
    want = union.search_auto(q, filt, k=10, ls=32, planner=FORCE_PRE)
    got = sharded.search_auto(q, filt, k=10, ls=32, planner=FORCE_PRE)
    _assert_bitwise(got, want, msg=kind)


def test_s1_compound_expression_bit_identical():
    union, sharded, q, _ = _setup(F.LABEL)
    # rebuild both over a joint table so a compound tree applies
    rng = np.random.default_rng(7)
    xb = rng.normal(size=(N, D)).astype(np.float32)
    labels = rng.integers(0, 4, N).astype(np.int32)
    vals = rng.uniform(0, 1, N).astype(np.float32)
    tab = joint_table(F.label_table(labels), F.range_table(vals))
    union = JAGIndex.build(xb, tab, CFG)
    sharded = ShardedJAGIndex.build(xb, tab, CFG, n_shards=1)
    q = (xb[rng.integers(0, N, B)]
         + 0.1 * rng.normal(size=(B, D))).astype(np.float32)
    expr = (Label(np.full(B, 2)) | Label(np.full(B, 3))) \
        & Range(np.zeros(B, np.float32), np.full(B, 0.7, np.float32))
    want = union.search_auto(q, expr, k=10, ls=32, planner=FORCE_PRE)
    got = sharded.search_auto(q, expr, k=10, ls=32, planner=FORCE_PRE)
    _assert_bitwise(got, want, msg="compound")


def test_s1_graph_route_parity():
    union, sharded, q, filt = _setup(F.RANGE)
    want = union.search(q, filt, k=10, ls=32)
    got = sharded.search(q, filt, k=10, ls=32)
    # one shard = the same graph, entries, and traversal; the sharded
    # routes deliberately emit the width-0 vlog (shard-local logs are
    # id-ambiguous after globalization), so compare everything else
    _assert_bitwise(got, want,
                    fields=("ids", "primary", "secondary", "n_expanded",
                            "n_dist"), msg="graph")
    assert np.asarray(got.vlog).shape == (B, 0)


def test_s1_postfilter_route_parity():
    union, sharded, q, _ = _setup(F.RANGE)
    wide = F.range_filters(np.zeros(B, np.float32),
                           np.full(B, 0.95, np.float32))
    want = union.executor.postfilter(q, wide, k=10, ls=32, max_iters=64)
    got = sharded.executor.postfilter(q, wide, k=10, ls=32, max_iters=64)
    _assert_bitwise(got, want,
                    fields=("ids", "primary", "secondary", "n_expanded",
                            "n_dist"), msg="postfilter")


def test_shard_convenience_and_unfiltered():
    union, _, q, filt = _setup(F.RANGE)
    sh = union.shard(1)
    assert isinstance(sh, ShardedJAGIndex) and sh.n_shards == 1
    got = sh.executor.unfiltered(q, k=10, ls=32, max_iters=64)
    want = union.search_unfiltered(q, k=10, ls=32, max_iters=64)
    _assert_bitwise(got, want,
                    fields=("ids", "primary", "secondary"), msg="unfilt")
    assert shard_index(union, 1).n_shards == 1


# ---------------------------------------------------------------------------
# construction validation
# ---------------------------------------------------------------------------

def test_build_validation():
    rng = np.random.default_rng(0)
    xb = rng.normal(size=(30, 4)).astype(np.float32)
    tab = F.range_table(rng.uniform(0, 1, 30).astype(np.float32))
    # serve_mesh guards the device count before any row math (this process
    # sees 1 device; divisibility is asserted in the 8-device subprocess)
    with pytest.raises(ValueError, match="devices"):
        ShardedJAGIndex.build(xb, tab, CFG, n_shards=3)
    with pytest.raises(ValueError, match="pass n_shards"):
        ShardedJAGIndex.build(xb, tab, CFG)


def test_from_shards_validation():
    rng = np.random.default_rng(1)
    mk = lambda n: JAGIndex.build(  # noqa: E731
        rng.normal(size=(n, 4)).astype(np.float32),
        F.range_table(rng.uniform(0, 1, n).astype(np.float32)), CFG)
    with pytest.raises(ValueError, match="at least one"):
        ShardedJAGIndex.from_shards([])
    with pytest.raises(ValueError, match="same row count"):
        ShardedJAGIndex.from_shards([mk(20), mk(30)])


# ---------------------------------------------------------------------------
# cost routing at the per-shard shape
# ---------------------------------------------------------------------------

def _grid_model(n, d, scale=1.0):
    from repro.cost import CostModel, Observation, fit, phi
    rng = np.random.default_rng(int(n))
    obs = []
    for route, w in (("prefilter", [2.0, 0.5, 0.1, 0.3]),
                     ("graph", [1.0 * scale, 0.8, -0.3, 0.2]),
                     ("postfilter", [1.5, 0.7, 0.1, 0.05])):
        for _ in range(12):
            f = dict(sel=float(rng.uniform(0.01, 1.0)), n=n, d=d,
                     ls=int(rng.choice([32, 64])), k=10, n_clauses=1)
            obs.append(Observation(route, f,
                                   us=float(np.exp(phi(route, f)
                                                   @ np.asarray(w)))))
    m = fit(obs, dict(backend="cpu", shard_shape=[int(n), int(d)]))
    assert isinstance(m, CostModel)
    return m


def test_sharded_cost_router_predicts_at_per_shard_shape():
    from repro.cost import InterpolatedCostModel
    union, sharded, q, filt = _setup(F.RANGE)
    model = InterpolatedCostModel([_grid_model(100, D),
                                   _grid_model(10000, D)])
    sharded.attach_cost_model(model)
    try:
        r = sharded.executor.cost_router(k=10, ls=32)
        assert r is not None
        assert r.n == sharded.n_loc          # per-shard rows, not union N
        assert r.route(0.5) in ("prefilter", "graph", "postfilter")
        # the cost-routed sharded search serves end to end
        res = sharded.search_auto(q, filt, k=10, ls=32)
        assert np.asarray(res.ids).shape == (B, 10)
    finally:
        sharded.attach_cost_model(None)
    assert sharded.executor.cost_router(k=10, ls=32) is None


# ---------------------------------------------------------------------------
# the acceptance test: 8 fake devices, union bit-identity, all four kinds
# ---------------------------------------------------------------------------

def test_sharded_union_bit_identity_subprocess():
    """Sharded search_auto == single-device union index, bitwise, on 8
    faked host devices: all four filter kinds + a compound expression."""
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core import filters as F
from repro.core.jag import JAGConfig, JAGIndex
from repro.core.filters import Label, Range, joint_table
from repro.serve.planner import PlannerConfig
from repro.serve.sharded import ShardedJAGIndex

N, D, B, S = 320, 8, 6, 8
CFG = JAGConfig(degree=6, ls_build=8, batch_size=128, cand_pool=16,
                calib_samples=16, n_seeds=2)
FORCE_PRE = PlannerConfig(prefilter_max_sel=1.1, postfilter_min_sel=1.2)

def check(name, xb, tab, filt, q):
    union = JAGIndex.build(xb, tab, CFG)
    sh = ShardedJAGIndex.build(xb, tab, CFG, n_shards=S)
    for mode in ("per_query", "batch"):
        want = union.search_auto(q, filt, k=10, ls=16, planner=FORCE_PRE,
                                 mode=mode)
        got = sh.search_auto(q, filt, k=10, ls=16, planner=FORCE_PRE,
                             mode=mode)
        for f in want._fields:
            a = np.asarray(getattr(got, f)); b = np.asarray(getattr(want, f))
            assert a.shape == b.shape and np.array_equal(a, b), \
                (name, mode, f, a, b)
    print("OK", name)

rng = np.random.default_rng(0)
xb = rng.normal(size=(N, D)).astype(np.float32)
q = (xb[rng.integers(0, N, B)]
     + 0.1 * rng.normal(size=(B, D))).astype(np.float32)

check("range", xb, F.range_table(rng.uniform(0, 1, N).astype(np.float32)),
      F.range_filters(np.zeros(B, np.float32), np.full(B, 0.2, np.float32)),
      q)
check("label", xb, F.label_table(rng.integers(0, 5, N).astype(np.int32)),
      F.label_filters(np.full(B, 2)), q)
fb = np.zeros((B, 16), bool); fb[:, :3] = True
check("subset", xb, F.subset_table(rng.random((N, 16)) < 0.5, 16),
      F.subset_filters(fb, 16), q)
sat = np.zeros((B, 1 << 8), bool)
for i in range(B):
    sat[i, rng.choice(1 << 8, 64, replace=False)] = True
check("boolean", xb,
      F.boolean_table(rng.integers(0, 1 << 8, N).astype(np.uint32), 8),
      F.boolean_filters(sat, 8), q)
labels = rng.integers(0, 4, N).astype(np.int32)
vals = rng.uniform(0, 1, N).astype(np.float32)
expr = (Label(np.full(B, 2)) | Label(np.full(B, 3))) \
    & Range(np.zeros(B, np.float32), np.full(B, 0.7, np.float32))
check("compound", xb,
      joint_table(F.label_table(labels), F.range_table(vals)), expr, q)
try:
    ShardedJAGIndex.build(xb[:30], F.range_table(
        rng.uniform(0, 1, 30).astype(np.float32)), CFG, n_shards=8)
    raise SystemExit("expected a divisibility ValueError")
except ValueError as e:
    assert "split evenly" in str(e), e
print("SUBPROC_OK")
'''
    r = subprocess.run([sys.executable, "-c", code],
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH="src"),
                       timeout=1200)
    assert "SUBPROC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
