"""Flash attention Pallas kernel vs reference oracle (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.kernels.ref import flash_attention_ref


@pytest.mark.parametrize("B,H,Hkv,Tq,Tk,D,causal", [
    (1, 2, 2, 64, 64, 32, True),
    (2, 4, 2, 128, 128, 64, True),     # GQA
    (1, 4, 1, 64, 128, 32, False),     # MQA, cross-length, bidir
    (1, 2, 2, 256, 256, 16, True),     # multi q/k blocks
])
def test_flash_matches_ref(B, H, Hkv, Tq, Tk, D, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, Tq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.bfloat16)
    got = flash_attention(q, k, v, interpret=True)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
