"""Streaming-insert subsystem: delta segment, epoch-versioned executor,
merged exact search, compaction, and mid-stream persistence.

The acceptance contract: ``StreamingJAGIndex.search_auto`` over base+delta
returns ids/keys exactly equal to exact filtered k-NN over the concatenated
database (asserted for every filter kind with an exact base route, before
and after a compaction), and ``save`` -> ``load`` mid-stream preserves
epoch, delta rows, and search results bit-for-bit.
"""
import functools

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import filters as F
from repro.core.ground_truth import exact_filtered_knn
from repro.core.jag import JAGConfig, JAGIndex
from repro.serve.planner import PlannerConfig
from repro.stream import DeltaSegment, StreamingJAGIndex

N0, D, B = 500, 10, 8
M = 60                       # rows per insert batch
CFG = JAGConfig(degree=16, ls_build=32, batch_size=128, cand_pool=64,
                calib_samples=64, n_seeds=8)
# routes every query to the (exact) prefilter scan -> merged result must be
# bit-equal to brute force over the concatenated database at ANY selectivity
# (postfilter_min_sel lifted past it: thresholds must stay ordered)
EXACT_PLANNER = PlannerConfig(prefilter_max_sel=1.1, postfilter_min_sel=1.2)
_SEEDS = {F.LABEL: 101, F.RANGE: 202, F.SUBSET: 303, F.BOOLEAN: 404}


def _rows(kind, rng, n):
    """(vectors, AttrTable) of n fresh rows for one filter kind."""
    xv = rng.normal(size=(n, D)).astype(np.float32)
    if kind == F.RANGE:
        tab = F.range_table(rng.uniform(0, 1, n).astype(np.float32))
    elif kind == F.LABEL:
        tab = F.label_table(rng.integers(0, 6, n))
    elif kind == F.SUBSET:
        tab = F.subset_table(rng.random((n, 24)) < 0.5, 24)
    else:
        tab = F.boolean_table(rng.integers(0, 1 << 8, n).astype(np.uint32), 8)
    return xv, tab


def _filters(kind, rng, sel):
    """A filter batch with roughly the requested selectivity."""
    if kind == F.RANGE:
        return F.range_filters(np.zeros(B), np.full(B, sel, np.float32))
    if kind == F.LABEL:
        return F.label_filters(np.full(B, 2))          # ~1/6 of rows
    if kind == F.SUBSET:
        m = max(0, round(-np.log2(max(sel, 2 ** -9))))  # sel ~ 2^-m
        fb = np.zeros((B, 24), bool)
        fb[:, :m] = True
        return F.subset_filters(fb, 24)
    size = 1 << 8
    sat = np.zeros((B, size), bool)
    for i in range(B):
        sat[i, rng.choice(size, max(1, int(sel * size)), replace=False)] = 1
    return F.boolean_filters(sat, 8)


@functools.lru_cache(maxsize=None)
def _base(kind):
    """One frozen base index + queries per kind, cached across tests."""
    rng = np.random.default_rng(_SEEDS[kind])
    xb, tab = _rows(kind, rng, N0)
    base = JAGIndex.build(xb, tab, CFG)
    q = (xb[rng.integers(0, N0, B)]
         + 0.1 * rng.normal(size=(B, D))).astype(np.float32)
    return base, q


def _setup(kind, compact_frac=0.0):
    """A FRESH streaming wrapper per test — inserts must not leak between
    tests through the cached base (compaction replaces ``.base`` with a new
    index, never mutates the shared one)."""
    base, q = _base(kind)
    return StreamingJAGIndex(base, compact_frac=compact_frac), q


def _gt(idx, q, filt):
    """Exact filtered k-NN over the live concatenated database."""
    xv, dattr, _ = idx.delta_arrays()
    xb = jnp.concatenate([jnp.asarray(idx.base.xb), xv], axis=0)
    return exact_filtered_knn(xb, idx.attr, jnp.asarray(q), filt, k=10)


# ---------------------------------------------------------------------------
# acceptance: merged search == exact k-NN over concat, every kind, every
# epoch, before AND after compaction; save/load mid-stream is bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
@pytest.mark.parametrize("sel", [0.01, 0.5])
def test_search_auto_exact_over_base_plus_delta(kind, sel):
    idx, q = _setup(kind)
    rng = np.random.default_rng(1000 + _SEEDS[kind])
    filt = _filters(kind, rng, sel)
    for _ in range(2):                       # two insert epochs
        idx.insert(*_rows(kind, rng, M), auto_compact=False)
        res = idx.search_auto(q, filt, k=10, ls=64, planner=EXACT_PLANNER)
        gt = _gt(idx, q, filt)
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(gt.ids))
        np.testing.assert_array_equal(np.asarray(res.secondary),
                                      np.asarray(gt.d2))
        assert (np.asarray(res.primary)[np.asarray(res.ids) >= 0] == 0).all()


@pytest.mark.parametrize("kind", F.KINDS)
def test_exactness_preserved_across_compaction(kind):
    idx, q = _setup(kind)
    rng = np.random.default_rng(2000 + _SEEDS[kind])
    filt = _filters(kind, rng, 0.3)
    idx.insert(*_rows(kind, rng, M), auto_compact=False)
    pre = idx.search_auto(q, filt, k=10, ls=64, planner=EXACT_PLANNER)
    gt_pre = _gt(idx, q, filt)
    np.testing.assert_array_equal(np.asarray(pre.ids), np.asarray(gt_pre.ids))
    e0, n0 = idx.epoch, idx.n
    assert idx.compact()
    assert idx.epoch == e0 + 1 and idx.delta.n == 0
    assert int(idx.base.xb.shape[0]) == n0          # ids are stable
    post = idx.search_auto(q, filt, k=10, ls=64, planner=EXACT_PLANNER)
    gt_post = _gt(idx, q, filt)
    np.testing.assert_array_equal(np.asarray(post.ids),
                                  np.asarray(gt_post.ids))
    np.testing.assert_array_equal(np.asarray(gt_pre.ids),
                                  np.asarray(gt_post.ids))
    # graph invariants hold for the folded rows too
    st = idx.base.degree_stats()
    assert st["over_budget"] == 0 and st["max"] <= CFG.degree


@pytest.mark.parametrize("kind", F.KINDS)
def test_save_load_mid_stream_bit_for_bit(kind, tmp_path):
    idx, q = _setup(kind)
    rng = np.random.default_rng(3000 + _SEEDS[kind])
    idx.insert(*_rows(kind, rng, M), auto_compact=False)
    filt = _filters(kind, rng, 0.4)
    want = idx.search_auto(q, filt, k=10, ls=64)
    path = str(tmp_path / "stream.npz")
    idx.save(path)
    idx2 = StreamingJAGIndex.load(path)
    assert idx2.epoch == idx.epoch
    assert idx2.delta.n == idx.delta.n
    assert idx2.n_compactions == idx.n_compactions
    xv0, at0 = idx.delta.rows()
    xv1, at1 = idx2.delta.rows()
    np.testing.assert_array_equal(xv0, xv1)
    for k in at0:
        np.testing.assert_array_equal(at0[k], at1[k])
    got = idx2.search_auto(q, filt, k=10, ls=64)
    for field in want._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=field)


def test_frozen_archive_loads_as_streaming(tmp_path):
    idx, _ = _setup(F.RANGE)
    path = str(tmp_path / "frozen.npz")
    idx.base.save(path)
    s = StreamingJAGIndex.load(path)
    assert s.epoch == 0 and s.delta.n == 0
    assert int(s.base.xb.shape[0]) == int(idx.base.xb.shape[0])


def test_legacy_archive_refuses_compaction_but_serves(tmp_path):
    """An archive predating ``build_cfg`` loads with DEFAULT build params
    (row width 48 vs this graph's 32) — compaction must refuse loudly
    instead of folding rows at the wrong degree, while inserts and merged
    searches keep working."""
    idx, q = _setup(F.RANGE)
    rng = np.random.default_rng(83)
    full = str(tmp_path / "full.npz")
    legacy = str(tmp_path / "legacy.npz")
    idx.save(full)
    with np.load(full, allow_pickle=False) as z:
        np.savez_compressed(legacy,
                            **{k: z[k] for k in z.files if k != "build_cfg"})
    s = StreamingJAGIndex.load(legacy)
    assert s.build_cfg.row_width != int(s.base.graph.shape[1])
    s.insert(*_rows(F.RANGE, rng, M), auto_compact=False)
    filt = _filters(F.RANGE, rng, 0.3)
    res = s.search_auto(q, filt, k=10, ls=64, planner=EXACT_PLANNER)
    np.testing.assert_array_equal(np.asarray(res.ids),
                                  np.asarray(_gt(s, q, filt).ids))
    with pytest.raises(ValueError, match="row width"):
        s.compact()


# ---------------------------------------------------------------------------
# merge semantics: streaming search == base route + delta brute, composed
# ---------------------------------------------------------------------------

def test_graph_route_merge_matches_manual_composition():
    idx, q = _setup(F.RANGE)
    rng = np.random.default_rng(41)
    idx.insert(*_rows(F.RANGE, rng, M), auto_compact=False)
    filt = _filters(F.RANGE, rng, 0.4)
    res = idx.search(q, filt, k=10, ls=64)
    ex = idx.executor
    base = ex.graph(q, filt, k=10, ls=64, max_iters=128)
    extra = ex.delta(q, filt, k=10)
    want = ex.merge(base, extra, k=10)
    for field in res._fields:
        np.testing.assert_array_equal(np.asarray(getattr(res, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=field)
    # delta ids live past the graph segment and appear when they should
    assert (np.asarray(extra.ids)[np.asarray(extra.ids) >= 0]
            >= idx.base.xb.shape[0]).all()
    assert np.asarray(res.n_dist).min() > 0


def test_delta_route_requires_streaming_index():
    idx, q = _setup(F.RANGE)
    filt = _filters(F.RANGE, np.random.default_rng(0), 0.4)
    with pytest.raises(TypeError, match="frozen"):
        idx.base.executor.delta(q, filt, k=5)


@pytest.mark.parametrize("layout", ["default", "fused"])
def test_int8_serving_across_compaction_matches_fresh_rebuild(layout):
    """``compact`` extends only the fused f32 layout and claims int8 state
    "is rebuilt lazily on next use" — pin that claim: post-compaction int8
    results (both the split-quantized default path and the packed int8
    fused layout) must be bit-identical to a from-scratch index over the
    SAME post-compaction arrays. The int8 state is deliberately warmed
    BEFORE compaction so any stale scale/codes/layout surviving the fold
    would be caught."""
    idx, q = _setup(F.RANGE)
    rng = np.random.default_rng(89)
    filt = _filters(F.RANGE, rng, 0.5)
    idx.insert(*_rows(F.RANGE, rng, M), auto_compact=False)
    # warm the pre-compaction int8 state (global quant scale, packed rows)
    idx.search_int8(q, filt, k=10, ls=64, layout=layout)
    assert idx.compact()
    b = idx.base
    fresh = JAGIndex(b.xb, b.attr, b.graph, b.degree, b.entry, b.cfg,
                     b.build_cfg)
    got = idx.search_int8(q, filt, k=10, ls=64, layout=layout)
    want = fresh.search_int8(q, filt, k=10, ls=64, layout=layout)
    for field in got._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=(layout, field))
    # the lazily rebuilt quantization really covers the folded rows
    if layout == "default":
        assert int(idx.base.quantized()[0].shape[0]) == idx.n
    else:
        assert int(idx.base.fused_layout("int8").packed.shape[0]) == idx.n


def test_int8_streaming_search_returns_delta_hits():
    idx, q = _setup(F.RANGE)
    rng = np.random.default_rng(43)
    idx.insert(*_rows(F.RANGE, rng, M), auto_compact=False)
    filt = _filters(F.RANGE, rng, 0.9)
    res = idx.search_int8(q, filt, k=10, ls=96)
    assert (np.asarray(res.ids)[:, 0] >= 0).all()
    rf = idx.search(q, filt, k=10, ls=96)
    same = np.mean([len(set(np.asarray(res.ids)[i])
                        & set(np.asarray(rf.ids)[i])) / 10
                    for i in range(B)])
    assert same > 0.8, same


# ---------------------------------------------------------------------------
# epoch-versioned executor: stale caches can never serve a grown index
# ---------------------------------------------------------------------------

def test_insert_bumps_epoch_and_rolls_executor_caches():
    idx, q = _setup(F.RANGE)
    rng = np.random.default_rng(47)
    filt = _filters(F.RANGE, rng, 0.4)
    idx.search_auto(q, filt, k=5, ls=32)
    ex = idx.executor
    assert len(ex.cache_keys()) > 0
    assert all(k[0] == idx.epoch for k in ex.cache_keys(full=True))
    e0 = idx.epoch
    idx.insert(*_rows(F.RANGE, rng, M), auto_compact=False)
    assert idx.epoch == e0 + 1
    idx.search_auto(q, filt, k=5, ls=32)
    # every live compilation and sample buffer belongs to the NEW epoch
    assert all(k[0] == idx.epoch for k in ex.cache_keys(full=True))
    assert all(key[0] == idx.epoch for key in ex._samples)
    # ... and every probe buffer was drawn over the grown row count
    assert all(key[1] == idx.n for key in ex._samples)


def test_planner_probe_tracks_live_attr_table():
    """A filter matching ONLY delta rows must route on the live table and
    return delta hits — the stale-n probe would estimate selectivity 0."""
    idx, q = _setup(F.RANGE)
    rng = np.random.default_rng(53)
    base_n = int(idx.base.xb.shape[0])
    xv = rng.normal(size=(M, D)).astype(np.float32)
    # delta attr values live OUTSIDE the base's [0, 1] range
    vals = rng.uniform(2.0, 3.0, M).astype(np.float32)
    filt = F.range_filters(np.full(B, 2.0, np.float32),
                           np.full(B, 3.0, np.float32))
    res0, p0 = idx.search_auto(q, filt, k=10, ls=32, return_plan=True)
    assert float(np.max(p0.selectivity)) == 0.0
    assert (np.asarray(res0.ids) == -1).all()
    idx.insert(xv, F.range_table(vals), auto_compact=False)
    res1, p1 = idx.search_auto(q, filt, k=10, ls=32, return_plan=True)
    assert float(np.min(p1.selectivity)) > 0.0
    assert p1.n_sampled == idx.n                 # full probe over base+delta
    ids = np.asarray(res1.ids)
    assert (ids[:, 0] >= base_n).all()           # hits come from the delta
    gt = _gt(idx, q, filt)
    np.testing.assert_array_equal(ids, np.asarray(gt.ids))


def test_frozen_index_epoch_is_zero_and_stable():
    idx, q = _setup(F.RANGE)
    base = idx.base
    assert base.epoch == 0 and base.executor.epoch == 0
    filt = _filters(F.RANGE, np.random.default_rng(0), 0.4)
    base.search(q, filt, k=5, ls=32)
    n = len(base.executor.cache_keys())
    base.search(q, filt, k=5, ls=32)
    assert len(base.executor.cache_keys()) == n   # no roll, no recompiles


# ---------------------------------------------------------------------------
# compaction triggering + recall through a full insert->compact lifecycle
# ---------------------------------------------------------------------------

def test_auto_compact_triggers_at_configured_fraction():
    idx, q = _setup(F.LABEL, compact_frac=0.2)
    rng = np.random.default_rng(59)
    rep1 = idx.insert(*_rows(F.LABEL, rng, 50), auto_compact=True)
    assert not rep1["compacted"] and idx.delta.n == 50     # 10% < 20%
    rep2 = idx.insert(*_rows(F.LABEL, rng, 60), auto_compact=True)
    assert rep2["compacted"] and idx.delta.n == 0          # 22% > 20%
    assert idx.n_compactions == 1
    assert int(idx.base.xb.shape[0]) == N0 + 110
    assert rep2["epoch"] == idx.epoch == 3   # 2 inserts + 1 compaction


def test_streamed_recall_matches_exact_after_lifecycle():
    """Default planner, mid selectivity: recall over a full insert ->
    compact -> insert lifecycle stays ~exact at saturating beam width."""
    idx, q = _setup(F.SUBSET, compact_frac=0.15)
    rng = np.random.default_rng(61)
    filt = _filters(F.SUBSET, rng, 0.125)
    for _ in range(3):
        idx.insert(*_rows(F.SUBSET, rng, 40), auto_compact=True)
        res = idx.search_auto(q, filt, k=10, ls=160)
        gt = _gt(idx, q, filt)
        recs = []
        for i in range(B):
            want = set(np.asarray(gt.ids)[i]) - {-1}
            if want:
                got = set(np.asarray(res.ids)[i]) - {-1}
                recs.append(len(want & got) / len(want))
        assert np.mean(recs) > 0.95, (idx.epoch, np.mean(recs))
    assert idx.n_compactions >= 1


# ---------------------------------------------------------------------------
# units: DeltaSegment growth + AttrTable.append + extend_layout guards
# ---------------------------------------------------------------------------

def test_delta_segment_amortized_growth_and_device_cache():
    rng = np.random.default_rng(67)
    tab = F.range_table(rng.uniform(0, 1, 4).astype(np.float32))
    seg = DeltaSegment.for_table(tab, D)
    assert seg.n == 0
    caps = []
    for i in range(5):
        seg.append(rng.normal(size=(30, D)).astype(np.float32),
                   F.range_table(rng.uniform(0, 1, 30).astype(np.float32)))
        caps.append(seg._cap)
    assert seg.n == 150
    assert caps == sorted(caps) and len(set(caps)) < len(caps)  # doubling
    xv, dattr = seg.device()
    assert xv.shape == (150, D) and dattr.n == 150
    assert seg.device()[0] is xv                  # cached until next append
    seg.append(rng.normal(size=(1, D)).astype(np.float32),
               F.range_table(np.zeros(1, np.float32)))
    assert seg.device()[0] is not xv              # append invalidates
    seg.reset()
    assert seg.n == 0 and seg.device()[0].shape == (0, D)


def test_delta_segment_validates_shapes_and_kind():
    tab = F.range_table(np.zeros(3, np.float32))
    seg = DeltaSegment.for_table(tab, D)
    with pytest.raises(ValueError, match="vectors"):
        seg.append(np.zeros((2, D + 1), np.float32),
                   F.range_table(np.zeros(2, np.float32)))
    with pytest.raises(ValueError, match="attr rows"):
        seg.append(np.zeros((2, D), np.float32),
                   F.label_table(np.zeros(2, np.int64)))
    with pytest.raises(ValueError, match="vs"):
        seg.append(np.zeros((2, D), np.float32),
                   F.range_table(np.zeros(3, np.float32)))


@pytest.mark.parametrize("kind", F.KINDS)
def test_attr_table_append_all_kinds(kind):
    rng = np.random.default_rng(71)
    _, a = _rows(kind, rng, 7)
    _, b = _rows(kind, rng, 5)
    ab = a.append(b)
    assert ab.n == 12 and ab.kind == kind and ab.n_bits == a.n_bits
    for k in a.data:
        np.testing.assert_array_equal(np.asarray(ab.data[k][:7]),
                                      np.asarray(a.data[k]))
        np.testing.assert_array_equal(np.asarray(ab.data[k][7:]),
                                      np.asarray(b.data[k]))


def test_attr_table_append_keeps_global_bit_weights_and_checks_kind():
    rng = np.random.default_rng(73)
    w = rng.random(24).astype(np.float32)
    a = F.subset_table(rng.random((6, 24)) < 0.5, 24, bit_weights=w)
    b = F.subset_table(rng.random((4, 24)) < 0.5, 24)
    ab = a.append(b)
    assert ab.n == 10
    np.testing.assert_array_equal(np.asarray(ab.data["bit_weights"]), w)
    with pytest.raises(ValueError, match="append"):
        a.append(F.range_table(np.zeros(2, np.float32)))


def test_extend_layout_rejects_int8():
    from repro.serve.layout import build_layout, extend_layout
    rng = np.random.default_rng(79)
    tab = F.range_table(rng.uniform(0, 1, 16).astype(np.float32))
    lay = build_layout(rng.normal(size=(16, D)).astype(np.float32), tab,
                       vec_dtype="int8")
    with pytest.raises(ValueError, match="int8"):
        extend_layout(lay, np.zeros((2, D), np.float32),
                      F.range_table(np.zeros(2, np.float32)))
