"""Calibrated cost-model subsystem: fit/predict, registry persistence,
argmin routing through ``search_auto``, static-threshold fallback, and the
streaming compaction break-even.

The acceptance contract: with a calibrated model attached, every routing
decision is the argmin of the router's own per-route cost predictions and
each route's results are bit-identical to solo execution; with no model
(or a partial one) the static-threshold behavior of ``serve.planner`` is
reproduced exactly; ``StreamingJAGIndex`` compacts on the predicted
delta-tax vs compaction-cost break-even instead of ``compact_frac``.
"""
import math

import numpy as np
import pytest

from repro.core import filters as F
from repro.core.jag import JAGConfig, JAGIndex
from repro.cost import (BASE_ROUTES, CostModel, CostModelRouter,
                        CostRegistry, InterpolatedCostModel, Observation,
                        calibrate, fit, from_json, model_key, phi,
                        time_route, to_json)
from repro.cost.model import delta_scan_tax
from repro.stream import StreamingJAGIndex

N, D, B = 600, 8, 12
CFG = JAGConfig(degree=16, ls_build=32, batch_size=128, cand_pool=64,
                calib_samples=64, n_seeds=8)


# ---------------------------------------------------------------------------
# model: fit/predict round-trip, coverage semantics, router argmin
# ---------------------------------------------------------------------------

W_TRUE = {"prefilter": [2.0, 0.5, 0.1, 0.3], "graph": [1.0, 0.8, -0.3, 0.2],
          "postfilter": [1.5, 0.7, 0.1, 0.05], "delta": [0.5, 0.9],
          "merge": [0.2, 0.3], "compact": [3.0, 1.0]}


def _synthetic_obs(n_per_route=24, seed=0):
    """Noise-free observations drawn exactly from W_TRUE's log-linear law."""
    rng = np.random.default_rng(seed)
    obs = []
    for route, w in W_TRUE.items():
        for _ in range(n_per_route):
            f = dict(sel=float(rng.uniform(0.001, 1.0)),
                     n=int(rng.integers(500, 50000)),
                     d=int(rng.integers(8, 128)),
                     ls=int(rng.choice([32, 64, 128])), k=10,
                     delta_n=int(rng.integers(10, 1000)),
                     n_clauses=int(rng.integers(1, 5)))
            us = float(np.exp(phi(route, f) @ np.asarray(w)))
            obs.append(Observation(route, f, us=us, n_dist=2.0 * us))
    return obs


def test_fit_recovers_exact_log_linear_data():
    model = fit(_synthetic_obs(), dict(backend="cpu"))
    assert set(model.routes()) == set(W_TRUE)
    f = dict(sel=0.05, n=5000, d=32, ls=64, k=10, delta_n=100)
    for route, w in W_TRUE.items():
        want = float(np.exp(phi(route, f) @ np.asarray(w)))
        assert math.isclose(model.predict(route, f), want, rel_tol=1e-6)
        # the n_dist metric was generated at exactly 2x the us law
        assert math.isclose(model.predict(route, f, "n_dist"), 2 * want,
                            rel_tol=1e-6)
        assert model.fit_stats[route]["median_rel_err"] < 1e-9


def test_fit_skips_underdetermined_routes():
    """Fewer observations than coefficients -> the route stays uncovered
    (the planner then falls back to static thresholds), never a garbage
    fit."""
    obs = _synthetic_obs(n_per_route=24)
    f = dict(sel=0.5, n=1000, d=16, ls=64, k=10, delta_n=50)
    us = float(np.exp(phi("graph", f) @ np.asarray(W_TRUE["graph"])))
    partial = [ob for ob in obs if ob.route != "graph"]
    partial.append(Observation("graph", f, us=us, n_dist=1.0))
    model = fit(partial)
    assert not model.covers(("graph",))
    assert not model.covers(BASE_ROUTES)
    assert model.covers(("prefilter", "postfilter"))


def test_predictions_always_positive():
    model = fit(_synthetic_obs())
    for route in model.routes():
        for sel in (0.0, 1e-9, 0.5, 1.0, 5.0):
            c = model.predict(route, dict(sel=sel, n=10, d=4, ls=8, k=2,
                                          delta_n=0))
            assert c > 0.0, (route, sel, c)


def test_legacy_prefilter_coefs_zero_pad_bit_identically():
    """A 3-coefficient prefilter model (fitted before the log(n_clauses)
    term existed) predicts exactly what it always predicted, at every
    clause count — the append-only term policy."""
    legacy = CostModel(coef={"prefilter": {"us": [2.0, 0.5, 0.1]}},
                       meta={"backend": "old"})
    f = dict(sel=0.05, n=5000, d=32, ls=64, k=10)
    want = float(np.exp(phi("prefilter", f)[:3] @ np.asarray([2.0, 0.5,
                                                              0.1])))
    for nc in (1, 2, 7):
        got = legacy.predict("prefilter", dict(f, n_clauses=nc))
        assert got == want > 0.0, nc
    # the reverse direction is a hard error, not silent truncation
    future = CostModel(coef={"merge": {"us": [0.1, 0.2, 0.3, 0.4, 0.5]}},
                       meta={})
    with pytest.raises(ValueError, match="newer"):
        future.predict("merge", f)


def test_fit_recovers_n_clauses_coefficient_and_monotone_cost():
    """The fitted prefilter law recovers W_TRUE's positive n_clauses slope,
    so predicted prefilter cost grows with clause count."""
    model = fit(_synthetic_obs())
    w = model.coef["prefilter"]["us"]
    assert len(w) == 4 and math.isclose(w[3], 0.3, rel_tol=1e-6)
    f = dict(sel=0.05, n=5000, d=32, ls=64, k=10)
    costs = [model.predict("prefilter", dict(f, n_clauses=nc))
             for nc in (1, 2, 4, 8)]
    assert all(a < b for a, b in zip(costs, costs[1:])), costs


def test_fit_ignores_identically_zero_term_columns():
    """All-atomic calibration grids (n_clauses=1 everywhere -> a zero
    log(n_clauses) column) still fit prefilter: a structurally absent term
    costs no degree of freedom and its coefficient pins at exactly 0."""
    rng = np.random.default_rng(2)
    obs = []
    for _ in range(3):      # 3 obs < 4 coefficients, but only 3 live terms
        f = dict(sel=float(rng.uniform(0.01, 1.0)),
                 n=int(rng.integers(500, 50000)),
                 d=int(rng.integers(8, 128)), n_clauses=1)
        us = float(np.exp(phi("prefilter", f)
                          @ np.asarray(W_TRUE["prefilter"])))
        obs.append(Observation("prefilter", f, us=us))
    model = fit(obs)
    assert model.covers(("prefilter",))
    assert model.coef["prefilter"]["us"][3] == 0.0


def test_router_n_leaves_feeds_prefilter_prediction():
    model = fit(_synthetic_obs())
    r1 = CostModelRouter(model, n=5000, d=32, k=10, ls=64)
    r3 = CostModelRouter(model, n=5000, d=32, k=10, ls=64, n_leaves=3)
    assert r1.features(0.1)["n_clauses"] == 1
    assert r3.features(0.1)["n_clauses"] == 3
    for sel in (0.01, 0.5):
        assert r3.costs(sel)["prefilter"] > r1.costs(sel)["prefilter"]
        # graph/postfilter have no clause term: identical predictions
        assert r3.costs(sel)["graph"] == r1.costs(sel)["graph"]
        assert r3.costs(sel)["postfilter"] == r1.costs(sel)["postfilter"]


def test_router_picks_argmin_and_folds_delta_tax():
    model = fit(_synthetic_obs(), dict(backend="cpu"))
    r0 = CostModelRouter(model, n=5000, d=32, k=10, ls=64, delta_n=0)
    r1 = CostModelRouter(model, n=5000, d=32, k=10, ls=64, delta_n=400)
    assert r0.delta_tax == 0.0
    want_tax = delta_scan_tax(model, n=5000, d=32, k=10, delta_n=400)
    assert r1.delta_tax == want_tax > 0.0
    for sel in (0.001, 0.02, 0.3, 0.9):
        costs = r0.costs(sel)
        assert r0.route(sel) == min(BASE_ROUTES, key=costs.__getitem__)
        # the tax is constant across routes: argmin must not change
        assert r1.route(sel) == r0.route(sel)
        for route in BASE_ROUTES:
            assert math.isclose(r1.costs(sel)[route], costs[route] + want_tax,
                                rel_tol=1e-9)


def test_router_requires_coverage():
    model = fit([ob for ob in _synthetic_obs() if ob.route == "prefilter"])
    with pytest.raises(ValueError, match="static"):
        CostModelRouter(model, n=100, d=8, k=10, ls=32)


def test_time_route_median_and_warmup():
    calls = []

    def fn():
        calls.append(1)
        return np.zeros(3)

    res, dt = time_route(fn, warmup=2, repeats=5)
    assert len(calls) == 7 and res.shape == (3,) and dt >= 0.0


# ---------------------------------------------------------------------------
# registry + archive persistence
# ---------------------------------------------------------------------------

def test_json_round_trip_and_schema_guard():
    model = fit(_synthetic_obs(),
                dict(backend="cpu", dtype="f32", layout="default"))
    m2 = from_json(to_json(model))
    assert m2.coef == model.coef and m2.meta == model.meta
    f = dict(sel=0.1, n=2000, d=16, ls=32, k=10, delta_n=20)
    assert m2.predict("graph", f) == model.predict("graph", f)
    bad = to_json(model).replace('"schema": 1', '"schema": 99')
    with pytest.raises(ValueError, match="schema"):
        from_json(bad)


def test_registry_keys_and_round_trip(tmp_path):
    reg = CostRegistry(str(tmp_path / "reg"))
    assert reg.keys() == () and reg.load("cpu") is None
    model = fit(_synthetic_obs(),
                dict(backend="cpu", dtype="f32", layout="default"))
    path = reg.save(model)
    assert path.endswith("cost-cpu-f32-default.json")
    assert reg.keys() == (model_key("cpu"),)
    got = reg.load("cpu")
    assert got is not None and got.coef == model.coef
    assert reg.load("tpu") is None


# ---------------------------------------------------------------------------
# per-shard (N, d) grids: registry round-trip + interpolated predictions
# ---------------------------------------------------------------------------

def _shard_grid_model(n, d=16):
    """Noise-free base-route calibration pinned at one per-shard (n, d)."""
    rng = np.random.default_rng(n)
    obs = []
    for route in BASE_ROUTES:
        w = np.asarray(W_TRUE[route])
        for _ in range(16):
            f = dict(sel=float(rng.uniform(0.001, 1.0)), n=n, d=d,
                     ls=int(rng.choice([32, 64, 128])), k=10,
                     n_clauses=int(rng.integers(1, 4)))
            us = float(np.exp(phi(route, f) @ w))
            obs.append(Observation(route, f, us=us, n_dist=2.0 * us))
    return fit(obs, dict(backend="cpu", dtype="f32", layout="default",
                         shard_shape=[n, d]))


def test_shard_grid_key_round_trip_and_interpolation(tmp_path):
    reg = CostRegistry(str(tmp_path / "reg"))
    assert reg.load_shard_grids("cpu") is None       # uncalibrated state
    m_lo, m_hi = _shard_grid_model(1000), _shard_grid_model(8000)
    assert reg.save(m_lo).endswith("cost-cpu-f32-default@n1000-d16.json")
    assert reg.save(m_hi).endswith("cost-cpu-f32-default@n8000-d16.json")
    assert set(reg.keys()) == {model_key("cpu", shard_shape=(1000, 16)),
                               model_key("cpu", shard_shape=(8000, 16))}
    assert reg.load("cpu") is None     # grid entries never shadow the base
    interp = reg.load_shard_grids("cpu")
    assert isinstance(interp, InterpolatedCostModel)
    assert interp.covers(BASE_ROUTES) and interp.covers(BASE_ROUTES,
                                                        "n_dist")
    f = dict(sel=0.1, d=16, ls=64, k=10, n_clauses=1)
    for route in BASE_ROUTES:
        # exact at the calibrated grid points
        for m, n in ((m_lo, 1000), (m_hi, 8000)):
            assert math.isclose(interp.predict(route, dict(f, n=n)),
                                m.predict(route, dict(f, n=n)),
                                rel_tol=1e-12), (route, n)
        # strictly monotone in n between the grids (every route's fitted
        # n-slope is positive, so the log-log line must ascend)
        ns = np.geomspace(1000, 8000, 9)
        costs = [interp.predict(route, dict(f, n=float(n))) for n in ns]
        assert all(a < b for a, b in zip(costs, costs[1:])), (route, costs)
        # the second metric interpolates independently (generated at 2x us)
        assert math.isclose(interp.predict(route, dict(f, n=2500), "n_dist"),
                            2 * interp.predict(route, dict(f, n=2500)),
                            rel_tol=1e-9)
        # outside the span the endpoint model extrapolates with the TRUE n
        assert math.isclose(interp.predict(route, dict(f, n=500)),
                            m_lo.predict(route, dict(f, n=500)),
                            rel_tol=1e-12)
        assert math.isclose(interp.predict(route, dict(f, n=30000)),
                            m_hi.predict(route, dict(f, n=30000)),
                            rel_tol=1e-12)


def test_interpolated_model_validates_and_gates_like_cost_model():
    plain = fit(_synthetic_obs(), dict(backend="cpu"))
    with pytest.raises(ValueError, match="shard_shape"):
        InterpolatedCostModel([plain])
    # partial grids gate covers() exactly like a partial CostModel
    m = _shard_grid_model(1000)
    partial = CostModel(coef={"graph": m.coef["graph"]},
                        meta=dict(m.meta, shard_shape=[4000, 16]))
    mixed = InterpolatedCostModel([m, partial])
    assert not mixed.covers(BASE_ROUTES)
    assert mixed.covers(("graph",))
    assert not InterpolatedCostModel([]).covers(BASE_ROUTES)
    # a CostModelRouter accepts the duck-typed interpolated model
    router = CostModelRouter(InterpolatedCostModel(
        [_shard_grid_model(1000), _shard_grid_model(8000)]),
        n=2000, d=16, k=10, ls=64)
    assert router.route(0.5) in BASE_ROUTES


# ---------------------------------------------------------------------------
# serving integration: built index + model, argmin routing, bit-identity,
# exact static fallback when uncalibrated
# ---------------------------------------------------------------------------

_STATE = {}


def _index():
    """One built index + a measured calibration model, shared per session.

    The calibration runs the REAL harness (tiny grid, repeats=1) on the
    index's own (n, d), so the attached model is a genuine measured
    artifact, not hand-picked coefficients.
    """
    if "idx" not in _STATE:
        rng = np.random.default_rng(7)
        xb = rng.normal(size=(N, D)).astype(np.float32)
        vals = rng.uniform(0, 1, N).astype(np.float32)
        idx = JAGIndex.build(xb, F.range_table(vals), CFG)
        q = (xb[rng.integers(0, N, B)]
             + 0.1 * rng.normal(size=(B, D))).astype(np.float32)
        model = calibrate(fast=True, ns=(N,), ds=(D,), cfg=CFG,
                          sels=(0.005, 0.1, 0.9), lss=(24, 48), b=B,
                          delta_ns=(30, 90), repeats=1, warmup=1)
        _STATE["idx"] = (idx, q, vals, model)
    return _STATE["idx"]


def _mixed_filter(rng):
    his = np.where(np.arange(B) % 2 == 0, 0.005, 0.9).astype(np.float32)
    return F.range_filters(np.zeros(B, np.float32), his)


def test_calibration_covers_all_routes_and_reports_fit():
    _, _, _, model = _index()
    assert model.covers(BASE_ROUTES)
    assert model.covers(("delta", "merge", "compact"))
    assert model.meta["backend"] and model.meta["dtype"] == "f32"
    for route, st in model.fit_stats.items():
        assert st["n_obs"] >= 2 and st["median_rel_err"] >= 0.0


@pytest.mark.parametrize("metric", ["us", "n_dist"])
def test_search_auto_routes_by_predicted_cost_argmin(metric):
    idx, q, _, model = _index()
    filt = _mixed_filter(np.random.default_rng(0))
    try:
        idx.attach_cost_model(model, metric=metric)
        res, p = idx.search_auto(q, filt, k=10, ls=48, return_plan=True)
        assert p.costs is not None and set(p.costs) == set(BASE_ROUTES)
        router = idx.executor.cost_router(k=10, ls=48)
        assert router is not None and router.metric == metric
        for i, s in enumerate(p.selectivity):
            costs = router.costs(float(s))
            assert p.routes[i] == min(BASE_ROUTES, key=costs.__getitem__), (
                i, float(s), costs, p.routes[i])
        assert res.ids.shape == (B, 10)
    finally:
        idx.attach_cost_model(None)


def test_cost_routed_results_bit_identical_to_solo_execution():
    from repro.serve.dispatch import run_route
    idx, q, _, model = _index()
    filt = _mixed_filter(np.random.default_rng(1))
    try:
        idx.attach_cost_model(model)
        res, p = idx.search_auto(q, filt, k=10, ls=48, return_plan=True)
        for i in range(B):
            solo = run_route(idx.executor, p.routes[i], q[i:i + 1],
                             filt.take(np.asarray([i], np.int32)), k=10,
                             ls=48, max_iters=96)
            for field in ("ids", "primary", "secondary", "n_dist"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(res, field))[i],
                    np.asarray(getattr(solo, field))[0],
                    err_msg=(field, i, p.routes[i]))
    finally:
        idx.attach_cost_model(None)


def test_uncalibrated_index_reproduces_static_thresholds_exactly():
    """No model (or a partial one) -> routing, plans, and results are the
    static planner's, bit for bit."""
    from repro.serve.planner import PlannerConfig, choose_route
    idx, q, _, model = _index()
    filt = _mixed_filter(np.random.default_rng(2))
    assert idx.executor.cost_router(k=10, ls=48) is None
    want, wp = idx.search_auto(q, filt, k=10, ls=48, return_plan=True)
    assert wp.costs is None
    cfg = PlannerConfig()
    assert wp.routes == tuple(choose_route(float(s), cfg)
                              for s in wp.selectivity)
    # a partial model (missing base routes) must behave as if absent
    partial = fit([ob for ob in _synthetic_obs()
                   if ob.route in ("prefilter", "delta")])
    try:
        idx.attach_cost_model(partial)
        assert idx.executor.cost_router(k=10, ls=48) is None
        got, gp = idx.search_auto(q, filt, k=10, ls=48, return_plan=True)
        assert gp.routes == wp.routes and gp.costs is None
        for field in want._fields:
            np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                          np.asarray(getattr(want, field)),
                                          err_msg=field)
    finally:
        idx.attach_cost_model(None)


def test_explicit_planner_override_wins_over_attached_model():
    """``planner=`` is an explicit routing instruction (e.g. the
    EXACT_PLANNER idiom forcing the prefilter scan everywhere) — an
    attached cost model must never shadow it."""
    from repro.serve.planner import PlannerConfig
    idx, q, _, model = _index()
    filt = _mixed_filter(np.random.default_rng(9))
    force = PlannerConfig(prefilter_max_sel=1.1, postfilter_min_sel=1.2)
    try:
        idx.attach_cost_model(model)
        res, p = idx.search_auto(q, filt, k=10, ls=48, planner=force,
                                 return_plan=True)
        assert p.routes == ("prefilter",) * B and p.costs is None
        # and the scan really ran: primary is 0/INF, never a graph key
        assert (np.asarray(res.primary)[np.asarray(res.ids) >= 0] == 0).all()
    finally:
        idx.attach_cost_model(None)


def test_cost_model_rides_in_index_archive(tmp_path):
    idx, q, _, model = _index()
    path = str(tmp_path / "with_model.npz")
    try:
        idx.attach_cost_model(model, metric="n_dist")
        idx.save(path)
    finally:
        idx.attach_cost_model(None)
    idx2 = JAGIndex.load(path)
    assert idx2.cost_model is not None and idx2.cost_metric == "n_dist"
    assert idx2.cost_model.coef == model.coef
    assert idx2.executor.cost_router(k=10, ls=48) is not None
    # and a model-free save stays model-free
    path2 = str(tmp_path / "without_model.npz")
    idx.save(path2)
    assert JAGIndex.load(path2).cost_model is None


# ---------------------------------------------------------------------------
# streaming: compaction break-even replaces compact_frac when calibrated
# ---------------------------------------------------------------------------

def _flat_model(delta_us: float, compact_us: float) -> CostModel:
    """A model with constant delta/compact predictions (zero slope), so
    break-even arithmetic is exact in tests."""
    return CostModel(coef={"delta": {"us": [math.log(delta_us), 0.0]},
                           "compact": {"us": [math.log(compact_us), 0.0]}},
                     meta={"backend": "test"})


def test_break_even_compacts_long_before_compact_frac():
    """Cheap compaction + hot query stream -> compact at a delta far below
    the static fraction (the static trigger would have waited)."""
    idx, _, _, _ = _index()
    rng = np.random.default_rng(3)
    s = StreamingJAGIndex(idx, compact_frac=0.9, query_horizon=1000)
    s.attach_cost_model(_flat_model(delta_us=50.0, compact_us=1000.0))
    xv = rng.normal(size=(10, D)).astype(np.float32)
    rep = s.insert(xv, F.range_table(rng.uniform(0, 1, 10).astype(
        np.float32)))
    # tax*horizon = 50us * 1000 = 50_000us >= 1000us -> compacted, even
    # though 10 rows is nowhere near 0.9 * N
    assert rep["compacted"] and s.delta.n == 0 and s.n_compactions == 1


def test_break_even_defers_when_compaction_is_expensive():
    """Expensive compaction -> the delta rides past compact_frac without
    compacting (the static trigger would have fired)."""
    idx, _, _, _ = _index()
    rng = np.random.default_rng(4)
    s = StreamingJAGIndex(idx, compact_frac=0.05, query_horizon=10)
    s.attach_cost_model(_flat_model(delta_us=1.0, compact_us=1e9))
    m = int(0.2 * N)
    xv = rng.normal(size=(m, D)).astype(np.float32)
    rep = s.insert(xv, F.range_table(rng.uniform(0, 1, m).astype(
        np.float32)))
    assert not rep["compacted"] and s.delta.n == m
    tax, cost, fire = s.compaction_break_even()
    assert math.isclose(tax, 1.0, rel_tol=1e-9)
    assert math.isclose(cost, 1e9, rel_tol=1e-9) and not fire


def test_break_even_none_when_uncalibrated_falls_back_to_frac():
    idx, _, _, _ = _index()
    rng = np.random.default_rng(5)
    s = StreamingJAGIndex(idx, compact_frac=0.05)
    assert s.compaction_break_even() is None
    m = int(0.1 * N)
    rep = s.insert(rng.normal(size=(m, D)).astype(np.float32),
                   F.range_table(rng.uniform(0, 1, m).astype(np.float32)))
    assert rep["compacted"]           # static fraction fired, as before


def test_delta_tax_telemetry_accumulates():
    idx, q, _, _ = _index()
    rng = np.random.default_rng(6)
    s = StreamingJAGIndex(idx, compact_frac=0.0, query_horizon=10)
    s.attach_cost_model(_flat_model(delta_us=7.0, compact_us=1e9))
    s.insert(rng.normal(size=(20, D)).astype(np.float32),
             F.range_table(rng.uniform(0, 1, 20).astype(np.float32)),
             auto_compact=False)
    filt = F.range_filters(np.zeros(B, np.float32),
                           np.full(B, 0.5, np.float32))
    assert s.delta_tax_us == 0.0
    s.search_auto(q, filt, k=5, ls=24)
    assert math.isclose(s.delta_tax_us, 7.0 * B, rel_tol=1e-9)
    s.search_auto(q, filt, k=5, ls=24)
    assert math.isclose(s.delta_tax_us, 2 * 7.0 * B, rel_tol=1e-9)


def test_compact_frac_zero_disables_auto_compaction_even_calibrated():
    """compact_frac<=0 is the explicit OFF switch — a calibrated
    break-even that says 'compact now' must not override it (bulk loads
    rely on it)."""
    idx, _, _, _ = _index()
    rng = np.random.default_rng(10)
    s = StreamingJAGIndex(idx, compact_frac=0.0, query_horizon=10**9)
    s.attach_cost_model(_flat_model(delta_us=50.0, compact_us=1.0))
    rep = s.insert(rng.normal(size=(10, D)).astype(np.float32),
                   F.range_table(rng.uniform(0, 1, 10).astype(np.float32)))
    tax, cost, fire = s.compaction_break_even()
    assert fire                              # break-even WOULD fire...
    assert not rep["compacted"] and s.delta.n == 10   # ...but OFF wins


def test_detached_model_stays_detached_across_save_load(tmp_path):
    """attach(None) on a wrapper loaded from a model-carrying archive must
    not resurrect the base archive's model on the next save/load."""
    idx, _, _, model = _index()
    rng = np.random.default_rng(11)
    s = StreamingJAGIndex(idx, compact_frac=0.5)
    s.attach_cost_model(model)
    p1 = str(tmp_path / "with.npz")
    s.save(p1)
    s2 = StreamingJAGIndex.load(p1)
    assert s2.cost_model is not None         # archive carried it
    s2.attach_cost_model(None)
    p2 = str(tmp_path / "detached.npz")
    s2.save(p2)
    s3 = StreamingJAGIndex.load(p2)
    assert s3.cost_model is None and s3.compaction_break_even() is None


def test_streaming_archive_round_trips_model_and_horizon(tmp_path):
    idx, _, _, model = _index()
    rng = np.random.default_rng(8)
    s = StreamingJAGIndex(idx, compact_frac=0.5, query_horizon=777)
    s.attach_cost_model(model, metric="n_dist")
    s.insert(rng.normal(size=(15, D)).astype(np.float32),
             F.range_table(rng.uniform(0, 1, 15).astype(np.float32)),
             auto_compact=False)
    path = str(tmp_path / "stream_model.npz")
    s.save(path)
    s2 = StreamingJAGIndex.load(path)
    assert s2.query_horizon == 777 and s2.cost_metric == "n_dist"
    assert s2.cost_model is not None
    assert s2.cost_model.coef == model.coef
    assert s2.compaction_break_even() is not None
