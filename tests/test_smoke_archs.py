"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values. The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct; no allocation)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, get
from repro.data.pipelines import lm_batch, recsys_batch
from repro.data.graph_sampler import random_graph, batched_molecules
from repro.train import OptConfig, init_state, make_train_step

ARCHS = sorted(all_archs().keys())


def _finite(tree):
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_reduced_one_step(arch_id):
    spec = get(arch_id)
    cfg = spec.make_reduced()
    key = jax.random.PRNGKey(0)

    if spec.family == "lm":
        from repro.models import transformer as T
        params, _ = T.init_params(cfg, key)
        batch = {k: jnp.asarray(v)
                 for k, v in lm_batch(0, 2, 32, cfg.vocab).items()}
        step = make_train_step(lambda p, b: T.loss_fn(cfg, p, b),
                               OptConfig(warmup_steps=1, total_steps=10))
        p2, opt, metrics = jax.jit(step)(params, init_state(params), batch)
        assert np.isfinite(float(metrics["loss"]))
        assert _finite(p2)
        # decode path too
        cache, _ = T.init_cache(cfg, 2, 40)
        lg, cache = jax.jit(lambda p, t, c: T.prefill(cfg, p, t, c))(
            params, batch["tokens"][:, :16], cache)
        assert lg.shape == (2, cfg.padded_vocab) and _finite(lg)
        lg2, _ = jax.jit(lambda p, c, t, cur: T.decode_step(cfg, p, c, t,
                                                            cur))(
            params, cache, jnp.zeros((2,), jnp.int32),
            jnp.full((2,), 16, jnp.int32))
        assert lg2.shape == (2, cfg.padded_vocab) and _finite(lg2)

    elif spec.family == "gnn":
        from repro.models import gnn as G
        params, _ = G.init_params(cfg, key)
        g = random_graph(300, 1500, cfg.d_feat, cfg.n_classes, seed=1)
        batch = {"feats": jnp.asarray(g.feats),
                 "edges": jnp.asarray(g.edges),
                 "labels": jnp.asarray(g.labels),
                 "label_mask": jnp.ones(300)}
        step = make_train_step(lambda p, b: G.loss_fn(cfg, p, b),
                               OptConfig(warmup_steps=1, total_steps=10))
        p2, _, metrics = jax.jit(step)(params, init_state(params), batch)
        assert np.isfinite(float(metrics["loss"])) and _finite(p2)
        mb = batched_molecules(4, 10, 20, cfg.d_feat, cfg.n_classes)
        loss, _ = jax.jit(lambda p, b: G.graph_loss_fn(cfg, p, b))(
            params, {k: jnp.asarray(v) for k, v in mb.items()})
        assert np.isfinite(float(loss))

    elif spec.family == "recsys":
        from repro.models import recsys as R
        params, _ = R.init_params(cfg, key)
        batch = {k: jnp.asarray(v) for k, v in recsys_batch(
            0, 32, cfg.n_sparse, cfg.vocabs(), n_dense=cfg.n_dense,
            kind=cfg.kind, seq_len=cfg.seq_len).items()}
        step = make_train_step(lambda p, b: R.loss_fn(cfg, p, b),
                               OptConfig(warmup_steps=1, total_steps=10))
        p2, _, metrics = jax.jit(step)(params, init_state(params), batch)
        assert np.isfinite(float(metrics["loss"])) and _finite(p2)
        logits = jax.jit(lambda p, b: R.forward(cfg, p, b))(params, batch)
        assert logits.shape == (32,) and _finite(logits)

    elif spec.family == "jag":
        from repro.core import JAGIndex, range_table, range_filters
        rng = np.random.default_rng(0)
        xb = rng.normal(size=(600, 16)).astype(np.float32)
        idx = JAGIndex.build(xb, range_table(rng.uniform(0, 100, 600)), cfg)
        res = idx.search(xb[:8], range_filters([0] * 8, [100] * 8), k=5,
                         ls=24)
        assert res.ids.shape == (8, 5)
        assert (np.asarray(res.ids)[:, 0] >= 0).all()


def test_all_ten_assigned_archs_present():
    ids = set(ARCHS)
    expected = {"llama4-maverick-400b-a17b", "llama4-scout-17b-a16e",
                "minicpm-2b", "gemma-7b", "qwen3-1.7b", "gcn-cora",
                "deepfm", "din", "fm", "wide-deep", "jag"}
    assert expected <= ids, expected - ids


@pytest.mark.parametrize("arch_id", [a for a in ARCHS
                                     if get(a).family == "lm"])
def test_lm_param_counts_match_public_sizes(arch_id):
    cfg = get(arch_id).make_config()
    n = cfg.param_count()
    expected = {"llama4-maverick-400b-a17b": (370e9, 430e9),
                "llama4-scout-17b-a16e": (95e9, 120e9),
                "minicpm-2b": (2.0e9, 3.2e9),
                "gemma-7b": (7.5e9, 9.5e9),
                "qwen3-1.7b": (1.4e9, 2.2e9)}[arch_id]
    assert expected[0] < n < expected[1], f"{arch_id}: {n / 1e9:.1f}B"
    if cfg.n_experts:
        na = cfg.active_param_count()
        assert na < 0.2 * n, "MoE active fraction implausible"
