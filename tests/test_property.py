"""Hypothesis property tests on system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import filters as F
from repro.core import distances as D
from repro.core.prune import joint_robust_prune
from repro.train.optimizer import OptConfig, schedule_lr


@given(st.integers(1, 2 ** 31 - 1), st.integers(1, 2 ** 31 - 1),
       st.integers(1, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_hamming_triangle_inequality(a, b, c):
    """dist_A (boolean/subset Hamming) satisfies the triangle inequality."""
    ua = {"assign": jnp.asarray([np.uint32(a & 0xFFFFFFFF)])}
    ub = {"assign": jnp.asarray([[np.uint32(b & 0xFFFFFFFF)]])}
    uc = {"assign": jnp.asarray([[np.uint32(c & 0xFFFFFFFF)]])}
    dab = float(D.dist_a(F.BOOLEAN, ua, ub)[0, 0])
    dac = float(D.dist_a(F.BOOLEAN, ua, uc)[0, 0])
    ubc = {"assign": jnp.asarray([np.uint32(b & 0xFFFFFFFF)])}
    dbc = float(D.dist_a(F.BOOLEAN, ubc, uc)[0, 0])
    assert dab <= dac + dbc + 1e-6


@given(st.lists(st.floats(0, 100), min_size=4, max_size=16),
       st.floats(0.0, 50.0))
@settings(max_examples=40, deadline=None)
def test_capped_distance_monotone_in_threshold(vals, t):
    """Raising t never increases any capped distance (threshold hierarchy:
    higher-t buckets are strictly more permissive — §3.2)."""
    da = jnp.asarray(vals, jnp.float32)
    c1 = D.capped(da, jnp.float32(t))
    c2 = D.capped(da, jnp.float32(t + 1.0))
    assert bool(jnp.all(c2 <= c1))


@given(st.integers(0, 2 ** 20 - 1))
@settings(max_examples=30, deadline=None)
def test_bool_table_validity(a):
    """dist table is 0 exactly on satisfying assignments."""
    L = 8
    rng = np.random.default_rng(a % 97)
    sat = rng.random(1 << L) < 0.2
    sat[a % (1 << L)] = True
    tab = np.asarray(F.bool_dist_table(jnp.asarray(sat[None]), L))[0]
    assert (tab == 0).sum() == sat.sum()
    assert tab.max() <= L


@given(st.integers(2, 40), st.integers(2, 12), st.floats(1.0, 2.0))
@settings(max_examples=20, deadline=None)
def test_prune_never_exceeds_degree(c, deg, alpha):
    rng = np.random.default_rng(c * 7 + deg)
    B = 3
    d2p = jnp.asarray(rng.uniform(0, 10, (B, c)), jnp.float32)
    da = jnp.asarray(rng.uniform(0, 4, (B, c)), jnp.float32)
    pair = jnp.asarray(rng.uniform(0, 10, (B, c, c)), jnp.float32)
    sel = joint_robust_prune(jnp.ones((B, c), bool), d2p, da, pair,
                             degree=deg, alpha=alpha,
                             thresholds=(np.inf, 0.0))
    assert int(jnp.sum(sel, axis=1).max()) <= deg
    assert int(jnp.sum(sel, axis=1).min()) >= 1


@given(st.integers(0, 100_000))
@settings(max_examples=30, deadline=None)
def test_lr_schedules_bounded_and_warmup(step):
    for sched in ("cosine", "wsd", "linear", "const"):
        cfg = OptConfig(lr=1e-3, schedule=sched, warmup_steps=100,
                        total_steps=10_000)
        lr = float(schedule_lr(cfg, jnp.int32(step % 10_000)))
        assert 0.0 <= lr <= cfg.lr * (1 + 1e-6)
        if step % 10_000 < 10:
            assert lr <= cfg.lr * (step % 10_000 + 1) / 100 + 1e-9


@given(st.integers(1, 63), st.integers(0, 2 ** 30), st.integers(0, 2 ** 30))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(L, x, y):
    bits = np.array([[(x >> i) & 1 for i in range(L)],
                     [(y >> i) & 1 for i in range(min(L, 31))]
                     + [0] * max(L - 31, 0)], bool)
    packed = F.pack_bits(bits)
    out = np.asarray(F.unpack_bits(packed, L))
    np.testing.assert_array_equal(out, bits)
