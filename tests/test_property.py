"""Hypothesis property tests on system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import filters as F
from repro.core import distances as D
from repro.core.prune import joint_robust_prune
from repro.train.optimizer import OptConfig, schedule_lr


@given(st.integers(1, 2 ** 31 - 1), st.integers(1, 2 ** 31 - 1),
       st.integers(1, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_hamming_triangle_inequality(a, b, c):
    """dist_A (boolean/subset Hamming) satisfies the triangle inequality."""
    ua = {"assign": jnp.asarray([np.uint32(a & 0xFFFFFFFF)])}
    ub = {"assign": jnp.asarray([[np.uint32(b & 0xFFFFFFFF)]])}
    uc = {"assign": jnp.asarray([[np.uint32(c & 0xFFFFFFFF)]])}
    dab = float(D.dist_a(F.BOOLEAN, ua, ub)[0, 0])
    dac = float(D.dist_a(F.BOOLEAN, ua, uc)[0, 0])
    ubc = {"assign": jnp.asarray([np.uint32(b & 0xFFFFFFFF)])}
    dbc = float(D.dist_a(F.BOOLEAN, ubc, uc)[0, 0])
    assert dab <= dac + dbc + 1e-6


@given(st.lists(st.floats(0, 100), min_size=4, max_size=16),
       st.floats(0.0, 50.0))
@settings(max_examples=40, deadline=None)
def test_capped_distance_monotone_in_threshold(vals, t):
    """Raising t never increases any capped distance (threshold hierarchy:
    higher-t buckets are strictly more permissive — §3.2)."""
    da = jnp.asarray(vals, jnp.float32)
    c1 = D.capped(da, jnp.float32(t))
    c2 = D.capped(da, jnp.float32(t + 1.0))
    assert bool(jnp.all(c2 <= c1))


@given(st.integers(0, 2 ** 20 - 1))
@settings(max_examples=30, deadline=None)
def test_bool_table_validity(a):
    """dist table is 0 exactly on satisfying assignments."""
    L = 8
    rng = np.random.default_rng(a % 97)
    sat = rng.random(1 << L) < 0.2
    sat[a % (1 << L)] = True
    tab = np.asarray(F.bool_dist_table(jnp.asarray(sat[None]), L))[0]
    assert (tab == 0).sum() == sat.sum()
    assert tab.max() <= L


@given(st.integers(2, 40), st.integers(2, 12), st.floats(1.0, 2.0))
@settings(max_examples=20, deadline=None)
def test_prune_never_exceeds_degree(c, deg, alpha):
    rng = np.random.default_rng(c * 7 + deg)
    B = 3
    d2p = jnp.asarray(rng.uniform(0, 10, (B, c)), jnp.float32)
    da = jnp.asarray(rng.uniform(0, 4, (B, c)), jnp.float32)
    pair = jnp.asarray(rng.uniform(0, 10, (B, c, c)), jnp.float32)
    sel = joint_robust_prune(jnp.ones((B, c), bool), d2p, da, pair,
                             degree=deg, alpha=alpha,
                             thresholds=(np.inf, 0.0))
    assert int(jnp.sum(sel, axis=1).max()) <= deg
    assert int(jnp.sum(sel, axis=1).min()) >= 1


@given(st.integers(0, 100_000))
@settings(max_examples=30, deadline=None)
def test_lr_schedules_bounded_and_warmup(step):
    for sched in ("cosine", "wsd", "linear", "const"):
        cfg = OptConfig(lr=1e-3, schedule=sched, warmup_steps=100,
                        total_steps=10_000)
        lr = float(schedule_lr(cfg, jnp.int32(step % 10_000)))
        assert 0.0 <= lr <= cfg.lr * (1 + 1e-6)
        if step % 10_000 < 10:
            assert lr <= cfg.lr * (step % 10_000 + 1) / 100 + 1e-9


@given(st.integers(1, 63), st.integers(0, 2 ** 30), st.integers(0, 2 ** 30))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(L, x, y):
    bits = np.array([[(x >> i) & 1 for i in range(L)],
                     [(y >> i) & 1 for i in range(min(L, 31))]
                     + [0] * max(L - 31, 0)], bool)
    packed = F.pack_bits(bits)
    out = np.asarray(F.unpack_bits(packed, L))
    np.testing.assert_array_equal(out, bits)


# ---------------------------------------------------------------------------
# compound filter expressions: random trees over all four leaf kinds
# ---------------------------------------------------------------------------

_EXPR_N, _EXPR_B, _EXPR_L = 48, 2, 5


def _expr_table(rng):
    """Composite table carrying all four attribute families, shared n_bits."""
    return F.joint_table(
        F.label_table(rng.integers(0, 3, _EXPR_N)),
        F.range_table(rng.uniform(0, 1, _EXPR_N).astype(np.float32)),
        F.subset_table(rng.random((_EXPR_N, _EXPR_L)) < 0.5, _EXPR_L),
        F.boolean_table(rng.integers(0, 1 << _EXPR_L, _EXPR_N).astype(
            np.uint32), _EXPR_L))


def _rand_leaf(rng):
    kind = rng.choice(["label", "range", "subset", "boolean"])
    if kind == "label":
        return F.Label(rng.integers(0, 3, _EXPR_B))
    if kind == "range":
        lo = rng.uniform(0, 0.7, _EXPR_B).astype(np.float32)
        return F.Range(lo, lo + rng.uniform(0, 0.6, _EXPR_B)
                       .astype(np.float32))
    if kind == "subset":
        return F.Subset(rng.random((_EXPR_B, _EXPR_L)) < 0.3)
    return F.Boolean(rng.random((_EXPR_B, 1 << _EXPR_L)) < 0.4)


def _rand_tree(rng, depth):
    if depth <= 0 or rng.random() < 0.3:
        return _rand_leaf(rng)
    op = rng.choice(["and", "or", "not"])
    if op == "not":
        return ~_rand_tree(rng, depth - 1)
    kids = [_rand_tree(rng, depth - 1)
            for _ in range(int(rng.integers(2, 4)))]
    out = kids[0]
    for c in kids[1:]:
        out = (out & c) if op == "and" else (out | c)
    return out


def _ref_valid(expr, table):
    """Numpy logical composition over the ATOMIC leaf validities."""
    if isinstance(expr, F.Leaf):
        return np.asarray(F.matches_all(expr.filt, table))
    if isinstance(expr, F.Not):
        return ~_ref_valid(expr.child, table)
    ref = _ref_valid(expr.children[0], table)
    for c in expr.children[1:]:
        r = _ref_valid(c, table)
        ref = (ref & r) if isinstance(expr, F.And) else (ref | r)
    return ref


@given(st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_expr_matches_equals_numpy_logical_composition(seed):
    """matches() over a random depth<=3 tree == numpy and/or/not over the
    atomic leaf validities, and dist_f's zero set is exactly that validity
    (the graph comparator's compound invariant)."""
    rng = np.random.default_rng(seed)
    table = _expr_table(rng)
    expr = _rand_tree(rng, 3)
    want = _ref_valid(expr, table)
    got = np.asarray(F.matches_all(expr, table))
    np.testing.assert_array_equal(got, want, err_msg=expr.kind)
    ids = jnp.arange(_EXPR_N)
    attrs = {k: (v[None] if k != "bit_weights" else v)
             for k, v in table.gather(ids).items()}
    df = np.asarray(D.dist_f(expr, attrs))
    np.testing.assert_array_equal(df == 0.0, want, err_msg=expr.kind)
    # short-circuit eval counts are bounded by the leaf count and >= 1
    _, ev = F.matches_counted(expr, attrs)
    ev = np.asarray(ev)
    assert (ev >= 1).all() and (ev <= F.n_leaves(expr)).all()


@given(st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_expr_selectivity_composition_bounds(seed):
    """Composed estimates stay in [0,1]; And is <= every clause's estimate
    and Or >= every clause's (independence composition is conservative in
    exactly this direction)."""
    from repro.serve.planner import estimate_selectivity
    rng = np.random.default_rng(seed)
    table = _expr_table(rng)
    ids = jnp.arange(_EXPR_N)     # exact probe
    kids = [_rand_leaf(rng) for _ in range(3)]
    sels = [np.asarray(estimate_selectivity(c.filt, table, ids))
            for c in kids]
    s_and = np.asarray(estimate_selectivity(
        F.And(*kids), table, ids))
    s_or = np.asarray(estimate_selectivity(F.Or(*kids), table, ids))
    for s in (s_and, s_or):
        assert (s >= 0.0).all() and (s <= 1.0).all()
    eps = 1e-6
    assert (s_and <= np.min(sels, axis=0) + eps).all()
    assert (s_or >= np.max(sels, axis=0) - eps).all()
    s_not = np.asarray(estimate_selectivity(~kids[0], table, ids))
    np.testing.assert_allclose(s_not, 1.0 - sels[0], atol=eps)
