"""Selectivity-adaptive planner + unified executor.

Covers: (1) the sampled selectivity estimator across all four filter kinds,
(2) the router picking the expected route at the band extremes of a
~0.1% -> ~90% selectivity sweep, (3) ``search_auto`` recall parity with the
best forced route per band, and fewer distance computations than
always-graph at <=1% selectivity, (4) the executor's single-jit-cache
contract (no recompiles, no ``@jax.jit`` left in core/jag.py), and (5) the
shims' bit-identity with the pre-refactor per-method jit blocks.
"""
import functools
import inspect

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import filters as F
from repro.core import jag as jag_module
from repro.core.beam_search import greedy_search
from repro.core.distances import query_key_fn, unfiltered_key_fn
from repro.core.ground_truth import exact_filtered_knn
from repro.core.jag import JAGConfig, JAGIndex
from repro.core.recall import recall_at_k
from repro.serve.planner import (PlannerConfig, estimate_selectivity, plan,
                                 sample_ids)

N, D, B = 1200, 12, 16
LS = 192          # parity beam: large enough that graph/postfilter saturate
BANDS = ("low", "mid", "high")          # ~0.1-0.7% / ~12-15% / >=85%
EXPECTED_ROUTE = {"low": "prefilter", "mid": "graph", "high": "postfilter"}


def _dataset(kind, rng):
    """(attr table, band -> FilterBatch) with controllable selectivity."""
    if kind == F.RANGE:
        tab = F.range_table(rng.uniform(0, 1, N).astype(np.float32))

        def mk(band):
            hi = {"low": 0.004, "mid": 0.15, "high": 0.92}[band]
            return F.range_filters(np.zeros(B), np.full(B, hi))
    elif kind == F.LABEL:
        labels = np.zeros(N, np.int64)
        labels[:2] = 1                      # sel ~0.0017
        labels[2:2 + N // 7] = 2            # sel ~0.14
        rng.shuffle(labels)
        tab = F.label_table(labels)

        def mk(band):
            lab = {"low": 1, "mid": 2, "high": 0}[band]
            return F.label_filters(np.full(B, lab))
    elif kind == F.SUBSET:
        tab = F.subset_table(rng.random((N, 24)) < 0.5, 24)

        def mk(band):
            m = {"low": 9, "mid": 3, "high": 0}[band]   # sel 2^-m
            fb = np.zeros((B, 24), bool)
            fb[:, :m] = True
            return F.subset_filters(fb, 24)
    else:  # BOOLEAN
        nv, size = 10, 1 << 10
        tab = F.boolean_table(rng.integers(0, size, N).astype(np.uint32), nv)

        def mk(band):
            n_sat = {"low": 2, "mid": 128, "high": 920}[band]
            sat = np.zeros((B, size), bool)
            for i in range(B):
                sat[i, rng.choice(size, n_sat, replace=False)] = True
            return F.boolean_filters(sat, nv)
    return tab, mk


_SEEDS = {F.LABEL: 11, F.RANGE: 22, F.SUBSET: 33, F.BOOLEAN: 44}


@functools.lru_cache(maxsize=None)
def _setup(kind):
    """Built index + band filters for one kind (cached across tests)."""
    rng = np.random.default_rng(_SEEDS[kind])
    xb = rng.normal(size=(N, D)).astype(np.float32)
    tab, mk = _dataset(kind, rng)
    cfg = JAGConfig(degree=24, ls_build=48, batch_size=128, cand_pool=96,
                    calib_samples=128, n_seeds=8)
    idx = JAGIndex.build(xb, tab, cfg)
    # queries near the data manifold so graph traversal can saturate recall
    q = (xb[rng.integers(0, N, B)]
         + 0.1 * rng.normal(size=(B, D))).astype(np.float32)
    filters = {band: mk(band) for band in BANDS}
    return xb, tab, idx, q, filters


def _recall(res, gt):
    return recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                       np.asarray(gt.ids)).mean()


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
def test_estimator_exact_with_full_sample(kind):
    _, tab, _, _, filters = _setup(kind)
    for band in BANDS:
        filt = filters[band]
        ids = sample_ids(tab.n, tab.n)          # full probe -> exact
        est = np.asarray(estimate_selectivity(filt, tab, ids))
        true = np.asarray(F.selectivity(filt, tab))
        np.testing.assert_allclose(est, true, atol=1e-6)


@pytest.mark.parametrize("kind", F.KINDS)
def test_estimator_sampled_within_tolerance(kind):
    _, tab, _, _, filters = _setup(kind)
    ids = sample_ids(tab.n, 512, seed=3)
    assert ids.shape[0] == 512
    for band in BANDS:
        filt = filters[band]
        est = np.asarray(estimate_selectivity(filt, tab, ids))
        true = np.asarray(F.selectivity(filt, tab))
        np.testing.assert_allclose(est, true, atol=0.06)


def test_estimator_jit_compatible_all_kinds():
    for kind in F.KINDS:
        _, tab, _, _, filters = _setup(kind)
        ids = sample_ids(tab.n, 256, seed=1)
        jitted = jax.jit(estimate_selectivity)
        est = jitted(filters["mid"], tab, ids)
        assert est.shape == (B,) and est.dtype == jnp.float32


# ---------------------------------------------------------------------------
# router: expected route at the band extremes, for every filter kind
# ---------------------------------------------------------------------------

def test_choose_route_thresholds():
    from repro.serve.planner import choose_route
    cfg = PlannerConfig(prefilter_max_sel=0.02, postfilter_min_sel=0.75)
    assert choose_route(0.0, cfg) == "prefilter"
    assert choose_route(0.02, cfg) == "prefilter"
    assert choose_route(0.021, cfg) == "graph"
    assert choose_route(0.5, cfg) == "graph"
    assert choose_route(0.75, cfg) == "postfilter"
    assert choose_route(1.0, cfg) == "postfilter"


def test_choose_route_boundaries_are_inclusive():
    """Exactly AT a threshold the extreme route wins (<=, >=) — the band
    edges must not fall through to graph."""
    from repro.serve.planner import choose_route
    lo, hi = 0.1, 0.6
    cfg = PlannerConfig(prefilter_max_sel=lo, postfilter_min_sel=hi)
    assert choose_route(lo, cfg) == "prefilter"
    assert choose_route(np.nextafter(lo, 1.0), cfg) == "graph"
    assert choose_route(np.nextafter(hi, 0.0), cfg) == "graph"
    assert choose_route(hi, cfg) == "postfilter"


def test_planner_config_rejects_inverted_thresholds():
    """prefilter_max_sel >= postfilter_min_sel used to be accepted
    silently (the graph band empty, the ladder order-dependent) — it must
    refuse at construction."""
    with pytest.raises(ValueError, match="inverted"):
        PlannerConfig(prefilter_max_sel=0.8, postfilter_min_sel=0.75)
    with pytest.raises(ValueError, match="inverted"):
        PlannerConfig(prefilter_max_sel=0.75, postfilter_min_sel=0.75)
    with pytest.raises(ValueError, match="n_samples"):
        PlannerConfig(n_samples=0)
    with pytest.raises(ValueError, match="prefilter_max_sel"):
        PlannerConfig(prefilter_max_sel=-0.01)
    # still legal on purpose: >1 thresholds force one route everywhere
    # (tests/ground-truth tooling route everything to the exact scan)
    cfg = PlannerConfig(prefilter_max_sel=1.1, postfilter_min_sel=1.2)
    from repro.serve.planner import choose_route
    assert choose_route(1.0, cfg) == "prefilter"


def test_plan_without_executor_matches_with_executor():
    _, tab, idx, _, filters = _setup(F.RANGE)
    filt = filters["mid"]
    p0 = plan(filt, tab)                          # one-off traced estimate
    p1 = plan(filt, tab, executor=idx.executor)   # executor-cached estimate
    assert p0.route == p1.route
    np.testing.assert_allclose(p0.selectivity, p1.selectivity, atol=1e-6)
    assert any(key[0] == "estimate" for key in idx.executor.cache_keys())

@pytest.mark.parametrize("kind", F.KINDS)
@pytest.mark.parametrize("band", BANDS)
def test_router_picks_expected_route(kind, band):
    _, tab, idx, q, filters = _setup(kind)
    res, p = idx.search_auto(q, filters[band], k=10, ls=LS,
                             return_plan=True)
    assert p.route == EXPECTED_ROUTE[band], (
        kind, band, p.route, p.batch_selectivity)
    assert res.ids.shape == (B, 10)


# ---------------------------------------------------------------------------
# search_auto recall parity + distance-computation win at low selectivity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
def test_search_auto_matches_best_forced_route(kind):
    xb, tab, idx, q, filters = _setup(kind)
    ex = idx.executor
    for band in BANDS:
        filt = filters[band]
        gt = exact_filtered_knn(jnp.asarray(xb), tab, jnp.asarray(q), filt,
                                k=10)
        auto = _recall(idx.search_auto(q, filt, k=10, ls=LS), gt)
        forced = {
            "prefilter": _recall(ex.prefilter(q, filt, k=10), gt),
            "graph": _recall(ex.graph(q, filt, k=10, ls=LS,
                                      max_iters=2 * LS), gt),
            "postfilter": _recall(ex.postfilter(q, filt, k=10, ls=LS,
                                                max_iters=2 * LS), gt),
        }
        best = max(forced.values())
        assert auto >= best - 0.01, (kind, band, auto, forced)


@pytest.mark.parametrize("kind", F.KINDS)
def test_auto_fewer_dist_comps_than_graph_at_low_selectivity(kind):
    _, _, idx, q, filters = _setup(kind)
    filt = filters["low"]
    res, p = idx.search_auto(q, filt, k=10, ls=64, return_plan=True)
    assert p.batch_selectivity <= 0.01
    always_graph = idx.executor.graph(q, filt, k=10, ls=64, max_iters=128)
    nd_auto = float(np.asarray(res.n_dist).mean())
    nd_graph = float(np.asarray(always_graph.n_dist).mean())
    assert nd_auto < nd_graph, (kind, nd_auto, nd_graph)


# ---------------------------------------------------------------------------
# executor: single cache, no recompiles, no @jax.jit left in core/jag.py
# ---------------------------------------------------------------------------

def test_core_jag_has_no_jit_blocks():
    src = inspect.getsource(jag_module)
    assert "@jax.jit" not in src
    assert "jax.jit(" not in src


def test_executor_cache_stable_across_repeat_calls():
    _, _, idx, q, filters = _setup(F.RANGE)
    filt = filters["mid"]
    idx.search(q, filt, k=5, ls=32)
    idx.search_unfiltered(q, k=5, ls=32)
    idx.search_auto(q, filt, k=5, ls=32)
    n = len(idx.executor.cache_keys())
    idx.search(q, filt, k=5, ls=32)
    idx.search_unfiltered(q, k=5, ls=32)
    idx.search_auto(q, filt, k=5, ls=32)
    assert len(idx.executor.cache_keys()) == n
    routes = {key[0] for key in idx.executor.cache_keys()}
    assert "graph" in routes and "estimate" in routes


def test_executor_cache_shared_with_baselines():
    from repro.core import baselines as BL
    _, _, idx, q, filters = _setup(F.RANGE)
    filt = filters["mid"]
    BL.binary_search(idx, q, filt, k=5, ls=32)
    BL.acorn_search(idx, q, filt, k=5, ls=32)
    BL.post_filter_search(idx, q, filt, k=5, ls=32)
    n = len(idx.executor.cache_keys())
    BL.binary_search(idx, q, filt, k=5, ls=32)
    BL.acorn_search(idx, q, filt, k=5, ls=32)
    BL.post_filter_search(idx, q, filt, k=5, ls=32)
    assert len(idx.executor.cache_keys()) == n


def test_executor_engine_cached_per_dtype_and_kwargs():
    _, _, idx, q, _ = _setup(F.RANGE)
    ex = idx.executor
    e0 = ex.engine("f32")
    assert e0 is ex.engine("f32")                    # cached
    e1 = ex.engine("f32", use_kernel=True, interpret=True)
    assert e1 is not e0                              # kwargs key the cache
    assert e0.gathers_per_expansion == 1
    assert e0.row_bytes == (D + 1 + 1) * 4           # [vec | norm | 1 word]
    qn = np.sum(q[:2] * q[:2], axis=-1)
    d2, attrs = e0.fetch_fn(np.zeros((2, 4), np.int32), q[:2], qn)
    assert d2.shape == (2, 4) and attrs["value"].shape == (2, 4)


def test_prefilter_kernel_wiring_matches_default():
    xb, tab, idx, q, filters = _setup(F.RANGE)
    filt = filters["mid"]
    ex = idx.executor
    r0 = ex.prefilter(q, filt, k=10)
    r1 = ex.prefilter(q, filt, k=10, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    np.testing.assert_allclose(np.asarray(r0.secondary),
                               np.asarray(r1.secondary), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(r0.n_dist),
                                  np.asarray(r1.n_dist))


# ---------------------------------------------------------------------------
# shims return bit-identical results to the pre-refactor jit blocks
# ---------------------------------------------------------------------------

def test_search_shim_bit_identical_to_prerefactor_jit():
    _, _, idx, q, filters = _setup(F.RANGE)
    filt = filters["mid"]
    k, ls, max_iters = 10, 32, 64

    @jax.jit
    def ref_run(graph, xb, xb_norm, attr, q, filt, entry):
        return greedy_search(graph, xb, xb_norm, attr, q, entry,
                             query_key_fn(filt), ls=ls, k=k,
                             max_iters=max_iters)
    want = ref_run(idx.graph, idx.xb, idx.xb_norm, idx.attr,
                   jnp.asarray(q), filt, idx.entry)
    got = idx.search(q, filt, k=k, ls=ls, max_iters=max_iters)
    for field in ("ids", "primary", "secondary", "vlog", "n_expanded",
                  "n_dist"):
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=field)


def test_search_unfiltered_shim_bit_identical_to_prerefactor_jit():
    _, _, idx, q, _ = _setup(F.RANGE)
    k, ls, max_iters = 10, 32, 64

    @jax.jit
    def ref_run(graph, xb, xb_norm, attr, q, entry):
        return greedy_search(graph, xb, xb_norm, attr, q, entry,
                             unfiltered_key_fn(), ls=ls, k=k,
                             max_iters=max_iters)
    want = ref_run(idx.graph, idx.xb, idx.xb_norm, idx.attr,
                   jnp.asarray(q), idx.entry)
    got = idx.search_unfiltered(q, k=k, ls=ls, max_iters=max_iters)
    for field in ("ids", "primary", "secondary", "n_dist"):
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=field)


def test_search_int8_shim_bit_identical_to_prerefactor_jit():
    from repro.core.beam_search import SearchResult
    from repro.core.quantized import (make_int8_dist_fn, quantize_int8,
                                      rerank_exact)
    _, _, idx, q, filters = _setup(F.RANGE)
    filt = filters["mid"]
    k, ls, max_iters = 10, 32, 64
    xq, scale = quantize_int8(idx.xb)
    xq_norm = jnp.sum((xq.astype(jnp.float32) * scale) ** 2, -1)

    @jax.jit
    def ref_run(graph, xq, xq_norm, scale, xb, xb_norm, attr, q, filt,
                entry):
        res = greedy_search(graph, xq, xq_norm, attr, q, entry,
                            query_key_fn(filt), ls=ls, k=ls,
                            max_iters=max_iters,
                            dist_fn=make_int8_dist_fn(scale))
        i, p, s = rerank_exact(xb, xb_norm, res.ids, res.primary, q, k)
        return SearchResult(i, p, s, res.vlog, res.n_expanded, res.n_dist)

    want = ref_run(idx.graph, xq, xq_norm, scale, idx.xb, idx.xb_norm,
                   idx.attr, jnp.asarray(q), filt, idx.entry)
    got = idx.search_int8(q, filt, k=k, ls=ls, max_iters=max_iters)
    for field in ("ids", "primary", "secondary", "n_dist"):
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=field)
