"""jagcheck tests: per-rule lint fixtures + the compiled-route auditor.

Each JAG00x rule is demonstrated on a positive fixture reproducing its
original bug class (the PR 3 einsum, the PR 3 lru_cache, the PR 4
epoch-less cache key) AND a negative fixture of the sanctioned idiom, via
``ast.parse`` on inline snippets. The auditor section re-lowers every
executor route once (module-scoped report) and asserts the compiled
contracts; the sharded section runs on 8 faked devices in a subprocess,
mirroring tests/test_sharded.py.
"""
import textwrap

import pytest

from repro.analysis.lint import (AllowEntry, LintConfig, _parse_toml,
                                 lint_source, load_config, run_lint)

REPO = "/root/repo"


def codes(src, path="src/repro/serve/planner.py", cfg=None):
    return [f.rule for f in lint_source(textwrap.dedent(src), path, cfg)]


# ---------------------------------------------------------------------------
# Layer 1: one positive + one negative fixture per rule
# ---------------------------------------------------------------------------

def test_jag001_jit_outside_surface():
    src = """
    import jax
    step = jax.jit(lambda x: x + 1)
    """
    assert codes(src, "src/repro/core/jag.py") == ["JAG001"]
    # the three sanctioned jit surfaces pass untouched
    for ok in ("src/repro/serve/executor.py", "src/repro/core/build.py",
               "src/repro/launch/train.py"):
        assert codes(src, ok) == []


def test_jag001_decorator_form():
    src = """
    import jax
    from functools import partial

    @partial(jax.jit, static_argnums=0)
    def f(k, x):
        return x * k
    """
    assert codes(src, "src/repro/stream/index.py") == ["JAG001"]


def test_jag002_einsum_candidate_dot():
    # the PR 3 bug class verbatim (core/distributed.py:109 before this PR)
    src = """
    import jax.numpy as jnp

    def dist_fn(rows, q32, q_norm):
        d2 = (jnp.sum(rows * rows, -1)
              - 2.0 * jnp.einsum("bcd,bd->bc", rows, q32)
              + q_norm[:, None])
        return jnp.maximum(d2, 0.0)
    """
    assert codes(src) == ["JAG002"]
    # the sanctioned replacement, and a non-candidate-dot einsum spec
    ok = """
    import jax.numpy as jnp
    from repro.core.distances import gathered_dot

    def dist_fn(rows, q32):
        return gathered_dot(rows, q32) + jnp.einsum("bd,bd->b", rows[:, 0],
                                                    rows[:, 0])[:, None]
    """
    assert codes(ok) == []


def test_jag002_spec_whitespace_normalized():
    assert codes('import jax.numpy as jnp\n'
                 'y = jnp.einsum("bcd, bd -> bc", a, b)\n') == ["JAG002"]


def test_jag003_module_level_lru_cache():
    # the PR 3 sample_ids bug class: a module-level memo pinning buffers
    src = """
    import functools
    import jax.numpy as jnp

    @functools.lru_cache(maxsize=None)
    def sample_ids(n, n_samples, seed=0):
        return jnp.arange(n)[:n_samples]
    """
    assert codes(src) == ["JAG003"]
    assert codes("import functools\n"
                 "memo = functools.lru_cache(None)(lambda n: n)\n"
                 ) == ["JAG003"]
    # non-module-level (owned by an object) is the sanctioned shape
    ok = """
    import functools

    class Executor:
        @functools.lru_cache(maxsize=None)
        def _probe(self, n):
            return n
    """
    assert codes(ok) == []


def test_jag004_epoch_less_cache_key():
    # the PR 4 bug class: key omits the data epoch -> stale compilations
    src = """
    class Executor:
        def run(self, key, make, *args):
            fn = self._cache.get(key)
            if fn is None:
                fn = self._cache[key] = make()
            return fn(*args)
    """
    assert codes(src, "src/repro/serve/executor.py") == ["JAG004"]
    ok = """
    class Executor:
        def run(self, key, make, *args):
            fn = self._cache[(self._cache_epoch,) + key] = make()
            return fn(*args)
    """
    assert codes(ok, "src/repro/serve/executor.py") == []


def test_jag005_host_sync_in_jit_roots():
    # all three jit-root shapes: decorator, lexical wrap, make() factory.
    # Fixtures live on a JAG001-allowed path so only JAG005 is isolated
    # (a jax.jit on an unsanctioned path correctly fires JAG001 too).
    surface = "src/repro/core/build.py"
    dec = """
    import jax
    import numpy as np

    @jax.jit
    def f(x):
        return np.asarray(x).sum()
    """
    assert codes(dec, surface) == ["JAG005"]
    wrap = """
    import jax

    def g(x):
        return float(x)

    h = jax.jit(g)
    """
    assert codes(wrap, surface) == ["JAG005"]
    factory = """
    def make():
        def run(x):
            return x.item()
        return run
    """
    assert codes(factory) == ["JAG005"]
    # the same calls outside any jit root are host-side and fine
    ok = """
    import numpy as np

    def probe(x):
        return float(np.asarray(x).mean())
    """
    assert codes(ok) == []


def test_jag006_telemetry_in_jit_roots():
    # telemetry mutations inside an executor make() factory: the obs/
    # contract is host-side-after-return only
    surface = "src/repro/core/build.py"
    factory = """
    def make():
        def run(x):
            self.telemetry.traces.append(x)
            return x
        return run
    """
    assert codes(factory) == ["JAG006"]
    metric = """
    def make():
        def run(x):
            tel.metrics.counter("jag_x").inc()
            return x
        return run
    """
    assert codes(metric) == ["JAG006"]
    # host timestamps constant-fold at trace time inside a jit root
    timer = """
    import jax, time

    @jax.jit
    def f(x):
        t0 = time.perf_counter()
        return x + t0
    """
    assert codes(timer, surface) == ["JAG006"]


def test_jag006_host_side_telemetry_is_fine():
    # the actual dispatch/search_auto wrapper shape: timing + recording
    # around (not inside) the compiled route
    ok = """
    import time

    def timed(route, *args):
        t0 = time.perf_counter()
        out = jax.block_until_ready(route(*args))
        tel.metrics.counter("jag_route_call_total").inc()
        tel.traces.append(out)
        return out, time.perf_counter() - t0
    """
    assert codes(ok) == []
    # a plain list append inside a make() factory is not telemetry
    plain = """
    def make():
        def run(xs):
            out = []
            out.append(xs)
            return out
        return run
    """
    assert codes(plain) == []
    # the executor's trace_log analysis hook is exempt by name
    log = """
    def make():
        def run(x):
            self.trace_log.append(x)
            return x
        return run
    """
    assert codes(log) == []


def test_lint_real_executor_passes():
    with open(f"{REPO}/src/repro/serve/executor.py") as fh:
        assert codes(fh.read(), "src/repro/serve/executor.py") == []


# ---------------------------------------------------------------------------
# config / allowlist
# ---------------------------------------------------------------------------

def test_toml_fallback_parser_multiline_arrays():
    data = _parse_toml(textwrap.dedent("""
        [tool.jagcheck]
        include = ["src/repro"]
        jit_allowed = [
            "a.py",
            "b/*.py",
        ]

        [[tool.jagcheck.allow]]
        rule = "JAG001"
        path = "c.py"
        reason = "because"
    """))
    cfg = data["tool"]["jagcheck"]
    assert cfg["include"] == ["src/repro"]
    assert cfg["jit_allowed"] == ["a.py", "b/*.py"]
    assert cfg["allow"] == [{"rule": "JAG001", "path": "c.py",
                             "reason": "because"}]


def test_allow_entry_requires_reason(tmp_path):
    (tmp_path / "pyproject.toml").write_text(textwrap.dedent("""
        [[tool.jagcheck.allow]]
        rule = "JAG001"
        path = "src/repro/x.py"
    """))
    cfg, errors = load_config(str(tmp_path))
    assert not cfg.allow
    assert [e.rule for e in errors] == ["JAGCFG"]
    assert "reason" in errors[0].msg


def test_stale_allowlist_entry_is_flagged(tmp_path):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "clean.py").write_text("x = 1\n")
    cfg = LintConfig(allow=(AllowEntry("JAG002", "src/repro/gone.py",
                                       "used to matter"),))
    report = run_lint(str(tmp_path), cfg, [])
    assert not report.findings
    assert [e.rule for e in report.config_errors] == ["JAGCFG"]
    assert "stale" in report.config_errors[0].msg


def test_repo_lint_is_burned_down():
    """The satellite contract: zero unjustified findings on the repo."""
    report = run_lint(REPO)
    assert report.ok, [str(f) for f in
                       report.findings + report.config_errors]
    # the live JAG002 violation this PR fixed must NOT be suppressed
    assert not any(f.path == "src/repro/core/distributed.py"
                   for f, _ in report.suppressed)


# ---------------------------------------------------------------------------
# Layer 2: HLO text parsers on synthetic fixtures
# ---------------------------------------------------------------------------

def test_while_region_and_call_resolution():
    from repro.analysis.audit import _expansion_gathers
    stable = textwrap.dedent("""\
    module @jit_f {
      func.func public @main(%arg0: tensor<256x8xf32> {x.y = "z"}) -> tensor<4xf32> {
        %0 = "stablehlo.gather"(%arg0, %c) <{g = #stablehlo.gather<a = [2]>, s = array<i64: 1, 8>}> : (tensor<256x8xf32>, tensor<4x1xi32>) -> tensor<4x8xf32>
        %1:2 = stablehlo.while(%iterArg = %arg0, %iterArg_1 = %0) : tensor<256x8xf32>, tensor<4x8xf32>
         cond {
          stablehlo.return %t : tensor<i1>
         } do {
          %2 = "stablehlo.gather"(%adj, %i) <{s = array<i64: 1, 22>}> : (tensor<256x22xi32>, tensor<4x16x1xi32>) -> tensor<4x16x22xi32>
          %3 = call @_take(%iterArg, %2) : (tensor<256x8xf32>, tensor<4x16x22xi32>) -> tensor<4x16x8xf32>
          stablehlo.return %iterArg, %3#0 : tensor<256x8xf32>, tensor<4x8xf32>
         }
        return %1#1 : tensor<4xf32>
      }
      func.func private @_take(%arg0: tensor<256x8xf32>, %arg1: tensor<4x16x22xi32>) -> tensor<4x16x8xf32> {
        %0 = "stablehlo.gather"(%arg0, %arg1) <{s = array<i64: 1, 8>}> : (tensor<256x8xf32>, tensor<4x16x1xi32>) -> tensor<4x16x8xf32>
        return %0 : tensor<4x16x8xf32>
      }
    }
    """)
    # entry gather is OUTSIDE the loop; in-loop = adjacency + 1 outlined
    # data gather reached through call @_take -> exactly 1 per expansion
    assert _expansion_gathers(stable, 256, "256x22xi32") == 1
    assert _expansion_gathers(stable, 256, "999x9xi32") is None


def test_gather_operand_parser_ignores_references():
    from repro.analysis.audit import _gather_operands
    line = ('%6 = "stablehlo.gather"(%a, %b) <{s = array<i64: 1, 10>}> : '
            '(tensor<320x10xf32>, tensor<6x16x1xi32>) -> tensor<6x16x10xf32>')
    assert _gather_operands(line) == ["320x10xf32"]
    assert _gather_operands('stablehlo.return "stablehlo.gather"') == []


# ---------------------------------------------------------------------------
# Layer 2: the real executor routes (one build+lower pass, module-scoped)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def audit_report():
    from repro.analysis.audit import audit_single_device
    return audit_single_device()


def test_audit_covers_every_route(audit_report):
    graph = {f"graph:{la}:{dt}" for la in ("default", "fused")
             for dt in ("f32", "int8")}
    assert set(audit_report["routes"]) == (
        {"prefilter", "postfilter", "unfiltered", "delta", "merge"}
        | graph | {g + ":introspect" for g in graph})
    # PR 9: the audited programs were captured WITH telemetry attached —
    # the zero-callback budgets below therefore prove tracing adds none
    assert audit_report["meta"]["telemetry"] is True


def test_audit_introspective_routes_match_their_twins(audit_report):
    # PR 10: the introspective compilation may add counters but must not
    # add gathers, callbacks, or collectives relative to its twin route
    routes = audit_report["routes"]
    twins = [n for n in routes if n.endswith(":introspect")]
    assert len(twins) == 4
    for name in twins:
        twin = routes[name.rsplit(":introspect", 1)[0]]
        r = routes[name]
        assert r["gathers_per_expansion"] == twin["gathers_per_expansion"]
        assert r["callbacks"] == 0 and r["collectives"] == {}


def test_audit_fused_routes_one_gather_per_expansion(audit_report):
    for name, r in audit_report["routes"].items():
        if name.startswith("graph:fused"):
            assert r["gathers_per_expansion"] == 1, (name, r)
            assert r["adjacency_gathers"] >= 1, (name, r)
        elif name.startswith("graph:default") or name in ("postfilter",
                                                          "unfiltered"):
            # split layout: vector + norm + attr fetches per expansion
            assert r["gathers_per_expansion"] == 3, (name, r)
        else:  # scans and merges have no traversal loop
            assert r["gathers_per_expansion"] is None, (name, r)


def test_audit_no_callbacks_f64_or_collectives(audit_report):
    for name, r in audit_report["routes"].items():
        assert r["callbacks"] == 0, (name, r)
        assert r["f64_ops"] == 0, (name, r)
        assert r["collectives"] == {}, (name, r)


def test_audit_check_report_flags_violations(audit_report):
    from repro.analysis.audit import check_report
    assert check_report(audit_report) == []
    import copy
    bad = copy.deepcopy(audit_report)
    bad["routes"]["graph:fused:f32"]["gathers_per_expansion"] = 2
    bad["routes"]["prefilter"]["callbacks"] = 1
    bad["sharded"] = {"routes": {"graph": {
        "callbacks": 0, "f64_ops": 0,
        "collectives": {"all-gather": 2}}}}
    msgs = check_report(bad)
    assert any("graph:fused:f32" in m for m in msgs)
    assert any("prefilter" in m and "callback" in m for m in msgs)
    assert any("sharded/graph" in m for m in msgs)


def test_audit_sharded_subprocess():
    """Sharded routes on 8 faked devices: exactly one all-gather each."""
    from repro.analysis.audit import check_report, run_sharded_audit
    sh = run_sharded_audit(REPO)
    assert set(sh["routes"]) == {"prefilter", "graph", "postfilter",
                                 "unfiltered"}
    for name, r in sh["routes"].items():
        assert r["collectives"] == {"all-gather": 1}, (name, r)
        assert r["callbacks"] == 0 and r["f64_ops"] == 0, (name, r)
        # the one collective moves the packed [B, 3k+2] int32 payload
        assert r["collective_bytes"]["all-gather"] == (
            sh["meta"]["devices"] * sh["meta"]["merge_payload_bytes"])
    assert check_report({"routes": {}, "sharded": sh}) == []
