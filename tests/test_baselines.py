"""Baselines sanity: each produces valid results; JAG dominates at low
selectivity (the paper's central claim, tested at toy scale)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import JAGConfig, JAGIndex, range_filters
from repro.core import baselines as BL
from repro.core.ground_truth import exact_filtered_knn
from repro.core.recall import recall_at_k
from repro.data.synthetic import msturing_range, sift_like


@pytest.fixture(scope="module")
def range_setup():
    ds = msturing_range(n=3000, d=16, b=48, seed=1,
                        sel_ks=(1, 100, 1000))
    cfg = JAGConfig(degree=24, ls_build=48, batch_size=256, cand_pool=96)
    jag = JAGIndex.build(ds.xb, ds.attr, cfg)
    unf = BL.build_unfiltered(ds.xb, ds.attr, cfg)
    gt = exact_filtered_knn(jnp.asarray(ds.xb), ds.attr,
                            jnp.asarray(ds.queries), ds.filt, k=10)
    return ds, cfg, jag, unf, gt


def _recall(res, gt):
    return recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                       np.asarray(gt.ids)).mean()


def test_ground_truth_exact(range_setup):
    ds, _, _, _, gt = range_setup
    vals = np.asarray(ds.attr.data["value"])
    lo = np.asarray(ds.filt.data["lo"])
    hi = np.asarray(ds.filt.data["hi"])
    d2 = ((ds.queries[:, None] - ds.xb[None]) ** 2).sum(-1)
    mask = (vals[None] >= lo[:, None]) & (vals[None] <= hi[:, None])
    d2m = np.where(mask, d2, np.inf)
    ref = np.argsort(d2m, 1)[:, :10]
    got = np.asarray(gt.ids)
    for b in range(len(ref)):
        want = [i for i in ref[b] if d2m[b, i] < np.inf]
        assert list(got[b][:len(want)]) == want


def test_post_filter_works_high_selectivity(range_setup):
    ds, _, _, unf, _ = range_setup
    b = 16
    filt = range_filters(np.zeros(b), np.full(b, 1e6))  # selectivity 1
    gt = exact_filtered_knn(jnp.asarray(ds.xb), ds.attr,
                            jnp.asarray(ds.queries[:b]), filt, k=10)
    res = BL.post_filter_search(unf, ds.queries[:b], filt, k=10, ls=64)
    assert _recall(res, gt) > 0.9


def test_jag_beats_post_filter_low_selectivity(range_setup):
    ds, _, jag, unf, gt = range_setup
    low = np.asarray(ds.selectivity) < 0.02
    res_j = jag.search(ds.queries, ds.filt, k=10, ls=64)
    res_p = BL.post_filter_search(unf, ds.queries, ds.filt, k=10, ls=64)
    rj = recall_at_k(np.asarray(res_j.ids), np.asarray(res_j.primary) == 0,
                     np.asarray(gt.ids))
    rp = recall_at_k(np.asarray(res_p.ids), np.asarray(res_p.primary) == 0,
                     np.asarray(gt.ids))
    assert low.sum() >= 5
    assert rj[low].mean() > rp[low].mean() + 0.15, (
        rj[low].mean(), rp[low].mean())
    assert rj.mean() > 0.8


def test_acorn_and_binary_run(range_setup):
    ds, _, _, unf, gt = range_setup
    res_a = BL.acorn_search(unf, ds.queries, ds.filt, k=10, ls=48)
    res_b = BL.binary_search(unf, ds.queries, ds.filt, k=10, ls=48)
    assert _recall(res_a, gt) > 0.25
    assert _recall(res_b, gt) > 0.2
    # returned results genuinely satisfy the filter
    for res in (res_a, res_b):
        ids = np.asarray(res.ids)
        ok = np.asarray(res.primary) == 0
        vals = np.asarray(ds.attr.data["value"])
        lo = np.asarray(ds.filt.data["lo"])
        hi = np.asarray(ds.filt.data["hi"])
        for b in range(ids.shape[0]):
            for i, v in zip(ids[b], ok[b]):
                if v and i >= 0:
                    assert lo[b] <= vals[i] <= hi[b]


def test_rwalks_runs(range_setup):
    ds, cfg, _, unf, gt = range_setup
    rw = BL.build_rwalks(ds.xb, ds.attr, cfg, index=unf)
    res = BL.rwalks_search(rw, ds.queries, ds.filt, k=10, ls=48)
    assert _recall(res, gt) > 0.25


def test_stitched_label_index():
    ds = sift_like(n=2400, d=16, b=32, n_labels=4, seed=2)
    cfg = JAGConfig(degree=12, ls_build=24, batch_size=128, cand_pool=64)
    st = BL.StitchedLabelIndex(ds.xb, ds.attr, cfg)
    gt = exact_filtered_knn(jnp.asarray(ds.xb), ds.attr,
                            jnp.asarray(ds.queries), ds.filt, k=10)
    res = st.search(ds.queries, ds.filt, k=10, ls=48)
    assert _recall(res, gt) > 0.9
