"""GreedySearch behaviour tests: exactness on small graphs, termination,
visited-set semantics, dedup, and comparator ordering."""
import numpy as np
import jax.numpy as jnp

from repro.core import filters as F
from repro.core.beam_search import greedy_search
from repro.core.distances import (query_key_fn, unfiltered_key_fn, sq_norms)


def _complete_graph(n):
    g = np.stack([np.delete(np.arange(n), i) for i in range(n)])
    return jnp.asarray(g, jnp.int32)


def test_unfiltered_exact_on_complete_graph():
    """With a complete graph and full beam, search is exact brute force."""
    rng = np.random.default_rng(0)
    n, d, b = 64, 8, 16
    xb = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(b, d)).astype(np.float32)
    attr = F.range_table(np.zeros(n))
    res = greedy_search(_complete_graph(n), jnp.asarray(xb), sq_norms(xb),
                        attr, jnp.asarray(q), jnp.int32(0),
                        unfiltered_key_fn(), ls=n, k=5, max_iters=3 * n)
    d2 = ((q[:, None] - xb[None]) ** 2).sum(-1)
    gt = np.argsort(d2, axis=1)[:, :5]
    np.testing.assert_array_equal(np.asarray(res.ids), gt)


def test_filtered_exact_on_complete_graph():
    rng = np.random.default_rng(1)
    n, d, b = 64, 8, 8
    xb = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(b, d)).astype(np.float32)
    vals = rng.uniform(0, 100, n).astype(np.float32)
    attr = F.range_table(vals)
    filt = F.range_filters(np.full(b, 20.0), np.full(b, 60.0))
    res = greedy_search(_complete_graph(n), jnp.asarray(xb), sq_norms(xb),
                        attr, jnp.asarray(q), jnp.int32(0),
                        query_key_fn(filt), ls=n, k=5, max_iters=3 * n)
    ids = np.asarray(res.ids)
    prim = np.asarray(res.primary)
    valid_mask = (vals >= 20) & (vals <= 60)
    d2 = ((q[:, None] - xb[None]) ** 2).sum(-1)
    d2m = np.where(valid_mask[None], d2, np.inf)
    gt = np.argsort(d2m, 1)[:, :5]
    for row in range(b):
        got = [i for i, p in zip(ids[row], prim[row]) if p == 0]
        want = [i for i in gt[row] if d2m[row, i] < np.inf]
        assert got[:len(want)] == want[:len(got)] or set(want) <= set(got)


def test_termination_and_no_revisit():
    """Every expanded id appears at most once in the visited log."""
    rng = np.random.default_rng(2)
    n, d, R = 200, 8, 8
    xb = rng.normal(size=(n, d)).astype(np.float32)
    g = rng.integers(0, n, (n, R)).astype(np.int32)
    attr = F.range_table(np.zeros(n))
    q = rng.normal(size=(4, d)).astype(np.float32)
    res = greedy_search(jnp.asarray(g), jnp.asarray(xb), sq_norms(xb), attr,
                        jnp.asarray(q), jnp.int32(0), unfiltered_key_fn(),
                        ls=16, k=5, max_iters=64)
    vlog = np.asarray(res.vlog)
    for row in vlog:
        ids = row[row >= 0]
        assert len(ids) == len(set(ids)), "node expanded twice"
    assert np.all(np.asarray(res.n_expanded) <= 64)


def test_sentinel_neighbors_ignored():
    n, d = 32, 4
    rng = np.random.default_rng(3)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    g = np.full((n, 6), -1, np.int32)
    g[:, 0] = (np.arange(n) + 1) % n  # ring with sentinel padding
    attr = F.range_table(np.zeros(n))
    q = xb[:2]
    res = greedy_search(jnp.asarray(g), jnp.asarray(xb), sq_norms(xb), attr,
                        jnp.asarray(q), jnp.int32(0), unfiltered_key_fn(),
                        ls=n, k=1, max_iters=4 * n)
    # ring reaches everything; nearest neighbor of xb[i] is i itself
    np.testing.assert_array_equal(np.asarray(res.ids)[:, 0], [0, 1])


def test_lexicographic_priority():
    """A filter-satisfying far point must outrank a violating near point."""
    xb = np.array([[0.0], [0.1], [5.0]], np.float32)
    attr = F.label_table([0, 1, 0])
    filt = F.label_filters([0])
    g = jnp.asarray([[1, 2], [0, 2], [0, 1]], jnp.int32)
    q = np.array([[0.05]], np.float32)
    res = greedy_search(g, jnp.asarray(xb), sq_norms(xb), attr,
                        jnp.asarray(q), jnp.int32(1), query_key_fn(filt),
                        ls=3, k=3, max_iters=10)
    ids = np.asarray(res.ids)[0]
    assert list(ids[:2]) == [0, 2]  # both label-0 points before label-1
