"""Distributed JAG (shard_map) + sharding-rule resolution tests.

Multi-device cases run in a subprocess with faked host devices so the rest
of the suite keeps seeing 1 device (the dry-run sets its own flags)."""
import subprocess
import sys
import os

import jax

from jax.sharding import PartitionSpec as P


def test_resolve_spec_divisibility_and_dedup():
    from types import SimpleNamespace
    from repro.distributed.sharding import Rules, resolve_spec
    mesh = SimpleNamespace(shape={"data": 4})   # resolution is mesh-shape-only
    rules = Rules(mesh, {"a": "data", "b": "data", "c": None})
    # divisible -> bound; non-divisible -> dropped
    assert resolve_spec(("a",), (4,), rules) == P("data")
    assert resolve_spec(("a",), (3,), rules) == P(None)
    # duplicate mesh axis across dims -> later dim replicated
    assert resolve_spec(("a", "b"), (4, 4), rules) == P("data", None)
    assert resolve_spec(("c", "a"), (4, 4), rules) == P(None, "data")


def test_production_rules_cover_all_model_specs():
    from types import SimpleNamespace
    from repro.configs import get
    from repro.distributed.sharding import make_rules, resolve_spec

    # shape-only stand-in for the 512-chip mesh (1 real device here)
    mesh = SimpleNamespace(axis_names=("pod", "data", "model"),
                           shape={"pod": 2, "data": 16, "model": 16})
    rules = make_rules(mesh)
    # every logical name used by the models must resolve without KeyError
    from repro.models import transformer as T
    key = jax.random.PRNGKey(0)
    for arch in ("qwen3-1.7b", "llama4-scout-17b-a16e"):
        cfg = get(arch).make_reduced()
        _, specs = T.init_params(cfg, key)
        for axes in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, tuple)):
            resolve_spec(axes, (8,) * len(axes), rules)


def test_shard_map_serve_and_build_subprocess():
    """End-to-end distributed serve+build on 8 fake devices."""
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core import JAGConfig, JAGIndex, range_table
from repro.core.distributed import make_serve_step, ShardedServeConfig
from repro.launch.mesh import mesh_kwargs, set_mesh
mesh = jax.make_mesh((4, 2), ("data", "model"), **mesh_kwargs(2))
rng = np.random.default_rng(0)
S, Nloc, d = 8, 300, 8
xb = rng.normal(size=(S, Nloc, d)).astype(np.float32)
vals = rng.uniform(0, 100, (S, Nloc)).astype(np.float32)
cfg = JAGConfig(degree=10, ls_build=16, batch_size=128, cand_pool=48)
graphs, entries = [], []
for s in range(S):
    idx = JAGIndex.build(xb[s], range_table(vals[s]), cfg)
    graphs.append(np.asarray(idx.graph))
    entries.append(np.resize(np.atleast_1d(np.asarray(idx.entry)), 4))
graphs = np.stack(graphs); entries = np.stack(entries).astype(np.int32)
xbn = (xb.astype(np.float64)**2).sum(-1).astype(np.float32)
B = 16
q = rng.normal(size=(B, d)).astype(np.float32)
lo = rng.uniform(0, 90, B).astype(np.float32)
step = jax.jit(make_serve_step(mesh, ShardedServeConfig(k=5, ls=24,
    max_iters=48, query_chunk=8), "range", "range"))
with set_mesh(mesh):
    ids, prim, sec = step(jnp.asarray(graphs), jnp.asarray(xb),
        jnp.asarray(xbn), {"value": jnp.asarray(vals)},
        jnp.asarray(entries), jnp.asarray(q),
        {"lo": jnp.asarray(lo), "hi": jnp.asarray(lo + 10)})
ids = np.asarray(ids); prim = np.asarray(prim)
xf = xb.reshape(-1, d); vf = vals.reshape(-1)
d2 = ((q[:, None] - xf[None])**2).sum(-1)
mask = (vf[None] >= lo[:, None]) & (vf[None] <= (lo+10)[:, None])
d2m = np.where(mask, d2, np.inf)
recs = []
for b in range(B):
    gt = [j for j in np.argsort(d2m[b])[:5] if d2m[b, j] < np.inf]
    got = [i for i, p in zip(ids[b], prim[b]) if p == 0 and i >= 0]
    if gt: recs.append(len(set(gt) & set(got)) / len(gt))
rec = float(np.mean(recs))
assert rec > 0.75, rec
print("SUBPROC_OK", rec)
'''
    r = subprocess.run([sys.executable, "-c", code],
                       cwd="/root/repo", capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH="src"))
    assert "SUBPROC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_int8_reg_dist_batch_invariance():
    """JAG002 fix (analysis PR): the int8_reg in-register distance now
    uses distances.gathered_dot, so per-query results are BITWISE
    identical across query_chunk regroupings. The einsum("bcd,bd->bc")
    it replaced lowers to a batched dot whose reduction vectorization
    varies with the chunk batch size — exactly the call-site shape this
    test varies (one 16-query chunk vs two 8-query chunks)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import JAGConfig, JAGIndex, range_table
    from repro.core.distributed import ShardedServeConfig, make_serve_step
    from repro.core.quantized import quantize_int8
    from repro.launch.mesh import mesh_kwargs, set_mesh

    mesh = jax.make_mesh((1, 1), ("data", "model"), **mesh_kwargs(2))
    rng = np.random.default_rng(3)
    n, d, B = 240, 8, 16
    xb = rng.normal(size=(n, d)).astype(np.float32)
    vals = rng.uniform(0, 100, n).astype(np.float32)
    idx = JAGIndex.build(xb, range_table(vals),
                         JAGConfig(degree=10, ls_build=16, batch_size=128,
                                   cand_pool=48))
    xq, scale = quantize_int8(idx.xb)
    q = rng.normal(size=(B, d)).astype(np.float32)
    lo = rng.uniform(0, 90, B).astype(np.float32)
    args = (jnp.asarray(idx.graph)[None], jnp.asarray(xq)[None],
            jnp.asarray(idx.xb_norm)[None],
            {"value": jnp.asarray(vals)[None]},
            jnp.asarray(np.resize(np.atleast_1d(np.asarray(idx.entry)),
                                  4).astype(np.int32))[None],
            jnp.asarray(q),
            {"lo": jnp.asarray(lo), "hi": jnp.asarray(lo + 10)},
            jnp.asarray(scale))
    outs = []
    with set_mesh(mesh):
        for chunk in (16, 8):  # 1x16 vs 2x8: different GEMM batch sizes
            step = jax.jit(make_serve_step(
                mesh, ShardedServeConfig(k=5, ls=24, max_iters=48,
                                         query_chunk=chunk),
                "range", "range", variant="int8_reg"))
            outs.append([np.asarray(x) for x in step(*args)])
    (i1, p1, s1), (i2, p2, s2) = outs
    np.testing.assert_array_equal(i1, i2)
    assert p1.tobytes() == p2.tobytes()   # bitwise, not approx
    assert s1.tobytes() == s2.tobytes()


def test_hlo_collective_parser():
    from repro.launch.hlo_stats import collective_bytes
    txt = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[64,64]{1,0} all-gather(%y), dimensions={0}
  %cp = (f32[8,8]{1,0}, f32[8,8]{1,0}) collective-permute(%a, %b)
  %notacoll = f32[4,4]{1,0} add(%p, %q)
"""
    out = collective_bytes(txt)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 64 * 64 * 2
    assert out["collective-permute"] == 2 * 8 * 8 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
    from repro.launch.hlo_stats import collective_counts
    assert collective_counts(txt) == {"all-reduce": 1, "all-gather": 1,
                                      "collective-permute": 1}
    # operand references and -done halves are not op instances
    assert collective_counts("  ROOT %t = f32[4]{0} tuple(%all-gather.1)\n"
                             "  %d = f32[4]{0} all-gather-done(%s)\n") == {}
