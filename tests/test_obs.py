"""Telemetry subsystem tests: traces, metrics, hooks, drift/recal, realized
routes, and the jagstat CLI.

The index fixtures here are tiny (N=400) — telemetry is host-side
bookkeeping, so the assertions are about record/counter correctness and
policy (hysteresis, exactly-once miss accounting), not performance; the
<5% overhead bar lives in ``benchmarks/obs_bench.py`` under CI.
"""
import importlib.util
import os

import numpy as np
import pytest

from repro.core import JAGConfig, JAGIndex, range_filters, range_table
from repro.cost.model import BASE_ROUTES, Observation, fit
from repro.obs import Telemetry
from repro.obs.drift import detect_drift, relative_error
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.recal import (heldout_error, observations_from_traces,
                             recalibrate)
from repro.obs.trace import TraceBuffer, TraceRecord, load_jsonl
from repro.serve.planner import PlannerConfig, explain
from repro.stream import StreamingJAGIndex

N, D, B = 400, 8, 8


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    xb = rng.normal(size=(N, D)).astype(np.float32)
    vals = rng.uniform(0, 1, N).astype(np.float32)
    q = (xb[rng.integers(0, N, B)] +
         0.05 * rng.normal(size=(B, D))).astype(np.float32)
    cfg = JAGConfig(degree=6, ls_build=8, batch_size=128, cand_pool=16,
                    calib_samples=16, n_seeds=2)
    index = JAGIndex.build(xb, range_table(vals), cfg)
    return index, q


def mixed_filt(b=B):
    his = np.where(np.arange(b) % 2 == 0, 0.01, 0.9).astype(np.float32)
    return range_filters(np.zeros(b, np.float32), his)


def uniform_filt(sel, b=B):
    return range_filters(np.zeros(b, np.float32),
                         np.full(b, sel, np.float32))


# ---------------------------------------------------------------------------
# trace ring buffer
# ---------------------------------------------------------------------------

def _rec(qid, **kw):
    base = dict(qid=qid, ts=0.0, epoch=0, band="graph", route="graph",
                group=0, group_size=1, batch=1, mode="batch", sel=0.1,
                k=10, ls=64, n=1000, d=16, n_clauses=1, delta_n=0,
                shard=None, predicted=None, cost_metric=None,
                observed_us=100.0, n_dist=50, n_expanded=5)
    base.update(kw)
    return TraceRecord(**base)


def test_ring_buffer_bounded_ordered_dropped():
    buf = TraceBuffer(capacity=4)
    for i in range(10):
        buf.append(_rec(i))
    assert len(buf) == 4
    assert [r.qid for r in buf] == [6, 7, 8, 9]     # oldest-first
    assert buf.dropped == 6
    assert [r.qid for r in buf.window(2)] == [8, 9]
    buf.clear()
    assert len(buf) == 0 and buf.dropped == 0


def test_trace_jsonl_roundtrip(tmp_path):
    buf = TraceBuffer(capacity=8)
    buf.append(_rec(0, predicted={"graph": 12.5, "prefilter": 99.0},
                    cost_metric="us", shard=[8, 125]))
    buf.append(_rec(1, route="graph[fused,int8]+delta", delta_n=64))
    path = str(tmp_path / "traces.jsonl")
    assert buf.dump_jsonl(path) == 2
    back = load_jsonl(path)
    assert back == list(buf)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counters_and_labels():
    reg = MetricsRegistry()
    reg.counter("jag_x_total", route="graph").inc()
    reg.counter("jag_x_total", route="graph").inc(2)
    reg.counter("jag_x_total", route="prefilter").inc()
    assert reg.value("jag_x_total", route="graph") == 3
    assert reg.value("jag_x_total", route="none") == 0
    assert reg.counter_total("jag_x_total") == 4


def test_histogram_quantiles_log_buckets():
    h = Histogram(lo=1.0, factor=2.0, n_buckets=16)
    for v in range(1, 1001):
        h.observe(float(v))
    # p50 rank is 500 -> bucket upper bound 512; p99 -> 1024
    assert h.quantile(0.5) == 512.0
    assert h.quantile(0.99) == 1024.0
    assert h.count == 1000
    p = h.percentiles()
    assert p["p50"] <= p["p95"] <= p["p99"]
    assert h.quantile(0.0) <= h.quantile(1.0)


def test_histogram_overflow_bucket():
    h = Histogram(lo=1.0, factor=2.0, n_buckets=3)   # bounds 1, 2, 4
    h.observe(1e9)
    assert h.quantile(1.0) == float("inf")


def test_prometheus_render():
    reg = MetricsRegistry()
    reg.counter("jag_call_total", route="graph").inc(5)
    reg.histogram("jag_lat_us", lo=1.0, factor=2.0, n_buckets=4,
                  route="graph").observe(3.0)
    text = reg.render()
    assert 'jag_call_total{route="graph"} 5' in text
    assert 'jag_lat_us_bucket{route="graph",le="4"} 1' in text
    assert 'jag_lat_us_bucket{route="graph",le="+Inf"} 1' in text
    assert 'jag_lat_us_count{route="graph"} 1' in text
    snap = reg.snapshot()
    assert snap["counters"]['jag_call_total{route="graph"}'] == 5


# ---------------------------------------------------------------------------
# attach / trace recording through search_auto
# ---------------------------------------------------------------------------

def test_attach_records_per_query_traces(setup):
    index, q = setup
    tel = index.attach_telemetry()
    try:
        tel.traces.clear()
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        recs = list(tel.traces)
        assert len(recs) == 2 * B
        assert len({r.qid for r in recs}) == 2 * B
        assert all(r.band in ("prefilter", "graph", "postfilter")
                   for r in recs)
        assert all(r.observed_us > 0 for r in recs)
        assert all(r.n == N and r.d == D and r.batch == B for r in recs)
        assert all(r.shard is None and r.epoch == 0 for r in recs)
        assert all(0.0 <= r.sel <= 1.0 for r in recs)
        # per-query traces cover both bands of the mixed batch
        assert len({r.band for r in recs}) >= 2
        # route counters tick per group, query counters per query
        assert tel.metrics.counter_total("jag_route_query_total") == 2 * B
        assert tel.metrics.value("jag_search_total") == 2
    finally:
        index.attach_telemetry(None)


def test_detach_stops_tracing(setup):
    index, q = setup
    tel = index.attach_telemetry()
    index.search_auto(q, mixed_filt(), k=3, ls=8)
    n0 = len(tel.traces)
    assert n0 > 0
    assert index.attach_telemetry(None) is None
    index.search_auto(q, mixed_filt(), k=3, ls=8)
    assert len(tel.traces) == n0
    assert index.executor.miss_hook is None
    # disabled-but-attached is also off
    tel2 = index.attach_telemetry(Telemetry(enabled=False))
    index.search_auto(q, mixed_filt(), k=3, ls=8)
    assert len(tel2.traces) == 0
    index.attach_telemetry(None)


def _toy_cost(route, f):
    if route == "prefilter":
        return 0.002 * (f["n"] * f["d"]) * f["sel"] ** 0.5
    if route == "graph":
        return 0.3 * (f["ls"] * f["d"]) ** 0.8 * f["sel"] ** -0.2 \
            * f["n"] ** 0.1
    assert route == "postfilter"
    return 0.1 * (f["ls"] * f["d"]) ** 0.9 * f["n"] ** 0.05 \
        * f["sel"] ** 0.3


def _toy_model(scale=1.0):
    """A model whose true costs are exactly in phi's span (exact fit)."""
    obs = []
    for n in (300.0, 600.0, 1200.0):
        for sel in (0.001, 0.01, 0.1, 0.5, 0.9):
            f = dict(sel=sel, n=n, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
            for route in BASE_ROUTES:
                us = _toy_cost(route, f) * scale
                obs.append(Observation(route, f, us=us, n_dist=us))
    return fit(obs, {"source": "toy"})


def test_traces_carry_predictions_with_cost_model(setup):
    index, q = setup
    index.attach_cost_model(_toy_model(), metric="us")
    tel = index.attach_telemetry()
    try:
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        recs = list(tel.traces)
        assert len(recs) == B
        for r in recs:
            assert r.cost_metric == "us"
            assert set(r.predicted) == set(BASE_ROUTES)
            assert all(c > 0 for c in r.predicted.values())
            assert relative_error(r) is not None
    finally:
        index.attach_telemetry(None)
        index.attach_cost_model(None)


# ---------------------------------------------------------------------------
# executor miss hook + trace_log composition (satellite)
# ---------------------------------------------------------------------------

def test_miss_hook_exactly_once_per_key(setup):
    index, q = setup
    ex = index.executor
    misses = []
    ex.miss_hook = misses.append
    try:
        filt = uniform_filt(0.4)
        index.search(q, filt, k=3, ls=9)      # odd ls -> fresh cache key
        n1 = len(misses)
        assert n1 >= 1
        index.search(q, filt, k=3, ls=9)      # warm: same key, no new miss
        assert len(misses) == n1
        index.search(q, filt, k=4, ls=9)      # distinct key -> one more
        assert len(misses) == n1 + 1
        # exactly once per distinct (epoch,)+key
        assert len(set(misses)) == len(misses)
        assert all(key[0] == ex._cache_epoch for key in misses)
        assert all((key in ex._cache) for key in misses)
    finally:
        ex.miss_hook = None


def test_epoch_roll_hook_and_trace_log_compose(setup):
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    tel = stream.attach_telemetry()
    filt = uniform_filt(0.4)
    stream.search_auto(q, filt, k=3, ls=8)
    assert tel.metrics.value("jag_epoch_roll_total") == 0
    m0 = tel.jit_misses()
    assert m0 > 0

    rng = np.random.default_rng(7)
    stream.insert(rng.normal(size=(16, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 16).astype(np.float32)))
    # PR 8 analysis capture must compose with telemetry enabled
    stream.executor.trace_log = captured = []
    stream.search_auto(q, filt, k=3, ls=8)
    stream.executor.trace_log = None
    assert captured, "trace_log capture dead with telemetry attached"
    assert tel.metrics.value("jag_epoch_roll_total") == 1
    assert tel.jit_misses() > m0          # rolled caches re-compile
    assert tel.delta_scan_fraction() > 0
    # streaming search traces got the +delta realized suffix
    assert any(t.route.endswith("+delta") for t in tel.traces)
    assert all(t.delta_n == 16 for t in list(tel.traces)[-B:])


def test_compaction_counter(setup):
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    tel = stream.attach_telemetry()
    rng = np.random.default_rng(8)
    stream.insert(rng.normal(size=(8, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 8).astype(np.float32)))
    assert stream.compact()
    assert tel.metrics.value("jag_compaction_total") == 1
    res, p = stream.search_auto(q, uniform_filt(0.4), k=3, ls=8,
                                return_plan=True)
    # compacted: no delta -> no +delta suffix on realized routes
    assert all(not r.endswith("+delta") for r in p.realized)


# ---------------------------------------------------------------------------
# drift + recalibration (satellite)
# ---------------------------------------------------------------------------

def _trace_window(model, scale, n_traces=240, n=2000.0, noise=0.02, seed=0,
                  bands=None):
    """Traces whose observed cost is ``scale`` x the model's prediction."""
    rng = np.random.default_rng(seed)
    sweep = (0.001, 0.003, 0.01, 0.05, 0.1, 0.3, 0.5, 0.7, 0.9)
    out = []
    for i in range(n_traces):
        sel = sweep[i % len(sweep)]
        f = dict(sel=sel, n=n, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
        pred = {r: model.predict(r, f) for r in BASE_ROUTES}
        band = (bands[i % len(bands)] if bands
                else min(pred, key=pred.get))
        obs = pred[band] * scale * (1.0 + noise * rng.standard_normal())
        out.append(_rec(i, band=band, route=band, sel=sel, k=5, ls=16,
                        n=int(n), d=8, predicted=pred, cost_metric="us",
                        observed_us=float(obs), n_dist=int(obs) + 1))
    return out


def test_drift_flagged_on_mis_scaled_model():
    model = _toy_model()
    window = _trace_window(model, scale=3.0)
    report = detect_drift(window, threshold=0.5)
    assert report.any_drifted
    # |p - 3p| / 3p = 2/3 for every trace
    for band, med in report.median_rel_err.items():
        assert 0.55 < med < 0.8, (band, med)
        assert report.drifted[band]
    assert "DRIFT" in report.summary()


def test_no_drift_on_unbiased_window():
    model = _toy_model()
    report = detect_drift(_trace_window(model, scale=1.0), threshold=0.5)
    assert not report.any_drifted
    assert report.median_rel_err            # measured, just small
    assert all(m < 0.1 for m in report.median_rel_err.values())


def test_observations_from_traces_roundtrip():
    model = _toy_model()
    window = _trace_window(model, scale=3.0, n_traces=30)
    obs = observations_from_traces(window)
    assert len(obs) == 30
    assert all(o.us > 0 and o.route in BASE_ROUTES for o in obs)
    assert obs[0].features["n"] == 2000.0
    err = heldout_error(model, window)
    assert 0.6 < err < 0.75                 # ~2/3 by construction


def test_recalibrate_swaps_on_drifted_window():
    model = _toy_model()
    # force band coverage so the refit re-learns every route's scale
    window = _trace_window(model, scale=3.0, bands=BASE_ROUTES)
    rep = recalibrate(model, window, metric="us", min_traces=32)
    assert rep.swapped, rep.reason
    assert rep.refit_err < rep.stale_err
    assert rep.model is not model
    assert rep.model.covers(BASE_ROUTES, "us")
    # the refit learned the x3: its predictions track observed costs
    f = dict(sel=0.1, n=2000.0, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
    for r in BASE_ROUTES:
        ratio = rep.model.predict(r, f) / model.predict(r, f)
        assert 2.5 < ratio < 3.5, (r, ratio)


def test_hysteresis_rejects_unbiased_window_no_oscillation():
    model = _toy_model()
    window = _trace_window(model, scale=1.0)
    for _ in range(3):                      # repeated calls stay rejected
        rep = recalibrate(model, window, metric="us", min_traces=32)
        assert not rep.swapped
        assert rep.reason.startswith("no drift")
        assert rep.model is model


def test_recalibrate_merges_unserved_routes():
    # window only ever served the graph band: the candidate must keep the
    # stale prefilter/postfilter coefficients (coverage never shrinks)
    model = _toy_model()
    window = _trace_window(model, scale=3.0, bands=("graph",))
    rep = recalibrate(model, window, metric="us", min_traces=32)
    assert rep.swapped, rep.reason
    assert rep.model.covers(BASE_ROUTES, "us")
    f = dict(sel=0.1, n=2000.0, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
    # unserved routes keep stale predictions bit-identically
    for r in ("prefilter", "postfilter"):
        assert rep.model.predict(r, f) == pytest.approx(model.predict(r, f))


def test_recalibrate_window_too_small():
    model = _toy_model()
    rep = recalibrate(model, _trace_window(model, 3.0, n_traces=8),
                      metric="us", min_traces=64)
    assert not rep.swapped and "window too small" in rep.reason


def test_maybe_recalibrate_attaches_on_swap(setup):
    index, q = setup
    stale = _toy_model()
    index.attach_cost_model(stale, metric="us")
    tel = index.attach_telemetry(Telemetry(drift_threshold=0.5))
    try:
        for t in _trace_window(stale, scale=3.0, n_traces=128):
            tel.traces.append(t)
        rep = tel.maybe_recalibrate(index)
        assert rep.swapped
        assert index.cost_model is rep.model
        assert tel.metrics.value("jag_recal_swap_total") == 1
        assert tel.last_recal is rep
    finally:
        index.attach_telemetry(None)
        index.attach_cost_model(None)


# ---------------------------------------------------------------------------
# realized-route satellite (bugfix): plans report what actually executed
# ---------------------------------------------------------------------------

def test_realized_routes_default_variant(setup):
    index, q = setup
    res, p = index.search_auto(q, mixed_filt(), k=3, ls=8, return_plan=True)
    assert p.realized == p.routes           # default layout == band names
    assert "executed[" not in explain(p)    # byte-stable when identical


def test_realized_routes_serving_variant(setup):
    index, q = setup
    res, p = index.search_auto(q, uniform_filt(0.4), k=3, ls=8,
                               return_plan=True, layout="fused",
                               dtype="int8")
    assert p.routes == ("graph",) * B
    assert p.realized == ("graph[fused,int8]",) * B
    note = explain(p)
    assert "executed[graph[fused,int8]:8]" in note


def test_realized_route_batch_mode(setup):
    index, q = setup
    res, p = index.search_auto(q, uniform_filt(0.4), k=3, ls=8,
                               return_plan=True, mode="batch",
                               layout="fused")
    assert p.route == "graph"
    assert p.realized == "graph[fused,f32]"
    assert "executed[graph[fused,f32]]" in explain(p)


def test_realized_streaming_delta_suffix(setup):
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    rng = np.random.default_rng(9)
    stream.insert(rng.normal(size=(8, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 8).astype(np.float32)))
    res, p = stream.search_auto(q, mixed_filt(), k=3, ls=8,
                                return_plan=True)
    assert all(r.endswith("+delta") for r in p.realized)
    assert "executed[" in explain(p)


def test_plan_without_execution_has_no_realized(setup):
    from repro.serve.planner import plan_per_query
    index, q = setup
    p = plan_per_query(mixed_filt(), index.attr, PlannerConfig(),
                       executor=index.executor)
    assert p.realized is None
    assert "executed[" not in explain(p)


# ---------------------------------------------------------------------------
# jagstat CLI (exporter satellite)
# ---------------------------------------------------------------------------

def _load_jagstat():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                        "jagstat.py")
    spec = importlib.util.spec_from_file_location("jagstat", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_jagstat_renders_summary(tmp_path, capsys, setup):
    index, q = setup
    index.attach_cost_model(_toy_model(), metric="us")
    tel = index.attach_telemetry()
    try:
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        index.search_auto(q, uniform_filt(0.4), k=3, ls=8)
        path = str(tmp_path / "traces.jsonl")
        assert tel.traces.dump_jsonl(path) == 2 * B
    finally:
        index.attach_telemetry(None)
        index.attach_cost_model(None)

    jagstat = _load_jagstat()
    assert jagstat.main([path]) == 0
    out = capsys.readouterr().out
    assert "route" in out and "p50us" in out
    rows = jagstat.summarize(load_jsonl(path))
    assert sum(r["queries"] for r in rows) == 2 * B
    assert abs(sum(r["share_pct"] for r in rows) - 100.0) < 0.5
    assert all(r["p50_us"] > 0 for r in rows)
    # --json mode emits machine-readable rows
    assert jagstat.main([path, "--json"]) == 0
    import json as _json
    assert _json.loads(capsys.readouterr().out)


def test_jagstat_empty_file(tmp_path, capsys):
    path = str(tmp_path / "empty.jsonl")
    open(path, "w").close()
    assert _load_jagstat().main([path]) == 1
