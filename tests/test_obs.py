"""Telemetry subsystem tests: traces, metrics, hooks, drift/recal, realized
routes, quality observability (shadow oracle, introspection, spans,
health), and the jagstat CLI.

The index fixtures here are tiny (N=400) — telemetry is host-side
bookkeeping, so the assertions are about record/counter correctness and
policy (hysteresis, exactly-once miss accounting), not performance; the
<5% overhead bar lives in ``benchmarks/obs_bench.py`` under CI.
"""
import importlib.util
import json
import os
from dataclasses import asdict

import numpy as np
import pytest

from repro.core import JAGConfig, JAGIndex, range_filters, range_table
from repro.core.filters import as_filter
from repro.cost.model import BASE_ROUTES, Observation, fit
from repro.obs import Telemetry
from repro.obs.drift import detect_drift, relative_error
from repro.obs.health import (FAIL, PASS, WARN, HealthSLO, health_report,
                              render_health)
from repro.obs.introspect import introspection_summary
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.recal import (heldout_error, observations_from_traces,
                             recalibrate)
from repro.obs.shadow import (ShadowAuditor, cells_from_records,
                              load_shadow_jsonl, sampled_qid, sel_band,
                              wilson_interval)
from repro.obs.spans import SpanRecorder
from repro.obs.trace import TraceBuffer, TraceRecord, load_buffer, load_jsonl
from repro.serve.planner import PlannerConfig, explain
from repro.stream import StreamingJAGIndex

N, D, B = 400, 8, 8


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    xb = rng.normal(size=(N, D)).astype(np.float32)
    vals = rng.uniform(0, 1, N).astype(np.float32)
    q = (xb[rng.integers(0, N, B)] +
         0.05 * rng.normal(size=(B, D))).astype(np.float32)
    cfg = JAGConfig(degree=6, ls_build=8, batch_size=128, cand_pool=16,
                    calib_samples=16, n_seeds=2)
    index = JAGIndex.build(xb, range_table(vals), cfg)
    return index, q


def mixed_filt(b=B):
    his = np.where(np.arange(b) % 2 == 0, 0.01, 0.9).astype(np.float32)
    return range_filters(np.zeros(b, np.float32), his)


def uniform_filt(sel, b=B):
    return range_filters(np.zeros(b, np.float32),
                         np.full(b, sel, np.float32))


# ---------------------------------------------------------------------------
# trace ring buffer
# ---------------------------------------------------------------------------

def _rec(qid, **kw):
    base = dict(qid=qid, ts=0.0, epoch=0, band="graph", route="graph",
                group=0, group_size=1, batch=1, mode="batch", sel=0.1,
                k=10, ls=64, n=1000, d=16, n_clauses=1, delta_n=0,
                shard=None, predicted=None, cost_metric=None,
                observed_us=100.0, n_dist=50, n_expanded=5)
    base.update(kw)
    return TraceRecord(**base)


def test_ring_buffer_bounded_ordered_dropped():
    buf = TraceBuffer(capacity=4)
    for i in range(10):
        buf.append(_rec(i))
    assert len(buf) == 4
    assert [r.qid for r in buf] == [6, 7, 8, 9]     # oldest-first
    assert buf.dropped == 6
    assert [r.qid for r in buf.window(2)] == [8, 9]
    buf.clear()
    assert len(buf) == 0 and buf.dropped == 0


def test_trace_jsonl_roundtrip(tmp_path):
    buf = TraceBuffer(capacity=8)
    buf.append(_rec(0, predicted={"graph": 12.5, "prefilter": 99.0},
                    cost_metric="us", shard=[8, 125]))
    buf.append(_rec(1, route="graph[fused,int8]+delta", delta_n=64))
    path = str(tmp_path / "traces.jsonl")
    assert buf.dump_jsonl(path) == 2
    back = load_jsonl(path)
    assert back == list(buf)


def test_trace_ring_wraparound_roundtrip(tmp_path):
    # overflow the ring, dump, restore: the newest `capacity` records AND
    # the dropped counter must survive the JSONL round-trip
    buf = TraceBuffer(capacity=4)
    for i in range(11):
        buf.append(_rec(i, dead_ends=i, sat_step=i + 1))
    assert buf.dropped == 7
    path = str(tmp_path / "wrap.jsonl")
    assert buf.dump_jsonl(path) == 4
    back = load_buffer(path)
    assert [r.qid for r in back] == [7, 8, 9, 10]
    assert back.capacity == 4
    assert back.dropped == 7
    assert list(back) == list(buf)
    # the restored ring keeps ring semantics: next append evicts oldest
    back.append(_rec(11))
    assert [r.qid for r in back] == [8, 9, 10, 11]
    assert back.dropped == 8
    # line-oriented consumers skip the meta header transparently
    assert [r.qid for r in load_jsonl(path)] == [7, 8, 9, 10]


def test_load_buffer_headerless_backcompat(tmp_path):
    # dumps written before the meta header (and before the introspection
    # fields) existed must still load: capacity = record count, dropped 0
    path = str(tmp_path / "old.jsonl")
    with open(path, "w") as fh:
        for i in range(3):
            raw = asdict(_rec(i))
            del raw["dead_ends"], raw["sat_step"]
            fh.write(json.dumps(raw) + "\n")
    back = load_buffer(path)
    assert [r.qid for r in back] == [0, 1, 2]
    assert back.capacity == 3 and back.dropped == 0
    assert all(r.dead_ends is None and r.sat_step is None for r in back)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counters_and_labels():
    reg = MetricsRegistry()
    reg.counter("jag_x_total", route="graph").inc()
    reg.counter("jag_x_total", route="graph").inc(2)
    reg.counter("jag_x_total", route="prefilter").inc()
    assert reg.value("jag_x_total", route="graph") == 3
    assert reg.value("jag_x_total", route="none") == 0
    assert reg.counter_total("jag_x_total") == 4


def test_histogram_quantiles_log_buckets():
    h = Histogram(lo=1.0, factor=2.0, n_buckets=16)
    for v in range(1, 1001):
        h.observe(float(v))
    # p50 rank is 500 -> bucket upper bound 512; p99 -> 1024
    assert h.quantile(0.5) == 512.0
    assert h.quantile(0.99) == 1024.0
    assert h.count == 1000
    p = h.percentiles()
    assert p["p50"] <= p["p95"] <= p["p99"]
    assert h.quantile(0.0) <= h.quantile(1.0)


def test_histogram_overflow_bucket():
    h = Histogram(lo=1.0, factor=2.0, n_buckets=3)   # bounds 1, 2, 4
    h.observe(1e9)
    assert h.quantile(1.0) == float("inf")


def test_prometheus_render():
    reg = MetricsRegistry()
    reg.counter("jag_call_total", route="graph").inc(5)
    reg.histogram("jag_lat_us", lo=1.0, factor=2.0, n_buckets=4,
                  route="graph").observe(3.0)
    text = reg.render()
    assert 'jag_call_total{route="graph"} 5' in text
    assert 'jag_lat_us_bucket{route="graph",le="4"} 1' in text
    assert 'jag_lat_us_bucket{route="graph",le="+Inf"} 1' in text
    assert 'jag_lat_us_count{route="graph"} 1' in text
    snap = reg.snapshot()
    assert snap["counters"]['jag_call_total{route="graph"}'] == 5


def test_prometheus_label_escaping():
    # the exposition format requires backslash, double quote, and line
    # feed escaped inside label values — route descriptors are free text
    reg = MetricsRegistry()
    reg.counter("jag_x_total", route='a"b\\c\nd').inc()
    text = reg.render()
    assert 'route="a\\"b\\\\c\\nd"' in text
    assert "\n\n" not in text            # the raw newline never leaks
    reg2 = MetricsRegistry()
    reg2.histogram("jag_h", n_buckets=1, route='q"r').observe(1.0)
    assert 'jag_h_count{route="q\\"r"} 1' in reg2.render()


# ---------------------------------------------------------------------------
# attach / trace recording through search_auto
# ---------------------------------------------------------------------------

def test_attach_records_per_query_traces(setup):
    index, q = setup
    tel = index.attach_telemetry()
    try:
        tel.traces.clear()
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        recs = list(tel.traces)
        assert len(recs) == 2 * B
        assert len({r.qid for r in recs}) == 2 * B
        assert all(r.band in ("prefilter", "graph", "postfilter")
                   for r in recs)
        assert all(r.observed_us > 0 for r in recs)
        assert all(r.n == N and r.d == D and r.batch == B for r in recs)
        assert all(r.shard is None and r.epoch == 0 for r in recs)
        assert all(0.0 <= r.sel <= 1.0 for r in recs)
        # per-query traces cover both bands of the mixed batch
        assert len({r.band for r in recs}) >= 2
        # route counters tick per group, query counters per query
        assert tel.metrics.counter_total("jag_route_query_total") == 2 * B
        assert tel.metrics.value("jag_search_total") == 2
    finally:
        index.attach_telemetry(None)


def test_detach_stops_tracing(setup):
    index, q = setup
    tel = index.attach_telemetry()
    index.search_auto(q, mixed_filt(), k=3, ls=8)
    n0 = len(tel.traces)
    assert n0 > 0
    assert index.attach_telemetry(None) is None
    index.search_auto(q, mixed_filt(), k=3, ls=8)
    assert len(tel.traces) == n0
    assert index.executor.miss_hook is None
    # disabled-but-attached is also off
    tel2 = index.attach_telemetry(Telemetry(enabled=False))
    index.search_auto(q, mixed_filt(), k=3, ls=8)
    assert len(tel2.traces) == 0
    index.attach_telemetry(None)


def _toy_cost(route, f):
    if route == "prefilter":
        return 0.002 * (f["n"] * f["d"]) * f["sel"] ** 0.5
    if route == "graph":
        return 0.3 * (f["ls"] * f["d"]) ** 0.8 * f["sel"] ** -0.2 \
            * f["n"] ** 0.1
    assert route == "postfilter"
    return 0.1 * (f["ls"] * f["d"]) ** 0.9 * f["n"] ** 0.05 \
        * f["sel"] ** 0.3


def _toy_model(scale=1.0):
    """A model whose true costs are exactly in phi's span (exact fit)."""
    obs = []
    for n in (300.0, 600.0, 1200.0):
        for sel in (0.001, 0.01, 0.1, 0.5, 0.9):
            f = dict(sel=sel, n=n, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
            for route in BASE_ROUTES:
                us = _toy_cost(route, f) * scale
                obs.append(Observation(route, f, us=us, n_dist=us))
    return fit(obs, {"source": "toy"})


def test_traces_carry_predictions_with_cost_model(setup):
    index, q = setup
    index.attach_cost_model(_toy_model(), metric="us")
    tel = index.attach_telemetry()
    try:
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        recs = list(tel.traces)
        assert len(recs) == B
        for r in recs:
            assert r.cost_metric == "us"
            assert set(r.predicted) == set(BASE_ROUTES)
            assert all(c > 0 for c in r.predicted.values())
            assert relative_error(r) is not None
    finally:
        index.attach_telemetry(None)
        index.attach_cost_model(None)


# ---------------------------------------------------------------------------
# executor miss hook + trace_log composition (satellite)
# ---------------------------------------------------------------------------

def test_miss_hook_exactly_once_per_key(setup):
    index, q = setup
    ex = index.executor
    misses = []
    ex.miss_hook = misses.append
    try:
        filt = uniform_filt(0.4)
        index.search(q, filt, k=3, ls=9)      # odd ls -> fresh cache key
        n1 = len(misses)
        assert n1 >= 1
        index.search(q, filt, k=3, ls=9)      # warm: same key, no new miss
        assert len(misses) == n1
        index.search(q, filt, k=4, ls=9)      # distinct key -> one more
        assert len(misses) == n1 + 1
        # exactly once per distinct (epoch,)+key
        assert len(set(misses)) == len(misses)
        assert all(key[0] == ex._cache_epoch for key in misses)
        assert all((key in ex._cache) for key in misses)
    finally:
        ex.miss_hook = None


def test_epoch_roll_hook_and_trace_log_compose(setup):
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    tel = stream.attach_telemetry()
    filt = uniform_filt(0.4)
    stream.search_auto(q, filt, k=3, ls=8)
    assert tel.metrics.value("jag_epoch_roll_total") == 0
    m0 = tel.jit_misses()
    assert m0 > 0

    rng = np.random.default_rng(7)
    stream.insert(rng.normal(size=(16, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 16).astype(np.float32)))
    # PR 8 analysis capture must compose with telemetry enabled
    stream.executor.trace_log = captured = []
    stream.search_auto(q, filt, k=3, ls=8)
    stream.executor.trace_log = None
    assert captured, "trace_log capture dead with telemetry attached"
    assert tel.metrics.value("jag_epoch_roll_total") == 1
    assert tel.jit_misses() > m0          # rolled caches re-compile
    assert tel.delta_scan_fraction() > 0
    # streaming search traces got the +delta realized suffix
    assert any(t.route.endswith("+delta") for t in tel.traces)
    assert all(t.delta_n == 16 for t in list(tel.traces)[-B:])


def test_compaction_counter(setup):
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    tel = stream.attach_telemetry()
    rng = np.random.default_rng(8)
    stream.insert(rng.normal(size=(8, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 8).astype(np.float32)))
    assert stream.compact()
    assert tel.metrics.value("jag_compaction_total") == 1
    res, p = stream.search_auto(q, uniform_filt(0.4), k=3, ls=8,
                                return_plan=True)
    # compacted: no delta -> no +delta suffix on realized routes
    assert all(not r.endswith("+delta") for r in p.realized)


# ---------------------------------------------------------------------------
# drift + recalibration (satellite)
# ---------------------------------------------------------------------------

def _trace_window(model, scale, n_traces=240, n=2000.0, noise=0.02, seed=0,
                  bands=None):
    """Traces whose observed cost is ``scale`` x the model's prediction."""
    rng = np.random.default_rng(seed)
    sweep = (0.001, 0.003, 0.01, 0.05, 0.1, 0.3, 0.5, 0.7, 0.9)
    out = []
    for i in range(n_traces):
        sel = sweep[i % len(sweep)]
        f = dict(sel=sel, n=n, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
        pred = {r: model.predict(r, f) for r in BASE_ROUTES}
        band = (bands[i % len(bands)] if bands
                else min(pred, key=pred.get))
        obs = pred[band] * scale * (1.0 + noise * rng.standard_normal())
        out.append(_rec(i, band=band, route=band, sel=sel, k=5, ls=16,
                        n=int(n), d=8, predicted=pred, cost_metric="us",
                        observed_us=float(obs), n_dist=int(obs) + 1))
    return out


def test_drift_flagged_on_mis_scaled_model():
    model = _toy_model()
    window = _trace_window(model, scale=3.0)
    report = detect_drift(window, threshold=0.5)
    assert report.any_drifted
    # |p - 3p| / 3p = 2/3 for every trace
    for band, med in report.median_rel_err.items():
        assert 0.55 < med < 0.8, (band, med)
        assert report.drifted[band]
    assert "DRIFT" in report.summary()


def test_no_drift_on_unbiased_window():
    model = _toy_model()
    report = detect_drift(_trace_window(model, scale=1.0), threshold=0.5)
    assert not report.any_drifted
    assert report.median_rel_err            # measured, just small
    assert all(m < 0.1 for m in report.median_rel_err.values())


def test_observations_from_traces_roundtrip():
    model = _toy_model()
    window = _trace_window(model, scale=3.0, n_traces=30)
    obs = observations_from_traces(window)
    assert len(obs) == 30
    assert all(o.us > 0 and o.route in BASE_ROUTES for o in obs)
    assert obs[0].features["n"] == 2000.0
    err = heldout_error(model, window)
    assert 0.6 < err < 0.75                 # ~2/3 by construction


def test_recalibrate_swaps_on_drifted_window():
    model = _toy_model()
    # force band coverage so the refit re-learns every route's scale
    window = _trace_window(model, scale=3.0, bands=BASE_ROUTES)
    rep = recalibrate(model, window, metric="us", min_traces=32)
    assert rep.swapped, rep.reason
    assert rep.refit_err < rep.stale_err
    assert rep.model is not model
    assert rep.model.covers(BASE_ROUTES, "us")
    # the refit learned the x3: its predictions track observed costs
    f = dict(sel=0.1, n=2000.0, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
    for r in BASE_ROUTES:
        ratio = rep.model.predict(r, f) / model.predict(r, f)
        assert 2.5 < ratio < 3.5, (r, ratio)


def test_hysteresis_rejects_unbiased_window_no_oscillation():
    model = _toy_model()
    window = _trace_window(model, scale=1.0)
    for _ in range(3):                      # repeated calls stay rejected
        rep = recalibrate(model, window, metric="us", min_traces=32)
        assert not rep.swapped
        assert rep.reason.startswith("no drift")
        assert rep.model is model


def test_recalibrate_merges_unserved_routes():
    # window only ever served the graph band: the candidate must keep the
    # stale prefilter/postfilter coefficients (coverage never shrinks)
    model = _toy_model()
    window = _trace_window(model, scale=3.0, bands=("graph",))
    rep = recalibrate(model, window, metric="us", min_traces=32)
    assert rep.swapped, rep.reason
    assert rep.model.covers(BASE_ROUTES, "us")
    f = dict(sel=0.1, n=2000.0, d=8.0, k=5.0, ls=16.0, n_clauses=1.0)
    # unserved routes keep stale predictions bit-identically
    for r in ("prefilter", "postfilter"):
        assert rep.model.predict(r, f) == pytest.approx(model.predict(r, f))


def test_recalibrate_window_too_small():
    model = _toy_model()
    rep = recalibrate(model, _trace_window(model, 3.0, n_traces=8),
                      metric="us", min_traces=64)
    assert not rep.swapped and "window too small" in rep.reason


def test_recalibrate_degenerate_windows_decline_deterministically():
    # windows below the held-out split minimum must decline with a
    # logged reason, never swap, and do so identically on every call
    model = _toy_model()
    one = _trace_window(model, scale=3.0, n_traces=1)
    reasons = set()
    for _ in range(3):
        rep = recalibrate(model, one, metric="us", min_traces=1,
                          require_drift=False)
        assert not rep.swapped
        assert rep.model is model
        assert "degenerate holdout split" in rep.reason
        assert rep.stale_err is None and rep.refit_err is None
        reasons.add(rep.reason)
    assert len(reasons) == 1                # decline is deterministic
    # below the window floor the gate names itself too
    for _ in range(2):
        rep = recalibrate(model, _trace_window(model, 3.0, n_traces=4),
                          metric="us", min_traces=8)
        assert not rep.swapped and "window too small" in rep.reason
    # an empty window is the same decline, not an exception
    rep = recalibrate(model, [], metric="us", min_traces=8)
    assert not rep.swapped and "window too small" in rep.reason


def test_maybe_recalibrate_attaches_on_swap(setup):
    index, q = setup
    stale = _toy_model()
    index.attach_cost_model(stale, metric="us")
    tel = index.attach_telemetry(Telemetry(drift_threshold=0.5))
    try:
        for t in _trace_window(stale, scale=3.0, n_traces=128):
            tel.traces.append(t)
        rep = tel.maybe_recalibrate(index)
        assert rep.swapped
        assert index.cost_model is rep.model
        assert tel.metrics.value("jag_recal_swap_total") == 1
        assert tel.last_recal is rep
    finally:
        index.attach_telemetry(None)
        index.attach_cost_model(None)


# ---------------------------------------------------------------------------
# realized-route satellite (bugfix): plans report what actually executed
# ---------------------------------------------------------------------------

def test_realized_routes_default_variant(setup):
    index, q = setup
    res, p = index.search_auto(q, mixed_filt(), k=3, ls=8, return_plan=True)
    assert p.realized == p.routes           # default layout == band names
    assert "executed[" not in explain(p)    # byte-stable when identical


def test_realized_routes_serving_variant(setup):
    index, q = setup
    res, p = index.search_auto(q, uniform_filt(0.4), k=3, ls=8,
                               return_plan=True, layout="fused",
                               dtype="int8")
    assert p.routes == ("graph",) * B
    assert p.realized == ("graph[fused,int8]",) * B
    note = explain(p)
    assert "executed[graph[fused,int8]:8]" in note


def test_realized_route_batch_mode(setup):
    index, q = setup
    res, p = index.search_auto(q, uniform_filt(0.4), k=3, ls=8,
                               return_plan=True, mode="batch",
                               layout="fused")
    assert p.route == "graph"
    assert p.realized == "graph[fused,f32]"
    assert "executed[graph[fused,f32]]" in explain(p)


def test_realized_streaming_delta_suffix(setup):
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    rng = np.random.default_rng(9)
    stream.insert(rng.normal(size=(8, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 8).astype(np.float32)))
    res, p = stream.search_auto(q, mixed_filt(), k=3, ls=8,
                                return_plan=True)
    assert all(r.endswith("+delta") for r in p.realized)
    assert "executed[" in explain(p)


def test_plan_without_execution_has_no_realized(setup):
    from repro.serve.planner import plan_per_query
    index, q = setup
    p = plan_per_query(mixed_filt(), index.attr, PlannerConfig(),
                       executor=index.executor)
    assert p.realized is None
    assert "executed[" not in explain(p)


# ---------------------------------------------------------------------------
# shadow-oracle recall auditing (tentpole)
# ---------------------------------------------------------------------------

def test_sampled_qid_deterministic_and_proportional():
    picks = [sampled_qid(i, 0.25) for i in range(4096)]
    assert picks == [sampled_qid(i, 0.25) for i in range(4096)]
    assert 0.2 < sum(picks) / 4096 < 0.3
    assert all(sampled_qid(i, 1.0) for i in range(16))
    assert not any(sampled_qid(i, 0.0) for i in range(16))
    # nested: every qid sampled at f stays sampled at any f' > f
    assert all(sampled_qid(i, 0.5)
               for i in range(4096) if sampled_qid(i, 0.25))


def test_wilson_interval_sanity():
    lo, hi = wilson_interval(90, 100)
    assert 0.0 <= lo < 0.9 < hi <= 1.0
    assert wilson_interval(0, 0) == (0.0, 1.0)
    lo_n, hi_n = wilson_interval(900, 1000)
    assert hi_n - lo_n < hi - lo            # tighter with more trials
    lo0, hi0 = wilson_interval(0, 50)
    assert lo0 < 1e-12 and hi0 < 0.15       # sane at p = 0
    lo1, hi1 = wilson_interval(50, 50)
    assert hi1 > 1.0 - 1e-12 and lo1 > 0.85  # ... and p = 1


def test_sel_band_edges():
    assert sel_band(0.0005) == "sel<=0.001"
    assert sel_band(0.001) == "sel<=0.001"
    assert sel_band(0.05) == "sel<=0.1"
    assert sel_band(0.3) == "sel<=0.5"
    assert sel_band(0.7) == "sel>0.5"


def test_shadow_deferred_flush_semantics(setup):
    index, q = setup
    aud = ShadowAuditor(1.0, max_pending=2)
    filt = as_filter(uniform_filt(0.4))
    res = index.search_auto(q, filt, k=3, ls=8)
    aud.audit(index, q, filt, res, k=3, qid0=0, routes=["graph"] * B,
              sels=np.full(B, 0.4))
    # serve time only enqueued — the oracle hasn't run yet
    assert aud.n_pending == B and aud.n_audited == 0
    rows = aud.recall_table()               # reporting accessors flush
    assert aud.n_pending == 0 and aud.n_audited == B
    assert rows and rows[0]["trials"] > 0
    # the pending queue is bounded: max_pending calls flush synchronously
    aud.audit(index, q, filt, res, k=3, qid0=B, routes=["graph"] * B,
              sels=np.full(B, 0.4))
    assert aud.n_pending == B
    aud.audit(index, q, filt, res, k=3, qid0=2 * B, routes=["graph"] * B,
              sels=np.full(B, 0.4))
    assert aud.n_pending == 0 and aud.n_audited == 3 * B
    assert aud.flush() == 0                 # idempotent when drained


def test_shadow_estimates_match_exact_oracle(setup):
    # the honesty property at unit scale: the 0.5-sampled telemetry
    # auditor must agree BIT-FOR-BIT with a fraction-1.0 auditor on
    # every query it sampled (same hits, trials, route, band) — the
    # population-level Wilson-containment acceptance check runs on the
    # bigger sweep in benchmarks/obs_bench.py --quality
    index, q = setup
    tel = index.attach_telemetry(Telemetry(shadow=0.5, capacity=512))
    exact = ShadowAuditor(1.0)
    try:
        qid0 = 0
        for sel in (0.05, 0.4, 0.9, 0.4, 0.05, 0.9):
            filt = as_filter(uniform_filt(sel))
            res, p = index.search_auto(q, filt, k=3, ls=8,
                                       return_plan=True)
            exact.audit(index, q, filt, res, k=3, qid0=qid0,
                        routes=[str(r) for r in p.realized],
                        sels=np.asarray(p.selectivity, np.float64))
            qid0 += B
        tel.shadow.flush()
        exact.flush()
        assert 0 < tel.shadow.n_audited < exact.n_audited == 6 * B
        ex_by_qid = {r.qid: r for r in exact.records}
        for r in tel.shadow.records:
            e = ex_by_qid[r.qid]             # sampled ⊂ exactly-audited
            assert (r.hits, r.trials, r.route, r.band, r.recall) \
                == (e.hits, e.trials, e.route, e.band, e.recall)
        # deterministic sampling: exactly the hash-selected qids audited
        assert sorted(r.qid for r in tel.shadow.records) \
            == [i for i in range(6 * B) if sampled_qid(i, 0.5)]
        # every sampled (route, band) cell exists in the exact census,
        # with a subset of its trials
        assert set(tel.shadow.cells) <= set(exact.cells)
        for key, cell in tel.shadow.cells.items():
            assert cell.trials <= exact.cells[key].trials
        assert tel.metrics.value("jag_shadow_audit_total") \
            == tel.shadow.n_audited
    finally:
        index.attach_telemetry(None)


def test_shadow_records_roundtrip_and_rebuild(tmp_path, setup):
    index, q = setup
    aud = ShadowAuditor(1.0)
    filt = as_filter(uniform_filt(0.4))
    res = index.search_auto(q, filt, k=3, ls=8)
    aud.audit(index, q, filt, res, k=3, qid0=0, routes=["graph"] * B,
              sels=np.full(B, 0.4))
    path = str(tmp_path / "shadow.jsonl")
    assert aud.dump_jsonl(path) == B        # dump flushes first
    back = load_shadow_jsonl(path)
    assert [r.qid for r in back] == list(range(B))
    assert all(r.route == "graph" and r.k == 3 for r in back)
    assert all(0.0 <= r.recall <= 1.0 for r in back)
    # per-cell estimators rebuild exactly from the dumped records
    cells = cells_from_records(back)
    assert set(cells) == set(aud.cells)
    for key, cell in cells.items():
        assert (cell.hits, cell.trials) == \
            (aud.cells[key].hits, aud.cells[key].trials)


def test_shadow_vacuous_filter_counts_no_trials(setup):
    # a filter no row satisfies contributes zero Bernoulli trials
    # (recall_at_k convention) — the cell can then only warn, not fail
    from repro.core.beam_search import SearchResult
    index, q = setup
    aud = ShadowAuditor(1.0)
    empty = as_filter(range_filters(np.full(B, 0.9, np.float32),
                                    np.full(B, 0.1, np.float32)))
    res = SearchResult(
        ids=np.full((B, 3), -1, np.int32),
        primary=np.full((B, 3), np.inf, np.float32),
        secondary=np.full((B, 3), np.inf, np.float32),
        vlog=np.full((B, 4), -1, np.int32),
        n_expanded=np.zeros(B, np.int32),
        n_dist=np.zeros(B, np.int32))
    aud.audit(index, q, empty, res, k=3, qid0=0,
              routes=["prefilter"] * B, sels=np.zeros(B))
    aud.flush()
    (cell,) = aud.cells.values()
    assert cell.trials == 0 and cell.n_queries == B
    assert cell.estimate == 1.0
    assert cell.wilson() == (0.0, 1.0)


def test_streaming_shadow_audits_post_merge_exactly_once(setup):
    # the streaming index audits the FINAL (delta-merged) result, and the
    # inner frozen-graph search must not double-audit the same queries
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    tel = stream.attach_telemetry(Telemetry(shadow=1.0))
    rng = np.random.default_rng(3)
    stream.insert(rng.normal(size=(16, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 16).astype(np.float32)))
    stream.search_auto(q, uniform_filt(0.4), k=3, ls=8)
    tel.shadow.flush()
    assert tel.shadow.n_audited == B
    # the audited routes are the realized (+delta) ones, and the oracle
    # covered base + delta rows (trials present for a 0.4-selectivity)
    assert all(route.endswith("+delta") for route, _, _ in tel.shadow.cells)
    assert all(c.trials > 0 for c in tel.shadow.cells.values())
    for r in tel.shadow.records:
        assert 0.0 <= r.recall <= 1.0


# ---------------------------------------------------------------------------
# traversal introspection (tentpole)
# ---------------------------------------------------------------------------

def test_introspective_route_bit_identical(setup):
    index, q = setup
    ex = index.executor
    filt = as_filter(uniform_filt(0.4))
    for layout in ("default", "fused"):
        r_std = ex.graph(q, filt, k=3, ls=8, max_iters=16, layout=layout)
        r_int, stats = ex.graph(q, filt, k=3, ls=8, max_iters=16,
                                layout=layout, introspect=True)
        np.testing.assert_array_equal(np.asarray(r_std.ids),
                                      np.asarray(r_int.ids))
        np.testing.assert_array_equal(np.asarray(r_std.primary),
                                      np.asarray(r_int.primary))
        np.testing.assert_array_equal(np.asarray(r_std.secondary),
                                      np.asarray(r_int.secondary))
        hops = np.asarray(stats.hops)
        dead = np.asarray(stats.dead_ends)
        sat = np.asarray(stats.sat_step)
        assert hops.shape == dead.shape == sat.shape == (B,)
        assert (hops >= 1).all()
        assert (dead >= 0).all() and (dead <= hops).all()
        assert (sat >= 0).all() and (sat <= hops).all()


def test_introspect_is_a_cache_key_component(setup):
    index, q = setup
    ex = index.executor
    misses = []
    ex.miss_hook = misses.append
    try:
        filt = as_filter(uniform_filt(0.4))
        ex.graph(q, filt, k=3, ls=11, max_iters=16)      # odd ls: fresh
        ex.graph(q, filt, k=3, ls=11, max_iters=16, introspect=True)
        assert len(misses) == 2                          # distinct entries
        assert any("introspect" in key for key in misses)
        ex.graph(q, filt, k=3, ls=11, max_iters=16, introspect=True)
        assert len(misses) == 2                          # warm second time
    finally:
        ex.miss_hook = None


def test_introspect_traces_stamped_and_summarized(setup):
    index, q = setup
    tel = index.attach_telemetry(Telemetry(introspect=True))
    try:
        index.search_auto(q, uniform_filt(0.4), k=3, ls=8)
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        recs = list(tel.traces)
        graph = [r for r in recs if r.band == "graph"]
        other = [r for r in recs if r.band != "graph"]
        assert graph, "0.4-selectivity batch should route graph"
        assert all(r.dead_ends is not None and r.sat_step is not None
                   for r in graph)
        assert all(r.dead_ends >= 0 and r.sat_step >= 0 for r in graph)
        # non-graph routes have no traversal loop: stamps stay None
        assert all(r.dead_ends is None and r.sat_step is None
                   for r in other)
        rows = introspection_summary(recs)
        assert len(rows) == 1 and rows[0]["queries"] == len(graph)
        assert rows[0]["dead_end_rate"] is not None
        assert 0.0 <= rows[0]["dead_end_rate"]
        assert tel.metrics.counter_total("jag_introspect_query_total") \
            == len(graph)
    finally:
        index.attach_telemetry(None)
    # introspection off (the default): nothing is stamped
    tel2 = index.attach_telemetry()
    try:
        index.search_auto(q, uniform_filt(0.4), k=3, ls=8)
        assert all(r.dead_ends is None and r.sat_step is None
                   for r in tel2.traces)
    finally:
        index.attach_telemetry(None)


# ---------------------------------------------------------------------------
# pipeline spans (tentpole)
# ---------------------------------------------------------------------------

def test_span_recorder_nesting_and_chrome_export(tmp_path):
    sr = SpanRecorder()
    with sr.span("outer", batch=2):
        with sr.span("inner"):
            pass
        with sr.span("inner2"):
            pass
    assert [s.name for s in sr.spans] == ["inner", "inner2", "outer"]
    by_name = {s.name: s for s in sr.spans}
    assert by_name["outer"].depth == 0 and by_name["outer"].parent is None
    assert by_name["inner"].depth == 1
    assert by_name["inner"].parent == "outer"
    # children are contained in the parent's time range
    for child in ("inner", "inner2"):
        assert by_name["outer"].t0 <= by_name[child].t0
        assert by_name[child].t1 <= by_name["outer"].t1
    totals = sr.totals_us()
    assert totals["outer"] >= totals["inner"] + totals["inner2"] - 1e-6
    path = str(tmp_path / "trace.json")
    assert sr.export_chrome_trace(path) == 3
    doc = json.load(open(path))
    events = doc["traceEvents"]
    assert all(e["ph"] == "X" and e["cat"] == "serve" for e in events)
    assert all(e["dur"] >= 0 for e in events)
    ev = {e["name"]: e for e in events}
    assert ev["inner"]["args"]["parent"] == "outer"
    assert ev["outer"]["args"]["batch"] == 2


def test_span_recorder_bounded():
    sr = SpanRecorder(capacity=3)
    for i in range(7):
        with sr.span(f"s{i}"):
            pass
    assert len(sr.spans) == 3
    assert sr.dropped == 4
    assert [s.name for s in sr.spans] == ["s4", "s5", "s6"]
    sr.clear()
    assert not sr.spans and sr.dropped == 0


def test_spans_recorded_through_search_auto(setup):
    index, q = setup
    tel = index.attach_telemetry(Telemetry(spans=True))
    try:
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        names = {s.name for s in tel.spans.spans}
        assert "search_auto" in names and "plan" in names
        assert any(n.startswith("execute:") for n in names)
        # execute spans nest under the top-level search span
        ex_spans = [s for s in tel.spans.spans
                    if s.name.startswith("execute:")]
        assert ex_spans and all(s.depth >= 1 for s in ex_spans)
        (top,) = [s for s in tel.spans.spans if s.name == "search_auto"]
        assert top.depth == 0
        assert sum(s.duration_us for s in ex_spans) <= top.duration_us
    finally:
        index.attach_telemetry(None)


def test_streaming_spans_cover_delta_and_merge(setup):
    index, q = setup
    stream = StreamingJAGIndex(index, compact_frac=10.0)
    tel = stream.attach_telemetry(Telemetry(spans=True))
    rng = np.random.default_rng(5)
    stream.insert(rng.normal(size=(16, D)).astype(np.float32),
                  range_table(rng.uniform(0, 1, 16).astype(np.float32)))
    stream.search_auto(q, uniform_filt(0.4), k=3, ls=8)
    names = [s.name for s in tel.spans.spans]
    assert "delta" in names and "merge" in names
    (delta_span,) = [s for s in tel.spans.spans if s.name == "delta"]
    assert delta_span.args.get("rows") == 16


# ---------------------------------------------------------------------------
# health report (tentpole)
# ---------------------------------------------------------------------------

def _shadow_rec(qid, hits, trials, route="graph", band="sel<=0.5",
                epoch=0, sel=0.3, k=5):
    from repro.obs.shadow import ShadowRecord
    return ShadowRecord(qid=qid, ts=0.0, epoch=epoch, route=route,
                        band=band, sel=sel, k=k, hits=hits, trials=trials,
                        recall=(hits / trials) if trials else 1.0)


def test_health_shadow_section_pass_warn_fail():
    slo = HealthSLO(recall=0.9, min_shadow_trials=20)
    # confident pass: high recall, plenty of trials
    good = [_shadow_rec(i, 5, 5) for i in range(10)]
    rep = health_report([], good, slo)
    assert rep["shadow_recall"]["status"] == PASS
    # confident fail: the whole interval sits below the SLO
    bad = [_shadow_rec(i, 2, 5) for i in range(40)]
    rep = health_report([], bad, slo)
    assert rep["shadow_recall"]["status"] == FAIL
    assert rep["status"] == FAIL
    # straddling interval: warn, not fail
    near = [_shadow_rec(i, 8, 10) for i in range(2)]
    rep = health_report([], near, slo)
    assert rep["shadow_recall"]["status"] == WARN
    # too few trials for a confident pass: warn
    thin = [_shadow_rec(0, 5, 5)]
    rep = health_report([], thin, slo)
    assert rep["shadow_recall"]["status"] == WARN
    # no audits at all: warn with a note
    rep = health_report([], [], slo)
    assert rep["shadow_recall"]["status"] == WARN
    assert rep["shadow_recall"]["note"]


def test_health_dead_end_and_latency_sections():
    slo = HealthSLO(dead_end_warn=0.5, dead_end_fail=0.9, p99_us=500.0)
    ok = [_rec(i, dead_ends=1, sat_step=5, n_expanded=10,
               observed_us=100.0) for i in range(8)]
    rep = health_report(ok, [], slo)
    assert rep["dead_ends"]["status"] == PASS
    assert rep["latency"]["status"] == PASS
    # dead-end rate between warn and fail thresholds
    warn = [_rec(i, dead_ends=7, sat_step=2, n_expanded=10,
                 observed_us=100.0) for i in range(8)]
    rep = health_report(warn, [], slo)
    assert rep["dead_ends"]["status"] == WARN
    # p99 above 2x the SLO: latency fails
    slow = [_rec(i, dead_ends=1, sat_step=5, n_expanded=10,
                 observed_us=5000.0) for i in range(8)]
    rep = health_report(slow, [], slo)
    assert rep["latency"]["status"] == FAIL
    assert rep["status"] == FAIL
    # without a p99 SLO latency is informational only
    rep = health_report(slow, [], HealthSLO())
    assert rep["latency"]["status"] == PASS


def test_health_render_and_telemetry_integration(setup):
    index, q = setup
    tel = index.attach_telemetry(Telemetry(shadow=1.0, introspect=True,
                                           spans=True))
    try:
        index.search_auto(q, uniform_filt(0.4), k=3, ls=8)
        rep = tel.health_report()
        assert rep["status"] in (PASS, WARN, FAIL)
        assert rep["n_traces"] == B and rep["n_shadow"] == B
        assert rep["shadow_recall"]["cells"]
        assert rep["dead_ends"]["routes"]
        assert rep["latency"]["routes"]
        text = render_health(rep)
        assert "health:" in text and "shadow recall" in text
        assert "dead ends" in text and "latency" in text
    finally:
        index.attach_telemetry(None)


# ---------------------------------------------------------------------------
# jagstat CLI (exporter satellite)
# ---------------------------------------------------------------------------

def _load_jagstat():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                        "jagstat.py")
    spec = importlib.util.spec_from_file_location("jagstat", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_jagstat_renders_summary(tmp_path, capsys, setup):
    index, q = setup
    index.attach_cost_model(_toy_model(), metric="us")
    tel = index.attach_telemetry()
    try:
        index.search_auto(q, mixed_filt(), k=3, ls=8)
        index.search_auto(q, uniform_filt(0.4), k=3, ls=8)
        path = str(tmp_path / "traces.jsonl")
        assert tel.traces.dump_jsonl(path) == 2 * B
    finally:
        index.attach_telemetry(None)
        index.attach_cost_model(None)

    jagstat = _load_jagstat()
    assert jagstat.main([path]) == 0
    out = capsys.readouterr().out
    assert "route" in out and "p50us" in out
    rows = jagstat.summarize(load_jsonl(path))
    assert sum(r["queries"] for r in rows) == 2 * B
    assert abs(sum(r["share_pct"] for r in rows) - 100.0) < 0.5
    assert all(r["p50_us"] > 0 for r in rows)
    # --json mode emits machine-readable rows
    assert jagstat.main([path, "--json"]) == 0
    import json as _json
    assert _json.loads(capsys.readouterr().out)


def test_jagstat_degrades_gracefully_on_empty_dumps(tmp_path, capsys):
    # log rotation racing a dump must not page anyone: explicit
    # "no traces" line, exit 0 — for empty AND missing files
    jagstat = _load_jagstat()
    path = str(tmp_path / "empty.jsonl")
    open(path, "w").close()
    assert jagstat.main([path]) == 0
    assert "no traces" in capsys.readouterr().out
    missing = str(tmp_path / "rotated-away.jsonl")
    assert jagstat.main([missing]) == 0
    assert "no traces" in capsys.readouterr().out


def test_jagstat_single_record(tmp_path, capsys):
    # a one-line dump renders a real table (percentiles of n=1 are fine)
    buf = TraceBuffer(capacity=4)
    buf.append(_rec(0, route="graph[default,f32]"))
    path = str(tmp_path / "one.jsonl")
    buf.dump_jsonl(path)
    jagstat = _load_jagstat()
    assert jagstat.main([path]) == 0
    out = capsys.readouterr().out
    assert "graph[default,f32]" in out and "100.0" in out


def test_jagstat_health_mode(tmp_path, capsys, setup):
    index, q = setup
    tel = index.attach_telemetry(Telemetry(shadow=1.0, introspect=True))
    try:
        index.search_auto(q, uniform_filt(0.4), k=3, ls=8)
        traces = str(tmp_path / "traces.jsonl")
        shadow = str(tmp_path / "shadow.jsonl")
        assert tel.traces.dump_jsonl(traces) == B
        assert tel.shadow.dump_jsonl(shadow) == B
    finally:
        index.attach_telemetry(None)
    jagstat = _load_jagstat()
    # lenient SLO the tiny index can meet: exit 0, render shows the cells
    rc = jagstat.main([traces, "--health", "--shadow", shadow,
                       "--slo-recall", "0.05"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "health:" in out and "shadow recall" in out
    assert "dead ends" in out and "latency" in out
    # impossible p99 SLO: overall fail, exit 1
    rc = jagstat.main([traces, "--health", "--shadow", shadow,
                       "--slo-recall", "0.05", "--slo-p99-us", "0.001"])
    out = capsys.readouterr().out
    assert rc == 1 and "health: FAIL" in out
    # --health --json emits the machine-checkable document
    rc = jagstat.main([traces, "--health", "--shadow", shadow,
                      "--slo-recall", "0.05", "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0 and doc["status"] in ("pass", "warn")
    assert doc["n_shadow"] == B and doc["shadow_recall"]["cells"]
    # health mode works on empty/missing dumps too (warn, exit 0)
    missing = str(tmp_path / "gone.jsonl")
    assert jagstat.main([missing, "--health"]) == 0
    assert "health:" in capsys.readouterr().out
