"""Per-query route batching: dispatch order-invariance + planner-path fixes.

Covers (1) the order-invariance contract — a shuffled mixed-selectivity
batch routed with ``mode="per_query"`` returns bit-identical per-query
(ids, primary, secondary) to each query run ALONE through its own route;
(2) ``FilterBatch.take`` group-gather semantics; (3) regression tests for
the planner-path bugs this PR fixes: ``search_auto`` dropping serving
options (layout/dtype never reached the executor cache key), the
postfilter route's n_dist omitting the survivor filter evaluations, the
module-level lru_cache pinning sample-id device buffers process-wide, and
the prefilter scan's B× redundant attr gather.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import filters as F
from repro.core.beam_search import SearchResult
from repro.core.ground_truth import exact_filtered_knn
from repro.core.jag import JAGConfig, JAGIndex
from repro.serve.dispatch import (dispatch_per_query, fold_topk, merge_topk,
                                  run_route)
from repro.serve.planner import (PerQueryPlan, PlannerConfig, plan,
                                 plan_per_query, sample_ids)

N, D, B = 1200, 12, 18
LS, MAX_ITERS = 48, 96
# per-band range-filter caps: ~0.4% / ~15% / ~92% selectivity — far enough
# from the 0.02/0.75 thresholds that the sampled probe can't misband
BAND_HI = {"prefilter": 0.004, "graph": 0.15, "postfilter": 0.92}


@functools.lru_cache(maxsize=None)
def _setup():
    rng = np.random.default_rng(7)
    xb = rng.normal(size=(N, D)).astype(np.float32)
    tab = F.range_table(rng.uniform(0, 1, N).astype(np.float32))
    cfg = JAGConfig(degree=24, ls_build=48, batch_size=128, cand_pool=96,
                    calib_samples=128, n_seeds=8)
    idx = JAGIndex.build(xb, tab, cfg)
    q = (xb[rng.integers(0, N, B)]
         + 0.1 * rng.normal(size=(B, D))).astype(np.float32)
    return xb, tab, idx, q


def _mixed_filters(rng):
    """A shuffled batch cycling through all three bands."""
    his = np.array([BAND_HI[r] for r in
                    ("prefilter", "graph", "postfilter")] * B)[:B]
    his = his[rng.permutation(B)].astype(np.float32)
    return F.range_filters(np.zeros(B, np.float32), his), his


# ---------------------------------------------------------------------------
# FilterBatch.take: group-gather of filter lanes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
def test_filter_batch_take_matches_lanes(kind):
    rng = np.random.default_rng(3)
    if kind == F.LABEL:
        filt = F.label_filters(rng.integers(0, 5, B))
    elif kind == F.RANGE:
        lo = rng.uniform(0, 0.4, B).astype(np.float32)
        filt = F.range_filters(lo, lo + 0.3)
    elif kind == F.SUBSET:
        filt = F.subset_filters(rng.random((B, 24)) < 0.2, 24)
    else:
        sat = rng.random((B, 1 << 6)) < 0.3
        filt = F.boolean_filters(sat, 6)
    ids = np.array([5, 0, 11, 5, 2], np.int32)   # unordered, with a repeat
    sub = filt.take(ids)
    assert sub.kind == filt.kind and sub.n_bits == filt.n_bits
    assert sub.batch == len(ids)
    for j, i in enumerate(ids):
        lane = filt.lane(int(i))
        for key in filt.data:
            np.testing.assert_array_equal(np.asarray(sub.data[key][j]),
                                          np.asarray(lane.data[key][0]),
                                          err_msg=(kind, key, int(i)))


def _kind_filters(kind, rng):
    if kind == F.LABEL:
        return F.label_filters(rng.integers(0, 5, B))
    if kind == F.RANGE:
        lo = rng.uniform(0, 0.4, B).astype(np.float32)
        return F.range_filters(lo, lo + 0.3)
    if kind == F.SUBSET:
        return F.subset_filters(rng.random((B, 24)) < 0.2, 24)
    sat = rng.random((B, 1 << 6)) < 0.3
    return F.boolean_filters(sat, 6)


@pytest.mark.parametrize("kind", F.KINDS)
def test_filter_batch_take_empty_singleton_full(kind):
    """Degenerate group shapes the end-to-end router happens not to hit:
    an EMPTY id set (0-query sub-batch), a singleton, and the full batch
    (identity gather) — all four filter kinds."""
    rng = np.random.default_rng(5)
    filt = _kind_filters(kind, rng)

    empty = filt.take(np.array([], np.int32))
    assert empty.batch == 0
    assert empty.kind == filt.kind and empty.n_bits == filt.n_bits
    for key, v in filt.data.items():
        got = np.asarray(empty.data[key])
        assert got.shape == (0,) + np.asarray(v).shape[1:], (key, got.shape)
        assert got.dtype == np.asarray(v).dtype

    one = filt.take(np.array([B - 1], np.int32))
    assert one.batch == 1
    for key in filt.data:
        np.testing.assert_array_equal(
            np.asarray(one.data[key]),
            np.asarray(filt.lane(B - 1).data[key]), err_msg=(kind, key))

    full = filt.take(np.arange(B, dtype=np.int32))
    assert full.batch == B
    for key in filt.data:
        np.testing.assert_array_equal(np.asarray(full.data[key]),
                                      np.asarray(filt.data[key]),
                                      err_msg=(kind, key))


@pytest.mark.parametrize("kind", F.KINDS)
def test_filter_batch_take_composes_with_matches(kind):
    """A taken sub-batch must behave like the corresponding lanes under
    ``matches`` — the property dispatch actually relies on."""
    rng = np.random.default_rng(8)
    filt = _kind_filters(kind, rng)
    if kind == F.LABEL:
        tab = F.label_table(rng.integers(0, 5, 64))
    elif kind == F.RANGE:
        tab = F.range_table(rng.uniform(0, 1, 64).astype(np.float32))
    elif kind == F.SUBSET:
        tab = F.subset_table(rng.random((64, 24)) < 0.5, 24)
    else:
        tab = F.boolean_table(rng.integers(0, 1 << 6, 64).astype(np.uint32),
                              6)
    ids = np.array([3, 3, 0, B - 1], np.int32)
    sub = filt.take(ids)
    ok_sub = np.asarray(F.matches_all(sub, tab))
    ok_full = np.asarray(F.matches_all(filt, tab))
    np.testing.assert_array_equal(ok_sub, ok_full[ids])


# ---------------------------------------------------------------------------
# order invariance: per-query dispatch == each query alone on its own route
# ---------------------------------------------------------------------------

def test_per_query_dispatch_bit_identical_to_solo_runs():
    _, _, idx, q = _setup()
    filt, _ = _mixed_filters(np.random.default_rng(11))
    res, p = idx.search_auto(q, filt, k=10, ls=LS, max_iters=MAX_ITERS,
                             return_plan=True)
    assert isinstance(p, PerQueryPlan)
    assert len(p.groups) == 3, [g.route for g in p.groups]   # batch split
    assert p.route == "mixed"
    ex = idx.executor
    for i in range(B):
        solo = run_route(ex, p.routes[i], q[i:i + 1], filt.lane(i), k=10,
                         ls=LS, max_iters=MAX_ITERS)
        for field in ("ids", "primary", "secondary"):
            np.testing.assert_array_equal(
                np.asarray(getattr(res, field))[i],
                np.asarray(getattr(solo, field))[0],
                err_msg=f"q{i} route={p.routes[i]} field={field}")


def test_per_query_dispatch_invariant_to_batch_shuffle():
    _, _, idx, q = _setup()
    rng = np.random.default_rng(13)
    filt, his = _mixed_filters(rng)
    res = idx.search_auto(q, filt, k=10, ls=LS, max_iters=MAX_ITERS)
    perm = rng.permutation(B)
    filt_s = F.range_filters(np.zeros(B, np.float32), his[perm])
    res_s = idx.search_auto(q[perm], filt_s, k=10, ls=LS,
                            max_iters=MAX_ITERS)
    for field in ("ids", "primary", "secondary", "n_dist"):
        np.testing.assert_array_equal(np.asarray(getattr(res_s, field)),
                                      np.asarray(getattr(res, field))[perm],
                                      err_msg=field)


def test_per_query_uniform_batch_single_group_matches_forced_route():
    _, _, idx, q = _setup()
    for route, hi in BAND_HI.items():
        filt = F.range_filters(np.zeros(B, np.float32),
                               np.full(B, hi, np.float32))
        res, p = idx.search_auto(q, filt, k=10, ls=LS, max_iters=MAX_ITERS,
                                 return_plan=True)
        assert len(p.groups) == 1 and p.route == route
        forced = run_route(idx.executor, route, q, filt, k=10, ls=LS,
                           max_iters=MAX_ITERS)
        for field in ("ids", "primary", "secondary", "n_dist"):
            np.testing.assert_array_equal(np.asarray(getattr(res, field)),
                                          np.asarray(getattr(forced, field)),
                                          err_msg=(route, field))


def test_regroup_pads_heterogeneous_vlogs_and_restores_order():
    _, _, idx, q = _setup()
    filt, _ = _mixed_filters(np.random.default_rng(17))
    p = plan_per_query(filt, idx.attr, PlannerConfig(),
                       executor=idx.executor)
    res = dispatch_per_query(idx.executor, q, filt, p, k=10, ls=LS,
                             max_iters=MAX_ITERS)
    # widest route wins; prefilter rows are all -1 holes
    assert res.vlog.shape == (B, MAX_ITERS)
    vlog = np.asarray(res.vlog)
    nexp = np.asarray(res.n_expanded)
    for i in range(B):
        if p.routes[i] == "prefilter":
            assert (vlog[i] == -1).all() and nexp[i] == 0
        else:
            assert (vlog[i] >= 0).any()


def test_prefilter_route_emits_width_zero_vlog():
    _, _, idx, q = _setup()
    filt = F.range_filters(np.zeros(B, np.float32),
                           np.full(B, BAND_HI["prefilter"], np.float32))
    res = idx.executor.prefilter(q, filt, k=10)
    assert res.vlog.shape == (B, 0)
    assert res.vlog.dtype == jnp.int32


# ---------------------------------------------------------------------------
# bugfix: search_auto serving options reach the executor cache key
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["per_query", "batch"])
def test_search_auto_threads_layout_dtype_to_graph_route(mode):
    _, _, idx, q = _setup()
    filt = F.range_filters(np.zeros(B, np.float32),
                           np.full(B, BAND_HI["graph"], np.float32))
    res = idx.search_auto(q, filt, k=10, ls=LS, max_iters=MAX_ITERS,
                          mode=mode, layout="fused", dtype="f32")
    key = ("graph", "fused", "f32", 10, LS, MAX_ITERS, filt.kind)
    assert key in idx.executor.cache_keys(), idx.executor.cache_keys()
    want = idx.executor.graph(q, filt, k=10, ls=LS, max_iters=MAX_ITERS,
                              layout="fused", dtype="f32")
    for field in ("ids", "primary", "secondary"):
        np.testing.assert_array_equal(np.asarray(getattr(res, field)),
                                      np.asarray(getattr(want, field)),
                                      err_msg=field)


def test_search_auto_rejects_unknown_mode():
    _, _, idx, q = _setup()
    filt = F.range_filters(np.zeros(B, np.float32),
                           np.full(B, 0.15, np.float32))
    with pytest.raises(ValueError, match="mode"):
        idx.search_auto(q, filt, k=10, ls=LS, mode="bogus")


# ---------------------------------------------------------------------------
# bugfix: postfilter n_dist counts the survivor filter evaluations
# ---------------------------------------------------------------------------

def test_postfilter_n_dist_counts_survivor_filter_evals():
    _, _, idx, q = _setup()
    filt = F.range_filters(np.zeros(B, np.float32),
                           np.full(B, BAND_HI["postfilter"], np.float32))
    post = idx.executor.postfilter(q, filt, k=10, ls=LS,
                                   max_iters=MAX_ITERS)
    # same unfiltered traversal, full beam returned (k=ls)
    unf = idx.executor.unfiltered(q, k=LS, ls=LS, max_iters=MAX_ITERS)
    survivors = np.sum(np.asarray(unf.ids) >= 0, axis=1)
    assert (survivors > 0).all()
    np.testing.assert_array_equal(np.asarray(post.n_dist),
                                  np.asarray(unf.n_dist) + survivors)
    # the DC metric must charge at least the beam entries it filter-checked
    assert (np.asarray(post.n_dist) >= survivors).all()


# ---------------------------------------------------------------------------
# bugfix: sample-id cache is executor-scoped, not a process-global lru
# ---------------------------------------------------------------------------

def test_sample_ids_has_no_module_level_cache():
    assert not hasattr(sample_ids, "cache_info")     # not an lru_cache
    assert not hasattr(sample_ids, "cache_clear")


def test_executor_scopes_sample_id_buffers():
    _, tab, idx, _ = _setup()
    ex = idx.executor
    a = ex.sample_ids(tab.n, 256, seed=1)
    assert a is ex.sample_ids(tab.n, 256, seed=1)    # cached per executor
    assert a is not ex.sample_ids(tab.n, 256, seed=2)
    # a second index's executor holds its own buffers
    rng = np.random.default_rng(23)
    xb2 = rng.normal(size=(200, D)).astype(np.float32)
    idx2 = JAGIndex.build(xb2, F.range_table(
        rng.uniform(0, 1, 200).astype(np.float32)),
        JAGConfig(degree=8, ls_build=16, batch_size=64, cand_pool=32,
                  calib_samples=64))
    b = idx2.executor.sample_ids(tab.n, 256, seed=1)
    assert b is not a
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_and_plan_per_query_share_the_probe():
    _, tab, idx, _ = _setup()
    filt, _ = _mixed_filters(np.random.default_rng(29))
    p0 = plan(filt, tab, executor=idx.executor)
    p1 = plan_per_query(filt, tab, executor=idx.executor)
    np.testing.assert_allclose(p0.selectivity, p1.selectivity, atol=1e-7)
    assert p0.n_sampled == p1.n_sampled
    assert tuple(sorted({g.route for g in p1.groups})) == (
        "graph", "postfilter", "prefilter")


# ---------------------------------------------------------------------------
# bugfix: prefilter scan gathers each attr block once, not B times
# ---------------------------------------------------------------------------

def test_exact_filtered_knn_attr_gather_not_batch_redundant():
    """The lowered scan must gather [block, W] attr rows, never [B, block, W].

    Regression for the broadcast [B, block] id matrix that re-gathered the
    same block's attribute rows once per query on the prefilter hot path.
    """
    rng = np.random.default_rng(31)
    n, block, b, w, L = 1024, 256, 8, 2, 64
    xb = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
    tab = F.subset_table(rng.random((n, L)) < 0.5, L)
    filt = F.subset_filters(np.zeros((b, L), bool), L)
    q = jnp.asarray(rng.normal(size=(b, D)).astype(np.float32))
    lowered = jax.jit(exact_filtered_knn,
                      static_argnames=("k", "block", "use_kernel")).lower(
        xb, tab, q, filt, k=5, block=block).as_text()
    assert w == tab.data["bits"].shape[1]
    gather_lines = [ln for ln in lowered.splitlines()
                    if "stablehlo.gather" in ln or "stablehlo.dynamic_gather"
                    in ln]
    assert any(f"tensor<{block}x{w}xui32>" in ln for ln in gather_lines), \
        gather_lines                                     # one block gather
    assert not any(f"tensor<{b}x{block}x{w}xui32>" in ln
                   for ln in gather_lines), gather_lines  # no B× attr gather


def test_exact_filtered_knn_unchanged_by_gather_fix():
    xb, tab, idx, q = _setup()
    filt, _ = _mixed_filters(np.random.default_rng(37))
    gt = exact_filtered_knn(jnp.asarray(xb), tab, jnp.asarray(q), filt,
                            k=10, block=256)
    # brute-force reference over the full validity matrix
    ok = np.asarray(F.matches_all(filt, tab))
    d2 = (((np.asarray(q)[:, None, :] - xb[None]) ** 2).sum(-1))
    d2 = np.where(ok, d2, np.inf)
    order = np.argsort(d2, axis=1, kind="stable")[:, :10]
    want = np.where(np.take_along_axis(d2, order, 1) < np.inf, order, -1)
    np.testing.assert_array_equal(np.asarray(gt.ids), want)
    np.testing.assert_array_equal(np.asarray(gt.n_dist), ok.sum(1))


# ---------------------------------------------------------------------------
# fold_topk: the sharded executor's N-way cross-segment merge
# ---------------------------------------------------------------------------

def _part(ids, sec):
    """A per-segment SearchResult in merge normal form: valid entries sorted
    by (0, sec), -1 padding at (INF, INF) — what every route emits."""
    ids = np.asarray(ids, np.int32)
    valid = ids >= 0
    prim = np.where(valid, 0.0, np.inf).astype(np.float32)
    sec = np.where(valid, np.asarray(sec, np.float32), np.inf)
    b = ids.shape[0]
    return SearchResult(jnp.asarray(ids), jnp.asarray(prim),
                        jnp.asarray(sec.astype(np.float32)),
                        jnp.zeros((b, 0), jnp.int32),
                        jnp.ones((b,), jnp.int32),
                        jnp.asarray(valid.sum(1).astype(np.int32)))


def _fold_reference(parts, k):
    """Brute-force fold reference: stable sort of the concatenation."""
    prim = np.concatenate([np.asarray(p.primary) for p in parts], axis=1)
    sec = np.concatenate([np.asarray(p.secondary) for p in parts], axis=1)
    ids = np.concatenate([np.asarray(p.ids) for p in parts], axis=1)
    order = np.lexsort((sec, prim), axis=1)[:, :k]   # np.lexsort is stable
    take = lambda a: np.take_along_axis(a, order, axis=1)  # noqa: E731
    return take(ids), take(prim), take(sec)


def test_fold_topk_absorbs_empty_shard_results():
    """A shard with zero filter-passing rows contributes only telemetry."""
    p0 = _part([[0, 3, -1]], [[1.0, 4.0, np.inf]])
    empty = _part([[-1, -1, -1]], [[np.inf] * 3])
    p2 = _part([[20, -1, -1]], [[2.0, np.inf, np.inf]])
    out = fold_topk([p0, empty, p2], k=3)
    np.testing.assert_array_equal(np.asarray(out.ids), [[0, 20, 3]])
    np.testing.assert_array_equal(np.asarray(out.secondary),
                                  [[1.0, 2.0, 4.0]])
    assert int(out.n_dist[0]) == 3            # 2 + 0 + 1 real evaluations
    # an all-empty fold stays the all-invalid result
    none = fold_topk([empty, empty], k=3)
    np.testing.assert_array_equal(np.asarray(none.ids), [[-1, -1, -1]])
    assert np.isinf(np.asarray(none.primary)).all()


def test_fold_topk_k_exceeds_single_shard_match_count():
    """k=5 with 1- and 3-match shards: the union's 4 matches fill first,
    then -1/INF padding — never a duplicated or invented id."""
    a = _part([[7, -1, -1, -1, -1]], [[3.0] + [np.inf] * 4])
    b = _part([[100, 105, 101, -1, -1]],
              [[1.0, 2.0, 9.0, np.inf, np.inf]])
    out = fold_topk([a, b], k=5)
    np.testing.assert_array_equal(np.asarray(out.ids),
                                  [[100, 105, 7, 101, -1]])
    np.testing.assert_array_equal(np.asarray(out.secondary),
                                  [[1.0, 2.0, 3.0, 9.0, np.inf]])
    assert int(out.n_dist[0]) == 4


def test_fold_topk_tie_break_is_segment_order_across_three_segments():
    """The same (primary, secondary) key on >= 3 segments resolves in
    segment order — the union-scan tie rule — and the fold gives the same
    answer under either association, because merge_topk's stable sort
    keeps base-side entries first on equal keys."""
    parts = [_part([[s * 100 + 1, s * 100 + 5]], [[2.5, 2.5]])
             for s in range(3)]                 # identical keys everywhere
    out = fold_topk(parts, k=4)
    np.testing.assert_array_equal(np.asarray(out.ids), [[1, 5, 101, 105]])
    left = merge_topk(merge_topk(parts[0], parts[1], k=4), parts[2], k=4)
    right = merge_topk(parts[0], merge_topk(parts[1], parts[2], k=4), k=4)
    for f in ("ids", "primary", "secondary"):
        np.testing.assert_array_equal(np.asarray(getattr(out, f)),
                                      np.asarray(getattr(left, f)), f)
        np.testing.assert_array_equal(np.asarray(getattr(out, f)),
                                      np.asarray(getattr(right, f)), f)


def test_fold_topk_matches_stable_concat_sort_reference():
    rng = np.random.default_rng(41)
    b, k, S = 6, 8, 5
    parts = []
    for s in range(S):
        n_valid = rng.integers(0, k + 1, b)
        sec = np.sort(rng.choice(np.arange(1, 50, dtype=np.float32) / 4,
                                 (b, k), replace=True), axis=1)
        ids = np.arange(k)[None] + s * 1000
        mask = np.arange(k)[None] < n_valid[:, None]
        parts.append(_part(np.where(mask, ids, -1),
                           np.where(mask, sec, np.inf)))
    out = fold_topk(parts, k=k)
    ids, prim, sec = _fold_reference(parts, k)
    np.testing.assert_array_equal(np.asarray(out.ids), ids)
    np.testing.assert_array_equal(np.asarray(out.primary), prim)
    np.testing.assert_array_equal(np.asarray(out.secondary), sec)
    want_nd = sum(int(np.asarray(p.n_dist).sum()) for p in parts)
    assert int(np.asarray(out.n_dist).sum()) == want_nd


def test_fold_topk_rejects_empty_part_list():
    with pytest.raises(ValueError, match="at least one"):
        fold_topk([], k=3)
