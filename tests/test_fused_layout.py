"""Fused serving layout: kernel/oracle parity, fetch contract, and the
bit-identical guarantee of ``JAGIndex.search(..., layout="fused")`` across
all four filter kinds, plus packed-layout persistence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import filters as F
from repro.core.distances import gathered_d2, sq_norms
from repro.core.jag import JAGConfig, JAGIndex
from repro.kernels import ops, ref
from repro.serve import (FusedEngine, build_layout, load_layout,
                         make_fetch_fn, save_layout)


def _bits(x):
    return np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint32))


def _table(kind, rng, n):
    if kind == F.LABEL:
        return F.label_table(rng.integers(0, 7, n))
    if kind == F.RANGE:
        return F.range_table(rng.uniform(0, 100, n).astype(np.float32))
    if kind == F.SUBSET:
        return F.subset_table(
            rng.integers(0, 2, (n, 40)).astype(bool), 40)
    if kind == F.BOOLEAN:
        return F.boolean_table(
            rng.integers(0, 2 ** 10, n).astype(np.uint32), 10)
    raise ValueError(kind)


def _filters(kind, rng, b):
    if kind == F.LABEL:
        return F.label_filters(rng.integers(0, 7, b))
    if kind == F.RANGE:
        lo = rng.uniform(0, 60, b).astype(np.float32)
        return F.range_filters(lo, lo + 30.0)
    if kind == F.SUBSET:
        return F.subset_filters(
            rng.integers(0, 2, (b, 40)) * (rng.integers(0, 4, (b, 40)) == 0),
            40)
    if kind == F.BOOLEAN:
        sat = rng.integers(0, 2, (b, 2 ** 10)).astype(bool)
        sat[:, 0] = True  # keep every predicate satisfiable
        return F.boolean_filters(sat, 10)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# attr-word codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
def test_attr_word_roundtrip_bit_exact(kind):
    rng = np.random.default_rng(0)
    tab = _table(kind, rng, 128)
    words = F.pack_attr_words(tab)
    assert words.shape == (128, F.attr_word_width(kind, tab.n_bits))
    back = F.unpack_attr_words(kind, words, tab.n_bits)
    for k, v in tab.data.items():
        got = back[k]
        assert got.dtype == v.dtype
        if v.dtype == jnp.float32:
            np.testing.assert_array_equal(_bits(got), _bits(v))
        else:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(v))


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
@pytest.mark.parametrize("vec_dtype", ["f32", "int8"])
def test_fused_expand_kernel_matches_oracle(kind, vec_dtype):
    rng = np.random.default_rng(1)
    N, d, B, C = 150, 24, 4, 9
    xb = rng.normal(size=(N, d)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    lay = build_layout(xb, _table(kind, rng, N), vec_dtype=vec_dtype)
    q_eff, q_norm = lay.fold_query(q)
    kd2, kw = ops.fused_expand(lay.packed, ids, q_eff, q_norm,
                               d=d, interpret=True)
    rd2, rw = ref.fused_expand_ref(lay.packed, ids, q_eff, q_norm, d=d)
    np.testing.assert_allclose(np.asarray(kd2), np.asarray(rd2),
                               rtol=1e-5, atol=1e-4)
    # attr lanes are opaque bit payloads: the kernel must copy them exactly
    # (NaN-payload-safe comparison via bitcast)
    np.testing.assert_array_equal(_bits(kw), _bits(rw))


# ---------------------------------------------------------------------------
# fetch contract: one-gather fetch == default two-gather expansion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", F.KINDS)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_fetch_fn_matches_two_gather_path(kind, use_kernel):
    rng = np.random.default_rng(2)
    N, d, B, C = 200, 16, 3, 8
    xb = rng.normal(size=(N, d)).astype(np.float32)
    tab = _table(kind, rng, N)
    lay = build_layout(xb, tab)
    fetch = make_fetch_fn(lay, use_kernel=use_kernel, interpret=True)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    qn = jnp.sum(q * q, axis=-1)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    d2, attrs = fetch(ids, q, qn)
    want_d2 = gathered_d2(jnp.asarray(xb), sq_norms(xb), ids, q, qn)
    want_attrs = tab.gather(ids)
    if use_kernel:
        np.testing.assert_allclose(np.asarray(d2), np.asarray(want_d2),
                                   rtol=1e-5, atol=1e-4)
    else:  # XLA path computes the same float ops -> bit-identical
        np.testing.assert_array_equal(np.asarray(d2), np.asarray(want_d2))
    assert set(attrs) == set(want_attrs)
    for k in want_attrs:
        np.testing.assert_array_equal(np.asarray(attrs[k]),
                                      np.asarray(want_attrs[k]))


def test_fused_engine_contract():
    rng = np.random.default_rng(3)
    lay = build_layout(rng.normal(size=(64, 8)).astype(np.float32),
                       _table(F.LABEL, rng, 64))
    eng = FusedEngine(lay)
    assert eng.gathers_per_expansion == 1
    assert eng.row_bytes == (8 + 1 + 1) * 4
    d2, attrs = eng.fetch_fn(jnp.zeros((2, 4), jnp.int32),
                             jnp.zeros((2, 8), jnp.float32),
                             jnp.zeros((2,), jnp.float32))
    assert d2.shape == (2, 4) and attrs["label"].shape == (2, 4)


def test_int8_layout_matches_int8_dist_fn():
    from repro.core.quantized import make_int8_dist_fn, quantize_int8
    rng = np.random.default_rng(4)
    N, d, B, C = 300, 32, 4, 12
    xb = rng.normal(size=(N, d)).astype(np.float32)
    lay = build_layout(xb, _table(F.RANGE, rng, N), vec_dtype="int8")
    fetch = make_fetch_fn(lay)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    qn = jnp.sum(q * q, axis=-1)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    d2, _ = fetch(ids, q, qn)
    xq, scale = quantize_int8(xb)
    xq_norm = jnp.sum((xq.astype(jnp.float32) * scale) ** 2, -1)
    want = make_int8_dist_fn(scale)(xq, xq_norm, ids, q, qn)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# end-to-end: layout="fused" is bit-identical to the default search path
# ---------------------------------------------------------------------------

def _build_index(kind, n=500, d=12, seed=0):
    rng = np.random.default_rng(seed)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    cfg = JAGConfig(degree=10, ls_build=20, batch_size=64, cand_pool=40,
                    calib_samples=64, n_seeds=4)
    return JAGIndex.build(xb, _table(kind, rng, n), cfg), rng


@pytest.mark.parametrize("kind", F.KINDS)
def test_search_fused_bit_identical(kind):
    idx, rng = _build_index(kind, seed=5)
    q = rng.normal(size=(8, 12)).astype(np.float32)
    filt = _filters(kind, rng, 8)
    r0 = idx.search(q, filt, k=5, ls=16)
    r1 = idx.search(q, filt, k=5, ls=16, layout="fused")
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    np.testing.assert_array_equal(np.asarray(r0.primary),
                                  np.asarray(r1.primary))
    np.testing.assert_array_equal(np.asarray(r0.secondary),
                                  np.asarray(r1.secondary))
    np.testing.assert_array_equal(np.asarray(r0.n_dist),
                                  np.asarray(r1.n_dist))


def test_search_int8_fused_runs_and_reranks():
    idx, rng = _build_index(F.RANGE, seed=6)
    q = rng.normal(size=(6, 12)).astype(np.float32)
    filt = _filters(F.RANGE, rng, 6)
    r8 = idx.search_int8(q, filt, k=5, ls=16, layout="fused")
    r0 = idx.search(q, filt, k=5, ls=16)
    assert r8.ids.shape == (6, 5)
    # re-rank makes secondaries exact, so shared ids must agree on d2
    for b in range(6):
        m0 = {int(i): float(s) for i, s in zip(r0.ids[b], r0.secondary[b])
              if int(i) >= 0}
        for i, s in zip(r8.ids[b], r8.secondary[b]):
            if int(i) in m0:
                np.testing.assert_allclose(float(s), m0[int(i)],
                                           rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vec_dtype", ["f32", "int8"])
def test_layout_save_load_roundtrip(tmp_path, vec_dtype):
    rng = np.random.default_rng(7)
    xb = rng.normal(size=(80, 8)).astype(np.float32)
    lay = build_layout(xb, _table(F.SUBSET, rng, 80), vec_dtype=vec_dtype)
    p = str(tmp_path / "layout.npz")
    save_layout(p, lay)
    back = load_layout(p)
    np.testing.assert_array_equal(_bits(back.packed), _bits(lay.packed))
    np.testing.assert_array_equal(np.asarray(back.q_scale),
                                  np.asarray(lay.q_scale))
    assert (back.kind, back.n_bits, back.d, back.vec_dtype) == \
        (lay.kind, lay.n_bits, lay.d, lay.vec_dtype)


def test_save_load_restores_build_config(tmp_path):
    """load() used to silently reconstruct with BuildConfig() defaults,
    dropping the calibrated thresholds/weights the graph was built with."""
    idx, _ = _build_index(F.RANGE, seed=9)
    p = str(tmp_path / "index.npz")
    idx.save(p)
    idx2 = JAGIndex.load(p)
    assert idx2.build_cfg == idx.build_cfg
    assert idx2.build_cfg.thresholds  # calibrated values, not defaults
    assert idx2.cfg == idx.cfg


def test_save_load_persists_int8_quantization(tmp_path):
    """A loaded index must not re-quantize the database on first
    search_int8: the codes/scale/norms ride along in the archive."""
    idx, rng = _build_index(F.RANGE, seed=10)
    q = rng.normal(size=(4, 12)).astype(np.float32)
    filt = _filters(F.RANGE, rng, 4)
    r1 = idx.search_int8(q, filt, k=5, ls=16)   # triggers quantization
    p = str(tmp_path / "index.npz")
    idx.save(p)
    idx2 = JAGIndex.load(p)
    assert idx2._q8 is not None                 # restored, not recomputed
    for a, b in zip(idx._q8, idx2._q8):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    r2 = idx2.search_int8(q, filt, k=5, ls=16)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.secondary),
                                  np.asarray(r2.secondary))


def test_index_save_load_keeps_fused_layout(tmp_path):
    idx, rng = _build_index(F.LABEL, seed=8)
    q = rng.normal(size=(4, 12)).astype(np.float32)
    filt = _filters(F.LABEL, rng, 4)
    r1 = idx.search(q, filt, k=5, ls=16, layout="fused")  # builds layout
    p = str(tmp_path / "index.npz")
    idx.save(p)
    idx2 = JAGIndex.load(p)
    assert "f32" in idx2._fused  # restored, not rebuilt
    np.testing.assert_array_equal(
        _bits(idx2._fused["f32"].packed), _bits(idx._fused["f32"].packed))
    r2 = idx2.search(q, filt, k=5, ls=16, layout="fused")
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.primary),
                                  np.asarray(r2.primary))
