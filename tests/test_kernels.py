"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.l2dist import l2dist as l2_raw
from repro.kernels.gather_dist import gather_dist_tile
from repro.kernels.bitset import bitset_dist


@pytest.mark.parametrize("B,N,d,dtype", [
    (8, 32, 16, np.float32),
    (128, 256, 128, np.float32),
    (64, 100, 48, np.float32),     # padding path
    (33, 257, 130, np.float32),    # awkward shapes
    (16, 64, 32, jnp.bfloat16),
])
def test_l2dist_matches_ref(B, N, d, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, d)), dtype)
    xb = jnp.asarray(rng.normal(size=(N, d)), dtype)
    got = ops.l2dist(q, xb, interpret=True)
    want = ref.l2dist_ref(q, xb)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_l2dist_raw_blocked_grid():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    got = l2_raw(q, xb, bq=128, bn=256, bd=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.l2dist_ref(q, xb)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("B,C,N,d", [(4, 8, 64, 16), (16, 32, 200, 64),
                                     (2, 5, 33, 128)])
def test_gather_dist_matches_ref(B, C, N, d):
    rng = np.random.default_rng(2)
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    got = ops.gather_dist(xb, ids, q, interpret=True)
    want = ref.gather_dist_ref(xb, ids, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_gather_dist_tile():
    rng = np.random.default_rng(3)
    N, d, tile, B = 256, 32, 64, 8
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    base = jnp.asarray(rng.integers(0, N // tile, B), jnp.int32)
    got = gather_dist_tile(xb, base, q, tile=tile, interpret=True)
    for b in range(B):
        rows = xb[int(base[b]) * tile:(int(base[b]) + 1) * tile]
        want = ((rows - q[b]) ** 2).sum(-1)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("B,N,W", [(8, 16, 1), (64, 128, 4), (33, 77, 7)])
@pytest.mark.parametrize("op", ["xor", "deficit"])
def test_bitset_matches_ref(B, N, W, op):
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (B, W), dtype=np.uint64),
                    jnp.uint32)
    b = jnp.asarray(rng.integers(0, 2 ** 32, (N, W), dtype=np.uint64),
                    jnp.uint32)
    if op == "xor":
        got = ops.hamming(a, b, interpret=True)
        want = ref.hamming_ref(a, b)
    else:
        got = ops.subset_deficit(a, b, interpret=True)
        want = ref.subset_deficit_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitset_raw_grid():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (256, 2), dtype=np.uint64),
                    jnp.uint32)
    b = jnp.asarray(rng.integers(0, 2 ** 32, (256, 2), dtype=np.uint64),
                    jnp.uint32)
    got = bitset_dist(a, b, op="xor", bq=128, bn=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.hamming_ref(a, b)))


def _np_popcount_words(w):
    """Independent numpy popcount reference: bytes -> unpackbits -> sum."""
    w = np.asarray(w, np.uint32)
    by = w.view(np.uint8).reshape(w.shape + (4,))
    return np.unpackbits(by, axis=-1).sum(axis=(-1, -2)).astype(np.int32)


@pytest.mark.parametrize("B,N,W", [(3, 5, 1), (130, 257, 3)])
def test_bitset_matches_numpy_popcount(B, N, W):
    """xor/deficit vs a from-scratch numpy unpackbits oracle (the jnp ref
    shares population_count with the kernel; this one shares nothing),
    including shapes that exercise the 128-row padding path."""
    rng = np.random.default_rng(7)
    a = rng.integers(0, 2 ** 32, (B, W), dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2 ** 32, (N, W), dtype=np.uint64).astype(np.uint32)
    want_xor = _np_popcount_words(a[:, None, :] ^ b[None, :, :])
    want_def = _np_popcount_words(a[:, None, :] & ~b[None, :, :])
    got_xor = ops.hamming(jnp.asarray(a), jnp.asarray(b), interpret=True)
    got_def = ops.subset_deficit(jnp.asarray(a), jnp.asarray(b),
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(got_xor), want_xor)
    np.testing.assert_array_equal(np.asarray(got_def), want_def)


@pytest.mark.parametrize("kind", ["subset", "boolean", "compound"])
def test_prefilter_scan_kernel_validity_bit_identical(kind):
    """exact_filtered_knn with use_kernel=True routes subset/boolean leaf
    validity through the bitset kernel — results (ids, d2, n_dist, n_feval)
    must be bit-identical to the dense comparator path."""
    from repro.core import filters as F
    from repro.core.filters import Boolean, Subset
    from repro.core.ground_truth import exact_filtered_knn
    rng = np.random.default_rng(8)
    N, d, B, L = 300, 16, 6, 24
    xb = rng.normal(size=(N, d)).astype(np.float32)
    q = rng.normal(size=(B, d)).astype(np.float32)
    bits = rng.random((N, L)) < 0.5
    assign = rng.integers(0, 1 << 8, N).astype(np.uint32)
    if kind == "subset":
        tab = F.subset_table(bits, L)
        fb = np.zeros((B, L), bool)
        fb[:, :3] = True
        filt = F.subset_filters(fb, L)
    elif kind == "boolean":
        tab = F.boolean_table(assign, 8)
        sat = rng.random((B, 1 << 8)) < 0.3
        filt = F.boolean_filters(sat, 8)
    else:
        L2 = 12          # joint tables share one n_bits across bit kinds
        tab = F.joint_table(F.subset_table(bits[:, :L2], L2),
                            F.boolean_table(assign % (1 << L2), L2))
        fb = np.zeros((B, L2), bool)
        fb[:, :2] = True
        sat = rng.random((B, 1 << L2)) < 0.5
        filt = Subset(fb) & ~Boolean(sat, L2)
    gt0 = exact_filtered_knn(xb, tab, q, filt, k=10, block=128,
                             use_kernel=False)
    gt1 = exact_filtered_knn(xb, tab, q, filt, k=10, block=128,
                             use_kernel=True)
    # validity must be bit-identical (same survivors, same scan counts,
    # same short-circuit evals); d2 comes from a different distance
    # kernel (tile scan vs norms+matmul), so it is allclose, not bitwise
    for f in ("ids", "n_dist", "n_feval"):
        np.testing.assert_array_equal(np.asarray(getattr(gt0, f)),
                                      np.asarray(getattr(gt1, f)),
                                      err_msg=(kind, f))
    np.testing.assert_allclose(np.asarray(gt0.d2), np.asarray(gt1.d2),
                               rtol=1e-4, atol=1e-4)
    assert int(np.asarray(gt0.n_dist).sum()) > 0


def test_kernel_agrees_with_core_distance_path():
    """gather_dist must agree with the beam-search gathered_d2 helper."""
    from repro.core.distances import gathered_d2, sq_norms
    rng = np.random.default_rng(6)
    N, d, B, C = 128, 32, 8, 16
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    want = gathered_d2(xb, sq_norms(xb), ids, q, sq_norms(q))
    got = ops.gather_dist(xb, ids, q, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
