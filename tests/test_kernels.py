"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.l2dist import l2dist as l2_raw
from repro.kernels.gather_dist import gather_dist_tile
from repro.kernels.bitset import bitset_dist


@pytest.mark.parametrize("B,N,d,dtype", [
    (8, 32, 16, np.float32),
    (128, 256, 128, np.float32),
    (64, 100, 48, np.float32),     # padding path
    (33, 257, 130, np.float32),    # awkward shapes
    (16, 64, 32, jnp.bfloat16),
])
def test_l2dist_matches_ref(B, N, d, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, d)), dtype)
    xb = jnp.asarray(rng.normal(size=(N, d)), dtype)
    got = ops.l2dist(q, xb, interpret=True)
    want = ref.l2dist_ref(q, xb)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_l2dist_raw_blocked_grid():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    got = l2_raw(q, xb, bq=128, bn=256, bd=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.l2dist_ref(q, xb)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("B,C,N,d", [(4, 8, 64, 16), (16, 32, 200, 64),
                                     (2, 5, 33, 128)])
def test_gather_dist_matches_ref(B, C, N, d):
    rng = np.random.default_rng(2)
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    got = ops.gather_dist(xb, ids, q, interpret=True)
    want = ref.gather_dist_ref(xb, ids, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_gather_dist_tile():
    rng = np.random.default_rng(3)
    N, d, tile, B = 256, 32, 64, 8
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    base = jnp.asarray(rng.integers(0, N // tile, B), jnp.int32)
    got = gather_dist_tile(xb, base, q, tile=tile, interpret=True)
    for b in range(B):
        rows = xb[int(base[b]) * tile:(int(base[b]) + 1) * tile]
        want = ((rows - q[b]) ** 2).sum(-1)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("B,N,W", [(8, 16, 1), (64, 128, 4), (33, 77, 7)])
@pytest.mark.parametrize("op", ["xor", "deficit"])
def test_bitset_matches_ref(B, N, W, op):
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (B, W), dtype=np.uint64),
                    jnp.uint32)
    b = jnp.asarray(rng.integers(0, 2 ** 32, (N, W), dtype=np.uint64),
                    jnp.uint32)
    if op == "xor":
        got = ops.hamming(a, b, interpret=True)
        want = ref.hamming_ref(a, b)
    else:
        got = ops.subset_deficit(a, b, interpret=True)
        want = ref.subset_deficit_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitset_raw_grid():
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (256, 2), dtype=np.uint64),
                    jnp.uint32)
    b = jnp.asarray(rng.integers(0, 2 ** 32, (256, 2), dtype=np.uint64),
                    jnp.uint32)
    got = bitset_dist(a, b, op="xor", bq=128, bn=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.hamming_ref(a, b)))


def test_kernel_agrees_with_core_distance_path():
    """gather_dist must agree with the beam-search gathered_d2 helper."""
    from repro.core.distances import gathered_d2, sq_norms
    rng = np.random.default_rng(6)
    N, d, B, C = 128, 32, 8, 16
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    want = gathered_d2(xb, sq_norms(xb), ids, q, sq_norms(q))
    got = ops.gather_dist(xb, ids, q, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
