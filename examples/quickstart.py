"""Quickstart: build a JAG over vectors+attributes, run filtered queries.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (JAGConfig, JAGIndex, range_table, range_filters)
from repro.core.ground_truth import exact_filtered_knn
from repro.core.recall import recall_at_k


def main():
    rng = np.random.default_rng(0)
    n, d = 5000, 32

    # vectors + a scalar attribute per point (e.g. price, timestamp)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    prices = rng.uniform(0, 1000, n).astype(np.float32)

    print("building Threshold-JAG (thresholds = {100%, 1%, 0} quantiles)...")
    index = JAGIndex.build(xb, range_table(prices),
                           JAGConfig(degree=24, ls_build=48))
    print("  degree stats:", index.degree_stats())

    # filtered queries: top-10 nearest with price in [lo, lo+50]
    b = 64
    q = rng.normal(size=(b, d)).astype(np.float32)
    lo = rng.uniform(0, 950, b).astype(np.float32)
    filt = range_filters(lo, lo + 50.0)        # ~5% selectivity

    res = index.search(q, filt, k=10, ls=64)
    gt = exact_filtered_knn(jnp.asarray(xb), index.attr, jnp.asarray(q),
                            filt, k=10)
    rec = recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                      np.asarray(gt.ids)).mean()
    print(f"recall@10 = {rec:.3f}  "
          f"(mean distance comps: {float(np.asarray(res.n_dist).mean()):.0f}"
          f" vs brute-force {float(np.asarray(gt.n_dist).mean()):.0f})")

    # persistence round-trip
    index.save("/tmp/jag_quickstart.npz")
    idx2 = JAGIndex.load("/tmp/jag_quickstart.npz")
    res2 = idx2.search(q, filt, k=10, ls=64)
    assert np.array_equal(np.asarray(res.ids), np.asarray(res2.ids))
    print("save/load round-trip OK")


if __name__ == "__main__":
    main()
