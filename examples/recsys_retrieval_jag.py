"""The paper's technique inside the recsys serving path (retrieval_cand):

candidate generation for a two-stage recommender = *filtered* nearest
neighbor search over item embeddings (filter = item category / price band),
served from a JAG index instead of brute-force scanning 10^6 candidates;
the DeepFM tower then scores the survivors.

  PYTHONPATH=src python examples/recsys_retrieval_jag.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (JAGConfig, JAGIndex, label_table, label_filters)
from repro.core.ground_truth import exact_filtered_knn
from repro.core.recall import recall_at_k
from repro.models import recsys as R


def main():
    rng = np.random.default_rng(0)
    n_items, d = 20_000, 16
    n_cats = 20

    # item tower embeddings + a category attribute per item
    items = rng.normal(size=(n_items, d)).astype(np.float32)
    cats = rng.integers(0, n_cats, n_items)

    print(f"building JAG over {n_items} item embeddings "
          f"(label attribute = category)...")
    t0 = time.time()
    index = JAGIndex.build(items, label_table(cats),
                           JAGConfig(degree=24, ls_build=48, batch_size=512))
    print(f"  built in {time.time() - t0:.0f}s")

    # user queries restricted to one category (the filter)
    b = 64
    users = rng.normal(size=(b, d)).astype(np.float32)
    want = rng.integers(0, n_cats, b)
    filt = label_filters(want)

    # stage 1a: JAG filtered candidate generation
    res = index.search(users, filt, k=50, ls=128)
    jax.block_until_ready(res.ids)
    t0 = time.perf_counter()
    res = index.search(users, filt, k=50, ls=128)
    jax.block_until_ready(res.ids)
    jag_dt = time.perf_counter() - t0

    # stage 1b: brute-force reference (what retrieval_cand does w/o JAG)
    t0 = time.perf_counter()
    gt = exact_filtered_knn(jnp.asarray(items), index.attr,
                            jnp.asarray(users), filt, k=50)
    jax.block_until_ready(gt.ids)
    bf_dt = time.perf_counter() - t0

    rec = recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                      np.asarray(gt.ids)).mean()
    print(f"candidate recall@50 = {rec:.3f}; "
          f"JAG {b / jag_dt:.0f} qps vs brute-force {b / bf_dt:.0f} qps "
          f"({bf_dt / jag_dt:.1f}x)")

    # stage 2: score survivors with a (reduced) DeepFM tower
    cfg = R.RecsysConfig(kind="deepfm", n_sparse=4, embed_dim=8,
                         total_vocab=4096, mlp_dims=(32, 16), n_dense=4)
    params, _ = R.init_params(cfg, jax.random.PRNGKey(0))
    cand = np.maximum(np.asarray(res.ids), 0)
    batch = {"sparse_ids": jnp.asarray(
        rng.integers(0, 64, (b * 50, 4)), jnp.int32),
        "dense": jnp.asarray(rng.normal(size=(b * 50, 4)), jnp.float32),
        "label": jnp.zeros(b * 50)}
    scores = jax.jit(lambda p, bt: R.forward(cfg, p, bt))(params, batch)
    scores = np.asarray(scores).reshape(b, 50)
    best = np.take_along_axis(cand, np.argmax(scores, 1)[:, None], 1)
    print(f"stage-2 ranked; example user 0 -> item {int(best[0, 0])} "
          f"(category {cats[best[0, 0]]}, wanted {want[0]})")


if __name__ == "__main__":
    main()
