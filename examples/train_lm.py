"""Train a ~100M-param LM (qwen3-shaped) for a few hundred steps on CPU —
the end-to-end training driver deliverable. Thin wrapper over the
fault-tolerant launcher (checkpoints, auto-resume, straggler logging):

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()
    sys.exit(train_main([
        "--arch", args.arch, "--scale", "tiny",
        "--steps", str(args.steps), "--batch", "8", "--seq", "256",
        "--ckpt-dir", "/tmp/repro_train_lm",
        "--metrics-out", "/tmp/repro_train_lm/metrics.jsonl",
    ]))
