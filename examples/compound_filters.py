"""Compound filter expressions: AND/OR/NOT trees over a composite index.

Builds one JAG over a joint label+range attribute table, then serves a
compound filter — ``(Label(9) | Label(1)) & Range(lo, hi)`` — through
``search_auto``, printing the plan (composed selectivity, chosen route)
and recall against exact ground truth. Finishes with the clause-reorder
demo: the planner rewrites a worst-order AND so the most selective
clause runs first, cutting short-circuit filter evaluations without
changing a single result id.

  PYTHONPATH=src python examples/compound_filters.py [--n 8000]
"""
import argparse

import numpy as np
import jax.numpy as jnp

import repro
from repro.core import filters as F
from repro.core.recall import recall_at_k
from repro.serve.planner import (PlannerConfig, explain, leaf_selectivities,
                                 reorder_clauses)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    args = ap.parse_args()
    n, d, b, k = args.n, 32, 64, 10

    rng = np.random.default_rng(0)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    labels = rng.integers(0, 4, n).astype(np.int32)
    labels[: n // 100] = 9                       # rare label, sel ~1%
    rng.shuffle(labels)
    vals = rng.uniform(0, 1, n).astype(np.float32)
    attr = repro.joint_table(F.label_table(labels), F.range_table(vals))
    index = repro.JAGIndex.build(xb, attr, repro.JAGConfig(degree=24))
    q = (xb[rng.integers(0, n, b)]
         + 0.1 * rng.normal(size=(b, d))).astype(np.float32)

    # one tree, every route: leaves are batched lanes, operators compose
    zeros = np.zeros(b, np.float32)
    expr = ((repro.Label(np.full(b, 9)) | repro.Label(np.full(b, 1)))
            & repro.Range(zeros, np.full(b, 0.7, np.float32)))
    gt = repro.exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q),
                                  expr, k=k)
    res, p = index.search_auto(q, expr, k=k, return_plan=True)
    rec = recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                      np.asarray(gt.ids)).mean()
    print(explain(p, PlannerConfig(), filt=expr))
    print(f"compound search_auto: recall@{k}={rec:.3f}")

    # clause reordering: same ids, fewer short-circuit evaluations
    fixed = (repro.Range(zeros, np.full(b, 0.9, np.float32))
             & repro.Label(np.full(b, 9)))
    sels = np.median(np.asarray(leaf_selectivities(
        fixed, attr, jnp.arange(n))), axis=1)
    better = reorder_clauses(fixed, sels)
    gt0 = repro.exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q),
                                   fixed, k=k)
    gt1 = repro.exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q),
                                   better, k=k)
    same = np.array_equal(np.asarray(gt0.ids), np.asarray(gt1.ids))
    print(f"reorder {F.describe(fixed)} -> {F.describe(better)}: "
          f"n_feval {float(np.asarray(gt0.n_feval).mean()):.0f} -> "
          f"{float(np.asarray(gt1.n_feval).mean()):.0f}, "
          f"ids identical: {same}")


if __name__ == "__main__":
    main()
