"""End-to-end serving driver (the paper's workload): build a JAG over a
mixed-selectivity dataset, serve batched filtered queries of all four
filter types, report recall/QPS against exact ground truth — plus the
post-filtering baseline and the selectivity-adaptive planner
(``search_auto``, which routes each query to prefilter | graph |
postfilter — a mixed batch prints as route "mixed") for contrast.

  PYTHONPATH=src python examples/filtered_search_e2e.py [--n 8000]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import JAGConfig, JAGIndex
from repro.core import baselines as BL
from repro.core.ground_truth import exact_filtered_knn
from repro.core.recall import recall_at_k
from repro.data import synthetic as SYN


def serve(name, make_ds, cfg, ls=64):
    ds = make_ds()
    t0 = time.time()
    index = JAGIndex.build(ds.xb, ds.attr, cfg)
    build_s = time.time() - t0
    unf = BL.build_unfiltered(ds.xb, ds.attr, cfg)
    gt = exact_filtered_knn(jnp.asarray(ds.xb), ds.attr,
                            jnp.asarray(ds.queries), ds.filt, k=10)

    plans = []

    def run_auto():
        res, p = index.search_auto(ds.queries, ds.filt, k=10, ls=ls,
                                   return_plan=True)
        plans.append(p)          # the route the measured call actually took
        return res

    out = {}
    for algo, run in (
            ("jag", lambda: index.search(ds.queries, ds.filt, k=10, ls=ls)),
            ("auto", run_auto),
            ("post", lambda: BL.post_filter_search(unf, ds.queries,
                                                   ds.filt, k=10, ls=ls))):
        res = run()
        jax.block_until_ready(res.ids)
        t0 = time.perf_counter()
        res = run()
        jax.block_until_ready(res.ids)
        dt = time.perf_counter() - t0
        rec = recall_at_k(np.asarray(res.ids),
                          np.asarray(res.primary) == 0,
                          np.asarray(gt.ids)).mean()
        out[algo] = (rec, len(ds.queries) / dt)
    print(f"{name:18s} build={build_s:5.0f}s  "
          f"JAG recall={out['jag'][0]:.3f} qps={out['jag'][1]:7.0f}   "
          f"auto[{plans[-1].route}] recall={out['auto'][0]:.3f} "
          f"qps={out['auto'][1]:7.0f}   "
          f"post recall={out['post'][0]:.3f} qps={out['post'][1]:7.0f}  "
          f"(mean selectivity {np.mean(ds.selectivity):.3f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    args = ap.parse_args()
    n = args.n
    cfg = JAGConfig(degree=24, ls_build=48, batch_size=256, cand_pool=96)
    serve("range (Fig.1)", lambda: SYN.msturing_range(n=n, b=128), cfg)
    serve("label (Fig.3)", lambda: SYN.sift_like(n=n, b=128), cfg)
    serve("subset (Fig.4)", lambda: SYN.msturing_subset(n=n, b=128), cfg)
    serve("boolean (Fig.5)", lambda: SYN.msturing_bool(n=n, b=64), cfg)


if __name__ == "__main__":
    main()
