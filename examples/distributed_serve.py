"""Distributed shard-and-merge JAG serving on a local device mesh.

Runs the exact shard_map program the 512-chip dry-run lowers, on however
many CPU devices this host exposes (set XLA_FLAGS to fake more):

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/distributed_serve.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import JAGConfig, JAGIndex, range_table
from repro.core.distributed import ShardedServeConfig, make_serve_step


def main():
    n_dev = len(jax.devices())
    model = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
    from repro.launch.mesh import mesh_kwargs, set_mesh
    mesh = jax.make_mesh((n_dev // model, model), ("data", "model"),
                         **mesh_kwargs(2))
    S = n_dev
    print(f"devices={n_dev} mesh={dict(mesh.shape)} -> {S} index shards")

    rng = np.random.default_rng(0)
    n_loc, d = 1000, 24
    xb = rng.normal(size=(S, n_loc, d)).astype(np.float32) * 2
    vals = rng.uniform(0, 100, (S, n_loc)).astype(np.float32)

    # build one independent JAG per shard (in production: one per host)
    cfg = JAGConfig(degree=16, ls_build=32, batch_size=256, cand_pool=64)
    graphs, entries = [], []
    for s in range(S):
        idx = JAGIndex.build(xb[s], range_table(vals[s]), cfg)
        graphs.append(np.asarray(idx.graph))
        entries.append(np.resize(np.atleast_1d(np.asarray(idx.entry)), 8))
    graphs = np.stack(graphs)
    entries = np.stack(entries).astype(np.int32)
    xbn = (xb.astype(np.float64) ** 2).sum(-1).astype(np.float32)

    B = 64
    q = rng.normal(size=(B, d)).astype(np.float32) * 2
    lo = rng.uniform(0, 80, B).astype(np.float32)
    filt_data = {"lo": jnp.asarray(lo), "hi": jnp.asarray(lo + 10)}

    step = jax.jit(make_serve_step(
        mesh, ShardedServeConfig(k=10, ls=48, max_iters=96,
                                 query_chunk=32), "range", "range"))
    with set_mesh(mesh):
        ids, prim, sec = step(jnp.asarray(graphs), jnp.asarray(xb),
                              jnp.asarray(xbn),
                              {"value": jnp.asarray(vals)},
                              jnp.asarray(entries), jnp.asarray(q),
                              filt_data)
    ids = np.asarray(ids)

    # verify against exact search over the union of shards
    xf = xb.reshape(-1, d)
    vf = vals.reshape(-1)
    d2 = ((q[:, None] - xf[None]) ** 2).sum(-1)
    mask = (vf[None] >= lo[:, None]) & (vf[None] <= (lo + 10)[:, None])
    d2m = np.where(mask, d2, np.inf)
    recs = []
    for b in range(B):
        gtb = [j for j in np.argsort(d2m[b])[:10] if d2m[b, j] < np.inf]
        got = [i for i, p in zip(ids[b], np.asarray(prim)[b])
               if p == 0 and i >= 0]
        if gtb:
            recs.append(len(set(gtb) & set(got)) / len(gtb))
    print(f"distributed recall@10 over {S * n_loc} points: "
          f"{np.mean(recs):.3f}")
    print("merge collective: one all_gather of [B, k] per shard axis "
          "(bytes independent of N)")


if __name__ == "__main__":
    main()
