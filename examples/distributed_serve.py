"""Sharded JAG serving: ShardedJAGIndex on a local "data"-axis mesh.

The database is sharded row-wise across the mesh (one self-contained JAG
sub-index per device), every route runs inside a shard_map program, and
per-shard top-k results merge exactly — one all_gather of [B, k] per
shard axis, bytes independent of N. The wrapper serves the same
``search_auto(queries, filt, k, ls)`` surface as a single-device
``JAGIndex``, so sharding is a build-time decision, not an API change:

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/distributed_serve.py

(When XLA_FLAGS is unset this script fakes 8 host devices itself.)
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import JAGConfig, JAGIndex, range_filters, range_table
from repro.core.filters import Label, Range, joint_table, label_table
from repro.core.ground_truth import exact_filtered_knn
from repro.core.recall import recall_at_k
from repro.serve.planner import PlannerConfig
from repro.serve.sharded import ShardedJAGIndex


def main():
    S = min(8, len(jax.devices()))
    n_loc, d, b, k, ls = 500, 24, 32, 10, 48
    n = S * n_loc
    print(f"devices={len(jax.devices())} -> {S} shards x {n_loc} rows")

    rng = np.random.default_rng(0)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    labels = rng.integers(0, 4, n).astype(np.int32)
    vals = rng.uniform(0, 1, n).astype(np.float32)
    attr = joint_table(label_table(labels), range_table(vals))
    cfg = JAGConfig(degree=16, ls_build=32, batch_size=256, cand_pool=64)

    # same rows, two servings: the sharded build splits rows contiguously
    # and builds one sub-graph per shard (JAGIndex.shard(S) reshards a
    # built index the same way)
    sharded = ShardedJAGIndex.build(xb, attr, cfg, n_shards=S)
    union = JAGIndex.build(xb, attr, cfg)
    q = (xb[rng.integers(0, n, b)]
         + 0.1 * rng.normal(size=(b, d))).astype(np.float32)

    # the same selectivity-adaptive surface, now fanning out across shards
    for name, hi in (("rare", 0.005), ("mid", 0.2), ("wide", 0.9)):
        filt = range_filters(np.zeros(b, np.float32),
                             np.full(b, hi, np.float32))
        gt = exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q),
                                filt, k=k)
        res, plan = sharded.search_auto(q, filt, k=k, ls=ls,
                                        return_plan=True)
        rec = recall_at_k(np.asarray(res.ids),
                          np.asarray(res.primary) == 0,
                          np.asarray(gt.ids)).mean()
        print(f"  band={name:4s} sel~{hi:<5} route={plan.route:10s} "
              f"recall@10={float(rec):.3f}")

    # compound expression trees dispatch through the same sharded routes
    expr = (Label(np.full(b, 2)) | Label(np.full(b, 3))) \
        & Range(np.zeros(b, np.float32), np.full(b, 0.6, np.float32))
    res, plan = sharded.search_auto(q, expr, k=k, ls=ls, return_plan=True)
    gt = exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q), expr,
                            k=k)
    rec = recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                      np.asarray(gt.ids)).mean()
    print(f"  compound (2|3)&range route={plan.route} "
          f"recall@10={float(rec):.3f}")

    # exact-merge semantics: force the exact-scan route everywhere and the
    # sharded result is BIT-identical to the single-device union index —
    # same ids, same keys, same telemetry, every field
    force_exact = PlannerConfig(prefilter_max_sel=1.1,
                                postfilter_min_sel=1.2)
    a = sharded.search_auto(q, expr, k=k, ls=ls, planner=force_exact)
    bres = union.search_auto(q, expr, k=k, ls=ls, planner=force_exact)
    same = all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(bres, f)))
               for f in a._fields)
    print(f"  exact route bit-identical to single-device union: {same}")
    print("merge collective: one all_gather of [B, k] per shard axis "
          "(bytes independent of N)")


if __name__ == "__main__":
    main()
