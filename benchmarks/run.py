"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Scale knobs via env:
  REPRO_BENCH_FAST=1  -> kernel microbenches only (CI mode; skips the
                         index-build figure benchmarks).

Usage: PYTHONPATH=src python -m benchmarks.run [--only substr] [--json PATH]

``--json PATH`` additionally writes ``{"rows": [{name, us, derived}, ...]}``
— the machine-readable form CI uploads as a per-PR build artifact so hot-path
regressions (e.g. the fused serving kernel) are visible in review.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benchmarks whose name contains this")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (CI artifact)")
    ap.add_argument("--audit", action="store_true",
                    help="stamp repro.analysis.audit per-route gather/"
                         "collective counts into the JSON artifact")
    args = ap.parse_args(argv)

    from . import kernels_bench, paper_figs
    benches = list(kernels_bench.ALL)
    if os.environ.get("REPRO_BENCH_FAST") != "1":
        benches += list(paper_figs.ALL)

    rows = []

    def emit(name, us, derived=""):
        rows.append({"name": name, "us": round(us, 1), "derived": derived})
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    t0 = time.time()
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            bench(emit)
        except Exception:
            traceback.print_exc()
            emit(f"{bench.__name__}/ERROR", 0.0, "see stderr")
    print(f"# total {time.time() - t0:.0f}s, {len(rows)} rows",
          file=sys.stderr)
    out = {"rows": rows, "total_s": round(time.time() - t0, 1)}
    if args.audit:
        from repro.analysis.audit import audit_stamp
        out["audit"] = audit_stamp()
        print(f"# audit stamp: {len(out['audit'])} routes",
              file=sys.stderr)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=1)


if __name__ == "__main__":
    main()
