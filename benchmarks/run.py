"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Scale knobs via env:
  REPRO_BENCH_FAST=1  -> kernel microbenches only (CI mode; skips the
                         index-build figure benchmarks).

Usage: PYTHONPATH=src python -m benchmarks.run [--only substr]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run benchmarks whose name contains this")
    args = ap.parse_args(argv)

    from . import kernels_bench, paper_figs
    benches = list(kernels_bench.ALL)
    if os.environ.get("REPRO_BENCH_FAST") != "1":
        benches += list(paper_figs.ALL)

    rows = []

    def emit(name, us, derived=""):
        row = f"{name},{us:.1f},{derived}"
        rows.append(row)
        print(row, flush=True)

    print("name,us_per_call,derived")
    t0 = time.time()
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            bench(emit)
        except Exception:
            traceback.print_exc()
            emit(f"{bench.__name__}/ERROR", 0.0, "see stderr")
    print(f"# total {time.time() - t0:.0f}s, {len(rows)} rows",
          file=sys.stderr)


if __name__ == "__main__":
    main()
