"""Streaming-insert benchmark: live recall vs rebuild, insert throughput.

Builds a base index, then inserts 4 batches of fresh rows. After each
insert epoch it measures (1) insert throughput (rows/s into the delta
segment, compaction time charged separately), (2) ``search_auto`` QPS over
the live base+delta index, and (3) recall@10 against exact ground truth
over the concatenated database — side by side with a FULL REBUILD of the
index over the same rows, the thing streaming replaces. The final batch
pushes the delta past ``compact_frac``, so the trajectory also covers an
auto-compaction epoch.

CI runs this in fast mode, uploads ``BENCH_streaming.json`` as the
streaming trajectory artifact, and asserts the live index's recall stays
within 0.01 of the rebuild's at every epoch (see .github/workflows/ci.yml).

Usage: PYTHONPATH=src python -m benchmarks.streaming_bench [--json PATH]
Env:   REPRO_BENCH_FAST=1 -> small scale (CI smoke).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.cost.calibrate import time_route   # shared warmup+median timer


def _timed(fn, repeats=3):
    return time_route(fn, warmup=1, repeats=repeats)


def _recall(res, gt):
    from repro.core.recall import recall_at_k
    return float(recall_at_k(np.asarray(res.ids),
                             np.asarray(res.primary) == 0,
                             np.asarray(gt.ids)).mean())


def main(argv=None) -> dict:
    from repro.core import JAGConfig, JAGIndex, range_filters, range_table
    from repro.core.ground_truth import exact_filtered_knn
    from repro.stream import StreamingJAGIndex

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as JSON (CI artifact)")
    ap.add_argument("--n", type=int, default=None, help="base database size")
    ap.add_argument("--b", type=int, default=None, help="query batch size")
    args = ap.parse_args(argv)

    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    n0 = args.n or (1200 if fast else 20000)
    b = args.b or (32 if fast else 128)
    d = 16 if fast else 64
    k, ls = 10, 160
    n_batches, batch_rows = 4, n0 // 8          # 4 x 12.5% of the base
    compact_frac = 0.45                         # 4th batch triggers compact
    sel = 0.3                                   # graph-route band

    rng = np.random.default_rng(0)
    xb = rng.normal(size=(n0, d)).astype(np.float32)
    vals = rng.uniform(0, 1, n0).astype(np.float32)
    cfg = JAGConfig(degree=16 if fast else 32, ls_build=32 if fast else 64,
                    batch_size=256, cand_pool=64 if fast else 192,
                    calib_samples=128)
    t0 = time.time()
    stream = StreamingJAGIndex.build(xb, range_table(vals), cfg,
                                     compact_frac=compact_frac)
    build_s = time.time() - t0
    q = (xb[rng.integers(0, n0, b)]
         + 0.1 * rng.normal(size=(b, d))).astype(np.float32)
    filt = range_filters(np.zeros(b, np.float32),
                         np.full(b, sel, np.float32))

    print(f"# n0={n0} d={d} b={b} base_build={build_s:.0f}s "
          f"batches={n_batches}x{batch_rows} compact_frac={compact_frac}")
    print("epoch,n_total,delta_rows,compacted,insert_rows_per_s,"
          "compact_s,qps_stream,recall_stream,rebuild_s,recall_rebuild")
    all_x, all_v = [xb], [vals]
    epochs = []
    for step in range(n_batches):
        xv = rng.normal(size=(batch_rows, d)).astype(np.float32)
        vv = rng.uniform(0, 1, batch_rows).astype(np.float32)
        all_x.append(xv)
        all_v.append(vv)
        t0 = time.perf_counter()
        rep = stream.insert(xv, range_table(vv), auto_compact=False)
        insert_s = time.perf_counter() - t0
        compact_s = 0.0
        if stream.delta.n > compact_frac * stream.base.xb.shape[0]:
            t0 = time.perf_counter()
            stream.compact()
            compact_s = time.perf_counter() - t0
            rep["compacted"] = True

        cx = np.concatenate(all_x)
        cv = np.concatenate(all_v)
        gt = exact_filtered_knn(jnp.asarray(cx), range_table(cv),
                                jnp.asarray(q), filt, k=k)
        res, dt = _timed(lambda: stream.search_auto(q, filt, k=k, ls=ls))
        rec_stream = _recall(res, gt)

        t0 = time.time()
        rebuilt = JAGIndex.build(cx, range_table(cv), cfg)
        rebuild_s = time.time() - t0
        rb, _ = _timed(lambda: rebuilt.search_auto(q, filt, k=k, ls=ls),
                       repeats=1)
        rec_rebuild = _recall(rb, gt)

        row = dict(epoch=stream.epoch, n_total=stream.n,
                   delta_rows=stream.delta.n,
                   compacted=bool(rep["compacted"]),
                   insert_rows_per_s=round(batch_rows / insert_s, 1),
                   compact_s=round(compact_s, 3),
                   qps_stream=round(b / dt, 1),
                   recall_stream=round(rec_stream, 4),
                   rebuild_s=round(rebuild_s, 2),
                   recall_rebuild=round(rec_rebuild, 4))
        epochs.append(row)
        print(",".join(str(row[c]) for c in
                       ("epoch", "n_total", "delta_rows", "compacted",
                        "insert_rows_per_s", "compact_s", "qps_stream",
                        "recall_stream", "rebuild_s", "recall_rebuild")),
              flush=True)

    out = {"n0": n0, "d": d, "b": b, "k": k, "ls": ls, "sel": sel,
           "base_build_s": round(build_s, 1),
           "batch_rows": batch_rows, "compact_frac": compact_frac,
           "n_compactions": stream.n_compactions,
           "epochs": epochs}
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=1)
    return out


if __name__ == "__main__":
    main()
