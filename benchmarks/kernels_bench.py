"""Kernel-path microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
only), so wall-times here measure the jnp oracle paths the system actually
executes on CPU; the kernels' target-hardware behaviour is captured by the
dry-run roofline instead. Derived column reports achieved GFLOP/s of the
oracle path + the kernel's VMEM tile plan.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *a, repeats=5):
    out = f(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) / repeats


def bench_l2dist(emit):
    B, N, d = 256, 8192, 128
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    f = jax.jit(ref.l2dist_ref)
    dt = _time(f, q, xb)
    gf = 2 * B * N * d / dt / 1e9
    emit("kernels/l2dist_oracle_256x8192x128", dt * 1e6,
         f"gflops={gf:.1f} tile=(128,256,128)VMEM")
    out_k = ops.l2dist(q[:8], xb[:256], interpret=True)
    out_r = ref.l2dist_ref(q[:8], xb[:256])
    emit("kernels/l2dist_interpret_allclose", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(out_k - out_r))):.2e}")


def bench_gather_dist(emit):
    N, d, B, C = 16384, 64, 128, 32
    rng = np.random.default_rng(1)
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    f = jax.jit(ref.gather_dist_ref)
    dt = _time(f, xb, ids, q)
    emit("kernels/gather_dist_oracle_128x32", dt * 1e6,
         f"gflops={2 * B * C * d / dt / 1e9:.1f} rows_dma={B * C}")


def _count_gathers(jitted, *args) -> int:
    """Number of gather ops in the lowered HLO of ``jitted(*args)``.

    This is the measured per-expansion gather count the CI bench artifact
    asserts on (one per N-row operand fetched), so the fused layout's
    one-gather contract can't silently regress while a hardcoded label
    stays green.
    """
    import re
    txt = jitted.lower(*args).as_text()
    return sum(1 for line in txt.splitlines()
               if re.search(r'=\s*"?stablehlo\.gather"?\(', line))


def bench_fused_expand(emit):
    """Fused one-gather serving path vs the default split-layout expansion.

    The fused serving layout (serve/layout.py) packs [vec | norm | attr]
    into one row so each beam expansion costs ONE gather; the default path
    gathers the vector matrix, the norm vector, and the attribute table
    separately. gathers_per_expansion is MEASURED from the lowered HLO of
    each fetch (not asserted by the code under test) so CI catches a fused
    path that regresses to multiple gathers.
    """
    from repro.core import filters as F
    from repro.core.distances import gathered_d2, sq_norms
    from repro.serve import build_layout, make_fetch_fn

    N, d, B, C = 16384, 64, 128, 32
    rng = np.random.default_rng(7)
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    qn = jnp.sum(q * q, axis=-1)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    attr = F.subset_table(
        jnp.asarray(rng.integers(0, 2, (N, 64)), jnp.bool_), 64)
    lay = build_layout(xb, attr)
    xb_norm = sq_norms(xb)

    def two_gather(xb, xb_norm, ids, q, qn):
        return gathered_d2(xb, xb_norm, ids, q, qn), attr.gather(ids)

    f2 = jax.jit(two_gather)
    g2 = _count_gathers(f2, xb, xb_norm, ids, q, qn)
    dt2 = _time(f2, xb, xb_norm, ids, q, qn)
    emit("kernels/fused_expand_baseline_split_128x32", dt2 * 1e6,
         f"gathers_per_expansion={g2} rows_dma={g2 * B * C}")

    fetch = jax.jit(make_fetch_fn(lay))
    g1 = _count_gathers(fetch, ids, q, qn)
    dt1 = _time(fetch, ids, q, qn)
    emit("kernels/fused_expand_xla_128x32", dt1 * 1e6,
         f"gathers_per_expansion={g1} rows_dma={g1 * B * C} "
         f"row_bytes={lay.packed.shape[1] * 4} speedup_vs_split="
         f"{dt2 / dt1:.2f}x")

    # Pallas kernel correctness (interpret mode on CPU): one DMA'd packed
    # row per grid step must match the pure-jnp oracle bit-for-bit on attrs.
    q_eff, _ = lay.fold_query(q[:8])
    kd2, kw = ops.fused_expand(lay.packed, ids[:8, :8], q_eff, qn[:8],
                               d=d, interpret=True)
    rd2, rw = ref.fused_expand_ref(lay.packed, ids[:8, :8], q_eff, qn[:8],
                                   d=d)
    bits = jax.lax.bitcast_convert_type  # NaN-payload-safe word compare
    emit("kernels/fused_expand_interpret_allclose", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(kd2 - rd2))):.2e} "
         f"attr_bits_exact="
         f"{bool(jnp.all(bits(kw, jnp.uint32) == bits(rw, jnp.uint32)))}")


def bench_bitset(emit):
    B, Nn, W = 256, 8192, 4
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (B, W), dtype=np.uint64),
                    jnp.uint32)
    bb = jnp.asarray(rng.integers(0, 2 ** 32, (Nn, W), dtype=np.uint64),
                     jnp.uint32)
    f = jax.jit(ref.hamming_ref)
    dt = _time(f, a, bb)
    emit("kernels/bitset_hamming_oracle_256x8192x4w", dt * 1e6,
         f"gops={B * Nn * W / dt / 1e9:.2f}")


ALL = [bench_l2dist, bench_gather_dist, bench_fused_expand, bench_bitset]
