"""Kernel-path microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (correctness
only), so wall-times here measure the jnp oracle paths the system actually
executes on CPU; the kernels' target-hardware behaviour is captured by the
dry-run roofline instead. Derived column reports achieved GFLOP/s of the
oracle path + the kernel's VMEM tile plan.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *a, repeats=5):
    out = f(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) / repeats


def bench_l2dist(emit):
    B, N, d = 256, 8192, 128
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    f = jax.jit(ref.l2dist_ref)
    dt = _time(f, q, xb)
    gf = 2 * B * N * d / dt / 1e9
    emit("kernels/l2dist_oracle_256x8192x128", dt * 1e6,
         f"gflops={gf:.1f} tile=(128,256,128)VMEM")
    out_k = ops.l2dist(q[:8], xb[:256], interpret=True)
    out_r = ref.l2dist_ref(q[:8], xb[:256])
    emit("kernels/l2dist_interpret_allclose", 0.0,
         f"maxerr={float(jnp.max(jnp.abs(out_k - out_r))):.2e}")


def bench_gather_dist(emit):
    N, d, B, C = 16384, 64, 128, 32
    rng = np.random.default_rng(1)
    xb = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, (B, C)), jnp.int32)
    f = jax.jit(ref.gather_dist_ref)
    dt = _time(f, xb, ids, q)
    emit("kernels/gather_dist_oracle_128x32", dt * 1e6,
         f"gflops={2 * B * C * d / dt / 1e9:.1f} rows_dma={B * C}")


def bench_bitset(emit):
    B, Nn, W = 256, 8192, 4
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (B, W), dtype=np.uint64),
                    jnp.uint32)
    bb = jnp.asarray(rng.integers(0, 2 ** 32, (Nn, W), dtype=np.uint64),
                     jnp.uint32)
    f = jax.jit(ref.hamming_ref)
    dt = _time(f, a, bb)
    emit("kernels/bitset_hamming_oracle_256x8192x4w", dt * 1e6,
         f"gops={B * Nn * W / dt / 1e9:.2f}")


ALL = [bench_l2dist, bench_gather_dist, bench_bitset]
