"""Cost-model calibration benchmark: fit quality + routing win, as JSON.

Stage 1 runs the ``repro.cost`` calibration grid (fast mode on CI) through
the real executor routes and fits the log-linear cost model; the artifact
records the fitted coefficients and the on-grid predicted-vs-measured
relative error per route — the honesty metric CI bounds.

Stage 2 replays ``planner_bench``'s MIXED band (half the batch at ~0.1%
selectivity, half at ~90%) on a fresh index three ways: the static
threshold router (no model), the cost-model router on the wall-time
metric, and the cost-model router on the ``n_dist`` metric (the paper's
hardware-independent distance-computation cost, deterministic per route).
CI asserts the DC-routed cost model spends no more mean distance
computations than the static thresholds, and that every routing decision
is the argmin of the router's own predictions.

Usage: PYTHONPATH=src python -m benchmarks.cost_bench [--json PATH]
                                                      [--registry DIR]
Env:   REPRO_BENCH_FAST=1 -> small grid (CI smoke).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax.numpy as jnp


def _mixed_eval(index, q, filt, gt, b, k, ls, label):
    from repro.core.recall import recall_at_k
    from repro.cost.calibrate import time_route

    res, dt = time_route(lambda: index.search_auto(q, filt, k=k, ls=ls),
                         warmup=1, repeats=2)
    _, p = index.search_auto(q, filt, k=k, ls=ls, return_plan=True)
    rec = recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                      np.asarray(gt.ids)).mean()
    out = {"routes": sorted(set(p.routes)),
           "groups": [{"route": g.route, "n": int(g.ids.size)}
                      for g in p.groups],
           "mean_n_dist": round(float(np.asarray(res.n_dist).mean()), 1),
           "recall": round(float(rec), 4),
           "qps": round(b / dt, 1)}
    # the acceptance invariant: every chosen route is the argmin of the
    # router's own per-query cost predictions
    router = index.executor.cost_router(k=k, ls=ls)
    if router is not None:
        out["argmin_consistent"] = all(
            p.routes[i] == router.route(float(s))
            for i, s in enumerate(p.selectivity))
        out["predicted_costs_at_median"] = {
            r: round(c, 2) for r, c in p.costs.items()}
    print(f"mixed,{label},{out['mean_n_dist']},{out['recall']},"
          f"{out['qps']},{'+'.join(out['routes'])}", flush=True)
    return out


def main(argv=None) -> dict:
    from repro.core import JAGConfig, JAGIndex, range_filters, range_table
    from repro.core.ground_truth import exact_filtered_knn
    from repro.cost import CostRegistry, feature_names, fit, run_calibration
    from repro.cost.calibrate import FAST_GRID, FULL_GRID, synth_dataset

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as JSON (CI artifact)")
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="also save the fitted model into this registry")
    ap.add_argument("--audit", action="store_true",
                    help="stamp repro.analysis.audit per-route gather/"
                         "collective counts into the JSON artifact")
    args = ap.parse_args(argv)

    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    # the canonical grids — this benchmark IS the calibration CI runs, so
    # it must measure exactly what calibrate(fast=...) would
    grid = dict(FAST_GRID if fast else FULL_GRID)

    t0 = time.time()
    cal = run_calibration(**grid, verbose=True)
    model = fit(cal.observations, cal.meta)
    calib_s = time.time() - t0
    print(f"# calibration: {len(cal.observations)} observations in "
          f"{calib_s:.0f}s; fitted routes: {model.routes()}")
    print("route,n_obs,median_rel_err,max_rel_err")
    for route, st in model.fit_stats.items():
        print(f"{route},{st['n_obs']},{st['median_rel_err']:.3f},"
              f"{st['max_rel_err']:.3f}", flush=True)
    if args.registry:
        path = CostRegistry(args.registry).save(model)
        print(f"# registry artifact: {path}")

    # ---- mixed band: static thresholds vs cost-model routing --------------
    n = 3000 if fast else 20000
    d = 16 if fast else 64
    b = 32 if fast else 128
    k, ls = grid["k"], 64
    lo_sel, hi_sel = 0.001, 0.9
    # SAME synthetic recipe the calibration grid measured on
    xb, vals, q = synth_dataset(n, d, b, seed=0)
    cfg = JAGConfig(degree=16 if fast else 32, ls_build=32 if fast else 64,
                    batch_size=256, cand_pool=64 if fast else 192,
                    calib_samples=128)
    index = JAGIndex.build(xb, range_table(vals), cfg)
    his = np.where(np.arange(b) % 2 == 0, lo_sel, hi_sel).astype(np.float32)
    filt = range_filters(np.zeros(b, np.float32), his)
    gt = exact_filtered_knn(jnp.asarray(xb), range_table(vals),
                            jnp.asarray(q), filt, k=k)

    print("mixed,router,mean_n_dist,recall,qps,routes")
    mixed = {}
    mixed["static"] = _mixed_eval(index, q, filt, gt, b, k, ls, "static")
    index.attach_cost_model(model, metric="us")
    mixed["cost_us"] = _mixed_eval(index, q, filt, gt, b, k, ls, "cost_us")
    index.attach_cost_model(model, metric="n_dist")
    mixed["cost_n_dist"] = _mixed_eval(index, q, filt, gt, b, k, ls,
                                       "cost_n_dist")

    out = {"fast": fast, "calib_s": round(calib_s, 1),
           "n_observations": len(cal.observations),
           "meta": model.meta,
           "feature_names": {r: list(feature_names(r))
                             for r in model.routes()},
           "coef": model.coef,
           "fit_stats": model.fit_stats,
           "mixed": {"target_sel": [lo_sel, hi_sel], "n": n, "d": d,
                     "b": b, "k": k, "ls": ls, **mixed}}
    if args.audit:
        from repro.analysis.audit import audit_stamp
        out["audit"] = audit_stamp()
        print(f"# audit stamp: {len(out['audit'])} routes")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=1)
    return out


if __name__ == "__main__":
    main()
