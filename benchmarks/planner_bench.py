"""Selectivity-sweep benchmark for the adaptive query planner.

For each selectivity band (~0.1% -> ~90%) this times every executor route
(prefilter | graph | postfilter) plus ``search_auto``, records the router's
decision, recall@10 against exact ground truth, and the mean distance
computations per query. A final MIXED band (half the batch at the lowest
target selectivity, half at the highest, interleaved) times per-query
routing (``mode="per_query"``: split by band, each group on its own route)
against whole-batch routing and each forced single route. CI runs it in
fast mode, uploads the JSON as the routing-decision artifact, and asserts
the router does not collapse every band onto one path AND that the
per-query router splits the mixed batch and wins on mean distance
computations (see .github/workflows/ci.yml).

A compound-filter section then repeats the exercise with expression trees
over a second, composite label+range index: a rare-label AND wide-range
conjunction, a two-label OR, a mixed per-lane OR band (per-query routing vs
every whole-batch route), and a fixed-vs-reordered AND measuring the clause
reorderer's short-circuit filter-eval savings (``GroundTruth.n_feval``).
``--compound-json`` writes that section as its own CI artifact
(BENCH_compound.json) with its own asserts in ci.yml.

Usage: PYTHONPATH=src python -m benchmarks.planner_bench [--json PATH]
Env:   REPRO_BENCH_FAST=1 -> small scale (CI smoke).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.cost.calibrate import time_route   # shared warmup+median timer

BANDS = (0.001, 0.01, 0.1, 0.5, 0.9)   # target selectivity per band
ROUTE_NAMES = ("prefilter", "graph", "postfilter")


def _timed(fn, repeats=3):
    return time_route(fn, warmup=1, repeats=repeats)


def main(argv=None) -> dict:
    from repro.core import JAGConfig, JAGIndex, range_filters, range_table
    from repro.core.ground_truth import exact_filtered_knn
    from repro.core.recall import recall_at_k
    from repro.serve.planner import (PlannerConfig, explain, plan,
                                     plan_per_query)

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as JSON (CI artifact)")
    ap.add_argument("--compound-json", default=None, metavar="PATH",
                    help="write the compound-filter section as its own "
                         "JSON artifact")
    ap.add_argument("--n", type=int, default=None, help="database size")
    ap.add_argument("--b", type=int, default=None, help="query batch size")
    args = ap.parse_args(argv)

    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    n = args.n or (3000 if fast else 20000)
    b = args.b or (32 if fast else 128)
    d = 16 if fast else 64
    k, ls = 10, 64

    rng = np.random.default_rng(0)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    vals = rng.uniform(0, 1, n).astype(np.float32)
    attr = range_table(vals)
    cfg = JAGConfig(degree=16 if fast else 32, ls_build=32 if fast else 64,
                    batch_size=256, cand_pool=64 if fast else 192,
                    calib_samples=128)
    t0 = time.time()
    index = JAGIndex.build(xb, attr, cfg)
    build_s = time.time() - t0
    q = (xb[rng.integers(0, n, b)]
         + 0.1 * rng.normal(size=(b, d))).astype(np.float32)
    ex = index.executor
    pcfg = PlannerConfig()
    # serving-layout metadata for the artifact, without packing the layout
    from repro.core.filters import attr_word_width
    from repro.serve import FusedEngine
    row_bytes = (d + 1 + attr_word_width(attr.kind, attr.n_bits)) * 4

    print(f"# n={n} d={d} b={b} build={build_s:.0f}s "
          f"row_bytes={row_bytes} "
          f"gathers_per_expansion={FusedEngine.gathers_per_expansion}")
    print("band_sel,route,path,qps,recall,mean_n_dist")
    bands_out = []
    for sel in BANDS:
        lo = np.zeros(b, np.float32)
        filt = range_filters(lo, np.full(b, sel, np.float32))
        gt = exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q),
                                filt, k=k)
        p = plan(filt, attr, pcfg, executor=ex)
        runs = {
            "prefilter": lambda: ex.prefilter(q, filt, k=k),
            "graph": lambda: ex.graph(q, filt, k=k, ls=ls,
                                      max_iters=2 * ls),
            "postfilter": lambda: ex.postfilter(q, filt, k=k, ls=ls,
                                                max_iters=2 * ls),
            "auto": lambda: index.search_auto(q, filt, k=k, ls=ls),
        }
        paths = {}
        for name, fn in runs.items():
            res, dt = _timed(fn)
            rec = recall_at_k(np.asarray(res.ids),
                              np.asarray(res.primary) == 0,
                              np.asarray(gt.ids)).mean()
            paths[name] = {"qps": round(b / dt, 1),
                           "recall": round(float(rec), 4),
                           "mean_n_dist": round(
                               float(np.asarray(res.n_dist).mean()), 1)}
            print(f"{sel},{p.route},{name},{paths[name]['qps']},"
                  f"{paths[name]['recall']},{paths[name]['mean_n_dist']}",
                  flush=True)
        bands_out.append({"target_sel": sel,
                          "est_sel": round(p.batch_selectivity, 5),
                          "route": p.route, "explain": explain(p, pcfg),
                          "paths": paths})

    # ---- mixed-selectivity batch: per-query vs whole-batch routing --------
    lo_sel, hi_sel = BANDS[0], BANDS[-1]
    his = np.where(np.arange(b) % 2 == 0, lo_sel, hi_sel).astype(np.float32)
    filt = range_filters(np.zeros(b, np.float32), his)
    gt = exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q), filt, k=k)
    pq = plan_per_query(filt, attr, pcfg, executor=ex)
    runs = {
        "prefilter": lambda: ex.prefilter(q, filt, k=k),
        "graph": lambda: ex.graph(q, filt, k=k, ls=ls, max_iters=2 * ls),
        "postfilter": lambda: ex.postfilter(q, filt, k=k, ls=ls,
                                            max_iters=2 * ls),
        "batch": lambda: index.search_auto(q, filt, k=k, ls=ls,
                                           mode="batch"),
        "per_query": lambda: index.search_auto(q, filt, k=k, ls=ls,
                                               mode="per_query"),
    }
    paths = {}
    for name, fn in runs.items():
        res, dt = _timed(fn)
        rec = recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                          np.asarray(gt.ids)).mean()
        paths[name] = {"qps": round(b / dt, 1),
                       "recall": round(float(rec), 4),
                       "mean_n_dist": round(
                           float(np.asarray(res.n_dist).mean()), 1)}
        print(f"mixed({lo_sel}|{hi_sel}),{pq.route},{name},"
              f"{paths[name]['qps']},{paths[name]['recall']},"
              f"{paths[name]['mean_n_dist']}", flush=True)
    mixed = {"target_sel": [lo_sel, hi_sel],
             "routes": [g.route for g in pq.groups],
             "groups": [{"route": g.route, "n": int(g.ids.size),
                         "median_sel": round(g.selectivity, 5)}
                        for g in pq.groups],
             "explain": explain(pq, pcfg),
             "paths": paths}

    # ---- compound expression trees over a composite label+range index ----
    from repro.core.filters import (Label, Range, describe, joint_table,
                                    label_table)
    from repro.serve.planner import leaf_selectivities, reorder_clauses

    n2 = 2000 if fast else 10000
    xb2 = rng.normal(size=(n2, d)).astype(np.float32)
    labels = rng.integers(0, 4, n2).astype(np.int32)
    # rare label at ~1%: OR-composed with a tight range it stays under the
    # 0.02 prefilter cutoff, so the mixed band's rare lanes route to the
    # exact scan (the per-query win the CI assert checks)
    labels[: max(4, n2 // 100)] = 9
    rng.shuffle(labels)
    vals2 = rng.uniform(0, 1, n2).astype(np.float32)
    attr2 = joint_table(label_table(labels), range_table(vals2))
    t0 = time.time()
    index2 = JAGIndex.build(xb2, attr2, cfg)
    build2_s = time.time() - t0
    q2 = (xb2[rng.integers(0, n2, b)]
          + 0.1 * rng.normal(size=(b, d))).astype(np.float32)
    ex2 = index2.executor
    zeros = np.zeros(b, np.float32)

    def _measure(runs, gt):
        paths = {}
        for name, fn in runs.items():
            res, dt = _timed(fn)
            rec = recall_at_k(np.asarray(res.ids),
                              np.asarray(res.primary) == 0,
                              np.asarray(gt.ids)).mean()
            paths[name] = {"qps": round(b / dt, 1),
                           "recall": round(float(rec), 4),
                           "mean_n_dist": round(
                               float(np.asarray(res.n_dist).mean()), 1)}
        return paths

    compound_bands = []
    cases = (
        ("rare_and_wide",
         Label(np.full(b, 9)) & Range(zeros, np.full(b, 0.9, np.float32))),
        ("two_label_or",
         Label(np.full(b, 1)) | Label(np.full(b, 2))),
    )
    for name, expr in cases:
        gt = exact_filtered_knn(jnp.asarray(xb2), attr2, jnp.asarray(q2),
                                expr, k=k)
        p = plan(expr, attr2, pcfg, executor=ex2)
        paths = _measure({
            "prefilter": lambda: ex2.prefilter(q2, expr, k=k),
            "graph": lambda: ex2.graph(q2, expr, k=k, ls=ls,
                                       max_iters=2 * ls),
            "postfilter": lambda: ex2.postfilter(q2, expr, k=k, ls=ls,
                                                 max_iters=2 * ls),
            "auto": lambda: index2.search_auto(q2, expr, k=k, ls=ls),
        }, gt)
        for pth, v in paths.items():
            print(f"compound:{name},{p.route},{pth},{v['qps']},"
                  f"{v['recall']},{v['mean_n_dist']}", flush=True)
        compound_bands.append({
            "case": name, "expr": describe(expr),
            "est_sel": round(p.batch_selectivity, 5), "route": p.route,
            "explain": explain(p, pcfg, filt=expr), "paths": paths,
            "mean_n_feval": round(float(np.asarray(gt.n_feval).mean()), 1)})

    # mixed per-lane OR band: even lanes rare (tight range OR rare label),
    # odd lanes wide -> the per-query router must split and win on DCs
    his = np.where(np.arange(b) % 2 == 0, lo_sel, hi_sel).astype(np.float32)
    labs = np.where(np.arange(b) % 2 == 0, 9, 2).astype(np.int32)
    cexpr = Range(zeros, his) | Label(labs)
    gt = exact_filtered_knn(jnp.asarray(xb2), attr2, jnp.asarray(q2),
                            cexpr, k=k)
    cpq = plan_per_query(cexpr, attr2, pcfg, executor=ex2)
    cpaths = _measure({
        "prefilter": lambda: ex2.prefilter(q2, cexpr, k=k),
        "graph": lambda: ex2.graph(q2, cexpr, k=k, ls=ls, max_iters=2 * ls),
        "postfilter": lambda: ex2.postfilter(q2, cexpr, k=k, ls=ls,
                                             max_iters=2 * ls),
        "batch": lambda: index2.search_auto(q2, cexpr, k=k, ls=ls,
                                            mode="batch"),
        "per_query": lambda: index2.search_auto(q2, cexpr, k=k, ls=ls,
                                                mode="per_query"),
    }, gt)
    for pth, v in cpaths.items():
        print(f"compound:mixed,{cpq.route},{pth},{v['qps']},{v['recall']},"
              f"{v['mean_n_dist']}", flush=True)
    cmixed = {"expr": describe(cexpr),
              "routes": [g.route for g in cpq.groups],
              "groups": [{"route": g.route, "n": int(g.ids.size),
                          "median_sel": round(g.selectivity, 5)}
                         for g in cpq.groups],
              "explain": explain(cpq, pcfg, filt=cexpr),
              "paths": cpaths}

    # clause reordering: deliberately-worst AND order vs the planner's
    # reordered tree — results identical, short-circuit evals drop
    wide = Range(zeros, np.full(b, 0.9, np.float32))
    rare = Label(np.full(b, 9))
    fixed = wide & rare
    sels = np.median(np.asarray(leaf_selectivities(
        fixed, attr2, jnp.arange(n2))), axis=1)
    better = reorder_clauses(fixed, sels)
    gt_fixed = exact_filtered_knn(jnp.asarray(xb2), attr2, jnp.asarray(q2),
                                  fixed, k=k)
    gt_best = exact_filtered_knn(jnp.asarray(xb2), attr2, jnp.asarray(q2),
                                 better, k=k)
    reorder = {
        "expr_fixed": describe(fixed),
        "expr_reordered": describe(better),
        "leaf_sels": [round(float(s), 5) for s in sels],
        "mean_n_feval_fixed": round(
            float(np.asarray(gt_fixed.n_feval).mean()), 1),
        "mean_n_feval_reordered": round(
            float(np.asarray(gt_best.n_feval).mean()), 1),
        "ids_identical": bool(np.array_equal(np.asarray(gt_fixed.ids),
                                             np.asarray(gt_best.ids))),
    }
    print(f"compound:reorder,{reorder['expr_fixed']} -> "
          f"{reorder['expr_reordered']}, n_feval "
          f"{reorder['mean_n_feval_fixed']} -> "
          f"{reorder['mean_n_feval_reordered']}", flush=True)

    compound = {"n": n2, "d": d, "b": b, "build_s": round(build2_s, 1),
                "attr_kind": attr2.kind,
                "routes": [bd["route"] for bd in compound_bands],
                "bands": compound_bands, "mixed": cmixed,
                "reorder": reorder}

    out = {"n": n, "d": d, "b": b, "k": k, "ls": ls,
           "build_s": round(build_s, 1),
           "row_bytes": row_bytes,
           "routes": [bd["route"] for bd in bands_out],
           "bands": bands_out,
           "mixed": mixed,
           "compound": compound}
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=1)
    if args.compound_json:
        with open(args.compound_json, "w") as fh:
            json.dump(compound, fh, indent=1)
    return out


if __name__ == "__main__":
    main()
