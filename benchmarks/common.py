"""Shared benchmark context: datasets + indexes built once, reused by every
figure/table benchmark (QPS-recall, selectivity, ablations, distance
computations, indexing time)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import JAGConfig, JAGIndex
from repro.core import baselines as BL
from repro.core.ground_truth import exact_filtered_knn
from repro.core.recall import recall_at_k
# the shared timing discipline (explicit warmup, per-repeat
# block_until_ready, median) — implemented in repro.cost.calibrate because
# src must not import the repo-root benchmarks package; re-exported here so
# every benchmark imports it from one place
from repro.cost.calibrate import time_route
from repro.data import synthetic as SYN

__all__ = ["ALGOS", "Ctx", "DATASETS", "get_ctx", "measure", "run_algo",
           "time_route"]

# benchmark scale: CPU-feasible analogue of the paper's 1M-10M datasets
N = 10_000
D = 48
B = 192
JCFG = JAGConfig(degree=28, ls_build=56, batch_size=256, cand_pool=128,
                 threshold_quantiles=(1.0, 0.01, 0.0))

DATASETS = {
    "msturing_range":  lambda: SYN.msturing_range(n=N, d=D, b=B, seed=1),
    "msturing_subset": lambda: SYN.msturing_subset(n=N, d=D, b=B, seed=2),
    "msturing_bool":   lambda: SYN.msturing_bool(n=N, d=D, b=96, seed=3),
    "sift_label":      lambda: SYN.sift_like(n=N, d=D, b=B, seed=4),
    "laion_subset":    lambda: SYN.laion_like(n=N, d=D, b=B, seed=5),
}


@dataclasses.dataclass
class Ctx:
    ds: SYN.FilteredDataset
    jag: JAGIndex
    unf: JAGIndex
    rw: BL.RWalksIndex
    gt: "GroundTruth"
    build_times: Dict[str, float]


_CACHE: Dict[str, Ctx] = {}


def get_ctx(name: str) -> Ctx:
    if name in _CACHE:
        return _CACHE[name]
    ds = DATASETS[name]()
    bt = {}
    t0 = time.time()
    jag = JAGIndex.build(ds.xb, ds.attr, JCFG)
    bt["jag"] = time.time() - t0
    t0 = time.time()
    unf = BL.build_unfiltered(ds.xb, ds.attr, JCFG)
    bt["unfiltered(post/acorn/binary)"] = time.time() - t0
    t0 = time.time()
    rw = BL.build_rwalks(ds.xb, ds.attr, JCFG, index=unf)
    bt["rwalks(diffusion only)"] = time.time() - t0
    gt = exact_filtered_knn(jnp.asarray(ds.xb), ds.attr,
                            jnp.asarray(ds.queries), ds.filt, k=10)
    jax.block_until_ready(gt.ids)
    _CACHE[name] = Ctx(ds, jag, unf, rw, gt, bt)
    return _CACHE[name]


ALGOS = ("jag", "post", "binary", "acorn", "rwalks")


def run_algo(ctx: Ctx, algo: str, ls: int, k: int = 10):
    ds = ctx.ds
    if algo == "jag":
        return ctx.jag.search(ds.queries, ds.filt, k=k, ls=ls)
    if algo == "post":
        return BL.post_filter_search(ctx.unf, ds.queries, ds.filt, k=k,
                                     ls=ls)
    if algo == "binary":
        return BL.binary_search(ctx.unf, ds.queries, ds.filt, k=k, ls=ls)
    if algo == "acorn":
        return BL.acorn_search(ctx.unf, ds.queries, ds.filt, k=k, ls=ls)
    if algo == "rwalks":
        return BL.rwalks_search(ctx.rw, ds.queries, ds.filt, k=k, ls=ls)
    raise ValueError(algo)


def measure(ctx: Ctx, algo: str, ls: int, k: int = 10, repeats: int = 2,
            warmup: int = 1):
    """(recall, qps, mean distance computations, us/query).

    Timed via :func:`time_route`: ``warmup`` blocked calls absorb jit
    compilation, then the MEDIAN of per-repeat wall times is reported —
    the old one-``perf_counter``-over-all-repeats loop averaged compile
    and steady-state together, which poisoned cost-model fits.
    """
    res, dt = time_route(lambda: run_algo(ctx, algo, ls, k),
                         warmup=warmup, repeats=repeats)
    B = ctx.ds.queries.shape[0]
    rec = recall_at_k(np.asarray(res.ids), np.asarray(res.primary) == 0,
                      np.asarray(ctx.gt.ids)).mean()
    nd = float(np.asarray(res.n_dist).mean())
    return float(rec), B / dt, nd, dt / B * 1e6
