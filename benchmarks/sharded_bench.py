"""Sharded-serving benchmark: recall parity + weak QPS scaling 1 -> 8.

Builds the same rows twice — one single-device union index and one
8-shard :class:`~repro.serve.sharded.ShardedJAGIndex` — and, per
selectivity band (prefilter / graph / postfilter), measures recall@10
against exact ground truth plus ``search_auto`` QPS for both. The sharded
graph route traverses 8 sub-graphs of N/8 rows each and merges exactly,
so its recall must at least match the union index's at every band (the
CI parity assertion).

The scaling section is WEAK scaling on the graph route: the 1-shard
point is a single-device index over N_loc rows, the 8-shard point serves
8x the rows from 8 devices. Linear scaling holds QPS constant
(efficiency 1.0); the ISSUE win condition is >= 0.7x linear. Faked host
devices (``--xla_force_host_platform_device_count=8``) timeshare the
host's real cores, so the artifact reports ``cores`` and scales the
pass bar by the parallelism the host can physically express:
``min_scaling = 0.7 * min(cores, 8) / 8`` — on a >=8-core host that is
exactly the 0.7x-linear bar. ``SHARDED_MIN_SCALING`` overrides the bar
(e.g. for a known-noisy runner).

Usage: PYTHONPATH=src python -m benchmarks.sharded_bench [--json PATH]
Env:   REPRO_BENCH_FAST=1    -> small scale (CI smoke)
       SHARDED_MIN_SCALING=x -> override the scaling pass bar
(The module self-sets XLA_FLAGS to fake 8 host devices when unset.)
"""
from __future__ import annotations

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.cost.calibrate import time_route   # shared warmup+median timer

S = 8
BAND_HI = (("prefilter", 0.004), ("graph", 0.15), ("postfilter", 0.92))


def _timed(fn, repeats=3):
    return time_route(fn, warmup=1, repeats=repeats)


def main(argv=None) -> dict:
    from repro.core import JAGConfig, JAGIndex, range_filters, range_table
    from repro.core.ground_truth import exact_filtered_knn
    from repro.core.recall import recall_at_k
    from repro.serve.sharded import ShardedJAGIndex

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results as JSON (CI artifact)")
    ap.add_argument("--n-loc", type=int, default=None,
                    help="rows per shard (total rows = 8x this)")
    ap.add_argument("--b", type=int, default=None, help="query batch size")
    args = ap.parse_args(argv)

    fast = os.environ.get("REPRO_BENCH_FAST") == "1"
    n_loc = args.n_loc or (400 if fast else 4000)
    b = args.b or (32 if fast else 128)
    d = 16 if fast else 64
    k, ls = 10, 64
    n = S * n_loc
    cores = os.cpu_count() or 1

    devs = len(jax.devices())
    if devs < S:
        raise SystemExit(
            f"{devs} devices < {S} shards — the module sets XLA_FLAGS "
            f"before jax loads; something imported jax first")

    rng = np.random.default_rng(0)
    xb = rng.normal(size=(n, d)).astype(np.float32)
    vals = rng.uniform(0, 1, n).astype(np.float32)
    attr = range_table(vals)
    cfg = JAGConfig(degree=16 if fast else 32, ls_build=32 if fast else 64,
                    batch_size=256, cand_pool=64 if fast else 192,
                    calib_samples=128)
    t0 = time.time()
    union = JAGIndex.build(xb, attr, cfg)
    union_build_s = time.time() - t0
    t0 = time.time()
    sharded = ShardedJAGIndex.build(xb, attr, cfg, n_shards=S)
    shard_build_s = time.time() - t0
    q = (xb[rng.integers(0, n, b)]
         + 0.1 * rng.normal(size=(b, d))).astype(np.float32)

    print(f"# n={n} (= {S} x {n_loc}) d={d} b={b} devices={devs} "
          f"cores={cores} build union={union_build_s:.0f}s "
          f"sharded={shard_build_s:.0f}s")
    print("band,sel,route_union,route_sharded,recall_union,recall_sharded,"
          "qps_union,qps_sharded")
    bands = []
    for name, hi in BAND_HI:
        filt = range_filters(np.zeros(b, np.float32),
                             np.full(b, hi, np.float32))
        gt = exact_filtered_knn(jnp.asarray(xb), attr, jnp.asarray(q),
                                filt, k=k)

        def _rec(res):
            return round(float(recall_at_k(
                np.asarray(res.ids), np.asarray(res.primary) == 0,
                np.asarray(gt.ids)).mean()), 4)

        ru, pu = union.search_auto(q, filt, k=k, ls=ls, return_plan=True)
        rs, ps = sharded.search_auto(q, filt, k=k, ls=ls, return_plan=True)
        _, dt_u = _timed(lambda: union.search_auto(q, filt, k=k, ls=ls))
        _, dt_s = _timed(lambda: sharded.search_auto(q, filt, k=k, ls=ls))
        row = dict(band=name, sel=hi,
                   route_union=pu.route, route_sharded=ps.route,
                   recall_union=_rec(ru), recall_sharded=_rec(rs),
                   qps_union=round(b / dt_u, 1),
                   qps_sharded=round(b / dt_s, 1))
        bands.append(row)
        print(",".join(str(row[c]) for c in
                       ("band", "sel", "route_union", "route_sharded",
                        "recall_union", "recall_sharded", "qps_union",
                        "qps_sharded")), flush=True)

    # ---- weak scaling on the graph route: 1 shard vs 8 shards ------------
    one = JAGIndex.build(xb[:n_loc], range_table(vals[:n_loc]), cfg)
    filt = range_filters(np.zeros(b, np.float32),
                         np.full(b, 0.15, np.float32))
    _, dt1 = _timed(lambda: one.search(q, filt, k=k, ls=ls))
    _, dt8 = _timed(lambda: sharded.search(q, filt, k=k, ls=ls))
    qps1, qps8 = b / dt1, b / dt8
    efficiency = qps8 / qps1
    parallel_frac = min(cores, S) / S
    env_bar = os.environ.get("SHARDED_MIN_SCALING")
    min_scaling = (float(env_bar) if env_bar
                   else round(0.7 * parallel_frac, 4))
    scaling = dict(n_loc=n_loc, qps_1shard=round(qps1, 1),
                   qps_8shard=round(qps8, 1),
                   efficiency=round(efficiency, 4),
                   cores=cores, parallel_frac=parallel_frac,
                   linear_target=0.7, min_scaling=min_scaling)
    print(f"scaling(graph,weak): qps 1shard={scaling['qps_1shard']} "
          f"8shard={scaling['qps_8shard']} efficiency="
          f"{scaling['efficiency']} (bar {min_scaling} on {cores} cores)",
          flush=True)

    out = {"n": n, "n_loc": n_loc, "n_shards": S, "d": d, "b": b, "k": k,
           "ls": ls, "devices": devs, "cores": cores,
           "union_build_s": round(union_build_s, 1),
           "shard_build_s": round(shard_build_s, 1),
           "bands": bands, "scaling": scaling}
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=1)
    return out


if __name__ == "__main__":
    main()
